"""End-to-end training driver: train a ~100M-parameter LM for a few hundred
steps with checkpointing, deterministic restartable data, and AdamW.

Presets:
  tiny  — 4M params, finishes in ~a minute on CPU (CI / smoke)
  100m  — GPT-2-small-scale decoder (~110M params); a few hundred steps is
          hours on 1 CPU core, minutes on a real accelerator.

  PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 60
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch import train as train_driver  # noqa: E402
import repro.configs.qwen1_5_4b  # noqa: F401,E402  (registry warm-up)
from repro.models.config import ModelConfig  # noqa: E402

PRESETS = {
    "tiny": ModelConfig(
        name="tiny-lm", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=512, vocab_size=2048),
    "100m": ModelConfig(
        name="lm-100m", family="dense", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=12, d_ff=3072, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="tiny")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"[train_lm] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")

    # register the preset so the generic driver can find it
    import types
    mod = types.ModuleType("preset")
    mod.full = lambda: cfg
    mod.smoke = lambda: cfg
    import repro.configs as configs
    sys.modules["repro.configs._preset"] = mod
    configs.ALIASES["_preset"] = "_preset"

    loss = train_driver.main([
        "--arch", "_preset", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "25",
        "--log-every", "10",
    ])
    print(f"[train_lm] done, final loss {loss:.4f} "
          f"(resume by re-running with more --steps)")


if __name__ == "__main__":
    main()
