"""Private serving: batched LM inference where the embedding lookup runs as
the paper's oblivious selection (§3.2.1) over Shamir-shared tables, plus a
multi-tenant oblivious QueryServer draining logical query plans over
several secret-shared relations (user profiles + orders) through one
scheduler — both through the unified ``repro.api`` surface (backend
registry for the kernels, QueryClient for the query suite).

The serving "clouds" hold only shares of the (fixed-point) embedding table;
each request's token ids are one-hot-encoded (the paper's unary encoding),
secret-shared with fresh polynomials, and the lookup is a share-space
matmul — the cloud sees neither the token id nor the embedding row, and
access patterns are uniform (every vocab row is touched identically).

  PYTHONPATH=src python examples/private_serving.py
"""
import sys
sys.path.insert(0, "src")

import dataclasses  # noqa: E402
import numpy as np  # noqa: E402
import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

import repro.configs as configs  # noqa: E402
from repro.api import Count, Eq, Select  # noqa: E402
from repro.core import outsource, Codec  # noqa: E402
from repro.models import init_params  # noqa: E402
from repro.models.private_embed import (setup_private_embed,  # noqa: E402
                                        private_lookup)
from repro.launch.serve import (BatchServer,  # noqa: E402
                                QueryServer, Request)


def main():
    cfg = configs.smoke("qwen1_5_4b")
    params = init_params(jax.random.PRNGKey(0), cfg)

    # --- DB-owner side: share the embedding table once -----------------
    shares = setup_private_embed(jax.random.PRNGKey(1), params["embed"],
                                 n_shares=4)
    print(f"embedding table ({cfg.vocab_size}x{cfg.d_model}) shared to "
          f"{shares.n_shares} clouds (degree {shares.degree})")

    # --- sanity: private lookup == plaintext lookup (to 2^-12) ---------
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(8,)), jnp.int32)
    priv = private_lookup(jax.random.PRNGKey(2), shares, toks)
    plain = np.asarray(params["embed"])[np.asarray(toks)]
    err = np.abs(np.asarray(priv) - plain).max()
    print(f"private lookup max err vs plaintext: {err:.2e} (<= 2^-12)")

    # --- serve a batch of requests with the private embedding on -------
    cfg_priv = dataclasses.replace(cfg, private_embed=True)
    server = BatchServer(params, cfg_priv, max_len=64)
    rng = np.random.default_rng(1)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=16,
                                        dtype=np.int32), max_new=8)
            for _ in range(4)]
    done = server.serve(reqs)
    for i, r in enumerate(done):
        print(f"req{i}: prompt[:4]={r.prompt[:4]}... -> {r.out} "
              f"({r.latency_s:.2f}s batch)")

    # --- outputs must match the non-private server ---------------------
    server_plain = BatchServer(params, cfg, max_len=64)
    reqs2 = [Request(prompt=r.prompt.copy(), max_new=8) for r in done]
    done2 = server_plain.serve(reqs2)
    same = all(np.array_equal(a.out, b.out) for a, b in zip(done, done2))
    print(f"private == plaintext generations: {same}")

    # --- the same clouds also serve oblivious DB queries ----------------
    # The owner shares a *database* — plural relations — once (§2); one
    # multi-tenant QueryServer then fronts all of them: each attach() gets
    # its own dataplane, batching policy and query-key stream, while every
    # relation's shard dispatches ride ONE bounded server pool.
    profiles = [["u01", "gold", "150"], ["u02", "free", "12"],
                ["u03", "gold", "87"], ["u04", "silver", "45"]]
    orders = [["o1", "u01", "open"], ["o2", "u03", "done"],
              ["o3", "u01", "open"], ["o4", "u02", "open"],
              ["o5", "u04", "done"], ["o6", "u01", "done"]]
    # word_length 6 -> match degree (1+1)·6 = 12, openable by 16 clouds
    codec = Codec(word_length=6)
    db_profiles = outsource(jax.random.PRNGKey(5), profiles,
                            column_names=["UserId", "Tier", "Requests"],
                            codec=codec, n_shares=16)
    db_orders = outsource(jax.random.PRNGKey(6), orders,
                          column_names=["OrderId", "UserId", "Status"],
                          codec=codec, n_shares=16)
    # async mode: the scheduler thread parks each relation's submissions
    # up to its max_wait_ms to fill its max_batch, closing per-relation
    # batches independently; tuple-axis sharding stays bit-identical and
    # both relations' shard dispatches share the server's 4-worker pool.
    qserver = QueryServer(max_batch=8, max_wait_ms=10, pool_workers=4)
    qserver.attach("profiles", db_profiles, shards=2, key=11)
    qserver.attach("orders", db_orders, shards=3, key=12, max_batch=4)
    with qserver:
        queries = [
            qserver.submit(Count(Eq("Tier", "gold")),
                           relation="profiles"),
            qserver.submit(Select(Eq("Tier", "gold")),
                           relation="profiles"),
            qserver.submit(Count(Eq("Status", "open")),
                           relation="orders"),
            qserver.submit(Select(Eq("UserId", "u01"),
                                  strategy="one_round"),
                           relation="orders"),
        ]
        for q in queries:
            q.wait()
    for q in queries:
        print(f"[{q.relation}] {type(q.plan).__name__}: "
              f"strategy={q.result.strategy} count={q.result.count} "
              f"({q.latency_s:.2f}s, {q.result.ledger.rounds} rounds)")
    st = qserver.stats.snapshot()
    print(f"server: {st['served']} queries in {st['batches']} batch(es) "
          f"(closed by {st['closes']}), "
          f"mean batch {st['mean_batch_size']:.1f}, "
          f"p50 queue wait {st['p50_queue_wait_s'] * 1e3:.1f}ms, "
          f"p50 latency {st['p50_latency_s']:.2f}s")
    for name, rs in st["relations"].items():
        print(f"  [{name}] served={rs['served']} in {rs['batches']} "
              f"batch(es), families={rs['served_by_family']}")

    # --- self-tuning overload: unequal weights under a 10x storm ---------
    # Two tenants share one pool with unequal DRR weights; the "hot"
    # tenant floods at ~10x the protected neighbour's rate. Adaptive
    # deadline steering dives the hot relation's effective wait toward
    # immediate closes while the neighbour's stays at its configured cap,
    # and the weighted quota keeps the neighbour's shard dispatches from
    # queueing behind the flood.
    import threading  # noqa: E402
    import time  # noqa: E402
    storm = QueryServer(pool_workers=4)
    storm.attach("hot", db_orders, shards=2, key=13,
                 max_batch=4, max_wait_ms=20, weight=1.0)
    storm.attach("steady", db_profiles, shards=2, key=14,
                 max_batch=4, max_wait_ms=20, weight=2.0)
    hot_plan = Count(Eq("Status", "open"))
    steady_plan = Count(Eq("Tier", "gold"))
    reqs_by_rel = {"hot": [], "steady": []}

    def pound(rel, plan, period_s, dur_s):
        t_end = time.time() + dur_s
        while time.time() < t_end:
            reqs_by_rel[rel].append(storm.submit(plan, relation=rel))
            time.sleep(period_s)

    with storm:
        threads = [
            threading.Thread(target=pound,
                             args=("hot", hot_plan, 0.004, 1.5)),
            threading.Thread(target=pound,
                             args=("steady", steady_plan, 0.04, 1.5)),
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        for rs in reqs_by_rel.values():
            for r in rs:
                r.wait(timeout=60)
    snap = storm.stats.snapshot()["relations"]
    for name in ("hot", "steady"):
        rs = snap[name]
        print(f"  storm[{name}]: served={rs['served']} "
              f"closes={rs['closes']} "
              f"steered_wait={rs['steered_wait_ms']:.2f}ms "
              f"(configured 20ms)")
    assert snap["hot"]["steered_wait_ms"] < snap["steady"]["steered_wait_ms"]
    print("  steering diverged: the flooding tenant dives to immediate "
          "closes, the weighted neighbour keeps a longer deadline")


if __name__ == "__main__":
    main()
