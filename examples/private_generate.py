"""Private-embedding decode loop: oblivious lookups at tokens/sec scale.

End-to-end proof of the embedding fast path: a small decoder LM generates
autoregressively while every token-embedding lookup runs as the paper's
§3.2.1 oblivious selection through the query engine — the embedding table
lives only as Shamir shares (one slice per "cloud"), attached to a
``QueryClient`` as a sharded relation, and each decode step issues ONE
``EmbedLookup`` plan whose batch of one-hots shares in one jitted program
and contracts in one ``ss_matmul`` dispatch per shard. The opened
embeddings feed ``decode_step`` through the ``batch["embeds"]`` seam.

Reported per run: tokens/sec of the batched private path, the per-call
baseline (one ``private_lookup`` per token — what serving looked like
before the fast path), the speedup, per-token communication bits from the
measured ledgers, and the steady-state dispatch count per step.

  PYTHONPATH=src python examples/private_generate.py --steps 16 --batch 8
  PYTHONPATH=src python examples/private_generate.py --shards 4 --verify
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.api import EmbedLookup, MeshDispatcher, QueryClient  # noqa: E402
from repro.models import lm  # noqa: E402
from repro.models import private_embed as pe  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402

CFG = ModelConfig(name="private-tiny", family="dense", n_layers=2,
                  d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                  vocab_size=2048, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--shards", type=int, default=1)
    ap.add_argument("--verify", action="store_true",
                    help="OBSCURE-style consistency check on every opened "
                         "embedding (and report its overhead)")
    args = ap.parse_args()
    cfg = CFG
    key = jax.random.PRNGKey(0)
    params = lm.init_params(jax.random.fold_in(key, 1), cfg)

    # -- the DB-owner step: quantize + share the table, attach as a relation
    table_sh = pe.setup_private_embed(jax.random.fold_in(key, 2),
                                      params["embed"], n_shares=4)
    client = QueryClient(key=7)
    client.attach(pe.as_embed_relation(table_sh), name="embeddings",
                  shards=args.shards, dispatcher=MeshDispatcher())
    plane = client._entry("embeddings").dataplane

    def lookup(tokens: np.ndarray) -> jax.Array:
        """One decode step's embeddings via ONE EmbedLookup plan."""
        res = client.run(EmbedLookup(tokens=tuple(int(t) for t in
                                                  tokens.reshape(-1)),
                                     verify=args.verify),
                         relation="embeddings")
        return (jnp.asarray(res.embeddings)
                .reshape(*tokens.shape, cfg.d_model), res.ledger)

    # -- prefill: the whole prompt is one batched lookup ---------------------
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size,
                          (args.batch, args.prompt_len)).astype(np.int32)
    embeds, _ = lookup(prompt)
    logits, cache = lm.prefill(params, cfg,
                               {"tokens": jnp.asarray(prompt),
                                "embeds": embeds},
                               max_len=args.prompt_len + args.steps)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

    # -- decode loop: one EmbedLookup == one ss_matmul dispatch per step ----
    out_tokens = [np.asarray(tok)]
    ledgers, t0 = [], time.perf_counter()
    d0 = plane.stats.dispatches
    for step in range(args.steps):
        embeds, ledger = lookup(np.asarray(tok)[:, None])
        ledgers.append(ledger)
        logits, cache = lm.decode_step(
            params, cfg, cache, args.prompt_len + step,
            {"tokens": tok[:, None], "embeds": embeds})
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    n_tok = args.steps * args.batch
    per_step = (plane.stats.dispatches - d0) / max(args.steps, 1)
    bits = sum(led.communication_bits for led in ledgers)

    # -- per-call baseline: the pre-fast-path serving shape ------------------
    base_toks = np.asarray(out_tokens[0])
    t0 = time.perf_counter()
    for i, t in enumerate(base_toks):
        pe.private_lookup(jax.random.fold_in(key, 100 + i), table_sh,
                          jnp.asarray([t]))
    base_dt = (time.perf_counter() - t0) / len(base_toks)

    print(f"[private_generate] {args.batch}×{args.steps} tokens decoded, "
          f"S={args.shards}, verify={args.verify}")
    print(f"  batched private path : {n_tok / dt:8.1f} tok/s "
          f"(full decode step incl. transformer)")
    print(f"  per-call baseline    : {1.0 / base_dt:8.1f} tok/s "
          f"(embedding lookups alone)")
    print(f"  per-token comm       : {bits / n_tok:8.0f} bits")
    print(f"  dispatches per step  : {per_step:.1f} "
          f"(= shard count; ONE fused ss_matmul each)")
    sample = np.stack(out_tokens)[:, 0]
    print(f"  sample continuation  : {sample.tolist()}")


if __name__ == "__main__":
    main()
