"""Fault-tolerance demo: the paper's MapReduce count query surviving worker
crashes + stragglers, and a training job surviving a kill/restart.

  PYTHONPATH=src python examples/fault_tolerance_demo.py
"""
import sys
sys.path.insert(0, "src")

import shutil  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import outsource, Codec, shamir, automata, encoding, field  # noqa: E402
from repro.data import synthetic_relation  # noqa: E402
from repro.runtime import MapReduceRunner, WorkerPool  # noqa: E402
from repro.launch import train as train_driver  # noqa: E402


def mapreduce_with_failures():
    print("== secret-shared COUNT as a MapReduce job with chaos ==")
    codec = Codec(word_length=8)
    rows = synthetic_relation(96, seed=0, skew=0.3)
    want = sum(1 for r in rows if r[1] == "John")
    db = outsource(jax.random.PRNGKey(0), rows, codec=codec, n_shares=20)
    p_sh = encoding.share_pattern(jax.random.PRNGKey(1), codec, "John",
                                  n_shares=20, degree=1)
    splits = [(s, s + 12) for s in range(0, 96, 12)]

    def map_fn(split):
        lo, hi = split
        col = shamir.Shares(db.relation.values[:, lo:hi, 1],
                            db.relation.degree)
        return np.asarray(automata.count_column(col, p_sh).values)

    def reduce_fn(partials):
        total = partials[0]
        for p in partials[1:]:
            total = np.asarray(field.add(jnp.asarray(total), jnp.asarray(p)))
        deg = (db.relation.degree + p_sh.degree) * codec.word_length
        return int(np.asarray(shamir.interpolate(
            shamir.Shares(jnp.asarray(total), deg))))

    # 30% task crash rate, one straggler worker 20x slower than the lease
    pool = WorkerPool(4, fail_prob=0.3, slow_workers={2: 4.0}, seed=7)
    runner = MapReduceRunner(pool, lease_s=0.8, spec_threshold=0.6,
                             max_attempts=40)
    t0 = time.time()
    got = runner.run(map_fn, splits, reduce_fn)
    print(f"  count(John) = {got} (expected {want}) in "
          f"{time.time()-t0:.1f}s")
    print(f"  re-executions={runner.reexecutions} "
          f"speculative={runner.speculative_launched} "
          f"lease-expiries={runner.worker_deaths}")
    assert got == want


def train_restart():
    print("\n== training kill/restart from checkpoint ==")
    ckpt = "/tmp/repro_ft_demo"
    shutil.rmtree(ckpt, ignore_errors=True)
    # phase 1: "crash" after 10 steps (we just stop)
    train_driver.main(["--arch", "gemma3-1b", "--smoke", "--steps", "10",
                       "--batch", "4", "--seq", "32", "--ckpt-dir", ckpt,
                       "--ckpt-every", "5", "--log-every", "5"])
    # phase 2: restart; must resume from step 10, not 0
    print("  -- restart --")
    train_driver.main(["--arch", "gemma3-1b", "--smoke", "--steps", "20",
                       "--batch", "4", "--seq", "32", "--ckpt-dir", ckpt,
                       "--ckpt-every", "5", "--log-every", "5"])


if __name__ == "__main__":
    mapreduce_with_failures()
    train_restart()
    print("\nfault-tolerance demo complete")
