"""Quickstart: the paper's Employee example through the unified QueryClient.

A trusted DB owner outsources a relation as Shamir secret-shares to c
simulated clouds; an (authorized) user then holds ONE QueryClient over the
shares and runs oblivious count, selection, join and range queries WITHOUT
the owner being online, and without any cloud learning the data, the query,
or the result. Queries are logical plans (columns by name, predicate
objects, explicit padding policy); per-query keys derive from the client's
root key; the cost-based planner picks the paper-optimal selection strategy.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.api import Aggregate, Count, Eq, Like, Padding, QueryClient, \
    Select
from repro.core import outsource, Codec

EMPLOYEE = [
    ["E101", "Adam", "Smith", "1000", "Sale"],
    ["E102", "John", "Taylor", "2000", "Design"],
    ["E103", "Eve", "Smith", "500", "Sale"],
    ["E104", "John", "Williams", "5000", "Sale"],
]


def main():
    codec = Codec(word_length=8)
    print("== DB owner: create & distribute secret-shares (one-time) ==")
    db = outsource(jax.random.PRNGKey(7), EMPLOYEE,
                   column_names=["EmployeeId", "FirstName", "LastName",
                                 "Salary", "Department"],
                   codec=codec, n_shares=20, degree=1,
                   numeric_columns={3: 14})
    print(f"  {db.n_tuples} tuples x {db.n_attrs} attrs -> "
          f"{db.n_shares} clouds; every value shared with an independent "
          f"degree-{db.base_degree} polynomial\n")

    # one cloud's view of the two 'John's — different shares (no frequency
    # attack possible)
    v0 = np.asarray(db.relation.values[0, 1, 1, 0])  # John #1, first letter
    v1 = np.asarray(db.relation.values[0, 3, 1, 0])  # John #2, first letter
    print(f"  cloud 0's share of 'J' in tuple 2: {v0[:4]}...")
    print(f"  cloud 0's share of 'J' in tuple 4: {v1[:4]}...  (different!)\n")

    print("== User: one QueryClient, per-query keys derived automatically ==")
    client = QueryClient(db, key=42, backend="jnp")

    print("== COUNT (§3.1): how many employees named John? ==")
    res = client.count("FirstName", "John")
    print(f"  -> {res.count}   [{res.ledger}]\n")

    print("== SELECT (§3.2): WHERE FirstName='John', planner-chosen ==")
    plan = Select(Eq("FirstName", "John"))
    for est in client.explain(plan):
        print(f"  planner: {est.strategy:<10} ~{est.bits} bits, "
              f"{est.rounds} rounds")
    res = client.run(plan)
    print(f"  -> chose {res.strategy!r}; addresses {res.addresses}; "
          f"rows: {res.rows}  [rounds={res.ledger.rounds}]\n")

    print("== SELECT forced strategies (§3.2.1 / §3.2.2) ==")
    res = client.select("FirstName", "Eve", strategy="one_tuple")
    print(f"  one_tuple  -> {res.rows[0]}")
    res = client.select("Department", "Sale", strategy="tree")
    print(f"  tree       -> {res.count} rows in {res.ledger.rounds} "
          f"Q&A rounds")
    # fake-row padding hides the true result size from the clouds
    res = client.select("FirstName", "John", strategy="one_round",
                        padding=Padding.to_rows(4))
    print(f"  one_round  -> {len(res.rows)} real rows behind a 4-row "
          f"padded fetch\n")

    print("== PATTERN (LIKE): wildcard predicates on shares ==")
    # LIKE lowers to the accumulating-automata pattern engine: a prefix
    # pattern chains only its k leading positions (cheaper than exact
    # match), a substring slides the tile over every window. The clouds
    # never see the pattern — it ships as secret-shared one-hot tiles.
    res = client.run(Count(Like("FirstName", "Jo%")))
    print(f"  COUNT(FirstName LIKE 'Jo%')        -> {res.count}")
    res = client.run(Select(Like("LastName", "%ith%")))
    print(f"  SELECT WHERE LastName LIKE '%ith%' -> "
          f"{[r[1] + ' ' + r[2] for r in res.rows]}  "
          f"[rounds={res.ledger.rounds}]\n")

    print("== RANGE (§3.4): Salary in [1000, 2000] ==")
    # 14-bit SS-SUB grows the polynomial degree past our 20 clouds ->
    # apply the paper's degree-reduction (re-sharing) every 2 bits
    cnt = client.range_count("Salary", 1000, 2000, reduce_every=2)
    sel = client.range_select("Salary", 1000, 2000, reduce_every=2)
    print(f"  -> count {cnt.count}; rows {[r[0] for r in sel.rows]}\n")

    print("== AGGREGATE: verified AVG(Salary) WHERE FirstName='John' ==")
    # verify=True buys an OBSCURE-style check: each cloud returns extra
    # redundant shares of the opened sum and the client cross-checks them
    # against the interpolating polynomial — a tampered share raises
    # VerificationError instead of a silently wrong average. explain()
    # prices the overhead (one extra round + c checksum elements) before
    # any share moves.
    plan = Aggregate("avg", "Salary", where=Eq("FirstName", "John"),
                     verify=True)
    est = client.explain([plan]).groups[0].estimate
    print(f"  planner: ~{est.bits} bits, {est.rounds} rounds "
          f"(verification included)")
    res = client.run(plan)
    print(f"  -> AVG = {res.value} over {res.count} matching rows, "
          f"verified  [rounds={res.ledger.rounds}]")
    lo = client.run(Aggregate("min", "Salary", reduce_every=2))
    print(f"  -> MIN(Salary) = {lo.value} via the ripple-comparator "
          f"tournament\n")

    print("== PK/FK JOIN (§3.3.1): X(A,B) |x| Y(B,C) ==")
    codec6 = Codec(word_length=6)
    X = [["a1", "b1"], ["a2", "b2"], ["a3", "b3"]]
    Y = [["b1", "c1"], ["b2", "c2"], ["b2", "c3"], ["b2", "c4"]]
    dbX = outsource(jax.random.PRNGKey(8), X, column_names=["A", "B"],
                    codec=codec6, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(9), Y, column_names=["B", "C"],
                    codec=codec6, n_shares=16)
    res = QueryClient(dbX, key=3).join(dbY, on=("B", "B"))
    print(f"  -> {res.rows}")
    print("\nAll queries executed obliviously on shares; the clouds saw "
          "only uniform field elements.")


if __name__ == "__main__":
    main()
