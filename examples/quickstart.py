"""Quickstart: the paper's Employee example, end to end.

A trusted DB owner outsources a relation as Shamir secret-shares to c
simulated clouds; an (authorized) user then runs oblivious count, selection,
join and range queries WITHOUT the owner being online, and without any cloud
learning the data, the query, or the result.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import outsource, Codec
from repro.core.queries import (count_query, select_one_tuple,
                                select_one_round, select_tree, pkfk_join,
                                range_count, range_select)

EMPLOYEE = [
    ["E101", "Adam", "Smith", "1000", "Sale"],
    ["E102", "John", "Taylor", "2000", "Design"],
    ["E103", "Eve", "Smith", "500", "Sale"],
    ["E104", "John", "Williams", "5000", "Sale"],
]


def main():
    codec = Codec(word_length=8)
    print("== DB owner: create & distribute secret-shares (one-time) ==")
    db = outsource(jax.random.PRNGKey(7), EMPLOYEE,
                   column_names=["EmployeeId", "FirstName", "LastName",
                                 "Salary", "Department"],
                   codec=codec, n_shares=20, degree=1,
                   numeric_columns={3: 14})
    print(f"  {db.n_tuples} tuples x {db.n_attrs} attrs -> "
          f"{db.n_shares} clouds; every value shared with an independent "
          f"degree-{db.base_degree} polynomial\n")

    # one cloud's view of the two 'John's — different shares (no frequency
    # attack possible)
    v0 = np.asarray(db.relation.values[0, 1, 1, 0])  # John #1, first letter
    v1 = np.asarray(db.relation.values[0, 3, 1, 0])  # John #2, first letter
    print(f"  cloud 0's share of 'J' in tuple 2: {v0[:4]}...")
    print(f"  cloud 0's share of 'J' in tuple 4: {v1[:4]}...  (different!)\n")

    print("== COUNT (§3.1): how many employees named John? ==")
    cnt, led = count_query(jax.random.PRNGKey(1), db, 1, "John")
    print(f"  -> {cnt}   [{led}]\n")

    print("== SELECT one-tuple (§3.2.1): WHERE FirstName='Eve' ==")
    rows, led = select_one_tuple(jax.random.PRNGKey(2), db, 1, "Eve")
    print(f"  -> {rows[0]}\n")

    print("== SELECT one-round (§3.2.2): WHERE FirstName='John' ==")
    rows, addrs, led = select_one_round(jax.random.PRNGKey(3), db, 1,
                                        "John")
    print(f"  -> addresses {addrs}; rows: {rows}  "
          f"[rounds={led.rounds}]\n")

    print("== SELECT tree-based (§3.2.2): WHERE Department='Sale' ==")
    rows, addrs, led = select_tree(jax.random.PRNGKey(4), db, 4, "Sale")
    print(f"  -> {len(rows)} rows in {led.rounds} Q&A rounds\n")

    print("== RANGE (§3.4): Salary in [1000, 2000] ==")
    # 14-bit SS-SUB grows the polynomial degree past our 20 clouds ->
    # apply the paper's degree-reduction (re-sharing) every 2 bits
    cnt, led = range_count(jax.random.PRNGKey(5), db, 3, 1000, 2000,
                           reduce_every=2)
    rows, addrs, _ = range_select(jax.random.PRNGKey(6), db, 3, 1000,
                                  2000, reduce_every=2)
    print(f"  -> count {cnt}; rows {[r[0] for r in rows]}\n")

    print("== PK/FK JOIN (§3.3.1): X(A,B) |x| Y(B,C) ==")
    codec6 = Codec(word_length=6)
    X = [["a1", "b1"], ["a2", "b2"], ["a3", "b3"]]
    Y = [["b1", "c1"], ["b2", "c2"], ["b2", "c3"], ["b2", "c4"]]
    dbX = outsource(jax.random.PRNGKey(8), X, codec=codec6, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(9), Y, codec=codec6, n_shares=16)
    rows, led = pkfk_join(dbX, dbY, 1, 0)
    print(f"  -> {rows}")
    print("\nAll queries executed obliviously on shares; the clouds saw "
          "only uniform field elements.")


if __name__ == "__main__":
    main()
