"""The oblivious embedding fast path (§3.2.1 selection at serving scale).

Acceptance shape of the batched lookup engine:

* the fused path (ONE share program + ONE ``ss_matmul`` per shard) opens to
  EXACTLY the per-token ``private_lookup`` oracle — post-dequantize
  bit-identity, for S ∈ {1, 2, 4} shards across the Serial, Threaded and
  Mesh dispatchers (per-shard mod-p partial sums are exact, so S never
  shows in the opened values OR the ledgers);
* one ``EmbedLookup`` plan == one fused dispatch per shard, measured on the
  dataplane's own telemetry;
* the fixed-point codec round-trips exactly across the signed range and
  refuses (raises, never wraps) out-of-range tables;
* ``verify=True`` rides the OBSCURE-style redundant-share check: honest
  openings pass with a priced overhead, a tampered table share raises;
* two inline lookups never reuse a sharing key (the frequency-attack
  regression for the old hardcoded ``PRNGKey(0)``);
* the pallas fused share-generation kernel and the tall-skinny matmul
  tiling are bit-identical to the jnp reference programs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (EmbedLookup, MeshDispatcher, QueryClient,
                       ThreadedDispatcher, estimate_embed_cost)
from repro.core import shamir
from repro.core.queries import embed as embed_q
from repro.models import private_embed as pe

V, D = 64, 16


@pytest.fixture(scope="module")
def table():
    rng = np.random.default_rng(5)
    return rng.uniform(-2.0, 2.0, (V, D)).astype(np.float32)


@pytest.fixture(scope="module")
def table_sh(table):
    return pe.setup_private_embed(jax.random.PRNGKey(5), table, n_shares=4)


def _client(table_sh, *, shards=1, dispatcher=None):
    client = QueryClient(key=3)
    client.attach(pe.as_embed_relation(table_sh), name="emb",
                  shards=shards, dispatcher=dispatcher)
    return client


def _oracle(table_sh, tokens):
    """Per-token reference: one private_lookup per id, same key stream as
    the batched engine (fold_in per position)."""
    outs = [np.asarray(pe.private_lookup(jax.random.fold_in(
        jax.random.PRNGKey(9), i), table_sh, jnp.asarray([t])))
        for i, t in enumerate(tokens)]
    return np.concatenate(outs)


# ---------------------------------------------------------------------------
# exactness: batched == per-token oracle == plain table row
# ---------------------------------------------------------------------------

def test_batched_matches_per_token_lookup(table, table_sh):
    toks = jnp.asarray([3, 3, 17, V - 1, 0], jnp.int32)
    got = pe.private_lookup_batched(jax.random.PRNGKey(1), table_sh, toks)
    want = np.stack([np.asarray(
        pe.private_lookup(jax.random.PRNGKey(2), table_sh,
                          jnp.asarray([t]))).reshape(D)
        for t in np.asarray(toks)])
    assert np.array_equal(np.asarray(got), want)      # sharing cancels
    # and both equal the quantized table rows exactly
    ref = embed_q.dequantize_from_field(
        embed_q.quantize_to_field(table))
    assert np.array_equal(np.asarray(got),
                          np.asarray(ref)[np.asarray(toks)])


def test_batched_keeps_token_shape(table_sh):
    toks = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    got = pe.private_lookup_batched(jax.random.PRNGKey(1), table_sh, toks)
    assert got.shape == (2, 3, D)


@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("disp", ["serial", "threaded", "mesh"])
def test_engine_bit_identical_across_shards_and_dispatchers(
        table_sh, shards, disp):
    dispatcher = {"serial": None,
                  "threaded": ThreadedDispatcher(max_workers=2),
                  "mesh": MeshDispatcher()}[disp]
    client = _client(table_sh, shards=shards, dispatcher=dispatcher)
    tokens = tuple(int(t) for t in
                   np.random.default_rng(7).integers(0, V, 12))
    res = client.run(EmbedLookup(tokens=tokens), relation="emb")
    base = _client(table_sh).run(EmbedLookup(tokens=tokens),
                                 relation="emb")
    assert np.array_equal(np.asarray(res.embeddings),
                          np.asarray(base.embeddings))
    assert res.ledger == base.ledger          # S is execution policy only
    assert res.strategy == "embed"


def test_one_fused_dispatch_per_step_per_shard(table_sh):
    for shards in (1, 3):
        client = _client(table_sh, shards=shards,
                         dispatcher=MeshDispatcher())
        plane = client._entry("emb").dataplane
        client.run(EmbedLookup(tokens=(1, 2, 3)), relation="emb")
        placed = plane.stats.transfer_bytes
        d0 = plane.stats.dispatches
        client.run(EmbedLookup(tokens=(4, 5, 6, 7)), relation="emb")
        assert plane.stats.dispatches - d0 == shards
        assert plane.stats.transfer_bytes == placed   # device residency


def test_batch_of_jobs_fuses_and_matches_sequential(table_sh):
    plans = [EmbedLookup(tokens=(1, 2)), EmbedLookup(tokens=(3,)),
             EmbedLookup(tokens=(4, 5, 6))]
    bat_client = _client(table_sh, shards=2)
    plane = bat_client._entry("emb").dataplane
    d0 = plane.stats.dispatches
    bat = bat_client.run_batch(plans, relation="emb")
    assert plane.stats.dispatches - d0 == 2   # ALL jobs in S dispatches
    seq_client = _client(table_sh, shards=2)
    seq = [seq_client.run(p, relation="emb") for p in plans]
    for a, b in zip(seq, bat):
        assert np.array_equal(np.asarray(a.embeddings),
                              np.asarray(b.embeddings))
        assert a.ledger == b.ledger


def test_explain_matches_measured_ledger(table_sh):
    client = _client(table_sh)
    plan = EmbedLookup(tokens=tuple(range(9)), verify=True)
    exp = client.explain([plan], relation="emb")
    res = client.run(plan, relation="emb")
    (grp,) = exp.groups
    assert grp.estimate.bits == res.ledger.communication_bits
    assert grp.estimate.rounds == res.ledger.rounds


def test_estimate_embed_cost_shape():
    from repro.api import DBStats
    stats = DBStats(n=V, m=D, c=4, w=8, a=64, shards=2)
    est = estimate_embed_cost(stats, n_tokens=8)
    assert est.rounds == 1 and est.dispatches == 2
    assert est.bits == (4 * 8 * V + 4 * 8 * D) * 31
    ver = estimate_embed_cost(stats, n_tokens=8, verify=True)
    assert ver.rounds == 2 and ver.bits > est.bits


# ---------------------------------------------------------------------------
# plan validation
# ---------------------------------------------------------------------------

def test_embed_plan_validates_tokens():
    with pytest.raises(ValueError):
        EmbedLookup(tokens=())
    with pytest.raises(ValueError):
        EmbedLookup(tokens=(1, -2))
    assert EmbedLookup(tokens=[np.int64(3), 1]).tokens == (3, 1)


def test_engine_rejects_out_of_range_tokens(table_sh):
    client = _client(table_sh)
    with pytest.raises(ValueError, match="out of range"):
        client.run(EmbedLookup(tokens=(0, V)), relation="emb")


def test_engine_rejects_non_embedding_relation():
    from repro.core import outsource
    from repro.data import synthetic_relation
    db = outsource(jax.random.PRNGKey(0), synthetic_relation(8, seed=0),
                   n_shares=4, degree=1)
    client = QueryClient(db, key=1)
    with pytest.raises(ValueError, match="embedding relation"):
        client.run(EmbedLookup(tokens=(1,)))


# ---------------------------------------------------------------------------
# fixed-point codec: exact round-trip inside the range, refusal outside
# ---------------------------------------------------------------------------

def test_fixed_point_round_trip_at_signed_edges():
    scale = embed_q.QUANT_SCALE
    edges = np.asarray([0.0, 1.0 / scale, -1.0 / scale,
                        embed_q.QUANT_RANGE, -embed_q.QUANT_RANGE,
                        embed_q.QUANT_RANGE - 1.0 / scale,
                        -(embed_q.QUANT_RANGE - 1.0 / scale)],
                       np.float32)
    back = embed_q.dequantize_from_field(embed_q.quantize_to_field(edges))
    assert np.array_equal(np.asarray(back), edges)   # exact, not approx


def test_fixed_point_half_ulp_rounds_to_nearest():
    ulp = 1.0 / embed_q.QUANT_SCALE
    x = np.asarray([0.49999 * ulp, 1.50001 * ulp, -0.49999 * ulp],
                   np.float32)
    back = np.asarray(embed_q.dequantize_from_field(
        embed_q.quantize_to_field(x)))
    assert np.array_equal(back, np.asarray([0.0, 2 * ulp, 0.0], np.float32))


def test_fixed_point_error_bound_random():
    rng = np.random.default_rng(11)
    x = rng.uniform(-embed_q.QUANT_RANGE, embed_q.QUANT_RANGE,
                    1024).astype(np.float32)
    back = np.asarray(embed_q.dequantize_from_field(
        embed_q.quantize_to_field(x)))
    assert np.abs(back - x).max() <= 0.5 / embed_q.QUANT_SCALE + 1e-7


def test_overflow_guard_refuses_out_of_range_tables():
    for bad in (embed_q.QUANT_RANGE * 1.01, -embed_q.QUANT_RANGE * 1.01):
        with pytest.raises(ValueError, match="fixed-point range"):
            embed_q.quantize_to_field(np.asarray([0.0, bad], np.float32))
    with pytest.raises(ValueError, match="fixed-point range"):
        pe.setup_private_embed(jax.random.PRNGKey(0),
                               np.full((4, 4), 100.0, np.float32))


# ---------------------------------------------------------------------------
# verification (OBSCURE-style redundant shares)
# ---------------------------------------------------------------------------

def test_verify_passes_honest_and_prices_overhead(table_sh):
    client = _client(table_sh)
    base = client.run(EmbedLookup(tokens=(1, 2, 3)), relation="emb")
    ver = client.run(EmbedLookup(tokens=(1, 2, 3), verify=True),
                     relation="emb")
    assert np.array_equal(np.asarray(ver.embeddings),
                          np.asarray(base.embeddings))
    assert ver.ledger.rounds == base.ledger.rounds + 1
    assert ver.ledger.communication_bits > base.ledger.communication_bits


def test_verify_catches_tampered_table_share(table):
    table_sh = pe.setup_private_embed(jax.random.PRNGKey(5), table,
                                      n_shares=5)
    vals = np.asarray(table_sh.values).copy()
    vals[4, 7, 3] ^= 1                      # cloud 4 lies about one word
    bad = shamir.Shares(jnp.asarray(vals), table_sh.degree)
    client = _client(bad)
    with pytest.raises(embed_q.VerificationError):
        client.run(EmbedLookup(tokens=(7,), verify=True), relation="emb")
    # without verify the lie goes unnoticed — that's what the check buys
    client2 = _client(bad)
    client2.run(EmbedLookup(tokens=(7,)), relation="emb")


def test_batched_verify_flag(table_sh):
    got = pe.private_lookup_batched(jax.random.PRNGKey(1), table_sh,
                                    jnp.asarray([1, 2], jnp.int32),
                                    verify=True)
    want = pe.private_lookup_batched(jax.random.PRNGKey(1), table_sh,
                                     jnp.asarray([1, 2], jnp.int32))
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# satellite 1: inline lookups never reuse a sharing key
# ---------------------------------------------------------------------------

def test_inline_lookup_keys_never_repeat(table):
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=D,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=V,
                      dtype="float32", private_embed=True)
    params = {"embed": jnp.asarray(table)}
    k1 = pe._next_inline_key(params)
    k2 = pe._next_inline_key(params)
    assert not np.array_equal(np.asarray(k1), np.asarray(k2))
    # and the share tensors those keys produce differ (fresh polynomials)
    sh1 = embed_q.share_tokens(k1, jnp.asarray([3], jnp.int32),
                               vocab=V, n_shares=4)
    sh2 = embed_q.share_tokens(k2, jnp.asarray([3], jnp.int32),
                               vocab=V, n_shares=4)
    assert not np.array_equal(np.asarray(sh1.values),
                              np.asarray(sh2.values))
    # while the *opened* value is key-independent
    out1 = pe.private_lookup_inline(params, cfg, jnp.asarray([[3]]))
    out2 = pe.private_lookup_inline(params, cfg, jnp.asarray([[3]]))
    assert np.array_equal(np.asarray(out1), np.asarray(out2))


def test_inline_lookup_threads_explicit_key(table):
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=D,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab_size=V,
                      dtype="float32", private_embed=True)
    params = {"embed": jnp.asarray(table)}
    out = pe.private_lookup_inline(params, cfg, jnp.asarray([[3, 5]]),
                                   key=jax.random.PRNGKey(42))
    ref = embed_q.dequantize_from_field(
        embed_q.quantize_to_field(jnp.asarray(table)))
    assert np.allclose(np.asarray(out), np.asarray(ref)[[3, 5]],
                       atol=1e-6)


# ---------------------------------------------------------------------------
# share generation: jnp program vs pallas fused kernel
# ---------------------------------------------------------------------------

def test_share_tokens_opens_to_onehot():
    key = jax.random.PRNGKey(8)
    toks = jnp.asarray([0, 5, V - 1], jnp.int32)
    sh = embed_q.share_tokens(key, toks, vocab=V, n_shares=4)
    assert sh.degree == 1 and sh.values.shape == (4, 3, V)
    opened = np.asarray(shamir.interpolate(sh))
    assert np.array_equal(opened, np.asarray(
        jax.nn.one_hot(toks, V, dtype=jnp.uint32)))


def test_share_tokens_rejects_empty():
    with pytest.raises(ValueError):
        embed_q.share_tokens(jax.random.PRNGKey(0), jnp.asarray([]),
                             vocab=V, n_shares=4)


def test_pallas_share_onehot_bit_identical():
    pytest.importorskip("jax.experimental.pallas")
    from repro.kernels.ss_matmul import share_onehot_pallas
    key = jax.random.PRNGKey(8)
    toks = jnp.asarray(np.random.default_rng(3).integers(0, 200, 70),
                       jnp.int32)
    a1 = embed_q.token_coeffs(key, toks, vocab=200)
    want = embed_q.share_tokens(key, toks, vocab=200, n_shares=4).values
    got = share_onehot_pallas(toks, a1, n_shares=4, interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_pallas_backend_share_tokens_bit_identical():
    pytest.importorskip("jax.experimental.pallas")
    from repro.api.backends import get_backend
    key = jax.random.PRNGKey(8)
    toks = jnp.asarray([1, 2, 3, 4, 5], jnp.int32)
    jnp_sh = embed_q.share_tokens(key, toks, vocab=V, n_shares=4,
                                  be=get_backend("jnp"))
    pl_sh = embed_q.share_tokens(key, toks, vocab=V, n_shares=4,
                                 be=get_backend("pallas"))
    assert np.array_equal(np.asarray(jnp_sh.values),
                          np.asarray(pl_sh.values))


def test_tall_skinny_kernel_parity():
    pytest.importorskip("jax.experimental.pallas")
    from repro.core import field
    from repro.kernels.ss_matmul import is_tall_skinny, ss_matmul_tall_pallas
    assert is_tall_skinny(32, 2048, 64)
    assert not is_tall_skinny(512, 2048, 64)      # M too big
    assert not is_tall_skinny(32, 512, 64)        # K too small
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.integers(0, field.P, (17, 1536), np.uint32))
    b = jnp.asarray(rng.integers(0, field.P, (1536, 40), np.uint32))
    got = ss_matmul_tall_pallas(a, b, interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(field.matmul(a, b)))


def test_interpret_autodetect_default():
    from repro.kernels import ss_matmul as k
    # on anything but a real TPU the default must resolve to interpret
    a = jnp.zeros((8, 128), jnp.uint32)
    b = jnp.zeros((128, 8), jnp.uint32)
    out = k.ss_matmul_pallas(a, b)        # interpret=None — must not raise
    assert out.shape == (8, 8)


# ---------------------------------------------------------------------------
# serving: EmbedLookup routes through the multi-tenant QueryServer
# ---------------------------------------------------------------------------

def test_query_server_routes_embed_family(table_sh):
    from repro.core import outsource
    from repro.data import synthetic_relation
    from repro.launch.serve import QueryServer
    from repro.api import Count, Eq
    from repro.core import Codec
    rows = synthetic_relation(8, seed=0)
    db = outsource(jax.random.PRNGKey(0), rows, codec=Codec(word_length=8),
                   n_shares=20, degree=1)
    pat = rows[0][1]
    with QueryServer() as srv:
        srv.attach("emp", db)
        srv.attach("emb", pe.as_embed_relation(table_sh))
        r_emb = srv.submit(EmbedLookup(tokens=(2, 4)), relation="emb")
        r_cnt = srv.submit(Count(Eq(1, pat)), relation="emp")
        srv.pump(relation="emb")
        srv.pump(relation="emp")
        emb = r_emb.wait(timeout=30).result
        cnt = r_cnt.wait(timeout=30).result
    assert emb.embeddings.shape == (2, D)
    solo = _client(table_sh).run(EmbedLookup(tokens=(2, 4)),
                                 relation="emb")
    assert np.array_equal(np.asarray(emb.embeddings),
                          np.asarray(solo.embeddings))
    assert emb.ledger == solo.ledger          # tenant == solo, bit for bit
    assert cnt.count >= 1
    assert srv.stats.batches >= 2
