"""Multi-tenant QueryServer: cross-relation routing through one scheduler.

The acceptance property of the multi-tenant frontend: a mixed workload
submitted to ONE server over several attached relations (different shard
counts, different batching policies) returns rows and ``CostLedger``s
bit-identical to running each relation on its own single-relation server —
relations batch independently, key streams are per relation, and the
shared shard pool is pure execution policy. ``ServeStats`` exposes the
per-relation breakdown, and faults stay isolated per request AND per
relation.
"""
import threading
import time

import jax
import pytest

from repro.api import Between, Count, Eq, RangeCount, Select
from repro.core import Codec, outsource
from repro.core.queries import CardinalityError
from repro.launch.serve import QueryRequest, QueryServer

CODEC = Codec(word_length=8)
EMP_COLUMNS = ["EmployeeId", "FirstName", "LastName", "Salary",
               "Department"]
EMPLOYEE = [
    ["E101", "Adam", "Smith", "1000", "Sale"],
    ["E102", "John", "Taylor", "2000", "Design"],
    ["E103", "Eve", "Smith", "500", "Sale"],
    ["E104", "John", "Williams", "5000", "Sale"],
]
ORD_COLUMNS = ["OrderId", "Customer", "Status"]
ORDERS = [
    ["O1", "acme", "open"],
    ["O2", "zeta", "open"],
    ["O3", "acme", "done"],
    ["O4", "gamma", "open"],
    ["O5", "acme", "done"],
    ["O6", "zeta", "done"],
]


@pytest.fixture(scope="module")
def employee_db():
    return outsource(jax.random.PRNGKey(7), EMPLOYEE,
                     column_names=EMP_COLUMNS, codec=CODEC, n_shares=20,
                     degree=1, numeric_columns={3: 14})


@pytest.fixture(scope="module")
def orders_db():
    return outsource(jax.random.PRNGKey(8), ORDERS,
                     column_names=ORD_COLUMNS, codec=CODEC, n_shares=20,
                     degree=1)


EMP_PLANS = [Count(Eq("FirstName", "John")),
             Select(Eq("Department", "Sale"), strategy="tree"),
             RangeCount(Between("Salary", 600, 4000), reduce_every=2),
             Count(Eq("LastName", "Smith"))]
ORD_PLANS = [Count(Eq("Customer", "acme")),
             Select(Eq("Status", "open"), strategy="one_round"),
             Count(Eq("Status", "done")),
             Select(Eq("Customer", "zeta"), strategy="tree"),
             Count(Eq("Customer", "gamma"))]


def _results_equal(a, b):
    assert a.count == b.count
    assert a.rows == b.rows
    assert a.addresses == b.addresses
    assert a.ledger == b.ledger
    assert a.strategy == b.strategy


def _solo_results(db, key, plans, shards):
    server = QueryServer(db, key=key, shards=shards)
    reqs = server.serve([QueryRequest(p) for p in plans])
    server.close()
    assert all(r.error is None for r in reqs)
    return [r.result for r in reqs]


def test_mixed_workload_matches_solo_servers(employee_db, orders_db):
    """THE acceptance test: two relations, different shard counts, served
    interleaved by one scheduler == each served alone (rows, ledgers)."""
    solo_emp = _solo_results(employee_db, 11, EMP_PLANS, shards=2)
    solo_ord = _solo_results(orders_db, 13, ORD_PLANS, shards=3)

    server = QueryServer(pool_workers=4)
    server.attach("employees", employee_db, shards=2, key=11)
    server.attach("orders", orders_db, shards=3, key=13)
    assert server.relations == ("employees", "orders")
    assert server.dataplane_of("employees").n_shards == 2
    assert server.dataplane_of("orders").n_shards == 3

    # interleave the two relations' traffic through one scheduler thread
    with server:
        emp_reqs = []
        ord_reqs = []
        for i in range(max(len(EMP_PLANS), len(ORD_PLANS))):
            if i < len(EMP_PLANS):
                emp_reqs.append(
                    server.submit(EMP_PLANS[i], relation="employees"))
            if i < len(ORD_PLANS):
                ord_reqs.append(
                    server.submit(ORD_PLANS[i], relation="orders"))
        for r in emp_reqs + ord_reqs:
            r.wait(timeout=60)

    for solo, req in zip(solo_emp, emp_reqs):
        _results_equal(solo, req.result)
    for solo, req in zip(solo_ord, ord_reqs):
        _results_equal(solo, req.result)

    # per-relation breakdown is exposed and adds up
    snap = server.stats.snapshot()
    emp, ords = snap["relations"]["employees"], snap["relations"]["orders"]
    assert emp["served"] == len(EMP_PLANS)
    assert ords["served"] == len(ORD_PLANS)
    assert server.stats.served == len(EMP_PLANS) + len(ORD_PLANS)
    assert emp["served_by_family"]["count"] == 2
    assert emp["served_by_family"]["range_count"] == 1
    assert ords["served_by_family"]["select"] == 2
    assert sum(emp["batch_fill"].values()) == emp["batches"]
    # one shared pool backs both dataplanes, via separate handles
    assert server._owned_dispatcher is not None
    ha = server.dataplane_of("employees").dispatcher
    hb = server.dataplane_of("orders").dispatcher
    assert ha is not hb
    assert ha._shared_pool is hb._shared_pool is server._owned_dispatcher


def test_tenant_results_independent_of_neighbour_traffic(employee_db,
                                                         orders_db):
    """A relation's transcript never depends on what (or whether) other
    tenants submit: per-relation key streams."""
    alone = QueryServer()
    alone.attach("employees", employee_db, key=5)
    only = alone.serve([QueryRequest(p, relation="employees")
                        for p in EMP_PLANS])

    noisy = QueryServer()
    noisy.attach("employees", employee_db, key=5)
    noisy.attach("orders", orders_db, key=6)
    mixed = []
    for i, p in enumerate(EMP_PLANS):
        mixed.append(noisy.submit(p, relation="employees"))
        noisy.submit(ORD_PLANS[i % len(ORD_PLANS)], relation="orders")
    while noisy.pending():
        noisy.pump()
    for a, b in zip(only, mixed):
        _results_equal(a.result, b.result)


def test_per_relation_batching_policy(employee_db, orders_db):
    """Per-relation max_batch/max_wait_ms overrides shape THAT relation's
    batches only; batches never mix relations."""
    server = QueryServer(max_batch=16, max_wait_ms=10_000)
    server.attach("employees", employee_db, key=1, max_batch=2)
    server.attach("orders", orders_db, key=2, max_batch=4,
                  max_wait_ms=5.0)
    with server:
        emp = [server.submit(Count(Eq("FirstName", "John")),
                             relation="employees") for _ in range(4)]
        ords = [server.submit(Count(Eq("Customer", "acme")),
                              relation="orders") for _ in range(4)]
        for r in emp + ords:
            r.wait(timeout=60)
    snap = server.stats.snapshot()
    emp_s, ord_s = snap["relations"]["employees"], \
        snap["relations"]["orders"]
    # employees: max_batch=2 -> fills of exactly 2, closed by fill
    assert emp_s["batch_fill"].get(2, 0) >= 2
    assert emp_s["closes"].get("full", 0) >= 2
    assert max(emp_s["batch_fill"]) <= 2
    # orders: fills of <= 4, and every one of its requests served
    assert ord_s["served"] == 4
    assert max(ord_s["batch_fill"]) <= 4
    assert all(r.result.count == 2 for r in emp)
    assert all(r.result.count == 3 for r in ords)


def test_fault_isolation_across_relations(employee_db, orders_db):
    """A poisoned plan on one relation fails alone — batch-mates AND the
    other relation's concurrent batch are unaffected."""
    server = QueryServer(max_wait_ms=15)
    server.attach("employees", employee_db, key=3)
    server.attach("orders", orders_db, key=4)
    with server:
        bad = server.submit(                    # ℓ=2 -> CardinalityError
            Select(Eq("FirstName", "John"), strategy="one_tuple"),
            relation="employees")
        good_emp = [server.submit(Count(Eq("FirstName", "John")),
                                  relation="employees") for _ in range(3)]
        good_ord = [server.submit(Count(Eq("Customer", "acme")),
                                  relation="orders") for _ in range(3)]
        for r in [bad] + good_emp + good_ord:
            r.wait(timeout=60)
    assert isinstance(bad.error, CardinalityError)
    assert all(r.error is None and r.result.count == 2 for r in good_emp)
    assert all(r.error is None and r.result.count == 3 for r in good_ord)
    snap = server.stats.snapshot()
    assert snap["relations"]["employees"]["failed"] == 1
    assert snap["relations"]["orders"]["failed"] == 0
    assert server.stats.failed == 1


def test_routing_validation_and_default_relation(employee_db, orders_db):
    server = QueryServer(employee_db, key=9)      # default tenant
    server.attach("orders", orders_db, key=10)
    # unknown relation: loud, listing what IS attached
    with pytest.raises(KeyError, match="unknown relation"):
        server.submit(Count(Eq("Customer", "acme")), relation="nope")
    # no relation: routed to the default tenant
    r_def = server.submit(Count(Eq("FirstName", "Eve")))
    r_ord = server.submit(Count(Eq("Customer", "zeta")),
                          relation="orders")
    while server.pending():
        server.pump()
    assert r_def.relation == "default" and r_def.result.count == 1
    assert r_ord.relation == "orders" and r_ord.result.count == 2
    # an empty server refuses submissions with a clear error
    empty = QueryServer()
    with pytest.raises(ValueError, match="no relation attached"):
        empty.submit(Count(Eq("FirstName", "Eve")))
    # shards=/dispatcher= are per-relation: without a db they would be
    # silently dropped, so the constructor refuses them
    with pytest.raises(ValueError, match="per-relation"):
        QueryServer(shards=4)


def test_derived_key_streams_order_independent_and_collision_loud(
        employee_db, orders_db, monkeypatch):
    """Tenants attached without explicit keys derive their stream from
    the name ALONE (order-independent replay); a derived-stream collision
    — astronomically unlikely, here forced — is refused loudly, never
    silently shared (the protocol's masking randomness must stay
    independent across relations)."""
    from repro.api import QueryClient
    fwd = QueryClient(key=7)
    fwd.attach(employee_db, name="a")
    fwd.attach(orders_db, name="b")
    rev = QueryClient(key=7)
    rev.attach(orders_db, name="b")              # other order, same streams
    rev.attach(employee_db, name="a")
    for name in ("a", "b"):
        assert bool((fwd._relations[name].root_key
                     == rev._relations[name].root_key).all())
    assert not bool((fwd._relations["a"].root_key
                     == fwd._relations["b"].root_key).all())
    # force both 31-bit folds to collide for every name
    import repro.api.client as client_mod
    monkeypatch.setattr(client_mod.zlib, "crc32", lambda data: 123)
    clash = QueryClient(key=7)
    clash.attach(employee_db, name="a")
    with pytest.raises(ValueError, match="collides"):
        clash.attach(orders_db, name="b")
    # an explicit key= sidesteps the derivation entirely
    clash.attach(orders_db, name="b", key=99)


def test_concurrent_submitters_two_relations_stats_monotone(employee_db,
                                                            orders_db):
    """Soak across relations: racing submitters on both tenants; served
    counts stay monotone, every request finishes exactly once, and the
    per-relation slices add up to the aggregate."""
    server = QueryServer(max_batch=4, max_wait_ms=5, pool_workers=4)
    server.attach("employees", employee_db, key=21, shards=2)
    server.attach("orders", orders_db, key=22, shards=3)
    server.start()
    per_thread, reqs, lock = 5, [], threading.Lock()

    def submitter(tid):
        for i in range(per_thread):
            if (tid + i) % 2 == 0:
                r = server.submit(Count(Eq("FirstName", "John")),
                                  relation="employees")
            else:
                r = server.submit(Count(Eq("Customer", "acme")),
                                  relation="orders")
            with lock:
                reqs.append(r)
            time.sleep(0.002)

    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(4)]
    observed = []
    for t in threads:
        t.start()
    while any(t.is_alive() for t in threads):
        snap = server.stats.snapshot()          # torn-read regression
        observed.append((snap["served"],
                         snap["relations"].get("employees",
                                               {}).get("served", 0)))
        time.sleep(0.002)
    for t in threads:
        t.join()
    for r in reqs:
        r.wait(timeout=60)
    server.close()

    assert len(reqs) == 4 * per_thread
    assert server.stats.served == len(reqs) and server.stats.failed == 0
    for r in reqs:
        want = 2 if r.relation == "employees" else 3
        assert r.result.count == want
    assert all(a[0] <= b[0] and a[1] <= b[1]
               for a, b in zip(observed, observed[1:]))
    snap = server.stats.snapshot()
    assert (snap["relations"]["employees"]["served"]
            + snap["relations"]["orders"]["served"]) == len(reqs)
    assert (snap["relations"]["employees"]["batches"]
            + snap["relations"]["orders"]["batches"]) == snap["batches"]
