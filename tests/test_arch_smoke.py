"""Per-architecture smoke tests: reduced same-family config, one forward +
train step + prefill/decode on CPU; asserts output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import (init_params, forward, train_loss, prefill,
                          decode_step)
from repro.train import AdamWConfig, init_state, make_train_step

B, T = 2, 16


def make_batch(cfg, key):
    ks = jax.random.split(key, 4)
    batch = {"tokens": jax.random.randint(ks[0], (B, T), 0, cfg.vocab_size),
             "labels": jax.random.randint(ks[1], (B, T), 0, cfg.vocab_size)}
    if cfg.frontend == "vit":
        batch["patches"] = jax.random.normal(
            ks[2], (B, cfg.n_prefix, cfg.frontend_dim), jnp.float32)
    if cfg.frontend == "audio":
        batch["frames"] = jax.random.normal(
            ks[3], (B, 8, cfg.frontend_dim), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.smoke(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    batch = make_batch(cfg, key)
    logits = forward(params, cfg, batch)
    t_expect = T + (cfg.n_prefix if cfg.frontend == "vit" else 0)
    assert logits.shape == (B, t_expect, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_one_train_step(arch):
    cfg = configs.smoke(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    opt = init_state(params)
    step = make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10))
    batch = make_batch(cfg, key)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert moved


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_decode(arch):
    cfg = configs.smoke(arch)
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    batch = make_batch(cfg, key)
    batch.pop("labels")
    logits, cache = prefill(params, cfg, batch, max_len=T + 4)
    assert bool(jnp.all(jnp.isfinite(logits)))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    cache_len = T + (cfg.n_prefix if cfg.frontend == "vit" else 0)
    logits2, cache2 = decode_step(params, cfg, cache, cache_len,
                                  {"tokens": tok})
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))


@pytest.mark.parametrize("arch", ["qwen1_5_4b", "mamba2_2_7b",
                                  "gemma3_1b", "hymba_1_5b"])
def test_decode_matches_forward(arch):
    """Prefill+decode logits ≡ full forward at the same position."""
    cfg = configs.smoke(arch)
    params = init_params(jax.random.PRNGKey(3), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 9), 0,
                              cfg.vocab_size)
    full = forward(params, cfg, {"tokens": toks})
    _, cache = prefill(params, cfg, {"tokens": toks[:, :8]}, max_len=12)
    l2, _ = decode_step(params, cfg, cache, 8, {"tokens": toks[:, 8:9]})
    np.testing.assert_allclose(np.asarray(full[:, 8]), np.asarray(l2[:, 0]),
                               atol=0.12, rtol=0.05)


def test_full_configs_match_assignment():
    """The full configs carry the exact published hyperparameters."""
    want = {
        "hymba_1_5b": dict(n_layers=32, d_model=1600, n_heads=25,
                           n_kv_heads=5, d_ff=5504, vocab_size=32001,
                           ssm_state=16),
        "internvl2_76b": dict(n_layers=80, d_model=8192, n_heads=64,
                              n_kv_heads=8, d_ff=28672, vocab_size=128256),
        "seamless_m4t_medium": dict(n_layers=12, d_model=1024, n_heads=16,
                                    n_kv_heads=16, d_ff=4096,
                                    vocab_size=256206, n_enc_layers=12),
        "qwen1_5_4b": dict(n_layers=40, d_model=2560, n_heads=20,
                           n_kv_heads=20, d_ff=6912, vocab_size=151936,
                           qkv_bias=True),
        "chatglm3_6b": dict(n_layers=28, d_model=4096, n_heads=32,
                            n_kv_heads=2, d_ff=13696, vocab_size=65024),
        "minicpm3_4b": dict(n_layers=62, d_model=2560, n_heads=40,
                            d_ff=6400, vocab_size=73448, attn_type="mla"),
        "gemma3_1b": dict(n_layers=26, d_model=1152, n_heads=4,
                          n_kv_heads=1, d_ff=6912, vocab_size=262144),
        "granite_moe_3b_a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, d_ff=512,
                                     vocab_size=49155, n_experts=40,
                                     top_k=8),
        "moonshot_v1_16b_a3b": dict(n_layers=48, d_model=2048, n_heads=16,
                                    n_kv_heads=16, d_ff=1408,
                                    vocab_size=163840, n_experts=64,
                                    top_k=6),
        "mamba2_2_7b": dict(n_layers=64, d_model=2560, vocab_size=50280,
                            ssm_state=128),
    }
    for arch, fields in want.items():
        cfg = configs.full(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_param_counts_in_expected_range():
    """Sanity: analytic param counts land near the advertised sizes."""
    expect = {"qwen1_5_4b": (3e9, 5e9), "chatglm3_6b": (5e9, 8e9),
              "mamba2_2_7b": (2e9, 3.5e9), "gemma3_1b": (0.7e9, 1.6e9),
              "internvl2_76b": (60e9, 85e9),
              # assigned config (48L × 64e × d_ff 1408) is larger than the
              # "16b" marketing name; we implement the assigned numbers.
              "moonshot_v1_16b_a3b": (13e9, 30e9)}
    for arch, (lo, hi) in expect.items():
        n = configs.full(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_moe_sort_dispatch_matches_einsum():
    """Both MoE dispatch modes compute the same routing (ample capacity)."""
    import dataclasses
    cfg_e = dataclasses.replace(configs.smoke("granite_moe_3b_a800m"),
                                capacity_factor=8.0)
    cfg_s = dataclasses.replace(cfg_e, moe_dispatch="sort")
    params = init_params(jax.random.PRNGKey(0), cfg_e)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg_e.vocab_size)
    le = forward(params, cfg_e, {"tokens": toks})
    ls = forward(params, cfg_s, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(le, np.float32),
                               np.asarray(ls, np.float32), atol=0.06)


def test_batch_server_generates():
    """Batched prefill+decode server end to end (cache-donating decode)."""
    from repro.launch.serve import BatchServer, Request
    cfg = configs.smoke("chatglm3_6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    server = BatchServer(params, cfg, max_len=48)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, size=12,
                                        dtype=np.int32), max_new=6)
            for _ in range(3)]
    done = server.serve(reqs)
    for r in done:
        assert r.out is not None and r.out.shape == (6,)
        assert (0 <= r.out).all() and (r.out < cfg.vocab_size).all()
