"""Shamir sharing: round-trip, homomorphisms, privacy, degree reduction."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import field, shamir

P = int(field.P)


@settings(max_examples=50, deadline=None)
@given(st.integers(min_value=0, max_value=P - 1),
       st.integers(min_value=1, max_value=4),
       st.integers(min_value=0, max_value=5))
def test_roundtrip(secret, degree, extra_shares):
    s = shamir.share(jax.random.PRNGKey(secret % 997),
                     np.array([secret]), n_shares=degree + 1 + extra_shares,
                     degree=degree)
    assert int(shamir.interpolate(s)[0]) == secret


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=P - 1),
       st.integers(min_value=0, max_value=P - 1))
def test_additive_homomorphism(a, b):
    k1, k2 = jax.random.split(jax.random.PRNGKey(a % 991))
    sa = shamir.share(k1, np.array([a]), n_shares=4, degree=1)
    sb = shamir.share(k2, np.array([b]), n_shares=4, degree=1)
    assert int(shamir.interpolate(sa + sb)[0]) == (a + b) % P
    assert int(shamir.interpolate(sa - sb)[0]) == (a - b) % P


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=P - 1),
       st.integers(min_value=0, max_value=P - 1))
def test_multiplicative_homomorphism(a, b):
    k1, k2 = jax.random.split(jax.random.PRNGKey(a % 983))
    sa = shamir.share(k1, np.array([a]), n_shares=5, degree=1)
    sb = shamir.share(k2, np.array([b]), n_shares=5, degree=1)
    prod = sa * sb
    assert prod.degree == 2
    assert int(shamir.interpolate(prod)[0]) == (a * b) % P


def test_insufficient_shares_raises():
    s = shamir.share(jax.random.PRNGKey(0), np.array([5]), n_shares=3,
                     degree=1)
    with pytest.raises(ValueError):
        shamir.interpolate(s * s * s)  # degree 3 needs 4 shares


def test_identical_secrets_get_distinct_shares():
    """§2.1: multiple occurrences of a value must have different shares
    (frequency-count attack defence)."""
    secrets = np.zeros((64,), dtype=np.uint32) + 7
    s = shamir.share(jax.random.PRNGKey(1), secrets, n_shares=3, degree=1)
    vals = np.asarray(s.values)           # (3, 64)
    for k in range(3):
        assert len(np.unique(vals[k])) > 32, "shares of equal secrets collide"


def test_single_share_is_uniformish():
    """One cloud's view of a fixed secret is (near-)uniform over F_p."""
    n = 20_000
    s = shamir.share(jax.random.PRNGKey(2),
                     np.zeros((n,), dtype=np.uint32) + 12345,
                     n_shares=3, degree=1)
    one_cloud = np.asarray(s.values[0], dtype=np.float64)
    assert abs(one_cloud.mean() / (P / 2) - 1.0) < 0.05
    # spread over the field, not clustered
    assert np.percentile(one_cloud, 90) > 0.8 * P


def test_degree_reduction_preserves_secret():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    sa = shamir.share(k1, np.array([123]), n_shares=9, degree=2)
    sb = shamir.share(k2, np.array([456]), n_shares=9, degree=2)
    prod = sa * sb                        # degree 4
    red = shamir.reduce_degree(k3, prod, target_degree=2)
    assert red.degree == 2
    assert int(shamir.interpolate(red)[0]) == (123 * 456) % P


def test_consistency_check_detects_corruption():
    s = shamir.share(jax.random.PRNGKey(4), np.array([99]), n_shares=5,
                     degree=1)
    assert bool(shamir.verify_consistency(s).all())
    bad_vals = s.values.at[4, 0].add(1)
    bad = shamir.Shares(bad_vals, 1)
    assert not bool(shamir.verify_consistency(bad).all())


def test_tensor_shapes_and_sum():
    x = np.arange(24, dtype=np.uint32).reshape(2, 3, 4)
    s = shamir.share(jax.random.PRNGKey(5), x, n_shares=4, degree=1)
    assert s.shape == (2, 3, 4)
    total = shamir.interpolate(s.sum(axis=(0, 2)))
    assert np.array_equal(np.asarray(total), x.sum(axis=(0, 2)) % P)
