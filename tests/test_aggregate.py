"""Verified secret-shared aggregation (SUM / AVG / MIN-MAX).

Anchor properties:
  * batched SUM/AVG/MIN-MAX open exactly what a plaintext NumPy oracle
    computes (including negative values and empty predicates);
  * a batch's per-query rows/values/ledgers are bit-identical to the
    equivalent sequential runs;
  * ``verify=True`` is a no-op on an honest transcript, detects an
    injected corrupted cloud share, and its priced overhead appears in
    ``explain()`` — which predicts every aggregate ledger EXACTLY
    (comm bits and rounds), not just approximately;
  * unknown plan classes fail with a clear ``PlanNotSupported``.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.api import (Aggregate, Count, Eq, Plan, PlanNotSupported,
                       QueryClient, Select, VerificationError, get_backend,
                       ripple_segmenter)
from repro.core import Codec, outsource
from repro.core.queries import aggregate as agg_mod

CODEC = Codec(word_length=6)


@pytest.fixture(scope="module")
def signed_db():
    """12 rows with NEGATIVE and positive values — exercises the signed
    two's-complement opening and the comparator's sign handling."""
    rows = [[f"id{i}", f"nm{i % 5}", str(-300 + 137 * i)] for i in range(12)]
    db = outsource(jax.random.PRNGKey(19), rows,
                   column_names=["Id", "Name", "Val"], codec=CODEC,
                   n_shares=20, degree=1, numeric_columns={2: 14})
    return rows, db


def _oracle(rows):
    vals = np.array([int(r[2]) for r in rows])
    names = np.array([r[1] for r in rows])
    return vals, names


ALL_OPS_PLANS = [
    Aggregate("sum", "Val"),
    Aggregate("sum", "Val", where=Eq("Name", "nm1")),
    Aggregate("avg", "Val"),
    Aggregate("avg", "Val", where=Eq("Name", "nm2")),
    Aggregate("min", "Val", reduce_every=2),
    Aggregate("max", "Val", reduce_every=2),
    Aggregate("min", "Val", where=Eq("Name", "nm3"), reduce_every=2),
    Aggregate("max", "Val", where=Eq("Name", "nm4"), reduce_every=2),
]


def _expected(rows, plan):
    vals, names = _oracle(rows)
    mask = (names == plan.where.pattern if plan.where is not None
            else np.ones(len(vals), bool))
    sel = vals[mask]
    if plan.op == "sum":
        return int(sel.sum())
    if plan.op == "avg":
        return float(sel.mean()) if len(sel) else None
    if plan.op == "min":
        return int(sel.min()) if len(sel) else None
    return int(sel.max()) if len(sel) else None


# ---------------------------------------------------------------------------
# oracle parity
# ---------------------------------------------------------------------------

def test_all_ops_match_numpy_oracle(signed_db):
    rows, db = signed_db
    res = QueryClient(db, key=7).run_batch(ALL_OPS_PLANS)
    for plan, r in zip(ALL_OPS_PLANS, res):
        want = _expected(rows, plan)
        assert r.strategy == f"agg_{plan.op}"
        if plan.op == "avg":
            assert r.value == pytest.approx(want)
        else:
            assert r.value == want
        if plan.where is not None and plan.op != "sum":
            vals, names = _oracle(rows)
            assert r.count == int((names == plan.where.pattern).sum())


def test_batch_equals_sequential(signed_db):
    """Rows, values AND per-query ledgers are fusion-invariant."""
    _, db = signed_db
    seq = [QueryClient(db, key=7).run(p) for p in ALL_OPS_PLANS]
    bat = QueryClient(db, key=7).run_batch(ALL_OPS_PLANS)
    for a, b in zip(seq, bat):
        assert a.value == b.value
        assert a.count == b.count
        assert a.strategy == b.strategy
        assert a.ledger == b.ledger


def test_aggregates_fuse_with_other_families(signed_db):
    """Aggregation rides run_batch beside counts/selections; conditional
    AVG denominators fuse into the SAME count phase as explicit Counts."""
    rows, db = signed_db
    plans = [Count(Eq("Name", "nm1")),
             Aggregate("avg", "Val", where=Eq("Name", "nm1")),
             Select(Eq("Name", "nm2"), strategy="one_round"),
             Aggregate("sum", "Val")]
    seq = [QueryClient(db, key=3).run(p) for p in plans]
    bat = QueryClient(db, key=3).run_batch(plans)
    for a, b in zip(seq, bat):
        assert a.value == b.value and a.count == b.count
        assert a.rows == b.rows
        assert a.ledger == b.ledger
    vals, names = _oracle(rows)
    assert bat[1].value == pytest.approx(vals[names == "nm1"].mean())


def test_empty_predicate_yields_none_value(signed_db):
    _, db = signed_db
    cl = QueryClient(db, key=5)
    r = cl.run(Aggregate("min", "Val", where=Eq("Name", "zzz"),
                         reduce_every=2))
    assert r.value is None and r.count == 0
    r = cl.run(Aggregate("avg", "Val", where=Eq("Name", "zzz")))
    assert r.value is None and r.count == 0
    # an empty-predicate SUM is an honest 0
    r = cl.run(Aggregate("sum", "Val", where=Eq("Name", "zzz")))
    assert r.value == 0


def test_convenience_method(signed_db):
    rows, db = signed_db
    vals, _ = _oracle(rows)
    r = QueryClient(db, key=11).aggregate("max", "Val", reduce_every=2)
    assert r.value == int(vals.max())


def test_single_tuple_relation_minmax():
    """n = 1: the tournament is empty; the value opens at base degree."""
    db = outsource(jax.random.PRNGKey(2), [["E1", "42"]],
                   column_names=["Id", "V"], codec=CODEC, n_shares=20,
                   degree=1, numeric_columns={1: 8})
    cl = QueryClient(db, key=1)
    assert cl.run(Aggregate("min", "V", reduce_every=2)).value == 42
    assert cl.run(Aggregate("sum", "V", verify=True)).value == 42


# ---------------------------------------------------------------------------
# plan validation
# ---------------------------------------------------------------------------

def test_plan_validation():
    with pytest.raises(ValueError, match="unknown aggregate op"):
        Aggregate("median", "Val")
    with pytest.raises(ValueError, match="reduce_every"):
        Aggregate("sum", "Val", reduce_every=2)
    with pytest.raises(ValueError, match="reduce_every"):
        Aggregate("min", "Val", reduce_every=-1)


def test_non_numeric_column_rejected(signed_db):
    _, db = signed_db
    cl = QueryClient(db, key=1)
    with pytest.raises(ValueError, match="binary form"):
        cl.run(Aggregate("sum", "Name"))
    with pytest.raises(ValueError, match="binary form"):
        cl.explain([Aggregate("sum", "Name")])


def test_unknown_plan_raises_plan_not_supported(signed_db):
    """Regression: an unknown plan class used to die with an opaque
    TypeError deep in run_batch — now both the executor and the explainer
    name the offending type."""
    _, db = signed_db

    class Bogus(Plan):
        pass

    cl = QueryClient(db, key=1)
    with pytest.raises(PlanNotSupported, match="Bogus"):
        cl.run_batch([Count(Eq("Name", "nm1")), Bogus()])
    with pytest.raises(PlanNotSupported, match="Bogus"):
        cl.explain([Bogus()])
    with pytest.raises(PlanNotSupported, match="int"):
        cl.explain(7)
    # PlanNotSupported subclasses TypeError: legacy handlers still catch
    assert issubclass(PlanNotSupported, TypeError)


def test_explain_single_non_select_plan(signed_db):
    """Regression: explain(Count(...)) used to AttributeError on
    ``expected_matches``; any single plan now prices as a batch of one."""
    _, db = signed_db
    cl = QueryClient(db, key=1)
    exp = cl.explain(Count(Eq("Name", "nm1")))
    assert exp.groups[0].family == "count" and exp.bits > 0
    exp = cl.explain(Aggregate("min", "Val", reduce_every=2))
    assert exp.groups[0].family == "aggregate"


# ---------------------------------------------------------------------------
# explain(): exact ledger prediction, priced verification overhead
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("verify", [False, True])
def test_explain_predicts_aggregate_ledgers_exactly(signed_db, verify):
    _, db = signed_db
    for plan in ALL_OPS_PLANS:
        plan = dataclasses.replace(plan, verify=verify)
        exp = QueryClient(db, key=7).explain([plan])
        res = QueryClient(db, key=7).run(plan)
        assert exp.bits == res.ledger.communication_bits, plan
        assert exp.rounds == res.ledger.rounds, plan
        assert exp.groups[0].family == "aggregate"


def test_verify_overhead_is_priced_and_bounded(signed_db):
    """verify=True costs exactly one extra round and c checksum elements
    per opened tensor — and never changes the opened value."""
    _, db = signed_db
    for plan in (Aggregate("sum", "Val", where=Eq("Name", "nm1")),
                 Aggregate("min", "Val", where=Eq("Name", "nm3"),
                           reduce_every=2)):
        off = QueryClient(db, key=7).run(plan)
        on = QueryClient(db, key=7).run(
            dataclasses.replace(plan, verify=True))
        assert on.value == off.value
        assert on.ledger.rounds == off.ledger.rounds + 1
        tensors = 2 if (plan.op in ("min", "max")
                        and plan.where is not None) else 1
        assert (on.ledger.communication_bits
                - off.ledger.communication_bits) == 31 * db.n_shares * tensors


# ---------------------------------------------------------------------------
# verification: honest no-op, tampered cloud detected
# ---------------------------------------------------------------------------

def test_verify_detects_tampered_sum_share(signed_db):
    """A cloud corrupting its contraction output share is caught by the
    consistency round — and silently opens WRONG without verify."""
    _, db = signed_db
    base = get_backend("jnp")

    def bad_matmul(a, b):
        return base.ss_matmul(a, b).at[3].add(5)

    be = dataclasses.replace(base, name="jnp+tamper", ss_matmul=bad_matmul)
    plan = Aggregate("sum", "Val", where=Eq("Name", "nm1"))
    with pytest.raises(VerificationError, match="SUM verification failed"):
        QueryClient(db, key=7, backend=be).run(
            dataclasses.replace(plan, verify=True))
    honest = QueryClient(db, key=7).run(plan)
    tampered = QueryClient(db, key=7, backend=be).run(plan)
    assert tampered.value != honest.value      # the attack verify stops


def test_verify_detects_tampered_minmax_share(signed_db):
    """A cloud corrupting the final tournament level's comparator output
    (the last level has exactly one pair) fails MIN verification."""
    _, db = signed_db
    base = get_backend("jnp")
    base_seg = ripple_segmenter(base)

    def bad_segment(a, b, carry=None):
        rb, co = base_seg(a, b, carry)
        if a.shape[-2] == 1:                   # final level: one pair
            rb = rb.at[2].add(1)
        return rb, co

    be = dataclasses.replace(base, name="jnp+tamper",
                             ripple_segment=bad_segment)
    with pytest.raises(VerificationError, match="MIN verification failed"):
        QueryClient(db, key=7, backend=be).run(
            Aggregate("min", "Val", reduce_every=2, verify=True))


def test_verify_needs_redundant_clouds():
    """c = degree+1 opens fine but cannot cross-check: verify must refuse
    loudly instead of silently passing (verify_consistency is vacuous
    without redundant shares)."""
    db = outsource(jax.random.PRNGKey(4),
                   [[f"i{k}", str(10 * k)] for k in range(4)],
                   column_names=["Id", "V"], codec=CODEC, n_shares=2,
                   degree=1, numeric_columns={1: 8})
    cl = QueryClient(db, key=1)
    assert cl.run(Aggregate("sum", "V")).value == 60
    with pytest.raises(VerificationError, match="degree\\+2"):
        cl.run(Aggregate("sum", "V", verify=True))


# ---------------------------------------------------------------------------
# phase-level contracts
# ---------------------------------------------------------------------------

def test_sum_phase_rejects_overflowable_relations():
    """n·2^(t-1) beyond the Mersenne-31 half-range must refuse, not wrap."""
    db = outsource(jax.random.PRNGKey(4),
                   [[f"i{k}", "1"] for k in range(8)],
                   column_names=["Id", "V"], codec=CODEC, n_shares=20,
                   degree=1, numeric_columns={1: 28})
    with pytest.raises(ValueError, match="half-range"):
        QueryClient(db, key=1).run(Aggregate("sum", "V"))


def test_mixed_bit_width_jobs_must_group():
    """agg phases demand uniform t_bits per fused call (the client groups
    by bit width, so this is a phase-level contract test)."""
    db = outsource(jax.random.PRNGKey(4),
                   [[f"i{k}", str(k), str(2 * k)] for k in range(4)],
                   column_names=["Id", "A", "B"], codec=CODEC, n_shares=20,
                   degree=1, numeric_columns={1: 8, 2: 10})
    be = get_backend("jnp")
    from repro.core.costs import CostLedger
    jobs = [agg_mod.SumJob(value_column=1, key=jax.random.PRNGKey(0),
                           ledger=CostLedger()),
            agg_mod.SumJob(value_column=2, key=jax.random.PRNGKey(1),
                           ledger=CostLedger())]
    with pytest.raises(ValueError, match="uniform"):
        agg_mod.agg_sum_phase(be, db, jobs)
    # ...while the client transparently groups them into two fused calls
    res = QueryClient(db, key=2).run_batch([Aggregate("sum", "A"),
                                            Aggregate("sum", "B")])
    assert [r.value for r in res] == [6, 12]


def test_minmax_job_validation():
    with pytest.raises(ValueError, match="min.*max|'min' or 'max'"):
        agg_mod.MinMaxJob(value_column=0, key=jax.random.PRNGKey(0),
                          ledger=None, op="sum")


def test_distinct_value_columns_fuse_in_one_batch():
    """Conditional sums over DIFFERENT value columns of the same width
    still ride one phase (one ss_matmul per distinct column)."""
    rows = [[f"i{k}", f"g{k % 2}", str(k), str(10 * k)] for k in range(6)]
    db = outsource(jax.random.PRNGKey(8), rows,
                   column_names=["Id", "G", "A", "B"], codec=CODEC,
                   n_shares=20, degree=1, numeric_columns={2: 8, 3: 8})
    plans = [Aggregate("sum", "A", where=Eq("G", "g0")),
             Aggregate("sum", "B", where=Eq("G", "g1")),
             Aggregate("sum", "A")]
    res = QueryClient(db, key=3).run_batch(plans)
    assert res[0].value == 0 + 2 + 4
    assert res[1].value == 10 + 30 + 50
    assert res[2].value == sum(range(6))
    seq = [QueryClient(db, key=3).run(p) for p in plans]
    for a, b in zip(seq, res):
        assert a.value == b.value and a.ledger == b.ledger
