"""Sharded dataplane: partitioning, dispatch fan-out, transcript identity.

The anchor property of `repro.core.dataplane`: the shard count S is pure
*execution* policy. For every plan family, `run_batch` over a
``ShardedRelation(S)`` — serial, threaded, or MapReduce-placed — returns
bit-identical rows/addresses/counts AND equal per-query ``CostLedger``s to
the S = 1 path, while the cloud-side device fan-out scales as one dispatch
per shard per cloud step (ceil(n/S)-tuple blocks).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (Aggregate, Backend, Between, Count, Eq, Join,
                       Padding, QueryClient, RangeCount, RangeSelect, Select,
                       ShardedRelation, ThreadedDispatcher,
                       MapReduceDispatcher, batched_match_matrix,
                       batched_matcher, get_backend, ripple_segmenter,
                       ripple_stepper)
from repro.core import Codec, outsource
from repro.core.dataplane import as_dataplane
from repro.runtime import MapReduceRunner, WorkerPool

CODEC = Codec(word_length=6)


@pytest.fixture(scope="module")
def range_db():
    rows = [[f"id{i}", f"nm{i % 5}", str(500 + 137 * i)] for i in range(32)]
    db = outsource(jax.random.PRNGKey(19), rows,
                   column_names=["Id", "Name", "Val"], codec=CODEC,
                   n_shares=20, degree=1, numeric_columns={2: 14})
    return rows, db


@pytest.fixture(scope="module")
def child_db(range_db):
    rows, _ = range_db
    child = [[rows[i % len(rows)][0], f"t{i}"] for i in range(6)]
    return outsource(jax.random.PRNGKey(23), child,
                     column_names=["Id", "Task"], codec=CODEC,
                     n_shares=20, degree=1)


def _all_family_plans(child):
    return [
        Count(Eq("Name", "nm1")),
        Select(Eq("Name", "nm2"), strategy="one_round"),
        Select(Eq("Name", "nm3"), strategy="tree"),
        Select(Eq("Id", "id7"), strategy="one_tuple"),
        Select(Eq("Name", "nm4")),                          # auto
        RangeCount(Between("Val", 500, 2000), reduce_every=2),
        RangeSelect(Between("Val", 900, 1800), reduce_every=2),
        Join(right=child, on=("Id", "Id"), kind="pkfk"),
        Join(right=child, on=("Id", "Id"), kind="equi",
             padding=Padding.fake_values(1)),
        Select(Eq("Name", "zzz"), strategy="one_round"),    # zero match
        # aggregation: per-shard partial sums reduce exactly mod p; the
        # MIN/MAX tournament runs on the gathered relation — either way S
        # must stay invisible in values and ledgers. (Conditional MAX is
        # absent by design: range_db values reach 4747 > 2^(t-2)-1 = 4095,
        # outside the sentinel-masking headroom the comparator requires.)
        Aggregate("sum", "Val"),
        Aggregate("sum", "Val", where=Eq("Name", "nm1"), verify=True),
        Aggregate("avg", "Val", where=Eq("Name", "nm2")),
        Aggregate("min", "Val", where=Eq("Name", "nm1"), reduce_every=2),
        Aggregate("max", "Val", reduce_every=2),
    ]


def _assert_results_equal(a, b):
    assert a.strategy == b.strategy
    assert a.rows == b.rows
    assert a.addresses == b.addresses
    assert a.count == b.count
    assert a.value == b.value
    assert a.ledger == b.ledger


# ---------------------------------------------------------------------------
# structure
# ---------------------------------------------------------------------------

def test_sharded_relation_partitions_cover_and_clamp(range_db):
    _, db = range_db
    plane = ShardedRelation(db, shards=4)
    assert plane.n_shards == 4
    assert [s.lo for s in plane.shards][0] == 0
    assert plane.shards[-1].hi == db.n_tuples
    for a, b in zip(plane.shards, plane.shards[1:]):
        assert a.hi == b.lo                    # contiguous, no gaps
    assert plane.max_shard_rows == 8           # ceil(32/4)
    # views slice the share arrays without copying metadata semantics
    v = plane.view(1)
    assert v.n_tuples == 8
    np.testing.assert_array_equal(
        np.asarray(v.relation.values),
        np.asarray(db.relation.values[:, 8:16]))
    np.testing.assert_array_equal(
        np.asarray(v.numeric[2].values),
        np.asarray(db.numeric[2].values[:, 8:16]))
    # more shards than tuples clamps (split_bounds never yields empties)
    tiny = ShardedRelation(db, shards=100)
    assert tiny.n_shards == db.n_tuples
    # delegation: the plane reads like its relation
    assert plane.n_tuples == db.n_tuples and plane.codec is db.codec
    # re-wrapping a plane re-shards the underlying db
    assert ShardedRelation(plane, shards=2).n_shards == 2
    # as_dataplane: plain db -> S=1 plane, plane passes through
    assert as_dataplane(db).n_shards == 1
    assert as_dataplane(plane) is plane


def test_oversharded_tiny_relation_regression():
    """Regression (n=1, S=4): more shards than tuples must clamp to n
    non-empty shards — never emit zero-width shard dispatches — and the
    oversharded plane must still answer queries correctly end to end."""
    from repro.core.partition import split_bounds
    assert split_bounds(0, 1, 4) == [(0, 1)]      # clamp, no empties
    one = [["E1", "Ada", "Byron", "900", "Math"]]
    db1 = outsource(jax.random.PRNGKey(3), one,
                    column_names=["Id", "First", "Last", "Sal", "Dept"],
                    codec=CODEC, n_shares=20, degree=1)
    plane = ShardedRelation(db1, shards=4)
    assert plane.n_shards == 1
    assert all(s.n_tuples > 0 for s in plane.shards)
    assert plane.max_shard_rows == 1
    client = QueryClient(plane, key=9)
    assert client.stats().shards == 1              # planner sees the clamp
    res = client.run(Count(Eq("First", "Ada")))
    assert res.count == 1
    sel = client.run(Select(Eq("First", "Ada"), strategy="one_round"))
    assert sel.rows == [one[0]]
    # through attach too: an explicit shards=4 on a 1-tuple relation
    via_attach = QueryClient(db1, key=9)
    assert via_attach.attach(shards=4).n_shards == 1
    assert via_attach.run(Count(Eq("First", "Ada"))).count == 1


# ---------------------------------------------------------------------------
# S ∈ {1,2,4}: sharded batch == unsharded sequential, all five families
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shards", [1, 2, 4])
def test_sharded_batch_equals_unsharded_sequential(range_db, child_db,
                                                   shards):
    _, db = range_db
    plans = _all_family_plans(child_db)
    seq = [QueryClient(db, key=42).run(p) for p in plans]

    client = QueryClient(db, key=42)
    plane = client.attach(shards=shards)
    bat = client.run_batch(plans)
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)
    # fan-out accounting: every sharded cloud step emitted exactly one
    # dispatch per shard
    assert plane.stats.dispatches == plane.stats.steps * plane.n_shards


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_sharded_aggregation_matches_plaintext_oracle(range_db, shards):
    """SUM/AVG/MIN-MAX open the exact plaintext answer at every S: the
    per-shard partial sums combine additively mod p, the tournament's
    candidates are shard-order-independent by construction."""
    rows, db = range_db
    vals = np.array([int(r[2]) for r in rows])
    names = np.array([r[1] for r in rows])
    client = QueryClient(db, key=42)
    client.attach(shards=shards)
    res = client.run_batch([
        Aggregate("sum", "Val"),
        Aggregate("avg", "Val", where=Eq("Name", "nm2")),
        Aggregate("min", "Val", where=Eq("Name", "nm1"), reduce_every=2),
        Aggregate("max", "Val", reduce_every=2, verify=True),
    ])
    assert res[0].value == int(vals.sum())
    assert res[1].value == pytest.approx(vals[names == "nm2"].mean())
    assert res[2].value == int(vals[names == "nm1"].min())
    assert res[3].value == int(vals.max())


def test_shard_count_never_changes_step_count(range_db, child_db):
    """Steps (cloud rounds' dispatch sets) are a protocol property; only
    the per-step fan-out scales with S."""
    _, db = range_db
    plans = _all_family_plans(child_db)
    steps = set()
    for s in (1, 2, 4):
        client = QueryClient(db, key=42)
        plane = client.attach(shards=s)
        client.run_batch(plans)
        steps.add(plane.stats.steps)
    assert len(steps) == 1


def test_threaded_and_mapreduce_dispatchers_match_serial(range_db,
                                                         child_db):
    _, db = range_db
    plans = _all_family_plans(child_db)
    base = QueryClient(db, key=7).run_batch(plans)

    threaded = QueryClient(db, key=7)
    threaded.attach(shards=4, dispatcher=ThreadedDispatcher(max_workers=4))
    for a, b in zip(base, threaded.run_batch(plans)):
        _assert_results_equal(a, b)

    runner = MapReduceRunner(WorkerPool(3), lease_s=5.0, max_attempts=30)
    placed = QueryClient(db, key=7)
    placed.attach(shards=3, dispatcher=MapReduceDispatcher(runner))
    for a, b in zip(base, placed.run_batch(plans)):
        _assert_results_equal(a, b)


def test_sharded_client_constructor_and_attach_agree(range_db):
    _, db = range_db
    plans = [Count(Eq("Name", "nm1")), Select(Eq("Name", "nm2"))]
    via_ctor = QueryClient(ShardedRelation(db, shards=2), key=5)
    via_attach = QueryClient(db, key=5)
    via_attach.attach(shards=2)
    assert via_ctor.stats().shards == 2 == via_attach.stats().shards
    for a, b in zip(via_ctor.run_batch(plans), via_attach.run_batch(plans)):
        _assert_results_equal(a, b)


def test_attach_dispatcher_swap_preserves_sharding(range_db):
    """Swapping the placement policy must never collapse an existing
    partitioning; an explicit shards>1 re-shards."""
    _, db = range_db
    client = QueryClient(ShardedRelation(db, shards=4), key=5)
    pool = ThreadedDispatcher(max_workers=2)
    plane = client.attach(dispatcher=pool)
    assert plane.n_shards == 4 and plane.dispatcher is pool
    assert client.stats().shards == 4
    assert client.attach(shards=2).n_shards == 2
    pool.close()
    # a closed pool degrades to serial execution, still correct
    client2 = QueryClient(db, key=5)
    client2.attach(shards=3, dispatcher=pool)
    res = client2.run(Count(Eq("Name", "nm1")))
    assert res.count == QueryClient(db, key=5).run(
        Count(Eq("Name", "nm1"))).count


# ---------------------------------------------------------------------------
# dispatch counting backends: segments + batched join matrices
# ---------------------------------------------------------------------------

def _counting_backend(name="jnp"):
    """Count every hotspot dispatch, including the new fused ops."""
    base = get_backend(name)
    calls = {"aa_match_batch": 0, "ss_matmul": 0, "match_matrix": 0,
             "match_matrix_batch": 0, "ripple_carry": 0,
             "ripple_segment": 0}

    def wrap(op_name, fn):
        def run(a, b):
            calls[op_name] += 1
            return fn(a, b)
        return run

    base_ripple = ripple_stepper(base)
    base_segment = ripple_segmenter(base)

    def ripple(a, b, carry=None):
        calls["ripple_carry"] += 1
        return base_ripple(a, b, carry)

    def segment(a, b, carry=None):
        calls["ripple_segment"] += 1
        return base_segment(a, b, carry)

    be = Backend(
        name=f"{name}+counting",
        aa_match=wrap("aa_match", base.aa_match),
        ss_matmul=wrap("ss_matmul", base.ss_matmul),
        match_matrix=wrap("match_matrix", base.match_matrix),
        aa_match_batch=wrap("aa_match_batch", batched_matcher(base)),
        ripple_carry=ripple,
        ripple_segment=segment,
        match_matrix_batch=wrap("match_matrix_batch",
                                batched_match_matrix(base)))
    return be, calls


def test_range_phase_dispatches_one_segment_per_boundary(range_db):
    """t=14 bits at reduce_every=2 -> 7 fused segment dispatches (never 14
    per-bit steps) when the backend provides ripple_segment."""
    _, db = range_db
    plans = [RangeCount(Between("Val", 600, 600 + 200 * i), reduce_every=2)
             for i in range(4)]
    seq = [QueryClient(db, key=33).run(p) for p in plans]
    be, calls = _counting_backend()
    bat = QueryClient(db, key=33, backend=be).run_batch(plans)
    assert calls["ripple_segment"] == 7
    assert calls["ripple_carry"] == 0
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)
    # reduce_every=0: the whole chain is ONE dispatch (no reductions, the
    # carry degree climbs to 2t — needs enough clouds to open)
    deep = outsource(jax.random.PRNGKey(2),
                     [[f"i{k}", str(600 + 10 * k)] for k in range(8)],
                     column_names=["Id", "Val"], codec=CODEC, n_shares=34,
                     degree=1, numeric_columns={1: 14})
    calls_before = calls["ripple_segment"]
    QueryClient(deep, key=3, backend=be).run(
        RangeCount(Between("Val", 500, 900)))
    assert calls["ripple_segment"] == calls_before + 1


def test_join_group_stacks_match_matrices_into_one_dispatch(range_db,
                                                            child_db):
    """Equal-size right relations in a join group ride ONE (c,B,nx,ny)
    batched dispatch — the per-pkfk-job match_matrix loop is retired."""
    _, db = range_db
    plans = [Join(right=child_db, on=("Id", "Id"), kind="pkfk")
             for _ in range(3)]
    seq = [QueryClient(db, key=77).run(p) for p in plans]
    be, calls = _counting_backend()
    bat = QueryClient(db, key=77, backend=be).run_batch(plans)
    assert calls["match_matrix_batch"] == 1    # 3 joins, one dispatch
    assert calls["match_matrix"] == 0
    assert calls["ss_matmul"] == 1             # the shared fetch
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)


def test_join_groups_split_by_right_relation_size(range_db, child_db):
    """Different-size right relations cannot stack: one batched dispatch
    per size class, results still sequential-identical."""
    rows, db = range_db
    other = outsource(jax.random.PRNGKey(29),
                      [[rows[i][0], f"u{i}"] for i in range(4)],
                      column_names=["Id", "Task"], codec=CODEC,
                      n_shares=20, degree=1)
    plans = [Join(right=child_db, on=("Id", "Id"), kind="pkfk"),
             Join(right=other, on=("Id", "Id"), kind="pkfk"),
             Join(right=child_db, on=("Id", "Id"), kind="pkfk")]
    seq = [QueryClient(db, key=13).run(p) for p in plans]
    be, calls = _counting_backend()
    bat = QueryClient(db, key=13, backend=be).run_batch(plans)
    assert calls["match_matrix_batch"] == 2    # one per ny class
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)


def test_sharded_dispatch_counts_scale_with_shards(range_db):
    """One fused dispatch per cloud step at S=1 becomes S per step."""
    _, db = range_db
    plans = [Select(Eq("Name", "nm1"), strategy="one_round"),
             Select(Eq("Name", "nm2"), strategy="one_round")]
    be1, calls1 = _counting_backend()
    QueryClient(db, key=9, backend=be1).run_batch(plans)
    assert calls1["aa_match_batch"] == 1 and calls1["ss_matmul"] == 1

    be4, calls4 = _counting_backend()
    client = QueryClient(db, key=9, backend=be4)
    client.attach(shards=4)
    client.run_batch(plans)
    assert calls4["aa_match_batch"] == 4 and calls4["ss_matmul"] == 4


# ---------------------------------------------------------------------------
# fused-op parity oracles
# ---------------------------------------------------------------------------

def test_ripple_segment_equals_per_bit_stepper():
    from repro.api.backends import jnp_ripple_carry, jnp_ripple_segment
    key = jax.random.PRNGKey(0)
    a = jax.random.randint(key, (3, 4, 8, 6), 0, 2).astype(jnp.uint32)
    b = jax.random.randint(jax.random.fold_in(key, 1), (3, 4, 8, 6), 0,
                           2).astype(jnp.uint32)
    # from-LSB chain
    rb_s, co_s = jnp_ripple_segment(a, b, None)
    rb, co = None, None
    for i in range(6):
        rb, co = jnp_ripple_carry(a[..., i], b[..., i], co if i else None)
    np.testing.assert_array_equal(np.asarray(rb_s), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(co_s), np.asarray(co))
    # mid-chain continuation with an incoming carry
    carry0 = jax.random.randint(jax.random.fold_in(key, 2), (3, 4, 8), 0,
                                7).astype(jnp.uint32)
    rb_s, co_s = jnp_ripple_segment(a, b, carry0)
    rb, co = None, carry0
    for i in range(6):
        rb, co = jnp_ripple_carry(a[..., i], b[..., i], co)
    np.testing.assert_array_equal(np.asarray(rb_s), np.asarray(rb))
    np.testing.assert_array_equal(np.asarray(co_s), np.asarray(co))


def test_ripple_segment_pallas_equals_jnp():
    from repro.api.backends import jnp_ripple_segment
    from repro.kernels import ops
    key = jax.random.PRNGKey(5)
    a = jax.random.randint(key, (2, 6, 10, 5), 0, 2).astype(jnp.uint32)
    b = jax.random.randint(jax.random.fold_in(key, 1), (2, 6, 10, 5), 0,
                           2).astype(jnp.uint32)
    for carry in (None, jax.random.randint(jax.random.fold_in(key, 2),
                                           (2, 6, 10), 0,
                                           11).astype(jnp.uint32)):
        rb_p, co_p = ops.ripple_segment(a, b, carry)
        rb_j, co_j = jnp_ripple_segment(a, b, carry)
        np.testing.assert_array_equal(np.asarray(rb_p), np.asarray(rb_j))
        np.testing.assert_array_equal(np.asarray(co_p), np.asarray(co_j))


def test_match_matrix_batch_equals_per_pair(range_db, child_db):
    for name in ("jnp", "pallas"):
        be = get_backend(name)
        _, db = range_db
        bx = jnp.stack([db.column(0).values, db.column(1).values], axis=1)
        by = jnp.stack([child_db.column(0).values,
                        child_db.column(0).values], axis=1)
        fused = batched_match_matrix(be)(bx, by)
        for k in range(2):
            np.testing.assert_array_equal(
                np.asarray(fused[:, k]),
                np.asarray(be.match_matrix(bx[:, k], by[:, k])))


# ---------------------------------------------------------------------------
# planner: shard-aware dispatch pricing + batch explanation
# ---------------------------------------------------------------------------

def test_explain_batch_predicts_run_batch_ledger(range_db, child_db):
    _, db = range_db
    plans = _all_family_plans(child_db)
    client = QueryClient(db, key=1)
    exp = client.explain(plans)
    assert exp.shards == 1
    assert exp.bits > 0 and exp.rounds > 0 and exp.dispatches > 0
    # RangeCount and RangeSelect share (t_bits, reduce_every) -> ONE fused
    # range group, reported under range_select because a member fetches
    assert {g.family for g in exp.groups} == {
        "count", "one_round", "tree", "one_tuple", "range_select",
        "pkfk", "equi", "aggregate"}
    # bits/rounds are protocol: invariant to S; dispatches scale with it
    sharded = QueryClient(db, key=1)
    sharded.attach(shards=4)
    exp4 = sharded.explain(plans)
    assert exp4.shards == 4
    assert exp4.bits == exp.bits and exp4.rounds == exp.rounds
    assert exp4.dispatches > exp.dispatches


def test_reattach_invalidates_cached_explanations(range_db, child_db):
    """Regression: attach(shards=S) after explain() left stale
    ``CostEstimate.dispatches`` (priced at the OLD shard count) in cached
    BatchExplanations — re-attaching must invalidate the cache."""
    _, db = range_db
    plans = _all_family_plans(child_db)
    client = QueryClient(db, key=1)
    exp1 = client.explain(plans)
    assert client.explain(plans) is exp1            # cached while valid
    client.attach(shards=4)
    exp4 = client.explain(plans)
    assert exp4 is not exp1                         # invalidated
    assert exp4.shards == 4 and exp4.dispatches > exp1.dispatches
    # fresh-client parity: the recomputed estimate IS the sharded truth
    fresh = QueryClient(db, key=1)
    fresh.attach(shards=4)
    assert fresh.explain(plans) == exp4
    # per-relation namespaces cache (and label) independently
    multi = QueryClient(db, key=1)
    multi.attach(child_db, name="tasks")
    exp_default = multi.explain(
        [Select(Eq("Name", "nm1"), strategy="one_round")])
    exp_tasks = multi.explain(
        [Select(Eq("Task", "t1"), strategy="one_round")], relation="tasks")
    assert exp_default.relation == "default"
    assert exp_tasks.relation == "tasks"
    assert exp_tasks.bits != exp_default.bits       # priced per target n
    multi.attach(shards=2, name="tasks")
    assert multi.explain(
        [Select(Eq("Task", "t1"), strategy="one_round")],
        relation="tasks").dispatches > exp_tasks.dispatches


def test_explain_batch_select_group_matches_group_estimate(range_db):
    from repro.api import estimate_batch_group_cost
    _, db = range_db
    plans = [Select(Eq("Name", "nm1"), strategy="one_round",
                    expected_matches=4),
             Select(Eq("Name", "nm2"), strategy="one_round",
                    expected_matches=2)]
    client = QueryClient(db, key=1)
    exp = client.explain(plans)
    (grp,) = exp.groups
    want = estimate_batch_group_cost(client.stats(), "one_round",
                                     ells=[4, 2])
    assert grp.family == "one_round" and grp.size == 2
    assert grp.estimate == want
    assert exp.bits == want.bits and exp.rounds == want.rounds


def test_explain_single_select_carries_dispatches(range_db):
    _, db = range_db
    client = QueryClient(db, key=1)
    ests = client.explain(Select(Eq("Name", "nm1")))
    assert all(e.dispatches >= 1 for e in ests)
    client.attach(shards=4)
    ests4 = client.explain(Select(Eq("Name", "nm1")))
    by_strategy = {e.strategy: e for e in ests4}
    for e in ests:
        assert by_strategy[e.strategy].dispatches > e.dispatches
        assert by_strategy[e.strategy].bits == e.bits


def test_explain_batch_counts_shared_fetch_once(range_db, child_db):
    """Two fetch-riding groups must not double-price the single
    cross-group fetch dispatch set."""
    from repro.api import estimate_pkfk_cost, estimate_select_cost, DBStats
    _, db = range_db
    client = QueryClient(db, key=1)
    exp = client.explain([Select(Eq("Name", "nm1"), strategy="one_round"),
                          Join(right=child_db, on=("Id", "Id"),
                               kind="pkfk")])
    stats = client.stats()
    solo = (estimate_select_cost("one_round", stats).dispatches
            + estimate_pkfk_cost(stats, DBStats.of(child_db)).dispatches)
    assert exp.dispatches == solo - stats.shards
