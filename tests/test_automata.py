"""Direct unit coverage for ``repro.core.automata`` (§3.1 AA matching).

The seed grew this module behind the query suite without its own tests;
these pin the primitive contracts the pattern engine now builds on:
count_column vs the cleartext count, match_words degree bookkeeping, the
Lagrange equality/zero indicators at their domain boundaries, and the
sliding-window trio (slide_windows / match_suffix / window_count) against
character-level oracles. Field arithmetic is exact — no tolerances.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Codec, automata, encoding, field, outsource, shamir

CODEC = Codec(word_length=8)
WORDS = ["banana", "bandana", "an", "nab", "ban", "anna", "", "cabana"]
N_SHARES = 20


@pytest.fixture(scope="module")
def db():
    return outsource(jax.random.PRNGKey(0), [[w] for w in WORDS],
                     codec=CODEC, n_shares=N_SHARES)


def _col(db):
    return shamir.Shares(db.relation.values[:, :, 0], db.relation.degree)


def _pattern(word: str, seed: int = 1):
    return encoding.share_pattern(jax.random.PRNGKey(seed), CODEC, word,
                                  n_shares=N_SHARES, degree=1)


def _tile(spec: encoding.PatternSpec, seed: int = 2):
    return encoding.share_encoded(
        jax.random.PRNGKey(seed), encoding.encode_pattern_tile(CODEC, spec),
        n_shares=N_SHARES, degree=1)


def _open(sh: shamir.Shares) -> np.ndarray:
    return np.asarray(shamir.interpolate(sh))


# ---------------------------------------------------------------------------
# exact-word chain: count_column / match_words
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("word,count", [("banana", 1), ("an", 1),
                                        ("", 1), ("xyz", 0)])
def test_count_column_matches_cleartext(db, word, count):
    got = int(_open(automata.count_column(_col(db), _pattern(word))))
    assert got == count


def test_match_words_bits_and_degree(db):
    col = _col(db)
    pat = _pattern("ban")
    out = automata.match_words(col, pat)
    # degree accumulates one (t_col + t_pat) factor per chained position
    assert out.degree == (col.degree + pat.degree) * CODEC.word_length
    bits = _open(out)
    assert bits.tolist() == [1 if w == "ban" else 0 for w in WORDS]


def test_match_words_needs_enough_shares_to_open(db):
    # the bookkeeping above is what tells the user-side interpolator how
    # many shares it needs: degree+1 points reconstruct, degree points don't
    out = automata.match_words(_col(db), _pattern("ban"))
    assert N_SHARES >= out.degree + 1
    short = shamir.Shares(out.values[:out.degree], out.degree)
    with pytest.raises(ValueError):
        shamir.interpolate(short)


# ---------------------------------------------------------------------------
# Lagrange indicators at the domain boundary
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("w", [1, 2, 5, CODEC.word_length])
def test_equality_indicator_boundary(w):
    dom = jnp.arange(w + 1, dtype=field.DTYPE)
    got = np.asarray(automata.equality_indicator(dom, w))
    assert got.tolist() == [0] * w + [1]


@pytest.mark.parametrize("m", [1, 3, CODEC.word_length])
def test_zero_indicator_boundary(m):
    dom = jnp.arange(m + 1, dtype=field.DTYPE)
    got = np.asarray(automata.zero_indicator(dom, m))
    assert got.tolist() == [1] + [0] * m


# ---------------------------------------------------------------------------
# sliding-window trio
# ---------------------------------------------------------------------------

def _windows_oracle(word: str, body: str):
    padded = word + "\0" * CODEC.word_length
    m = CODEC.word_length - len(body) + 1
    return [1 if padded[o:o + len(body)] == body else 0 for o in range(m)]


@pytest.mark.parametrize("body", ["an", "ana", "b", "cabana"])
def test_slide_windows_oracle(db, body):
    spec = encoding.PatternSpec("contains", body, (), f"%{body}%")
    out = automata.slide_windows(_col(db), _tile(spec))
    assert out.degree == (db.relation.degree + 1) * len(body)
    got = _open(out)
    want = np.asarray([_windows_oracle(w, body) for w in WORDS])
    assert np.array_equal(got, want)


@pytest.mark.parametrize("body", ["ana", "an", "a", "nab"])
def test_match_suffix_oracle(db, body):
    spec = encoding.PatternSpec("suffix", body, (), f"%{body}")
    out = automata.match_suffix(_col(db), _tile(spec))
    got = _open(out)
    assert got.tolist() == [1 if w.endswith(body) else 0 for w in WORDS]


def test_window_count_counts_overlaps(db):
    # "banana" holds "ana" at offsets 1 and 3 — the raw window count is 2,
    # which is exactly why CONTAINS needs the zero-test, not a linear sum
    spec = encoding.PatternSpec("contains", "ana", (), "%ana%")
    counts = _open(automata.window_count(_col(db), _tile(spec)))
    want = [sum(_windows_oracle(w, "ana")) for w in WORDS]
    assert counts.tolist() == want
    assert counts[WORDS.index("banana")] == 2


# ---------------------------------------------------------------------------
# match_matrix: chain vs aggregate evaluation
# ---------------------------------------------------------------------------

def test_match_matrix_chain_vs_aggregate(db):
    right = outsource(jax.random.PRNGKey(5),
                      [["banana"], ["xyz"], ["an"]],
                      codec=CODEC, n_shares=N_SHARES)
    cx = _col(db)
    cy = shamir.Shares(right.relation.values[:, :, 0],
                       right.relation.degree)
    chain = automata.match_matrix(cx, cy, method="chain")
    agg = automata.match_matrix(cx, cy, method="aggregate")
    assert chain.degree == agg.degree == \
        (cx.degree + cy.degree) * CODEC.word_length
    opened_chain = _open(chain)
    assert np.array_equal(opened_chain, _open(agg))
    want = np.asarray([[1 if w == r[0] else 0
                        for r in [["banana"], ["xyz"], ["an"]]]
                       for w in WORDS])
    assert np.array_equal(opened_chain, want)
