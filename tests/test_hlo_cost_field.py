"""launch.hlo_cost must price the mod-p field ops the query kernels emit.

Two layers:

* a captured-HLO **fixture** with hand-countable instructions — exact
  FLOP / HBM-byte / collective-byte totals, so a parser or accounting
  regression shows up as a number, not a vibe;
* **real lowered HLO** from the field/kernels hot ops (``field.mul``,
  ``field.sum_``, the fused ripple segment, ``kernels.ops.ss_matmul``) —
  every integer ALU opcode XLA emits for the share arithmetic
  (``remainder``, ``and``, ``shift-*``, …) must be in the elementwise set,
  never falling through to the traffic-only default branch.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field
from repro.launch import hlo_cost

# --------------------------------------------------------------------------
# fixture: every instruction hand-countable
# --------------------------------------------------------------------------

FIXTURE_HLO = """
HloModule jit_mod_p_fold

%body_comp (bp: (s32[], u32[16])) -> (s32[], u32[16]) {
  %bp = (s32[], u32[16]) parameter(0)
  %i = s32[] get-tuple-element(%bp), index=0
  %v = u32[16]{0} get-tuple-element(%bp), index=1
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  %vv = u32[16]{0} multiply(%v, %v)
  ROOT %t = (s32[], u32[16]) tuple(%ip, %vv)
}

%cond_comp (cp: (s32[], u32[16])) -> pred[] {
  %cp = (s32[], u32[16]) parameter(0)
  %ci = s32[] get-tuple-element(%cp), index=0
  %lim = s32[] constant(5)
  ROOT %lt = pred[] compare(%ci, %lim), direction=LT
}

ENTRY %main (p0: u32[8,16], p1: u32[8,16], v0: u32[16]) -> u32[8,16] {
  %p0 = u32[8,16]{1,0} parameter(0)
  %p1 = u32[8,16]{1,0} parameter(1)
  %v0 = u32[16]{0} parameter(2)
  %lo = u32[8,16]{1,0} and(%p0, %p1)
  %hi = u32[8,16]{1,0} shift-right-logical(%p0, %p1)
  %sl = u32[8,16]{1,0} shift-left(%hi, %p1)
  %s = u32[8,16]{1,0} add(%lo, %sl)
  %w64 = u64[8,16]{1,0} convert(%s)
  %r = u64[8,16]{1,0} remainder(%w64, %w64)
  %ar = u64[8,16]{1,0} all-reduce(%r), to_apply=%sum_u64
  %out = u32[8,16]{1,0} convert(%ar)
  %d = u32[8,8]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={1}
  %zero = s32[] constant(0)
  %init = (s32[], u32[16]) tuple(%zero, %v0)
  %wh = (s32[], u32[16]) while(%init), condition=%cond_comp, body=%body_comp
  ROOT %res = u32[8,16]{1,0} add(%out, %out)
}
"""

# hand counts (128 = 8*16 elems; u32 4 B, u64 8 B):
#   elementwise entry: and + srl + sl + add + convert + remainder + convert
#     + final add = 8 ops x 128 elems                    -> 1024 flops
#   dot: 2 * |out|(64) * K(16)                           -> 2048 flops
#   while: 5 trips x (add[1] + multiply[16] + cond compare[1]) -> 90 flops
_FIX_FLOPS = 8 * 128 + 2048 + 90
#   hbm: and/srl/sl/add (4 x (512 out + 2*512 in)) + convert u64 (1024+512)
#     + remainder (1024 + 2*1024) + all-reduce io (1024+1024)
#     + convert back (512+1024) + dot (256 + 512 + 512)
#     + while body 5 x (add 12 + multiply 192) + final add (512 + 2*512)
_FIX_HBM = (4 * 1536 + 1536 + 3072 + 2048 + 1536 + 1280 + 5 * 204 + 1536)
_FIX_COLL = 8 * 16 * 8      # the u64 all-reduce output


def test_fixture_exact_flop_and_byte_counts():
    cost = hlo_cost.analyze_text(FIXTURE_HLO)
    assert cost.flops == _FIX_FLOPS
    assert cost.hbm_bytes == _FIX_HBM
    assert cost.collectives["all-reduce"] == _FIX_COLL
    assert cost.collective_bytes == _FIX_COLL


def test_fixture_mod_p_opcodes_are_elementwise():
    # the regression this file exists for: any of these dropping out of
    # the elementwise set silently zeroes the field-arithmetic FLOPs
    for op in ("remainder", "and", "shift-left", "shift-right-logical",
               "shift-right-arithmetic", "xor", "or", "not", "convert",
               "compare", "select"):
        assert op in hlo_cost._ELEMENTWISE, op


# --------------------------------------------------------------------------
# real lowered HLO from the kernels
# --------------------------------------------------------------------------

_OPCODE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^)]*\)|\S+?)\s+([\w\-]+)\(")

#: structural / control ops the walker prices through dedicated branches;
#: anything else it meets must be _ELEMENTWISE, _NO_TRAFFIC, or a pure
#: data-movement op (priced as traffic, zero flops).
_STRUCTURAL = {
    "dot", "convolution", "reduce", "reduce-window", "sort", "while",
    "fusion", "call", "async-start", "async-update", "async-done",
    "custom-call", "conditional", "dynamic-slice", "slice", "gather",
    "dynamic-update-slice", "broadcast", "iota",
} | set(hlo_cost._COLLECTIVES)
_DATA_MOVEMENT = {"copy", "copy-start", "copy-done", "pad", "reshape",
                  "transpose", "concatenate", "reverse", "scatter",
                  "reduce-precision", "rng", "rng-bit-generator"}


def _opcodes(text):
    ops = set()
    for line in text.splitlines():
        m = _OPCODE.match(line)
        if m:
            ops.add(m.group(1))
    return ops


def _lowered(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_field_mul_fold_ops_counted():
    a = jnp.asarray(np.arange(24, dtype=np.uint32).reshape(2, 3, 4)
                    % field.P)
    text = _lowered(field.mul, a, a)
    ops = _opcodes(text)
    # the Mersenne fold is and + shifts — they must be priced as flops
    assert "and" in ops and "shift-right-logical" in ops
    emitted = ops & {"remainder", "and", "shift-left",
                     "shift-right-logical", "shift-right-arithmetic"}
    assert emitted <= hlo_cost._ELEMENTWISE
    assert hlo_cost.analyze_text(text).flops > 0


def test_field_sum_remainder_counted():
    a = jnp.asarray(np.arange(120, dtype=np.uint32).reshape(2, 5, 12)
                    % field.P)
    text = _lowered(lambda x: field.sum_(x, axis=1), a)
    ops = _opcodes(text)
    assert "remainder" in ops          # the single fold of the uint64 sum
    assert "remainder" in hlo_cost._ELEMENTWISE
    cost = hlo_cost.analyze_text(text)
    assert cost.flops >= a.size        # at least the reduce itself


def test_ripple_segment_ops_counted():
    from repro.api.backends import jnp_ripple_segment
    a = jnp.asarray(np.arange(36, dtype=np.uint32).reshape(2, 2, 3, 3)
                    % field.P)
    text = _lowered(lambda x, y: jnp_ripple_segment(x, y, None), a, a)
    ops = _opcodes(text)
    assert ops & {"and", "shift-right-logical", "multiply"}
    assert hlo_cost.analyze_text(text).flops > 0


def test_no_kernel_opcode_falls_through_unpriced():
    """Every opcode the real kernels emit is known to the cost model —
    elementwise (flops), structural (dedicated branch), no-traffic, or an
    explicit data-movement op. An unknown ALU op would silently price as
    bytes-only."""
    from repro.kernels import ops as kops
    a = jnp.asarray(np.arange(2 * 4 * 6, dtype=np.uint32).reshape(2, 4, 6)
                    % field.P)
    b = jnp.asarray(np.arange(2 * 6 * 3, dtype=np.uint32).reshape(2, 6, 3)
                    % field.P)
    texts = [_lowered(kops.ss_matmul, a, b),
             _lowered(field.matmul, a, b)]
    known = (hlo_cost._ELEMENTWISE | hlo_cost._NO_TRAFFIC | _STRUCTURAL
             | _DATA_MOVEMENT)
    for text in texts:
        unknown = _opcodes(text) - known
        assert not unknown, f"unpriced opcodes: {sorted(unknown)}"
