"""Weighted fair quotas on the shared shard pool (deficit round robin).

``ThreadedDispatcher.handle(weight=w)`` gives each tenant a DRR share of
the pool's bounded fan-out: under contention a weight-3 handle gets three
shard slots per weight-1 neighbour visit, a flooding handle can never
starve a neighbour, and fairness is pure execution policy — unit results
(and exceptions) flow back through the same futures regardless of the
service order.
"""
import threading
import time

import pytest

from repro.core.dataplane import ThreadedDispatcher


def _tagger(tag, log, lock, gate=None):
    def thunk():
        if gate is not None:
            gate.wait()
        with lock:
            log.append(tag)
        return tag
    return thunk


def test_weighted_service_is_proportional():
    """With the single worker gated, a 3:1 weight split serves exactly
    3 hot units per cold unit per round-robin visit."""
    pool = ThreadedDispatcher(max_workers=1)
    hot, cold = pool.handle(weight=3.0), pool.handle(weight=1.0)
    log, lock, gate = [], threading.Lock(), threading.Event()
    blocker = pool.handle()
    fut_gate = pool.enqueue(blocker, [_tagger("gate", log, lock, gate)])
    hot_f = pool.enqueue(hot, [_tagger("h", log, lock) for _ in range(24)])
    cold_f = pool.enqueue(cold, [_tagger("c", log, lock) for _ in range(8)])
    gate.set()
    for f in fut_gate + hot_f + cold_f:
        assert f.result() in ("gate", "h", "c")
    pool.close()
    body = [t for t in log if t != "gate"]
    # deterministic DRR pattern: h h h c, repeated
    assert body[:16] == ["h", "h", "h", "c"] * 4
    assert body.count("h") == 24 and body.count("c") == 8


def test_flood_cannot_starve_neighbour():
    """A cold unit enqueued behind a 40-unit flood is served at the very
    next round-robin visit, not after the flood drains."""
    pool = ThreadedDispatcher(max_workers=1)
    hot, cold = pool.handle(), pool.handle()
    log, lock, gate = [], threading.Lock(), threading.Event()
    blocker = pool.handle()
    gate_f = pool.enqueue(blocker, [_tagger("gate", log, lock, gate)])
    hot_f = pool.enqueue(hot, [_tagger("h", log, lock) for _ in range(40)])
    cold_f = pool.enqueue(cold, [_tagger("c", log, lock)])
    gate.set()
    for f in gate_f + hot_f + cold_f:
        f.result()
    pool.close()
    body = [t for t in log if t != "gate"]
    assert body.index("c") <= 2, body[:6]


def test_weight_validation():
    pool = ThreadedDispatcher(max_workers=1)
    with pytest.raises(ValueError):
        pool.handle(weight=0.0)
    with pytest.raises(ValueError):
        pool.handle(weight=-1.5)
    pool.close()


def test_exceptions_propagate_per_unit():
    """A raising thunk fails only its own future/run_all — batch-mates
    complete."""
    pool = ThreadedDispatcher(max_workers=2)
    h = pool.handle()

    def boom():
        raise ValueError("unit failure")

    futs = pool.enqueue(h, [lambda: 1, boom, lambda: 3])
    assert futs[0].result() == 1
    with pytest.raises(ValueError, match="unit failure"):
        futs[1].result()
    assert futs[2].result() == 3
    with pytest.raises(ValueError, match="unit failure"):
        h.run_all([lambda: 1, boom])
    pool.close()


def test_close_drains_queued_units():
    """close() must complete every queued unit inline — a future handed
    out is never abandoned."""
    pool = ThreadedDispatcher(max_workers=1)
    h = pool.handle()
    gate = threading.Event()

    def slow():
        gate.wait(5.0)
        return "slow"

    slow_f = pool.enqueue(h, [slow])
    queued = pool.enqueue(h, [lambda i=i: i for i in range(5)])
    gate.set()
    pool.close()
    assert slow_f[0].result(timeout=5) == "slow"
    assert [f.result(timeout=5) for f in queued] == [0, 1, 2, 3, 4]
    # post-close handles degrade to serial execution, still correct
    assert h.run_all([lambda: 7, lambda: 8]) == [7, 8]


def test_run_all_surface_unchanged():
    """The single-tenant run_all path (no handle) is order-preserving."""
    pool = ThreadedDispatcher(max_workers=4)
    assert pool.run_all([lambda i=i: i * i for i in range(8)]) == \
        [i * i for i in range(8)]
    pool.close()
