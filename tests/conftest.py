import os

# Smoke tests / benches must see exactly ONE device (the dry-run sets its own
# 512-device flag as the very first thing in launch/dryrun.py, never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402

# The property tests use hypothesis when available; offline containers fall
# back to the seeded-parametrize shim (tests/_hypothesis_compat.py).
try:
    import hypothesis  # noqa: F401
    _HYP_SHIM = False
except ImportError:
    import _hypothesis_compat
    _hypothesis_compat.install()
    _HYP_SHIM = True


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running MapReduce/straggler tests; "
        "deselect with -m 'not slow' for the fast lane")


def pytest_generate_tests(metafunc):
    if _HYP_SHIM:
        _hypothesis_compat.generate(metafunc)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
