import os

# Smoke tests / benches must see exactly ONE device (the dry-run sets its own
# 512-device flag as the very first thing in launch/dryrun.py, never here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
