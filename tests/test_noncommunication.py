"""Structural verification of the paper's non-communicating-clouds model.

The c clouds must never exchange data (§2, footnote 3). In this framework the
clouds are axis 0 of every share tensor; we verify the property at the HLO
level: shard the cloud axis across devices and assert the compiled cloud-side
query program contains ZERO collective ops. (User-side interpolation DOES
cross the axis — it runs at the trusted user, not in the clouds.)

Runs in a subprocess so the 8-device host-platform flag never leaks into the
main test process.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    import repro  # x64
    from repro.core import automata, field
    from repro.core.shamir import Shares

    mesh = jax.make_mesh((8,), ("clouds",))
    C, N, W, A = 8, 64, 6, 16

    def cloud_side_count(rel_vals, pat_vals):
        # the MAP phase of Algorithm 2: everything the CLOUDS compute
        col = Shares(rel_vals, 1)
        pat = Shares(pat_vals, 1)
        return automata.count_column(col, pat).values

    def cloud_side_fetch(matrix_vals, rel_flat):
        return field.matmul(matrix_vals, rel_flat)

    sh = NamedSharding(mesh, P("clouds"))
    rel = jax.ShapeDtypeStruct((C, N, W, A), jnp.uint32, sharding=sh)
    pat = jax.ShapeDtypeStruct((C, W, A), jnp.uint32, sharding=sh)
    hlo1 = jax.jit(cloud_side_count).lower(rel, pat).compile().as_text()

    mat = jax.ShapeDtypeStruct((C, 4, N), jnp.uint32, sharding=sh)
    rf = jax.ShapeDtypeStruct((C, N, 3 * W * A), jnp.uint32, sharding=sh)
    hlo2 = jax.jit(cloud_side_fetch).lower(mat, rf).compile().as_text()

    def n_collectives(hlo):
        kinds = ("all-gather", "all-reduce", "reduce-scatter",
                 "all-to-all", "collective-permute")
        return sum(hlo.count(" " + k) for k in kinds)

    print(json.dumps({"count_q": n_collectives(hlo1),
                      "fetch_q": n_collectives(hlo2)}))
""")


def test_cloud_side_programs_have_no_cross_cloud_collectives():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["count_q"] == 0, "count query crossed the cloud axis!"
    assert res["fetch_q"] == 0, "fetch crossed the cloud axis!"
