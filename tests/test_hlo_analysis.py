"""Unit tests for the roofline-term extraction (HLO collective parsing)."""
import numpy as np

from repro.launch import hlo_analysis as H

SAMPLE_HLO = """
HloModule jit_step

fused_computation {
  ...
}

ENTRY main {
  %p0 = bf16[16,4096,512]{2,1,0} parameter(0)
  %ag = bf16[16,4096,8192]{2,1,0} all-gather(%p0), dimensions={2}
  %ar = f32[1024,1024]{1,0} all-reduce(%x), to_apply=%add
  %rs = f32[64,1024]{1,0} reduce-scatter(%y), dimensions={0}
  %cp = u32[128]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = bf16[8,256,256]{2,1,0} all-to-all(%w), dimensions={0}
  %ars = f32[2,2]{1,0} all-reduce-start(%q), to_apply=%add
  %not-a-collective = f32[4,4]{1,0} add(%a, %b)
}
"""


def test_collective_bytes_parses_all_kinds():
    got = H.collective_bytes(SAMPLE_HLO)
    assert got["all-gather"] == 16 * 4096 * 8192 * 2
    assert got["all-reduce"] == 1024 * 1024 * 4 + 2 * 2 * 4  # incl. -start
    assert got["reduce-scatter"] == 64 * 1024 * 4
    assert got["collective-permute"] == 128 * 4
    assert got["all-to-all"] == 8 * 256 * 256 * 2
    assert got["count"] == 6
    assert got["total"] == sum(got[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_roofline_terms_and_bottleneck():
    # quantities are PER-DEVICE (the HLO is the SPMD-partitioned module)
    r = H.Roofline(flops=197e12, bytes_accessed=819e9,
                   collective_bytes=50e9 * 2, n_chips=256,
                   collective_detail={})
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 1.0) < 1e-9
    assert abs(r.t_collective - 2.0) < 1e-9
    assert r.bottleneck == "collective"


def test_shape_bytes_tuple_shapes():
    assert H._shape_bytes("(f32[8,8], bf16[4])") == 8 * 8 * 4 + 4 * 2
    assert H._shape_bytes("pred[100]") == 100
    assert H._shape_bytes("u32[]") == 4  # scalar: empty dims -> 1 elem
