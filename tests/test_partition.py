"""Unit tests for the shared split helper (core.partition)."""
import numpy as np
import pytest

from repro.core.partition import split_bounds, split_sizes


def _reference_linspace(lo, hi, k):
    edges = np.linspace(lo, hi, k + 1).astype(int)
    return [(int(edges[i]), int(edges[i + 1])) for i in range(k)
            if edges[i] < edges[i + 1]]


@pytest.mark.parametrize("lo,hi,k", [
    (0, 10, 3), (0, 10, 10), (0, 10, 1), (0, 7, 4), (5, 9, 2),
    (0, 1, 4), (3, 100, 7), (0, 64, 8),
])
def test_covers_range_exactly(lo, hi, k):
    bounds = split_bounds(lo, hi, k)
    assert bounds[0][0] == lo and bounds[-1][1] == hi
    for (a1, b1), (a2, _) in zip(bounds, bounds[1:]):
        assert b1 == a2            # contiguous, no gaps or overlap
    assert all(a < b for a, b in bounds)


@pytest.mark.parametrize("lo,hi,k", [(0, 10, 3), (2, 9, 5), (0, 100, 16)])
def test_matches_historic_linspace_behavior(lo, hi, k):
    """The three deduplicated call sites all used linspace truncation; the
    shared helper must reproduce it bit-for-bit so splits/blocks are stable
    across the refactor."""
    k_eff = max(1, min(k, hi - lo))
    assert split_bounds(lo, hi, k) == _reference_linspace(lo, hi, k_eff)


def test_at_most_k_and_never_empty():
    assert len(split_bounds(0, 3, 10)) == 3          # clamps to range size
    assert len(split_bounds(0, 1000, 4)) == 4
    assert split_bounds(0, 0, 4) == []
    assert split_bounds(5, 5, 1) == []
    assert split_bounds(7, 3, 2) == []               # inverted -> empty


def test_split_sizes_sum_to_total():
    for total, k in [(10, 3), (64, 8), (7, 7), (1, 5)]:
        sizes = split_sizes(total, k)
        assert sum(sizes) == total
        assert all(s > 0 for s in sizes)


def test_balanced_within_one():
    sizes = split_sizes(100, 7)
    assert max(sizes) - min(sizes) <= 1
