"""Cross-relation fetch fusion and shard-aligned tree gathers.

Anchor properties of the fused dataplane path:

* **Transcript identity** — ``QueryClient.run_batch_multi`` over several
  relations returns rows, counts, addresses AND per-query ``CostLedger``s
  bit-identical to back-to-back solo ``run_batch`` calls, for
  S ∈ {1, 2, 4} and across Serial / Threaded(shared pool) / Mesh
  placement. Fusion co-schedules the per-relation fetch ``ss_matmul``
  dispatches as one wave; it never mixes batches, keys, rounds or
  ledgers.
* **Shard-aligned tree Q&A** — ``Select(strategy="tree")`` executes its
  block gathers per shard (each gather stays inside one shard's tuple
  range) while the PUBLIC block partition — and therefore the priced and
  measured ledger — never moves with S.
* **Pricing** — ``QueryClient.explain_multi`` equals the measured fused
  ledgers exactly when the cardinality hints are exact, and prices ONE
  shared dispatch wave for the fused fetch.
"""
import jax
import pytest

from repro.api import (Count, Eq, MeshDispatcher, QueryClient, RangeCount,
                       Select, Between)
from repro.core import Codec, outsource
from repro.core.dataplane import ThreadedDispatcher
from repro.launch.mesh import make_host_mesh

CODEC = Codec(word_length=6)


@pytest.fixture(scope="module")
def alpha_db():
    rows = [[f"id{i}", f"nm{i % 5}", str(500 + 137 * i)] for i in range(16)]
    db = outsource(jax.random.PRNGKey(31), rows,
                   column_names=["Id", "Name", "Val"], codec=CODEC,
                   n_shares=20, degree=1, numeric_columns={2: 14})
    return rows, db


@pytest.fixture(scope="module")
def beta_db():
    rows = [[f"o{i}", f"c{i % 3}", "open" if i % 2 else "done"]
            for i in range(12)]
    db = outsource(jax.random.PRNGKey(32), rows,
                   column_names=["OrderId", "Customer", "Status"],
                   codec=CODEC, n_shares=20, degree=1)
    return rows, db


ALPHA_PLANS = [Select(Eq("Name", "nm2"), strategy="one_round",
                      expected_matches=3),
               Count(Eq("Name", "nm1")),
               Select(Eq("Name", "nm3"), strategy="tree",
                      expected_matches=3),
               RangeCount(Between("Val", 600, 1500), reduce_every=2)]
BETA_PLANS = [Select(Eq("Status", "open"), strategy="one_round",
                     expected_matches=6),
              Select(Eq("Customer", "c1"), strategy="tree",
                     expected_matches=4),
              Count(Eq("Status", "done"))]


def _results_equal(a, b):
    assert a.strategy == b.strategy
    assert a.rows == b.rows
    assert a.addresses == b.addresses
    assert a.count == b.count
    assert a.ledger == b.ledger


def _solo(db, key, plans, shards, dispatcher=None):
    client = QueryClient(db, key=key)
    client.attach(shards=shards, dispatcher=dispatcher)
    return client.run_batch(plans)


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_run_batch_multi_matches_solo_serial(alpha_db, beta_db, shards):
    """Fused multi-batch == back-to-back solo batches, serial plane."""
    _, db_a = alpha_db
    _, db_b = beta_db
    ref_a = _solo(db_a, 51, ALPHA_PLANS, shards)
    ref_b = _solo(db_b, 52, BETA_PLANS, shards)

    client = QueryClient()
    client.attach(db_a, name="alpha", shards=shards, key=51)
    client.attach(db_b, name="beta", shards=shards, key=52)
    got_a, got_b = client.run_batch_multi(
        [("alpha", ALPHA_PLANS), ("beta", BETA_PLANS)])
    for r, g in zip(ref_a, got_a):
        _results_equal(r, g)
    for r, g in zip(ref_b, got_b):
        _results_equal(r, g)


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_run_batch_multi_fuses_on_shared_pool(alpha_db, beta_db, shards):
    """On a shared ThreadedDispatcher pool the cross-relation fetch runs
    as ONE fused wave (fused_steps ticks on both planes) and stays
    bit-identical; dispatch fan-out is unchanged (steps x shards)."""
    _, db_a = alpha_db
    _, db_b = beta_db
    ref_a = _solo(db_a, 51, ALPHA_PLANS, shards)
    ref_b = _solo(db_b, 52, BETA_PLANS, shards)

    pool = ThreadedDispatcher(max_workers=4)
    client = QueryClient()
    pa = client.attach(db_a, name="alpha", shards=shards, key=51,
                       dispatcher=pool.handle(weight=2.0))
    pb = client.attach(db_b, name="beta", shards=shards, key=52,
                       dispatcher=pool.handle(weight=1.0))
    got_a, got_b = client.run_batch_multi(
        [("alpha", ALPHA_PLANS), ("beta", BETA_PLANS)])
    pool.close()
    for r, g in zip(ref_a, got_a):
        _results_equal(r, g)
    for r, g in zip(ref_b, got_b):
        _results_equal(r, g)
    # both batches carry fetch traffic, so exactly one fused wave ran
    assert pa.stats.fused_steps == 1
    assert pb.stats.fused_steps == 1
    assert pa.stats.dispatches == pa.stats.steps * shards
    assert pb.stats.dispatches == pb.stats.steps * shards


@pytest.mark.parametrize("shards", [1, 2])
def test_run_batch_multi_mesh_parity(alpha_db, beta_db, shards):
    """A device-resident mesh plane joins a multi-batch without fusion
    (its transfer guards demand its own execution path) and still
    matches the solo transcript bit for bit."""
    _, db_a = alpha_db
    _, db_b = beta_db
    ref_a = _solo(db_a, 51, ALPHA_PLANS, shards)
    ref_b = _solo(db_b, 52, BETA_PLANS, shards)

    client = QueryClient()
    client.attach(db_a, name="alpha", shards=shards, key=51,
                  dispatcher=MeshDispatcher(make_host_mesh(),
                                            strict_transfers=True))
    client.attach(db_b, name="beta", shards=shards, key=52)
    got_a, got_b = client.run_batch_multi(
        [("alpha", ALPHA_PLANS), ("beta", BETA_PLANS)])
    for r, g in zip(ref_a, got_a):
        _results_equal(r, g)
    for r, g in zip(ref_b, got_b):
        _results_equal(r, g)


def test_run_batch_multi_single_and_empty_parts(alpha_db):
    """Degenerate shapes: a one-relation multi equals run_batch; an
    empty plan list contributes an empty result list."""
    _, db_a = alpha_db
    ref = _solo(db_a, 51, ALPHA_PLANS, 2)
    client = QueryClient()
    client.attach(db_a, name="alpha", shards=2, key=51)
    (got,) = client.run_batch_multi([("alpha", ALPHA_PLANS)])
    for r, g in zip(ref, got):
        _results_equal(r, g)
    got_a, got_empty = client.run_batch_multi(
        [("alpha", ALPHA_PLANS), ("alpha", [])])
    assert got_empty == []
    for r, g in zip(_solo(db_a, 51, ALPHA_PLANS, 2), got_a):
        _results_equal(r, g)


@pytest.mark.parametrize("shards", [1, 2, 4])
def test_tree_shard_aligned_bit_identity(alpha_db, shards):
    """The tree strategy's Q&A + address gathers execute per shard; the
    public block partition (and so the ledger) must not move with S."""
    _, db = alpha_db
    plan = [Select(Eq("Name", "nm3"), strategy="tree",
                   expected_matches=3)]
    base = _solo(db, 9, plan, 1)[0]
    sharded = _solo(db, 9, plan, shards)[0]
    _results_equal(base, sharded)
    pooled = _solo(db, 9, plan, shards,
                   dispatcher=ThreadedDispatcher(max_workers=shards))[0]
    _results_equal(base, pooled)


def test_explain_multi_exact_on_fused_path(alpha_db, beta_db):
    """With exact cardinality hints, explain_multi == the measured fused
    ledgers: bits sum exactly, dispatch fan-out is unchanged by fusion,
    >= 2 fetch-bearing parts price ONE shared wave, and rounds follow the
    co-scheduling semantics (max over parts — waves overlap, they don't
    serialize). The plan families here (one_round select / count) are the
    ones the planner prices exactly; tree openings depend on how matches
    cluster in blocks, which ``explain`` only bounds.
    """
    _, db_a = alpha_db
    _, db_b = beta_db
    plans_a = [Select(Eq("Name", "nm2"), strategy="one_round",
                      expected_matches=3),
               Count(Eq("Name", "nm1"))]
    plans_b = [Select(Eq("Status", "open"), strategy="one_round",
                      expected_matches=6),
               Count(Eq("Status", "done"))]
    pool = ThreadedDispatcher(max_workers=4)
    client = QueryClient()
    pa = client.attach(db_a, name="alpha", shards=2, key=51,
                       dispatcher=pool.handle())
    pb = client.attach(db_b, name="beta", shards=2, key=52,
                       dispatcher=pool.handle())
    exp = client.explain_multi([("alpha", plans_a), ("beta", plans_b)])
    got_a, got_b = client.run_batch_multi(
        [("alpha", plans_a), ("beta", plans_b)])
    pool.close()
    measured_bits = sum(r.ledger.communication_bits
                        for r in got_a + got_b)
    assert exp.bits == measured_bits
    assert exp.rounds == max(p.rounds for p in exp.parts)
    assert exp.bits == sum(p.bits for p in exp.parts)
    assert exp.fetch_parts == 2
    assert exp.fetch_waves == 1
    assert exp.dispatches == pa.stats.dispatches + pb.stats.dispatches
    assert len(exp.parts) == 2
