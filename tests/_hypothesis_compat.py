"""Offline fallback for ``hypothesis``: seeded deterministic example cases.

The container cannot ``pip install`` anything, so ``hypothesis`` may be
absent. ``install()`` registers a minimal stand-in module under the
``hypothesis`` name in ``sys.modules`` implementing exactly the API surface
this test-suite uses:

  * ``strategies.integers / sampled_from / lists`` (plus ``.filter``/``.map``)
  * ``@given(...)``  — tags the test with its strategies
  * ``@settings(max_examples=..., deadline=...)`` — tags the example budget

The tags are expanded at collection time by the ``pytest_generate_tests``
hook in ``conftest.py`` (via :func:`generate`), which draws ``max_examples``
seeded examples per test and hands them to ``metafunc.parametrize`` — so the
property tests still run against a deterministic spread of inputs and report
per-example, just without shrinking. When the real hypothesis is installed,
none of this activates.
"""
from __future__ import annotations

import inspect
import random
import sys
import types
import zlib
from typing import Any, Callable, List, Sequence

DEFAULT_EXAMPLES = 10
_MAX_FILTER_TRIES = 1000


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------

class Strategy:
    """Base: a seeded example generator with hypothesis' combinators."""

    def example(self, rng: random.Random) -> Any:
        raise NotImplementedError

    def filter(self, pred: Callable[[Any], bool]) -> "Strategy":
        return _Filtered(self, pred)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return _Mapped(self, fn)


class _Filtered(Strategy):
    def __init__(self, base: Strategy, pred: Callable[[Any], bool]):
        self._base, self._pred = base, pred

    def example(self, rng: random.Random) -> Any:
        for _ in range(_MAX_FILTER_TRIES):
            x = self._base.example(rng)
            if self._pred(x):
                return x
        raise ValueError("filter predicate rejected every drawn example")


class _Mapped(Strategy):
    def __init__(self, base: Strategy, fn: Callable[[Any], Any]):
        self._base, self._fn = base, fn

    def example(self, rng: random.Random) -> Any:
        return self._fn(self._base.example(rng))


class _Integers(Strategy):
    def __init__(self, min_value=None, max_value=None):
        self._lo = -(2 ** 16) if min_value is None else min_value
        self._hi = 2 ** 16 if max_value is None else max_value

    def example(self, rng: random.Random) -> int:
        return rng.randint(self._lo, self._hi)


class _SampledFrom(Strategy):
    def __init__(self, elements: Sequence[Any]):
        self._elements = list(elements)

    def example(self, rng: random.Random) -> Any:
        return rng.choice(self._elements)


class _Lists(Strategy):
    def __init__(self, elements: Strategy, min_size: int = 0,
                 max_size=None):
        self._elem = elements
        self._lo = min_size
        self._hi = max_size if max_size is not None else min_size + 8

    def example(self, rng: random.Random) -> List[Any]:
        size = rng.randint(self._lo, self._hi)
        return [self._elem.example(rng) for _ in range(size)]


def integers(min_value=None, max_value=None) -> Strategy:
    return _Integers(min_value, max_value)


def sampled_from(elements: Sequence[Any]) -> Strategy:
    return _SampledFrom(elements)


def lists(elements: Strategy, *, min_size: int = 0, max_size=None) -> Strategy:
    return _Lists(elements, min_size, max_size)


# ---------------------------------------------------------------------------
# decorators
# ---------------------------------------------------------------------------

def given(*strats: Strategy, **kwstrats: Strategy):
    def deco(fn):
        fn._hyp_given = (strats, kwstrats)
        return fn
    return deco


def settings(max_examples: int = DEFAULT_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._hyp_max_examples = max_examples
        return fn
    return deco


# ---------------------------------------------------------------------------
# pytest integration
# ---------------------------------------------------------------------------

def generate(metafunc) -> None:
    """Expand an ``@given``-tagged test into seeded parametrize cases.

    Called from ``conftest.pytest_generate_tests`` (shim-active runs only).
    """
    fn = metafunc.function
    tag = getattr(fn, "_hyp_given", None)
    if tag is None:
        return
    strats, kwstrats = tag
    n = getattr(fn, "_hyp_max_examples", DEFAULT_EXAMPLES)
    # positional strategies fill the test's TRAILING parameters (hypothesis
    # fills from the right, leaving leading params for pytest fixtures)
    sig_names = [p.name for p in
                 inspect.signature(fn).parameters.values()]
    free = [p for p in sig_names if p not in kwstrats]
    pos_names = free[len(free) - len(strats):] if strats else []
    argnames = pos_names + list(kwstrats)
    pairs = list(zip(pos_names, strats)) + list(kwstrats.items())
    # stable per-test seed -> identical cases on every run/machine
    rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
    cases = []
    for _ in range(n):
        drawn = {name: s.example(rng) for name, s in pairs}
        cases.append(tuple(drawn[a] for a in argnames))
    if len(argnames) == 1:
        metafunc.parametrize(argnames[0], [c[0] for c in cases])
    else:
        metafunc.parametrize(",".join(argnames), cases)


def install() -> None:
    """Register the stand-in ``hypothesis`` module tree in ``sys.modules``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.sampled_from = sampled_from
    st_mod.lists = lists
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
