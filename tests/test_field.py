"""Unit + property tests for F_p (Mersenne-31) arithmetic."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import field

P = int(field.P)
elems = st.integers(min_value=0, max_value=P - 1)


def as_f(*xs):
    return [np.asarray(np.uint32(x)) for x in xs]


@settings(max_examples=200, deadline=None)
@given(elems, elems)
def test_add_matches_python(a, b):
    fa, fb = as_f(a, b)
    assert int(field.add(fa, fb)) == (a + b) % P


@settings(max_examples=200, deadline=None)
@given(elems, elems)
def test_mul_matches_python(a, b):
    fa, fb = as_f(a, b)
    assert int(field.mul(fa, fb)) == (a * b) % P


@settings(max_examples=200, deadline=None)
@given(elems, elems)
def test_sub_matches_python(a, b):
    fa, fb = as_f(a, b)
    assert int(field.sub(fa, fb)) == (a - b) % P


@settings(max_examples=50, deadline=None)
@given(elems.filter(lambda x: x != 0))
def test_inverse(a):
    fa, = as_f(a)
    assert int(field.mul(fa, field.inv(fa))) == 1


@settings(max_examples=50, deadline=None)
@given(elems, elems, elems)
def test_distributive(a, b, c):
    fa, fb, fc = as_f(a, b, c)
    lhs = field.mul(fa, field.add(fb, fc))
    rhs = field.add(field.mul(fa, fb), field.mul(fa, fc))
    assert int(lhs) == int(rhs)


def test_edge_values():
    # p-1 squared, 0, 1 — the overflow-critical corners
    for a in [0, 1, P - 1, P - 2, 2**30]:
        for b in [0, 1, P - 1, P - 2, 2**30]:
            fa, fb = as_f(a, b)
            assert int(field.mul(fa, fb)) == (a * b) % P
            assert int(field.add(fa, fb)) == (a + b) % P


def test_sum_long_axis():
    # accumulate 1e6 near-maximal values: uint64 accumulator must not wrap
    n = 1_000_000
    x = np.full((n,), P - 1, dtype=np.uint32)
    assert int(field.sum_(jax.numpy.asarray(x))) == ((P - 1) * n) % P


def test_matmul_matches_numpy_bigint():
    rng = np.random.default_rng(0)
    a = rng.integers(0, P, size=(7, 11), dtype=np.uint64)
    b = rng.integers(0, P, size=(11, 5), dtype=np.uint64)
    want = (a.astype(object) @ b.astype(object)) % P
    got = np.asarray(field.matmul(a.astype(np.uint32), b.astype(np.uint32)))
    assert np.array_equal(got.astype(object), want)


def test_uniform_in_range():
    x = np.asarray(field.uniform(jax.random.PRNGKey(0), (4096,)))
    assert x.max() < P
    # crude uniformity: mean within 2% of p/2
    assert abs(float(x.mean()) / (P / 2) - 1.0) < 0.02
