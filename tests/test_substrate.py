"""Substrate tests: checkpointing (atomicity, corruption fallback, elastic
restore), fault-tolerant MapReduce runtime (failures, stragglers,
speculation), gradient compression, data pipeline determinism."""
import json
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import Prefetcher, TokenStream, synthetic_relation
from repro.runtime import MapReduceRunner, WorkerPool
from repro.train.compress import (compress_grads, decompress_grads,
                                  error_feedback_update)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _tree():
    return {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": jnp.ones((5,), jnp.bfloat16),
            "nested": {"u": jnp.zeros((2, 2), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 7, tree)
    step, restored = restore_checkpoint(str(tmp_path), tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))
        assert a.dtype == b.dtype  # bf16 survives the npy round-trip


def test_checkpoint_corruption_falls_back(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 2, tree)
    # corrupt the newest step's first leaf
    p = os.path.join(str(tmp_path), "step_2", "0.npy")
    with open(p, "r+b") as f:
        f.seek(80)
        f.write(b"\xff" * 16)
    step, _ = restore_checkpoint(str(tmp_path), tree)
    assert step == 1  # fell back to the newest VALID checkpoint


def test_checkpoint_torn_write_invisible(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), 1, tree)
    # simulate a crash mid-write: a .tmp dir that never got renamed
    os.makedirs(os.path.join(str(tmp_path), "step_9.tmp"))
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last_n=2, async_save=True)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(str(tmp_path))
                   if d.startswith("step_") and not d.endswith(".tmp"))
    assert steps == [3, 4]


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore onto a different mesh (1x1 here, but through the sharding
    path) — the elastic-restart mechanism."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 5, tree)
    shardings = {"w": NamedSharding(mesh, P(None, "model"))}
    step, restored = restore_checkpoint(str(tmp_path), tree,
                                        shardings=shardings)
    assert step == 5
    assert restored["w"].sharding == shardings["w"]


# ---------------------------------------------------------------------------
# MapReduce runtime
# ---------------------------------------------------------------------------

def test_mapreduce_happy_path():
    pool = WorkerPool(4)
    runner = MapReduceRunner(pool, lease_s=5.0)
    out = runner.run(lambda x: x * x, list(range(20)), sum)
    assert out == sum(i * i for i in range(20))
    assert runner.reexecutions == 0


@pytest.mark.slow
def test_mapreduce_reexecutes_failed_tasks():
    pool = WorkerPool(4, fail_prob=0.4, seed=1)
    runner = MapReduceRunner(pool, lease_s=0.3, max_attempts=50)
    out = runner.run(lambda x: x + 1, list(range(12)), sum)
    assert out == sum(range(1, 13))
    assert runner.reexecutions > 0  # failures happened and were recovered


@pytest.mark.slow
def test_mapreduce_dead_worker_recovery():
    pool = WorkerPool(3, dead_workers={1}, seed=2)
    runner = MapReduceRunner(pool, lease_s=0.3, max_attempts=20)
    out = runner.run(lambda x: 2 * x, list(range(9)), sum)
    assert out == sum(2 * i for i in range(9))
    assert runner.worker_deaths > 0


@pytest.mark.slow
def test_mapreduce_speculative_backup_beats_straggler():
    # worker 0 is 10x slower than the lease; the backup copy must win
    pool = WorkerPool(4, slow_workers={0: 3.0})
    runner = MapReduceRunner(pool, lease_s=0.5, spec_threshold=0.5,
                             max_attempts=10)
    t0 = time.time()
    out = runner.run(lambda x: x, list(range(8)), sum)
    assert out == sum(range(8))
    assert time.time() - t0 < 3.0  # did not wait for the straggler
    assert runner.speculative_launched + runner.reexecutions > 0


@pytest.mark.slow
def test_mapreduce_drives_secret_shared_count():
    """The paper's count query as an actual MapReduce job over input splits
    with injected failures: result must equal the plaintext count."""
    from repro.core import outsource, Codec, shamir, automata, encoding
    codec = Codec(word_length=6)
    rows = [[f"id{i}", "John" if i % 3 == 0 else "Eve"] for i in range(24)]
    db = outsource(jax.random.PRNGKey(0), rows, codec=codec, n_shares=16)
    p_sh = encoding.share_pattern(jax.random.PRNGKey(1), codec, "John",
                                  n_shares=16, degree=1)
    splits = [(s, min(s + 6, 24)) for s in range(0, 24, 6)]

    def map_fn(split):
        lo, hi = split
        col = shamir.Shares(db.relation.values[:, lo:hi, 1],
                            db.relation.degree)
        return np.asarray(automata.count_column(col, p_sh).values)

    def reduce_fn(partials):
        from repro.core import field
        total = partials[0]
        for p in partials[1:]:
            total = np.asarray(field.add(jnp.asarray(total),
                                         jnp.asarray(p)))
        deg = (db.relation.degree + p_sh.degree) * codec.word_length
        return int(np.asarray(shamir.interpolate(
            shamir.Shares(jnp.asarray(total), deg))))

    pool = WorkerPool(3, fail_prob=0.3, seed=3)
    runner = MapReduceRunner(pool, lease_s=1.0, max_attempts=30)
    got = runner.run(map_fn, splits, reduce_fn)
    assert got == 8  # 24/3 tuples have John
    assert runner.reexecutions >= 0


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    g = {"a": jnp.asarray(rng.normal(size=(300,)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(17, 5)), jnp.float32)}
    out = decompress_grads(compress_grads(g))
    for x, y in zip(jax.tree.leaves(g), jax.tree.leaves(out)):
        err = np.abs(np.asarray(x) - np.asarray(y)).max()
        scale = np.abs(np.asarray(x)).max()
        assert err <= scale / 127 + 1e-6


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(1000,)) * 1e-3, jnp.float32)}
    res = None
    acc_plain = np.zeros(1000)
    acc_ef = np.zeros(1000)
    for _ in range(20):
        deq, res = error_feedback_update(g, res)
        acc_ef += np.asarray(deq["w"])
        acc_plain += np.asarray(
            decompress_grads(compress_grads(g))["w"])
    true = 20 * np.asarray(g["w"])
    assert (np.abs(acc_ef - true).mean()
            <= np.abs(acc_plain - true).mean() + 1e-7)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_tokenstream_deterministic_and_restartable():
    s1 = TokenStream(1000, 4, 16, seed=7)
    s2 = TokenStream(1000, 4, 16, seed=7)
    b5a = s1.batch_at(5)
    b5b = s2.batch_at(5)   # fresh object, same index -> same batch
    assert np.array_equal(b5a["tokens"], b5b["tokens"])
    assert np.array_equal(b5a["labels"], b5b["labels"])
    assert not np.array_equal(s1.batch_at(6)["tokens"], b5a["tokens"])


def test_synthetic_relation_skew():
    rows = synthetic_relation(200, seed=0, skew=0.5)
    johns = sum(1 for r in rows if r[1] == "John")
    assert johns > 60  # skewed predicate has many occurrences


def test_prefetcher_yields_in_order():
    stream = TokenStream(100, 2, 8, seed=0)
    it = (stream.batch_at(i) for i in range(5))
    pf = Prefetcher(it, depth=2)
    got = [next(pf) for _ in range(5)]
    for i, b in enumerate(got):
        assert np.array_equal(b["tokens"], stream.batch_at(i)["tokens"])
    pf.close()
