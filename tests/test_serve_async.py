"""Async QueryServer: deadline-batched scheduling over the shared relation.

The scheduler thread parks submissions up to ``max_wait_ms`` to fill
``max_batch`` and closes each batch by *fill* or by *deadline* — both paths
must serve correct results, isolate faulting plans, and keep ``ServeStats``
monotone under concurrent submitters.
"""
import threading
import time

import jax
import pytest

from repro.api import Count, Eq, Select
from repro.core import Codec, outsource
from repro.core.queries import CardinalityError
from repro.launch.serve import (QueryRequest, QueryServer, ServeStats,
                                ServerStopped)

CODEC = Codec(word_length=8)
COLUMNS = ["EmployeeId", "FirstName", "LastName", "Salary", "Department"]
EMPLOYEE = [
    ["E101", "Adam", "Smith", "1000", "Sale"],
    ["E102", "John", "Taylor", "2000", "Design"],
    ["E103", "Eve", "Smith", "500", "Sale"],
    ["E104", "John", "Williams", "5000", "Sale"],
]


@pytest.fixture(scope="module")
def employee_db():
    return outsource(jax.random.PRNGKey(7), EMPLOYEE, column_names=COLUMNS,
                     codec=CODEC, n_shares=20, degree=1,
                     numeric_columns={3: 14})


def test_deadline_closes_partial_batch(employee_db):
    """max_batch is far above the traffic: the batch must close by the
    oldest submission's deadline, not wait for fill."""
    with QueryServer(employee_db, key=11, max_batch=64,
                     max_wait_ms=25) as server:
        reqs = [server.submit(QueryRequest(Count(Eq("FirstName", "John"))))
                for _ in range(3)]
        for r in reqs:
            r.wait(timeout=30)
    assert [r.result.count for r in reqs] == [2, 2, 2]
    assert server.stats.closes.get("deadline", 0) >= 1
    assert server.stats.closes.get("full", 0) == 0
    assert all(r.queue_wait_s >= 0 for r in reqs)
    assert len(server.stats.queue_waits_s) == 3
    assert sum(server.stats.batch_fill.values()) == server.stats.batches


def test_full_batch_closes_before_deadline(employee_db):
    """With max_batch=2 and a long deadline, fill must close batches."""
    with QueryServer(employee_db, key=12, max_batch=2,
                     max_wait_ms=10_000) as server:
        reqs = [server.submit(QueryRequest(Count(Eq("FirstName", "Eve"))))
                for _ in range(4)]
        for r in reqs:
            r.wait(timeout=30)
    assert all(r.result.count == 1 for r in reqs)
    assert server.stats.closes.get("full", 0) >= 2
    assert server.stats.batch_fill.get(2, 0) >= 2


def test_async_results_match_sync_client(employee_db):
    """The scheduler thread serves the same answers a synchronous client
    derives for the same plans (keys assign in pop order, so compare
    values, not transcripts)."""
    plans = [Count(Eq("FirstName", "John")),
             Select(Eq("Department", "Sale"), strategy="tree"),
             Count(Eq("Department", "Design"))]
    with QueryServer(employee_db, key=13, max_batch=8,
                     max_wait_ms=15) as server:
        reqs = [server.submit(QueryRequest(p)) for p in plans]
        for r in reqs:
            r.wait(timeout=30)
    assert reqs[0].result.count == 2
    assert len(reqs[1].result.rows) == 3
    assert reqs[2].result.count == 1


def test_async_soak_concurrent_submitters_stats_monotone(employee_db):
    """Soak: several submitter threads race the scheduler; served counts
    only grow, every request finishes exactly once, failures stay
    isolated to the bad plans."""
    server = QueryServer(employee_db, key=17, max_batch=4, max_wait_ms=5,
                         shards=2)
    server.start()
    good_per_thread, n_threads = 6, 3
    all_reqs = []
    lock = threading.Lock()

    def submitter(tid):
        for i in range(good_per_thread):
            plan = (Select(Eq("FirstName", "John"), strategy="one_tuple")
                    if (tid == 0 and i == 2)     # ℓ=2 -> CardinalityError
                    else Count(Eq("FirstName", "John")))
            r = server.submit(QueryRequest(plan))
            with lock:
                all_reqs.append(r)
            time.sleep(0.003)

    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(n_threads)]
    observed = []
    for t in threads:
        t.start()
    while any(t.is_alive() for t in threads):
        observed.append(server.stats.served)
        time.sleep(0.002)
    for t in threads:
        t.join()
    for r in all_reqs:
        r.wait(timeout=60)
    server.stop()
    observed.append(server.stats.served)

    total = good_per_thread * n_threads
    assert len(all_reqs) == total
    assert server.stats.served == total - 1
    assert server.stats.failed == 1
    # fault isolation: exactly the poisoned request errored
    errored = [r for r in all_reqs if r.error is not None]
    assert len(errored) == 1
    assert isinstance(errored[0].error, CardinalityError)
    good = [r for r in all_reqs if r.error is None]
    assert all(r.result.count == 2 for r in good)
    # stats monotonicity under concurrency
    assert all(a <= b for a, b in zip(observed, observed[1:]))
    assert server.stats.batches == sum(server.stats.batch_fill.values())
    d = server.stats.as_dict()
    assert d["served"] == total - 1 and d["closes"]


def test_stop_drains_queue(employee_db):
    server = QueryServer(employee_db, key=19, max_batch=4,
                         max_wait_ms=10_000)
    # no scheduler running: stop() must still drain pending work
    reqs = [server.submit(QueryRequest(Count(Eq("FirstName", "Eve"))))
            for _ in range(3)]
    server.stop()
    assert all(r.done() and r.result.count == 1 for r in reqs)
    assert server.stats.closes.get("drain", 0) >= 1


def test_stop_with_scheduler_serves_parked_requests(employee_db):
    """Regression: requests parked in the queue when stop() is called must
    be SERVED (a final drain batch closes inside the scheduler thread),
    not silently dropped."""
    server = QueryServer(employee_db, key=29, max_batch=64,
                         max_wait_ms=60_000)      # deadline far away
    server.start()
    reqs = [server.submit(QueryRequest(Count(Eq("FirstName", "John"))))
            for _ in range(3)]
    server.stop()                                # parked: deadline not due
    assert all(r.done() and r.result.count == 2 for r in reqs)
    assert server.stats.closes.get("drain", 0) >= 1


def test_stop_without_drain_raises_server_stopped(employee_db):
    """Regression: stop(drain=False) used to leave parked requests undone
    forever — wait() must raise ServerStopped, never hang."""
    server = QueryServer(employee_db, key=31, max_batch=64,
                         max_wait_ms=60_000)
    server.start()
    reqs = [server.submit(QueryRequest(Count(Eq("FirstName", "Eve"))))
            for _ in range(2)]
    server.stop(drain=False)
    for r in reqs:
        assert r.done()
        assert isinstance(r.error, ServerStopped)
        with pytest.raises(ServerStopped):
            r.wait(timeout=1)
    assert server.stats.failed == 2
    # a racer submitting AFTER stop(drain=False) fails fast too — it must
    # never be parked on a queue nothing will pump...
    late = server.submit(QueryRequest(Count(Eq("FirstName", "Eve"))))
    assert late.done()
    with pytest.raises(ServerStopped):
        late.wait(timeout=1)
    # ...and start() lifts the rejection (the server stays restartable):
    # the new submission parks normally (deadline is 60 s out) and the
    # draining stop() serves it
    server.start()
    again = server.submit(QueryRequest(Count(Eq("FirstName", "Eve"))))
    assert again.error is None and not again.done()
    server.stop()
    assert again.wait(timeout=1).result.count == 1
    # sync mode too: no scheduler thread, queued work still fails loudly
    server2 = QueryServer(employee_db, key=32)
    r2 = server2.submit(QueryRequest(Count(Eq("FirstName", "Eve"))))
    server2.stop(drain=False)
    with pytest.raises(ServerStopped):
        r2.wait(timeout=1)


def test_stats_snapshot_consistent_under_concurrent_pumps(employee_db):
    """Regression: snapshot()/quantiles used to read the histograms with
    no lock — a reader racing the scheduler could see a torn deque
    (RuntimeError mid-sort). Hammer both sides."""
    server = QueryServer(employee_db, key=33, max_batch=2, max_wait_ms=2)
    server.start()
    stop_reading = threading.Event()
    errors = []

    def reader():
        while not stop_reading.is_set():
            try:
                snap = server.stats.snapshot()
                assert snap["served"] >= 0
                server.stats.queue_wait_quantile(0.5)
                server.stats.latency_quantile(0.95)
            except Exception as e:  # noqa: BLE001 — the regression signal
                errors.append(e)
                return

    t = threading.Thread(target=reader)
    t.start()
    reqs = [server.submit(QueryRequest(Count(Eq("FirstName", "John"))))
            for _ in range(30)]
    for r in reqs:
        r.wait(timeout=60)
    stop_reading.set()
    t.join()
    server.stop()
    assert errors == []
    snap = server.stats.snapshot()
    assert snap["served"] == 30
    assert sum(snap["batch_fill"].values()) == snap["batches"]


def test_empty_and_unknown_histograms_quantile_zero():
    """queue_wait_quantile on an empty deque (or an unknown relation) is
    0.0, never an exception."""
    stats = ServeStats()
    assert stats.queue_wait_quantile(0.5) == 0.0
    assert stats.latency_quantile(0.95) == 0.0
    assert stats.queue_wait_quantile(0.5, relation="nope") == 0.0
    assert stats.latency_quantile(0.5, relation="nope") == 0.0
    snap = stats.snapshot()
    assert snap["p50_queue_wait_s"] == 0.0 and snap["relations"] == {}


def test_start_is_idempotent_and_restartable(employee_db):
    server = QueryServer(employee_db, key=21, max_batch=2, max_wait_ms=5)
    server.start()
    server.start()                               # no second thread
    r = server.submit(QueryRequest(Count(Eq("FirstName", "Adam"))))
    r.wait(timeout=30)
    server.stop()
    assert r.result.count == 1
    # restart after stop
    server.start()
    r2 = server.submit(QueryRequest(Count(Eq("FirstName", "Eve"))))
    r2.wait(timeout=30)
    server.stop()
    assert r2.result.count == 1


def test_wait_timeout_raises(employee_db):
    server = QueryServer(employee_db, key=23)    # scheduler not started
    r = server.submit(QueryRequest(Count(Eq("FirstName", "Eve"))))
    with pytest.raises(TimeoutError):
        r.wait(timeout=0.01)
    server.pump()
    assert r.wait(timeout=1).result.count == 1


def test_server_adopts_presharded_plane(employee_db):
    """A ShardedRelation handed to the server keeps its partitioning, with
    or without an explicit dispatcher; close() releases the owned pool."""
    from repro.api import ShardedRelation, ThreadedDispatcher
    plane = ShardedRelation(employee_db, shards=3)
    srv = QueryServer(plane, key=5, max_wait_ms=5,
                      dispatcher=ThreadedDispatcher(max_workers=3))
    assert srv.dataplane.n_shards == 3
    with srv:
        r = srv.submit(QueryRequest(Count(Eq("FirstName", "John"))))
        r.wait(timeout=30)
    assert r.result.count == 2

    srv2 = QueryServer(employee_db, key=5, max_wait_ms=5, shards=2)
    assert srv2.dataplane.n_shards == 2
    with srv2:
        r2 = srv2.submit(QueryRequest(Count(Eq("FirstName", "Eve"))))
        r2.wait(timeout=30)
    assert r2.result.count == 1
    # __exit__ -> close(): the owned pool is released; a late pump still
    # works (serial fallback)
    assert srv2._owned_dispatcher is not None
    r3 = srv2.submit(QueryRequest(Count(Eq("FirstName", "John"))))
    srv2.pump()
    assert r3.result.count == 2


def test_sync_pump_surface_unchanged(employee_db):
    """No scheduler thread: submit/pump/serve behave exactly as before."""
    server = QueryServer(employee_db, key=2, max_batch=8)
    assert server.pump() == []
    server.submit(QueryRequest(Count(Eq("FirstName", "Eve"))))
    server.submit(QueryRequest(Count(Eq("FirstName", "John"))))
    assert server.pending() == 2
    out = server.pump()
    assert server.pending() == 0
    assert [r.result.count for r in out] == [1, 2]
    assert all(r.done() for r in out)
