"""Pattern-predicate engine acceptance (LIKE / prefix / suffix / substring).

The matcher pipeline's §3.1 chain is generalized to four predicate shapes
that all ride the fused round engine: masked LIKE on the full-width chain,
prefix on a truncated k-chain, suffix/substring on the sliding-window
automata step. Pinned here:

* every kind opens bit-identically to a cleartext oracle (wildcards,
  repeated substrings, empty words included);
* a wildcard-free LIKE provably lowers to the exact-equality path (same
  planner estimate field for field, same transcript, one_tuple eligible);
* mixed B=16 batches (pattern + equality + range) equal sequential
  execution in rows AND ledgers, with pattern fetches riding the single
  cross-group fetch matmul;
* rows/ledgers are invariant across S ∈ {1, 2, 4} shards on the Serial,
  Threaded and Mesh dispatchers;
* ``explain()`` is exact against measured ledgers for pattern counts and
  one-round pattern selects;
* malformed/unknown predicates raise typed ``PlanNotSupported``;
* the PK/FK join match matrix opens identically under the chain and
  aggregate evaluations (the planner-priced ``match_method`` knob).
"""
import jax
import numpy as np
import pytest

from repro.api import (AUTO, Between, Contains, Count, DBStats, Eq, Join,
                       Like, MeshDispatcher, PlanNotSupported, Prefix,
                       QueryClient, RangeCount, Select, Suffix,
                       ThreadedDispatcher, choose_match_method,
                       estimate_count_cost, estimate_match_method_launches,
                       estimate_pattern_cost, estimate_select_cost)
from repro.api.client import _lower_match
from repro.core import Codec, encoding, outsource
from repro.launch.mesh import make_host_mesh

CODEC = Codec(word_length=8)
ROWS = [
    ["banana", "x", "1"], ["bandana", "y", "2"], ["an", "z", "3"],
    ["nab", "x", "4"], ["ban", "y", "5"], ["anna", "z", "6"],
    ["cab", "x", "7"], ["cabana", "y", "8"],
]
WORDS = [r[0] for r in ROWS]


@pytest.fixture(scope="module")
def db():
    return outsource(jax.random.PRNGKey(0), ROWS, codec=CODEC,
                     n_shares=20, numeric_columns={2: 8})


@pytest.fixture(scope="module")
def right_db():
    return outsource(jax.random.PRNGKey(9),
                     [["banana", "r1"], ["cab", "r2"], ["zzz", "r3"]],
                     codec=CODEC, n_shares=20)


def _like_oracle(word: str, pattern: str) -> bool:
    kind, body, wild = encoding.parse_like(pattern)
    if kind == "exact":
        return word == body
    if kind == "contains":
        return body in word
    if kind == "suffix":
        return word.endswith(body)
    padded = word + "\0" * CODEC.word_length
    ok = all(i in wild or padded[i] == ch for i, ch in enumerate(body))
    if kind == "prefix":
        return ok
    # masked: fixed width — everything past the body must be terminator
    return ok and all(padded[i] == "\0"
                      for i in range(len(body), CODEC.word_length))


# ---------------------------------------------------------------------------
# oracle correctness: counts and selects, every predicate shape
# ---------------------------------------------------------------------------

LIKE_PATTERNS = ["ban%", "%ana", "%an%", "b_n%", "banana", "b_nd_na",
                 "%na", "nab", "%a%", "c%", "_an%"]


@pytest.mark.parametrize("pattern", LIKE_PATTERNS)
def test_like_count_oracle(db, pattern):
    cl = QueryClient(db, key=7)
    want = sum(_like_oracle(w, pattern) for w in WORDS)
    assert cl.run(Count(Like(0, pattern))).count == want


@pytest.mark.parametrize("pred,oracle", [
    (Prefix(0, "ba"), lambda w: w.startswith("ba")),
    (Suffix(0, "ana"), lambda w: w.endswith("ana")),
    (Contains(0, "an"), lambda w: "an" in w),
    (Contains(0, "ana"), lambda w: "ana" in w),   # overlapping windows
])
def test_predicate_class_count_oracle(db, pred, oracle):
    cl = QueryClient(db, key=7)
    assert cl.run(Count(pred)).count == sum(oracle(w) for w in WORDS)


@pytest.mark.parametrize("strategy", ["one_round", "tree", AUTO])
@pytest.mark.parametrize("pattern", ["%an%", "%na", "b_n%", "ca%"])
def test_pattern_select_rows_oracle(db, strategy, pattern):
    cl = QueryClient(db, key=3)
    ell = sum(_like_oracle(w, pattern) for w in WORDS)
    res = cl.run(Select(Like(0, pattern), strategy=strategy,
                        expected_matches=ell))
    got = sorted(row[0] for row in res.rows)
    assert got == sorted(w for w in WORDS if _like_oracle(w, pattern))
    assert res.strategy in ("one_round", "tree")
    assert res.count == ell


def test_like_convenience(db):
    cl = QueryClient(db, key=1)
    assert cl.like(0, "%an%", count_only=True).count == \
        sum("an" in w for w in WORDS)
    rows = cl.like(0, "ban%").rows
    assert sorted(r[0] for r in rows) == ["ban", "banana", "bandana"]


# ---------------------------------------------------------------------------
# wildcard-free LIKE lowers to the exact Eq path — provably
# ---------------------------------------------------------------------------

def test_wildcard_free_like_lowers_to_eq(db):
    col, body, spec = _lower_match(db, Like(0, "banana"), "t")
    assert spec is None and body == "banana" and col == 0
    # planner: the pattern estimate degenerates field-for-field to Eq's
    stats = DBStats.of(db)
    assert estimate_pattern_cost(stats, None) == estimate_count_cost(stats)
    for strat in ("one_round", "tree"):
        assert estimate_pattern_cost(stats, None, select=strat, ell=3) == \
            estimate_select_cost(strat, stats, ell=3)
    # transcript: Count(Like) == Count(Eq) bit for bit under the same key
    a = QueryClient(db, key=5).run(Count(Like(0, "banana")))
    b = QueryClient(db, key=5).run(Count(Eq(0, "banana")))
    assert a.count == b.count == 1
    assert a.ledger == b.ledger
    # and the §3.2.1 single-tuple special case stays eligible
    res = QueryClient(db, key=5).run(
        Select(Like(0, "banana"), strategy="one_tuple",
               expected_matches=1))
    assert res.strategy == "one_tuple" and res.rows[0][0] == "banana"


# ---------------------------------------------------------------------------
# B=16 mixed batch == sequential (rows + ledgers), shard/dispatcher parity
# ---------------------------------------------------------------------------

def _mixed_plans():
    return [
        Count(Eq(0, "banana")), Count(Like(0, "%an%")),
        Count(Prefix(0, "ba")),
        Select(Eq(1, "x"), strategy="one_round"),
        Select(Like(0, "ban%"), strategy="one_round"),
        Select(Suffix(0, "na"), strategy="tree",
               expected_matches=sum(w.endswith("na") for w in WORDS)),
        Select(Contains(0, "ab"), strategy="one_round"),
        RangeCount(Between(2, 2, 6)),
        Select(Eq(1, "z"), strategy="tree", expected_matches=2),
        Count(Suffix(0, "b")), Count(Contains(0, "ban")),
        Select(Like(0, "c%"), strategy=AUTO),
        Select(Like(0, "b_n%"), strategy="one_round"),
        Count(Like(0, "an")),
        Select(Prefix(0, "an"), strategy="one_round"),
        Count(Eq(1, "y")),
    ]


def _assert_equal(a, b, ctx):
    assert a.rows == b.rows, ctx
    assert a.count == b.count, ctx
    assert a.strategy == b.strategy, ctx
    assert a.ledger == b.ledger, ctx


def test_mixed_batch_equals_sequential(db):
    plans = _mixed_plans()
    assert len(plans) == 16
    batched = QueryClient(db, key=3).run_batch(plans)
    seq_cl = QueryClient(db, key=3)
    seq = [seq_cl.run(p) for p in plans]
    for i, (b, s) in enumerate(zip(batched, seq)):
        _assert_equal(b, s, i)


@pytest.mark.parametrize("shards", [1, 2, 4])
@pytest.mark.parametrize("dispatcher", ["serial", "threaded", "mesh"])
def test_shard_dispatcher_bit_identity(db, shards, dispatcher):
    plans = _mixed_plans()
    ref = QueryClient(db, key=5).run_batch(plans)
    cl = QueryClient(db, key=5)
    disp = {"serial": lambda: None,
            "threaded": lambda: ThreadedDispatcher(max_workers=shards),
            "mesh": lambda: MeshDispatcher(make_host_mesh())}[dispatcher]()
    cl.attach(shards=shards, dispatcher=disp)
    got = cl.run_batch(plans)
    for i, (a, b) in enumerate(zip(ref, got)):
        _assert_equal(a, b, (dispatcher, shards, i))


# ---------------------------------------------------------------------------
# explain() exactness for the pattern family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plan", [
    Count(Contains(0, "an")), Count(Suffix(0, "ana")),
    Count(Like(0, "b_n%")), Count(Prefix(0, "c")),
])
def test_explain_exact_pattern_count(db, plan):
    cl = QueryClient(db, key=11)
    exp = cl.explain(plan)
    res = cl.run(plan)
    assert exp.bits == res.ledger.communication_bits
    assert exp.rounds == res.ledger.rounds


@pytest.mark.parametrize("pred,source", [
    (Suffix(0, "na"), "%na"), (Contains(0, "an"), "%an%"),
    (Like(0, "b_n%"), "b_n%")])
def test_explain_exact_pattern_one_round_select(db, pred, source):
    ell = sum(_like_oracle(w, source) for w in WORDS)
    plan = Select(pred, strategy="one_round", expected_matches=ell)
    cl = QueryClient(db, key=11)
    exp = cl.explain([plan])
    res = cl.run(plan)
    assert exp.bits == res.ledger.communication_bits
    assert exp.rounds == res.ledger.rounds


def test_explain_exact_mixed_count_one_round_batch(db):
    plans = [Count(Like(0, "%an%")), Count(Eq(0, "ban")),
             Select(Suffix(0, "na"), strategy="one_round",
                    expected_matches=sum(w.endswith("na") for w in WORDS)),
             Select(Eq(1, "x"), strategy="one_round", expected_matches=3)]
    cl = QueryClient(db, key=13)
    exp = cl.explain(plans)
    outs = cl.run_batch(plans)
    assert exp.bits == sum(o.ledger.communication_bits for o in outs)
    assert exp.rounds == max(o.ledger.rounds for o in outs)


# ---------------------------------------------------------------------------
# typed rejection: unknown predicates, malformed patterns, one_tuple
# ---------------------------------------------------------------------------

class _UnknownPredicate:
    column = 0
    pattern = "x"       # duck-typed fields must NOT be enough


@pytest.mark.parametrize("plan", [
    Count(Between(2, 1, 3)),                       # wrong predicate family
    Select(Between(2, 1, 3)),
    Count(_UnknownPredicate()),
    Select(_UnknownPredicate()),
    Count(Like(0, "a%b%")),                        # interior %
    Count(Like(0, "%a_b")),                        # _ under a shifted window
    Count(Like(0, "%%")),                          # empty body
    Count(Suffix(0, "waytoolongword")),            # tile longer than W
    Select(Like(0, "ban%"), strategy="one_tuple"),  # pattern one_tuple
])
def test_plan_not_supported(db, plan):
    cl = QueryClient(db, key=1)
    with pytest.raises(PlanNotSupported):
        cl.run(plan)
    with pytest.raises(PlanNotSupported):
        cl.explain([plan] if not isinstance(plan, Select) else plan)


def test_plan_not_supported_is_typed(db):
    cl = QueryClient(db, key=1)
    with pytest.raises(TypeError):                 # subclass contract
        cl.run(Count(Between(2, 1, 3)))
    try:
        cl.run(Count(Like(0, "a%b%")))
    except PlanNotSupported as e:
        assert "Like" in str(e)


# ---------------------------------------------------------------------------
# join match_method: chain vs aggregate parity + planner pricing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_join_match_method_parity(db, right_db, backend):
    outs = {}
    for mm in ("chain", "aggregate", "auto"):
        cl = QueryClient(db, key=13, backend=backend)
        res = cl.run(Join(right=right_db, on=(0, 0), match_method=mm))
        outs[mm] = (res.rows, res.ledger)
    assert outs["chain"] == outs["aggregate"] == outs["auto"]
    rows = outs["chain"][0]
    assert [r[0] for r in rows] == ["banana", "cab"]


def test_choose_match_method_pricing(db):
    stats = DBStats.of(db)
    # W=8 chain launches vs 2 aggregate launches: AUTO takes aggregate
    assert estimate_match_method_launches(stats, "chain") == 8
    assert estimate_match_method_launches(stats, "aggregate") == 2
    assert choose_match_method(stats) == "aggregate"
    assert choose_match_method(stats, "chain") == "chain"
    with pytest.raises(ValueError):
        choose_match_method(stats, "bogus")


# ---------------------------------------------------------------------------
# backend parity: the pallas slide kernel end to end
# ---------------------------------------------------------------------------

def test_pattern_backend_parity(db):
    plans = [Count(Contains(0, "an")), Count(Suffix(0, "ana")),
             Select(Like(0, "%an%"), strategy="one_round")]
    a = QueryClient(db, key=17, backend="jnp").run_batch(plans)
    b = QueryClient(db, key=17, backend="pallas").run_batch(plans)
    for i, (x, y) in enumerate(zip(a, b)):
        _assert_equal(x, y, i)
