"""End-to-end query correctness: q(R) ≡ q_interpolate(q_1(R^s_1)…) (§2.2).

Uses the paper's own Employee running example plus randomized relations via
hypothesis. Every query is checked against a plaintext oracle.
"""
import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import outsource, Codec
from repro.core.queries import (count_query, select_one_tuple,
                                select_one_round, select_tree, pkfk_join,
                                equijoin, range_count, range_select)

CODEC = Codec(word_length=8)

EMPLOYEE = [
    ["E101", "Adam", "Smith", "1000", "Sale"],
    ["E102", "John", "Taylor", "2000", "Design"],
    ["E103", "Eve", "Smith", "500", "Sale"],
    ["E104", "John", "Williams", "5000", "Sale"],
]


@pytest.fixture(scope="module")
def employee_db():
    return outsource(jax.random.PRNGKey(7), EMPLOYEE, codec=CODEC,
                     n_shares=20, degree=1, numeric_columns={3: 14})


# ---------------------------------------------------------------------------
# Count (§3.1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("col,pat,want", [
    (1, "John", 2), (1, "Adam", 1), (1, "Eve", 1), (1, "Zoe", 0),
    (2, "Smith", 2), (4, "Sale", 3), (4, "Design", 1),
])
def test_count_employee(employee_db, col, pat, want):
    got, _ = count_query(jax.random.PRNGKey(hash(pat) % 2**31), employee_db,
                         col, pat)
    assert got == want


def test_count_exact_word_not_prefix():
    """The John/Johnson aside (§3.1.2): terminator padding -> exact match."""
    rows = [["John", "x"], ["Johnson", "y"], ["John", "z"]]
    db = outsource(jax.random.PRNGKey(1), rows, codec=CODEC, n_shares=20)
    got, _ = count_query(jax.random.PRNGKey(2), db, 0, "John")
    assert got == 2


def test_count_communication_is_constant_in_n(employee_db):
    """Theorem 1: communication independent of n; cloud work = n·w."""
    big = outsource(jax.random.PRNGKey(3),
                    EMPLOYEE * 8, codec=CODEC, n_shares=20)
    _, small_led = count_query(jax.random.PRNGKey(4), employee_db, 1, "Eve")
    _, big_led = count_query(jax.random.PRNGKey(4), big, 1, "Eve")
    assert big_led.communication_bits == small_led.communication_bits
    assert big_led.rounds == small_led.rounds == 1
    assert big_led.cloud_ops_bits == 8 * small_led.cloud_ops_bits


# ---------------------------------------------------------------------------
# Selection (§3.2)
# ---------------------------------------------------------------------------

def test_select_one_tuple(employee_db):
    rows, _ = select_one_tuple(jax.random.PRNGKey(5), employee_db, 1, "Eve")
    assert rows == [["E103", "Eve", "Smith", "500", "Sale"]]


def test_select_one_tuple_rejects_multi(employee_db):
    with pytest.raises(ValueError):
        select_one_tuple(jax.random.PRNGKey(6), employee_db, 1, "John")


def test_select_one_round(employee_db):
    rows, addrs, led = select_one_round(jax.random.PRNGKey(8), employee_db,
                                        1, "John")
    assert addrs == [1, 3]
    assert rows == [EMPLOYEE[1], EMPLOYEE[3]]
    assert led.rounds == 2  # one to get bits, one to fetch


def test_select_one_round_padded_output(employee_db):
    """Fake-row padding hides ℓ (output-size attack defence, §3.2.2)."""
    rows, addrs, led = select_one_round(jax.random.PRNGKey(8), employee_db,
                                        1, "John", padded_rows=4)
    assert rows == [EMPLOYEE[1], EMPLOYEE[3]]      # padding stripped by user


def test_select_tree(employee_db):
    rows, addrs, led = select_tree(jax.random.PRNGKey(9), employee_db,
                                   4, "Sale")
    assert addrs == [0, 2, 3]
    assert rows == [EMPLOYEE[0], EMPLOYEE[2], EMPLOYEE[3]]


def test_select_tree_round_bound():
    """Theorem 4: rounds ≤ ⌊log_ℓ n⌋ + ⌊log₂ ℓ⌋ + 1 (+1 count, +1 fetch)."""
    n_rep = 8
    rows = [[f"id{i}", "John" if i % 4 == 0 else f"nm{i}"]
            for i in range(n_rep * 4)]
    db = outsource(jax.random.PRNGKey(10), rows, codec=CODEC, n_shares=20)
    got, addrs, led = select_tree(jax.random.PRNGKey(11), db, 1, "John")
    ell, n = n_rep, n_rep * 4
    import math
    bound = (math.floor(math.log(n, ell)) + math.floor(math.log2(ell)) + 1
             + 2)  # + count round + fetch round
    assert led.rounds <= bound
    assert addrs == [i for i in range(n) if i % 4 == 0]


def test_select_tree_single_hit(employee_db):
    rows, addrs, _ = select_tree(jax.random.PRNGKey(12), employee_db,
                                 1, "Adam")
    assert addrs == [0] and rows == [EMPLOYEE[0]]


def test_select_tree_no_hit(employee_db):
    rows, addrs, _ = select_tree(jax.random.PRNGKey(13), employee_db,
                                 1, "Zoe")
    assert rows == [] and addrs == []


# ---------------------------------------------------------------------------
# Joins (§3.3)
# ---------------------------------------------------------------------------

def test_pkfk_join_paper_example():
    codec = Codec(word_length=6)
    X = [["a1", "b1"], ["a2", "b2"], ["a3", "b3"]]
    Y = [["b1", "c1"], ["b2", "c2"], ["b2", "c3"], ["b2", "c4"]]
    dbX = outsource(jax.random.PRNGKey(1), X, codec=codec, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(2), Y, codec=codec, n_shares=16)
    rows, led = pkfk_join(dbX, dbY, 1, 0)
    assert rows == [["a1", "b1", "c1"], ["a2", "b2", "c2"],
                    ["a2", "b2", "c3"], ["a2", "b2", "c4"]]
    assert led.rounds == 1


def test_pkfk_join_dangling_child():
    codec = Codec(word_length=6)
    X = [["a1", "b1"]]
    Y = [["b1", "c1"], ["b9", "c2"]]
    dbX = outsource(jax.random.PRNGKey(3), X, codec=codec, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(4), Y, codec=codec, n_shares=16)
    rows, _ = pkfk_join(dbX, dbY, 1, 0)
    assert rows == [["a1", "b1", "c1"]]


def test_equijoin_multi_multi():
    codec = Codec(word_length=6)
    X = [["a1", "b1"], ["a2", "b2"], ["a3", "b2"]]
    Y = [["b2", "c1"], ["b2", "c2"], ["b9", "c3"]]
    dbX = outsource(jax.random.PRNGKey(5), X, codec=codec, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(6), Y, codec=codec, n_shares=16)
    rows, led = equijoin(jax.random.PRNGKey(7), dbX, dbY, 1, 0)
    want = sorted([("a2", "b2", "c1"), ("a2", "b2", "c2"),
                   ("a3", "b2", "c1"), ("a3", "b2", "c2")])
    assert sorted(map(tuple, rows)) == want


def test_equijoin_padded_fake_values():
    codec = Codec(word_length=6)
    X = [["a1", "b1"]]
    Y = [["b1", "c1"]]
    dbX = outsource(jax.random.PRNGKey(8), X, codec=codec, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(9), Y, codec=codec, n_shares=16)
    rows, led = equijoin(jax.random.PRNGKey(10), dbX, dbY, 1, 0,
                         padded_values=2)
    assert rows == [["a1", "b1", "c1"]]
    assert led.rounds == 1 + 2 * 3  # fake jobs cost rounds too (k hidden)


# ---------------------------------------------------------------------------
# Range (§3.4)
# ---------------------------------------------------------------------------

SALARY_DB = None


def _salary_db():
    global SALARY_DB
    if SALARY_DB is None:
        SALARY_DB = outsource(jax.random.PRNGKey(20),
                              EMPLOYEE, codec=CODEC, n_shares=34, degree=1,
                              numeric_columns={3: 14})
    return SALARY_DB


@pytest.mark.parametrize("lo,hi,want", [
    (1000, 2000, 2), (0, 8000, 4), (400, 600, 1), (6000, 7000, 0),
    (500, 500, 1), (5000, 5000, 1),
])
def test_range_count(lo, hi, want):
    got, _ = range_count(jax.random.PRNGKey(lo + hi), _salary_db(), 3, lo, hi)
    assert got == want


def test_range_count_negative_bounds():
    rows = [["a", "-5"], ["b", "3"], ["c", "-1"]]
    db = outsource(jax.random.PRNGKey(21), rows, codec=CODEC, n_shares=34,
                   degree=1, numeric_columns={1: 14})
    got, _ = range_count(jax.random.PRNGKey(22), db, 1, -4, 3)
    assert got == 2  # -1 and 3


def test_range_select():
    rows, addrs, _ = range_select(jax.random.PRNGKey(23), _salary_db(), 3,
                                  400, 1500)
    assert addrs == [0, 2]
    assert rows == [EMPLOYEE[0], EMPLOYEE[2]]


def test_range_with_degree_reduction():
    """reduce_every keeps the carry degree low -> fewer clouds needed."""
    db = outsource(jax.random.PRNGKey(24), EMPLOYEE, codec=CODEC,
                   n_shares=12, degree=1, numeric_columns={3: 14})
    got, led = range_count(jax.random.PRNGKey(25), db, 3, 1000, 2000,
                           reduce_every=2)
    assert got == 2
    assert led.rounds > 1  # degree-reduction rounds are counted


# ---------------------------------------------------------------------------
# Property: random relations, query ≡ plaintext oracle
# ---------------------------------------------------------------------------

names = st.sampled_from(["ann", "bob", "cat", "dan", "eve", "fay"])


@settings(max_examples=8, deadline=None)
@given(st.lists(names, min_size=2, max_size=10), names)
def test_count_matches_oracle(col_vals, pat):
    rows = [[f"id{i}", v] for i, v in enumerate(col_vals)]
    db = outsource(jax.random.PRNGKey(len(col_vals)), rows,
                   codec=Codec(word_length=6), n_shares=16)
    got, _ = count_query(jax.random.PRNGKey(0), db, 1, pat)
    assert got == col_vals.count(pat)


@settings(max_examples=6, deadline=None)
@given(st.lists(names, min_size=2, max_size=8), names)
def test_one_round_select_matches_oracle(col_vals, pat):
    rows = [[f"id{i}", v] for i, v in enumerate(col_vals)]
    db = outsource(jax.random.PRNGKey(1 + len(col_vals)), rows,
                   codec=Codec(word_length=6), n_shares=16)
    got, addrs, _ = select_one_round(jax.random.PRNGKey(2), db, 1, pat)
    assert addrs == [i for i, v in enumerate(col_vals) if v == pat]
    assert got == [rows[i] for i in addrs]


@settings(max_examples=5, deadline=None)
@given(st.lists(st.integers(min_value=-100, max_value=100),
                min_size=2, max_size=8),
       st.integers(min_value=-50, max_value=50),
       st.integers(min_value=0, max_value=60))
def test_range_count_matches_oracle(vals, lo, span):
    hi = lo + span
    rows = [[f"id{i}", str(v)] for i, v in enumerate(vals)]
    db = outsource(jax.random.PRNGKey(3 + len(vals)), rows,
                   codec=Codec(word_length=6), n_shares=14, degree=1,
                   numeric_columns={1: 9})
    got, _ = range_count(jax.random.PRNGKey(4), db, 1, lo, hi,
                         reduce_every=1)
    assert got == sum(1 for v in vals if lo <= v <= hi)
