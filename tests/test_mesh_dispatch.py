"""MeshDispatcher: device-resident SPMD execution of the sharded dataplane.

Anchor properties:

* **Transcript identity** — rows, opened values, addresses and per-query
  ``CostLedger``s through a ``MeshDispatcher`` are bit-identical to
  ``SerialDispatcher`` for S ∈ {1, 2, 4} across every query family
  (count / select / range / join / aggregate, ``verify=`` included). The
  shard count and the placement policy are both pure execution axes.
* **Device residency** — after the initial placement, zero host↔device
  share-buffer traffic inside ``run_batch``: strict mode runs every cloud
  step under ``jax.transfer_guard`` (device→host disallowed everywhere,
  both directions disallowed in the reduce), and the telemetry charges
  exactly the one-time placement, then stays at zero.
* **Seam transparency** — ``QueryClient.attach(dispatcher=...)`` and
  ``QueryServer`` tenants pick it up with no other code changes.

The SPMD psum path over real multiple devices (forced host platform,
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) runs in a
subprocess — tests/conftest.py pins this process to ONE device.
"""
import os
import subprocess
import sys

import jax
import pytest

from repro.api import (Aggregate, Between, Count, Eq, Join, MeshDispatcher,
                       Padding, QueryClient, RangeCount, RangeSelect, Select)
from repro.core import Codec, outsource
from repro.launch.mesh import (make_dispatch_mesh, make_host_mesh,
                               make_mesh)
from repro.launch.serve import QueryServer

CODEC = Codec(word_length=6)
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def range_db():
    rows = [[f"id{i}", f"nm{i % 5}", str(500 + 137 * i)] for i in range(32)]
    db = outsource(jax.random.PRNGKey(19), rows,
                   column_names=["Id", "Name", "Val"], codec=CODEC,
                   n_shares=20, degree=1, numeric_columns={2: 14})
    return rows, db


@pytest.fixture(scope="module")
def child_db(range_db):
    rows, _ = range_db
    child = [[rows[i % len(rows)][0], f"t{i}"] for i in range(6)]
    return outsource(jax.random.PRNGKey(23), child,
                     column_names=["Id", "Task"], codec=CODEC,
                     n_shares=20, degree=1)


def _family_plans(child):
    return [
        Count(Eq("Name", "nm1")),
        Select(Eq("Name", "nm2"), strategy="one_round"),
        Select(Eq("Name", "nm3"), strategy="tree"),
        Select(Eq("Id", "id7"), strategy="one_tuple"),
        RangeCount(Between("Val", 500, 2000), reduce_every=2),
        RangeSelect(Between("Val", 900, 1800), reduce_every=2),
        Join(right=child, on=("Id", "Id"), kind="pkfk"),
        Join(right=child, on=("Id", "Id"), kind="equi",
             padding=Padding.fake_values(1)),
        Aggregate("sum", "Val", where=Eq("Name", "nm1"), verify=True),
        Aggregate("avg", "Val", where=Eq("Name", "nm2")),
        Aggregate("min", "Val", where=Eq("Name", "nm1"), reduce_every=2),
    ]


def _assert_results_equal(a, b):
    assert a.strategy == b.strategy
    assert a.rows == b.rows
    assert a.addresses == b.addresses
    assert a.count == b.count
    assert a.value == b.value
    assert a.ledger == b.ledger


# ---------------------------------------------------------------------------
# transcript identity (host mesh: the single-device degradation path)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shards", [1, 2, 4])
def test_mesh_parity_with_serial_all_families(range_db, child_db, shards):
    _, db = range_db
    plans = _family_plans(child_db)
    serial = QueryClient(db, key=7)
    serial.attach(shards=shards)
    ref = serial.run_batch(plans)

    client = QueryClient(db, key=7)
    mesh = MeshDispatcher(make_host_mesh(), strict_transfers=True)
    plane = client.attach(shards=shards, dispatcher=mesh)
    got = client.run_batch(plans)
    for a, b in zip(ref, got):
        _assert_results_equal(a, b)
    assert plane.stats.dispatches == plane.stats.steps * shards


def test_mesh_device_residency_placement_then_zero(range_db, child_db):
    """Transfer accounting: the first batch pays exactly the one-time
    placement of the share arrays; every later batch moves zero bytes.
    Strict mode (active here) additionally guards every cloud step, so an
    implicit transfer would raise, not just miscount."""
    _, db = range_db
    client = QueryClient(db, key=7)
    mesh = MeshDispatcher(make_host_mesh(), strict_transfers=True)
    plane = client.attach(shards=2, dispatcher=mesh)
    placed = db.relation.values.nbytes + sum(
        s.values.nbytes for s in db.numeric.values())
    plans = _family_plans(child_db)[:4]
    client.run_batch(plans)
    assert plane.stats.transfer_bytes == placed
    before = plane.stats.transfer_bytes
    client.run_batch(plans)
    assert plane.stats.transfer_bytes == before  # zero after placement
    assert plane.stats.dispatch_s > 0.0
    assert plane.stats.steps > 0


def test_mesh_predicted_cost_report(range_db):
    _, db = range_db
    client = QueryClient(db, key=3)
    mesh = MeshDispatcher(make_host_mesh())
    client.attach(shards=2, dispatcher=mesh)
    client.run_batch([Count(Eq("Name", "nm1")),
                      Aggregate("sum", "Val")])
    cost = mesh.predicted_cost()
    assert cost["programs"] >= 1          # at least one compiled reduction
    assert cost["flops"] > 0
    assert cost["hbm_bytes"] > 0
    assert mesh.hlo_texts()               # texts retained for the bench


def test_query_server_tenant_gets_mesh_transparently(range_db):
    """A QueryServer tenant attached with a MeshDispatcher serves the same
    results as a serial tenant, and the serving snapshot now carries the
    measured dispatch wall-time and the placement-only transfer bytes."""
    _, db = range_db
    plans = [Count(Eq("Name", "nm1")), Count(Eq("Name", "nm2"))]

    solo = QueryServer()
    solo.attach("emp", db, key=5)
    with solo:
        ref = [solo.submit(p, relation="emp").wait().result for p in plans]

    server = QueryServer()
    mesh = MeshDispatcher(make_host_mesh())
    server.attach("emp", db, key=5, shards=2, dispatcher=mesh)
    with server:
        got = [server.submit(p, relation="emp").wait().result
               for p in plans]
    for a, b in zip(ref, got):
        _assert_results_equal(a, b)
    snap = server.stats.snapshot()["relations"]["emp"]
    assert snap["dispatches"] > 0
    assert snap["dispatch_s"] > 0.0
    assert snap["transfer_bytes"] > 0     # the one-time placement
    # a second helping of traffic moves nothing new
    server2_stats = server.stats.snapshot()
    assert server2_stats["transfer_bytes"] == snap["transfer_bytes"]


def test_serial_dispatchers_also_record_time_and_bytes(range_db):
    """Satellite: the host paths price wall-time and staged bytes too —
    every shard partial round-trips through the host combine."""
    _, db = range_db
    client = QueryClient(db, key=7)
    plane = client.attach(shards=2)
    client.run_batch([Count(Eq("Name", "nm1"))])
    assert plane.stats.dispatch_s > 0.0
    assert plane.stats.transfer_bytes > 0


# ---------------------------------------------------------------------------
# mesh construction seams (single-device side)
# ---------------------------------------------------------------------------

def test_host_and_elastic_mesh_shapes():
    hm = make_host_mesh()
    assert hm.axis_names == ("data", "model")
    assert dict(hm.shape) == {"data": 1, "model": 1}
    em = make_mesh((1, 1, 1), ("pod", "data", "model"))
    assert em.axis_names == ("pod", "data", "model")
    dm = make_dispatch_mesh()
    assert dm.axis_names == ("data", "model")
    assert dm.shape["data"] * dm.shape["model"] == jax.device_count()
    with pytest.raises(ValueError):
        make_dispatch_mesh(jax.device_count() + 1)


def test_share_spec_pins_cloud_and_tuple_axes():
    from jax.sharding import PartitionSpec as P
    from repro.sharding import share_spec
    mesh = make_host_mesh()
    # every axis divides a 1-sized mesh axis: cloud -> model, tuple -> data
    assert share_spec(mesh, (20, 32, 4, 3)) == P("model", ("data",))
    assert share_spec(mesh, (20,)) == P("model")


def test_mesh_dispatcher_requires_data_axis():
    with pytest.raises(ValueError):
        MeshDispatcher(make_mesh((1,), ("model",)))


# ---------------------------------------------------------------------------
# forced multi-device SPMD path (subprocess: needs its own XLA_FLAGS
# before jax import — this process is pinned to one device)
# ---------------------------------------------------------------------------

_FORCED_SCRIPT = r"""
import jax
assert jax.device_count() == 8, jax.device_count()
from jax.sharding import PartitionSpec as P
from repro.api import (Aggregate, Between, Count, Eq, MeshDispatcher,
                       QueryClient, RangeCount)
from repro.core import Codec, outsource
from repro.launch.mesh import make_dispatch_mesh, make_mesh
from repro.sharding import dp_axes, dp_size, model_size, share_spec

# -- construction: forced host platform, elastic shapes -------------------
dm = make_dispatch_mesh()
assert dict(dm.shape) == {"data": 8, "model": 1}, dm.shape
dm2 = make_dispatch_mesh(2)
assert dict(dm2.shape) == {"data": 4, "model": 2}, dm2.shape
mp = make_mesh((2, 2, 2), ("pod", "data", "model"))
assert dp_axes(mp) == ("pod", "data") and dp_size(mp) == 4
assert model_size(mp) == 2

# -- share_spec divisibility: non-divisible axes replicate ----------------
assert share_spec(dm2, (20, 32, 4, 3)) == P("model", ("data",))
assert share_spec(dm2, (21, 30, 4, 3)) == P(None, None)  # 21%2, 30%4

# -- SPMD parity: psum reduce across 4 data devices == serial -------------
CODEC = Codec(word_length=6)
rows = [[f"id{i}", f"nm{i % 4}", str(500 + 37 * i)] for i in range(16)]
db = outsource(jax.random.PRNGKey(11), rows,
               column_names=["Id", "Name", "Val"], codec=CODEC,
               n_shares=20, degree=1, numeric_columns={2: 14})
plans = [Count(Eq("Name", "nm1")),
         RangeCount(Between("Val", 500, 900), reduce_every=2),
         Aggregate("sum", "Val", where=Eq("Name", "nm2"), verify=True)]
serial = QueryClient(db, key=7); serial.attach(shards=4)
ref = serial.run_batch(plans)
client = QueryClient(db, key=7)
mesh = MeshDispatcher(dm2, strict_transfers=True)
client.attach(shards=4, dispatcher=mesh)
got = client.run_batch(plans)
for a, b in zip(ref, got):
    assert a.rows == b.rows and a.count == b.count and a.value == b.value
    assert a.ledger == b.ledger
# the reduction really is collective: psum lowers to all-reduce
texts = mesh.hlo_texts()
assert texts and any("all-reduce" in t for t in texts.values()), \
    sorted(texts)
assert mesh.predicted_cost()["collective_bytes"] > 0
print("FORCED-MESH-OK")
"""


@pytest.mark.slow
def test_forced_eight_device_spmd_parity():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env["PYTHONPATH"] = (os.path.join(ROOT, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", _FORCED_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "FORCED-MESH-OK" in proc.stdout
