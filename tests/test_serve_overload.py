"""Self-tuning overload behaviour of the QueryServer.

Adaptive deadline steering (full closes shrink a relation's effective
wait, deadline-underfilled closes grow it back to the configured cap),
the per-relation ``queue_depth`` / ``steered_wait_ms`` gauges and the
steering trajectory in ``ServeStats`` snapshots, the floored scheduler
park (no busy-spin on sub-millisecond deadlines), weight plumbing into
the shared pool, and ``ServeStats`` consistency under attach churn.
"""
import threading
import time

import jax
import pytest

from repro.api import Count, DEFAULT_RELATION, Eq
from repro.core import Codec, outsource
from repro.launch import serve as serve_mod
from repro.launch.serve import (MIN_PARK_S, MIN_STEER_WAIT_S, QueryRequest,
                                QueryServer, STEER_GROW, STEER_SHRINK)

CODEC = Codec(word_length=8)
COLUMNS = ["EmployeeId", "FirstName", "LastName", "Salary", "Department"]
EMPLOYEE = [
    ["E101", "Adam", "Smith", "1000", "Sale"],
    ["E102", "John", "Taylor", "2000", "Design"],
    ["E103", "Eve", "Smith", "500", "Sale"],
    ["E104", "John", "Williams", "5000", "Sale"],
]
PLAN = Count(Eq("FirstName", "John"))


@pytest.fixture(scope="module")
def employee_db():
    return outsource(jax.random.PRNGKey(7), EMPLOYEE, column_names=COLUMNS,
                     codec=CODEC, n_shares=20, degree=1,
                     numeric_columns={3: 14})


def test_full_closes_shrink_wait_monotonically(employee_db):
    """Every full close multiplies the effective wait by STEER_SHRINK;
    the snapshot trajectory is strictly decreasing."""
    srv = QueryServer(employee_db, key=21, max_batch=2, max_wait_ms=40)
    t = srv._tenant(None)
    base = t.wait_s
    for _ in range(4):
        srv.submit(PLAN)
        srv.submit(PLAN)
        srv.pump("full")
    assert t.base_wait_s == base
    assert t.wait_s == pytest.approx(base * STEER_SHRINK ** 4)
    rel = srv.stats.snapshot()["relations"][DEFAULT_RELATION]
    traj = rel["wait_trajectory_ms"]
    assert len(traj) == 4
    assert all(b < a for a, b in zip(traj, traj[1:]))
    assert rel["steered_wait_ms"] == pytest.approx(traj[-1])


def test_deadline_underfilled_grows_back_to_cap(employee_db):
    """Deadline closes below max_batch grow the wait by STEER_GROW, but
    never past the configured cap."""
    srv = QueryServer(employee_db, key=22, max_batch=4, max_wait_ms=30)
    t = srv._tenant(None)
    base = t.wait_s
    for _ in range(6):           # dive first
        srv.submit(PLAN)
        srv.submit(PLAN)
        srv.submit(PLAN)
        srv.submit(PLAN)
        srv.pump("full")
    dived = t.wait_s
    assert dived < base
    for _ in range(40):          # recover: underfilled deadline closes
        srv.submit(PLAN)
        srv.pump("deadline")
    assert t.wait_s == base      # capped exactly at the configured wait
    rel = srv.stats.snapshot()["relations"][DEFAULT_RELATION]
    assert rel["steered_wait_ms"] == pytest.approx(base * 1e3)


def test_steering_floor_and_inert_reasons(employee_db):
    """The steered wait never drops below MIN_STEER_WAIT_S, and
    manual/drain pumps do not steer."""
    srv = QueryServer(employee_db, key=23, max_batch=1, max_wait_ms=10)
    t = srv._tenant(None)
    for _ in range(80):
        srv.submit(PLAN)
        srv.pump("full")
    assert t.wait_s == pytest.approx(MIN_STEER_WAIT_S)
    w = t.wait_s
    srv.submit(PLAN)
    srv.pump()                   # "manual"
    srv.submit(PLAN)
    srv.pump("drain")
    assert t.wait_s == w
    # a full deadline close (fill == max_batch) does not grow either
    srv.submit(PLAN)
    srv.pump("deadline")
    assert t.wait_s == w


def test_zero_wait_relation_never_steers(employee_db):
    """max_wait_ms=0 pins the wait at zero — there is no cap to steer
    inside, and the grow rule must not resurrect a nonzero deadline."""
    srv = QueryServer(employee_db, key=24, max_batch=2, max_wait_ms=0)
    t = srv._tenant(None)
    for reason in ("full", "deadline", "full"):
        srv.submit(PLAN)
        srv.submit(PLAN)
        srv.pump(reason)
    assert t.wait_s == 0.0


def test_queue_depth_gauge(employee_db):
    """queue_depth reports what was still parked right after the close."""
    srv = QueryServer(employee_db, key=25, max_batch=2, max_wait_ms=1000)
    for _ in range(5):
        srv.submit(PLAN)
    srv.pump()
    rel = srv.stats.snapshot()["relations"][DEFAULT_RELATION]
    assert rel["queue_depth"] == 3
    while srv.pending():
        srv.pump()
    rel = srv.stats.snapshot()["relations"][DEFAULT_RELATION]
    assert rel["queue_depth"] == 0


def test_attach_weight_plumbs_to_pool_handle(employee_db):
    srv = QueryServer(pool_workers=2)
    srv.attach("emp", employee_db, shards=2, key=1, weight=2.5)
    plane = srv.dataplane_of("emp")
    assert plane.dispatcher.weight == 2.5
    assert plane.dispatcher._shared_pool is srv._owned_dispatcher
    with pytest.raises(ValueError):
        srv.attach("bad", employee_db, shards=2, key=2, weight=0.0)
    srv.close()


def test_scheduler_park_is_floored(employee_db):
    """Sub-millisecond deadlines must park the scheduler at least
    MIN_PARK_S per wait — never a ~0s spin-wait."""
    srv = QueryServer(employee_db, key=26, max_batch=64, max_wait_ms=0.5)
    recorded = []
    real_wait = srv._cond.wait

    def spy(timeout=None):
        if timeout is not None:
            recorded.append(timeout)
        return real_wait(timeout)

    srv._cond.wait = spy
    with srv:
        reqs = []
        for _ in range(40):
            reqs.append(srv.submit(QueryRequest(PLAN)))
            time.sleep(0.002)
        for r in reqs:
            r.wait(timeout=30)
    assert recorded, "scheduler never took a timed park"
    assert min(recorded) >= MIN_PARK_S - 1e-9
    assert all(r.result.count == 2 for r in reqs)


def test_first_deadline_close_uses_configured_wait(employee_db):
    """Steering only reacts to history: a fresh relation's first deadline
    close parks the full configured max_wait_ms."""
    with QueryServer(employee_db, key=27, max_batch=64,
                     max_wait_ms=60) as srv:
        t0 = time.time()
        r = srv.submit(QueryRequest(PLAN))
        r.wait(timeout=30)
        waited = time.time() - t0
    assert waited >= 0.055
    rel = srv.stats.snapshot()["relations"][DEFAULT_RELATION]
    assert rel["wait_trajectory_ms"][-1] == pytest.approx(60.0)


def test_stats_consistent_under_attach_churn(employee_db):
    """snapshot()/quantile reads race live attach() calls and a pumping
    scheduler without torn state; a relation attached mid-soak serves and
    exposes its own quantiles."""
    srv = QueryServer(employee_db, key=28, max_batch=4, max_wait_ms=2)
    errors = []
    stop = threading.Event()

    def churn():
        try:
            for i in range(12):
                srv.attach(f"r{i}", employee_db, key=100 + i,
                           max_batch=2, max_wait_ms=3)
                time.sleep(0.005)
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    def read():
        try:
            while not stop.is_set():
                snap = srv.stats.snapshot()
                assert snap["served"] >= 0
                for rel in snap["relations"].values():
                    assert rel["queue_depth"] >= 0
                    assert isinstance(rel["wait_trajectory_ms"], list)
                srv.stats.latency_quantile(0.95)
                srv.stats.queue_wait_quantile(0.5, relation="r3")
                srv.pending()
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    with srv:
        threads = [threading.Thread(target=churn),
                   threading.Thread(target=read)]
        for th in threads:
            th.start()
        reqs = [srv.submit(QueryRequest(PLAN)) for _ in range(30)]
        threads[0].join()
        # mid-soak attach serves its own traffic with its own quantiles
        late = [srv.submit(QueryRequest(PLAN), relation="r11")
                for _ in range(4)]
        for r in reqs + late:
            r.wait(timeout=30)
        stop.set()
        threads[1].join()
    assert not errors, errors
    assert srv.stats.queue_wait_quantile(0.95, relation="r11") >= 0.0
    assert srv.stats.snapshot()["relations"]["r11"]["served"] == 4
    assert all(r.result.count == 2 for r in late)
