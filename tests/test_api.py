"""Unified QueryClient API: plans, planner, backends, executor.

Covers plan construction + validation, name-based column resolution, the
cost-based planner's strategy choice across (n, ℓ) regimes, exact
``CostLedger``/row equivalence between the client and the legacy free
functions, and the MapReduce executor path.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.api import (AUTO, Between, Count, DBStats, Eq, Join,
                       MapReduceExecutor, Padding, QueryClient, QueryResult,
                       RangeCount, RangeSelect, Select, available_backends,
                       candidate_estimates, choose_select_strategy,
                       estimate_select_cost, get_backend, resolve_column)
from repro.core import outsource, Codec
from repro.core.queries import (count_query, pkfk_join, range_count,
                                range_select, select_one_round,
                                select_one_tuple, select_tree)
from repro.runtime import MapReduceRunner, WorkerPool

CODEC = Codec(word_length=8)
COLUMNS = ["EmployeeId", "FirstName", "LastName", "Salary", "Department"]

EMPLOYEE = [
    ["E101", "Adam", "Smith", "1000", "Sale"],
    ["E102", "John", "Taylor", "2000", "Design"],
    ["E103", "Eve", "Smith", "500", "Sale"],
    ["E104", "John", "Williams", "5000", "Sale"],
]


@pytest.fixture(scope="module")
def employee_db():
    return outsource(jax.random.PRNGKey(7), EMPLOYEE, column_names=COLUMNS,
                     codec=CODEC, n_shares=20, degree=1,
                     numeric_columns={3: 14})


@pytest.fixture()
def client(employee_db):
    return QueryClient(employee_db, key=42)


# ---------------------------------------------------------------------------
# plan construction + validation
# ---------------------------------------------------------------------------

def test_plans_are_frozen_plain_data():
    plan = Select(Eq("FirstName", "John"), padding=Padding.to_rows(4))
    assert plan.where.pattern == "John"
    assert plan.padding.rows == 4
    with pytest.raises(dataclasses.FrozenInstanceError):
        plan.strategy = "tree"


def test_plan_validation():
    with pytest.raises(ValueError):
        Select(Eq("A", "x"), strategy="bogus")
    with pytest.raises(ValueError):
        Between("Salary", 10, 5)
    with pytest.raises(ValueError):
        Padding(rows=-1)
    with pytest.raises(ValueError):
        Padding(values=-2)


def test_join_plan_validation(employee_db):
    with pytest.raises(ValueError):
        Join(right=employee_db, on=("A", "B"), kind="hash")


def test_query_result_count_defaults_to_len_rows():
    res = QueryResult(plan=Count(Eq("A", "x")), ledger=None,
                      strategy="count", rows=[["a"], ["b"]])
    assert res.count == 2


# ---------------------------------------------------------------------------
# column resolution by name
# ---------------------------------------------------------------------------

def test_resolve_column_by_name_and_index(employee_db):
    assert resolve_column(employee_db, "FirstName") == 1
    assert resolve_column(employee_db, 3) == 3
    with pytest.raises(KeyError):
        resolve_column(employee_db, "NoSuchColumn")
    with pytest.raises(IndexError):
        resolve_column(employee_db, 99)


def test_client_accepts_names_and_indices(client):
    by_name = client.count("FirstName", "John")
    by_idx = client.count(1, "John")
    assert by_name.count == by_idx.count == 2


# ---------------------------------------------------------------------------
# planner strategy choice (§3.2 bit/round formulas)
# ---------------------------------------------------------------------------

STATS_SMALL = DBStats(n=32, m=5, c=20, w=8, a=69)
STATS_LARGE = DBStats(n=1 << 20, m=5, c=20, w=8, a=69)


def test_planner_small_n_prefers_one_round():
    assert choose_select_strategy(STATS_SMALL, ell=4).strategy == "one_round"


def test_planner_large_n_prefers_tree():
    # one_round ships (and the user interpolates) all n match bits;
    # tree replaces them with O(ℓ log n) block counts.
    assert choose_select_strategy(STATS_LARGE, ell=4).strategy == "tree"


def test_planner_single_match_large_n_prefers_one_tuple():
    big = DBStats(n=4096, m=5, c=20, w=8, a=69)
    assert choose_select_strategy(big, ell=1).strategy == "one_tuple"


def test_planner_one_tuple_requires_ell_one():
    with pytest.raises(ValueError):
        estimate_select_cost("one_tuple", STATS_SMALL, ell=3)
    # unknown ℓ -> one_tuple never eligible
    names = [e.strategy for e in candidate_estimates(STATS_LARGE)]
    assert "one_tuple" not in names


def test_planner_round_cost_breaks_ties_toward_fewer_rounds():
    # price rounds high enough and the 2-round one_round beats tree
    # even at large n
    est = choose_select_strategy(STATS_LARGE, ell=4,
                                 round_cost_bits=10 ** 12)
    assert est.strategy == "one_round"


def test_planner_estimates_match_measured_ledger(employee_db):
    """The §3.2 formulas are in CostLedger units: the one_round estimate
    must equal the measured communication bits exactly."""
    stats = DBStats.of(employee_db)
    est = estimate_select_cost("one_round", stats, ell=2)
    _, _, led = select_one_round(jax.random.PRNGKey(0), employee_db, 1,
                                 "John")
    assert est.bits == led.communication_bits
    assert est.rounds == led.rounds


# ---------------------------------------------------------------------------
# QueryResult + ledger equivalence with the legacy free functions
# ---------------------------------------------------------------------------

def test_count_matches_legacy(client, employee_db):
    res = client.count("FirstName", "John")
    cnt, led = count_query(jax.random.PRNGKey(0), employee_db, 1, "John")
    assert res.count == cnt == 2
    assert res.strategy == "count"
    assert res.ledger == led


def test_select_auto_ledger_matches_legacy_exactly(client, employee_db):
    """Acceptance: auto-picked strategy's (bits, rounds) ledger equals the
    legacy per-function ledger on the quickstart dataset."""
    res = client.select("FirstName", "John")
    assert res.strategy == "one_round"        # small n -> one_round
    assert res.addresses == [1, 3]
    assert res.rows == [EMPLOYEE[1], EMPLOYEE[3]]
    _, _, led = select_one_round(jax.random.PRNGKey(0), employee_db, 1,
                                 "John")
    assert res.ledger == led


def test_select_forced_strategies_match_legacy(client, employee_db):
    key = jax.random.PRNGKey(0)
    res = client.select("FirstName", "Eve", strategy="one_tuple")
    rows, led = select_one_tuple(key, employee_db, 1, "Eve")
    assert res.rows == rows == [EMPLOYEE[2]]
    assert res.ledger == led

    res = client.select("Department", "Sale", strategy="tree")
    rows, addrs, led = select_tree(key, employee_db, 4, "Sale")
    assert res.rows == rows and res.addresses == addrs == [0, 2, 3]
    assert res.ledger == led


def test_select_padding_policy(client):
    res = client.select("FirstName", "John", strategy="one_round",
                        padding=Padding.to_rows(4))
    assert res.rows == [EMPLOYEE[1], EMPLOYEE[3]]  # padding stripped


def test_select_auto_falls_back_on_wrong_cardinality_hint(client):
    # hint says ℓ=1 at a size where the planner trusts it; reality is ℓ=2
    big_rows = ([[f"E{i}", f"nm{i}", "X", "1", "D"] for i in range(316)]
                + EMPLOYEE)
    db = outsource(jax.random.PRNGKey(1), big_rows, column_names=COLUMNS,
                   codec=CODEC, n_shares=20)
    cl = QueryClient(db, key=7)
    plan = Select(Eq("FirstName", "Eve"), expected_matches=1)
    assert cl.explain(plan)[0].strategy == "one_tuple"
    res = cl.run(dataclasses.replace(plan, where=Eq("FirstName", "John")))
    # John appears twice: one_tuple raises internally, the client replans
    assert res.strategy == "one_round"
    assert res.count == 2
    assert res.addresses == [317, 319]


def test_select_forced_wrong_strategy_raises(client):
    with pytest.raises(ValueError):
        client.select("FirstName", "John", strategy="one_tuple")


def test_fallback_ledger_includes_aborted_count_phase(client, employee_db):
    # planner hint wrong at small n: forced-path equivalent spends a count
    # round before replanning; the result ledger must report it
    res = client.run(Select(Eq("FirstName", "John"), strategy=AUTO,
                            expected_matches=2))
    base = client.run(Select(Eq("FirstName", "John"), strategy="one_round"))
    assert res.ledger == base.ledger    # no fallback happened: same cost
    big_rows = ([[f"E{i}", f"nm{i}", "X", "1", "D"] for i in range(316)]
                + EMPLOYEE)
    db = outsource(jax.random.PRNGKey(1), big_rows, column_names=COLUMNS,
                   codec=CODEC, n_shares=20)
    cl = QueryClient(db, key=7)
    fell = cl.run(Select(Eq("FirstName", "John"), expected_matches=1))
    clean = cl.run(Select(Eq("FirstName", "John"), strategy="one_round"))
    assert fell.strategy == "one_round"
    # aborted one_tuple = one count round + pattern upload on top
    assert fell.ledger.rounds == clean.ledger.rounds + 1
    assert (fell.ledger.communication_bits
            > clean.ledger.communication_bits)


def test_fallback_replans_with_learned_cardinality():
    """When the ℓ=1 hint fails on a large relation, the client replans with
    the true ℓ (CardinalityError.count) — picking tree, and reusing the
    aborted attempt's count via known_count instead of re-counting."""
    big_rows = ([[f"E{i}", f"nm{i}", "X", "1", "D"] for i in range(696)]
                + EMPLOYEE)
    db = outsource(jax.random.PRNGKey(2), big_rows, column_names=COLUMNS,
                   codec=CODEC, n_shares=20)
    cl = QueryClient(db, key=9)
    res = cl.run(Select(Eq("FirstName", "John"), expected_matches=1))
    assert res.strategy == "tree"
    assert res.addresses == [697, 699]
    assert res.count == 2


def test_unsupported_padding_raises(client, employee_db):
    with pytest.raises(ValueError):
        client.select("FirstName", "Eve", strategy="one_tuple",
                      padding=Padding.to_rows(4))
    with pytest.raises(ValueError):
        client.join(employee_db, on=(1, 1), kind="pkfk",
                    padding=Padding.fake_values(2))
    with pytest.raises(ValueError):
        client.join(employee_db, on=(1, 1), kind="equi",
                    padding=Padding.to_rows(3))


def test_pkfk_join_keyword_call_forms():
    codec = Codec(word_length=6)
    X = [["a1", "b1"]]
    Y = [["b1", "c1"]]
    dbX = outsource(jax.random.PRNGKey(3), X, codec=codec, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(4), Y, codec=codec, n_shares=16)
    want = [["a1", "b1", "c1"]]
    assert pkfk_join(dbX, dbY, 1, 0)[0] == want
    assert pkfk_join(dbX, dbY, col_x=1, col_y=0)[0] == want
    assert pkfk_join(jax.random.PRNGKey(5), dbX, dbY, 1, 0)[0] == want
    assert pkfk_join(key=jax.random.PRNGKey(5), dbX=dbX, dbY=dbY,
                     col_x=1, col_y=0)[0] == want
    with pytest.raises(TypeError):
        pkfk_join(dbX, dbY, 1)                     # missing col_y
    with pytest.raises(TypeError):
        pkfk_join(dbX, dbY, 1, 0, col_x=1)         # duplicate col_x
    with pytest.raises(TypeError):
        pkfk_join(jax.random.PRNGKey(5), dbX, dbY, 1, 0,
                  key=jax.random.PRNGKey(6))       # duplicate key


def test_range_queries_match_legacy(client, employee_db):
    res = client.range_count("Salary", 1000, 2000, reduce_every=2)
    cnt, led = range_count(jax.random.PRNGKey(0), employee_db, 3, 1000,
                           2000, reduce_every=2)
    assert res.count == cnt == 2
    assert res.ledger == led

    db34 = outsource(jax.random.PRNGKey(20), EMPLOYEE, column_names=COLUMNS,
                     codec=CODEC, n_shares=34, degree=1,
                     numeric_columns={3: 14})
    res = QueryClient(db34, key=5).range_select("Salary", 400, 1500)
    rows, addrs, led = range_select(jax.random.PRNGKey(0), db34, 3, 400,
                                    1500)
    assert res.rows == rows == [EMPLOYEE[0], EMPLOYEE[2]]
    assert res.addresses == addrs == [0, 2]
    assert res.ledger == led


def test_join_matches_legacy():
    codec = Codec(word_length=6)
    X = [["a1", "b1"], ["a2", "b2"], ["a3", "b3"]]
    Y = [["b1", "c1"], ["b2", "c2"], ["b2", "c3"], ["b2", "c4"]]
    dbX = outsource(jax.random.PRNGKey(1), X, column_names=["A", "B"],
                    codec=codec, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(2), Y, column_names=["B", "C"],
                    codec=codec, n_shares=16)
    res = QueryClient(dbX, key=3).join(dbY, on=("B", "B"))
    rows, led = pkfk_join(dbX, dbY, 1, 0)           # legacy key-less form
    assert res.rows == rows
    assert res.strategy == "pkfk"
    # keyed join re-randomizes outputs: same traffic/rounds, extra metered
    # cloud work for the zero-sharing additions
    assert res.ledger.communication_bits == led.communication_bits
    assert res.ledger.rounds == led.rounds == 1
    assert res.ledger.cloud_ops_bits > led.cloud_ops_bits

    res = QueryClient(dbX, key=4).join(dbY, on=("B", "B"), kind="equi",
                                       padding=Padding.fake_values(2))
    assert sorted(map(tuple, res.rows)) == sorted(map(tuple, rows))
    # 2 common values + 2 fake jobs, 2 rounds each (k hidden), 1 column open
    assert res.ledger.rounds == 1 + 2 * 4


def test_pkfk_join_key_rerandomizes_but_preserves_result():
    """The new key parameter re-randomizes transmitted shares (zero-sharing
    added) without changing the joined relation."""
    codec = Codec(word_length=6)
    X = [["a1", "b1"], ["a2", "b2"]]
    Y = [["b1", "c1"], ["b2", "c2"], ["b9", "c3"]]
    dbX = outsource(jax.random.PRNGKey(3), X, codec=codec, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(4), Y, codec=codec, n_shares=16)
    rows_legacy, _ = pkfk_join(dbX, dbY, 1, 0)
    rows_keyed, _ = pkfk_join(jax.random.PRNGKey(5), dbX, dbY, 1, 0)
    assert rows_keyed == rows_legacy == [["a1", "b1", "c1"],
                                        ["a2", "b2", "c2"]]


def test_per_query_keys_fold_in_deterministically(employee_db):
    a = QueryClient(employee_db, key=42)
    b = QueryClient(employee_db, key=42)
    ra, rb = a.count("FirstName", "Eve"), b.count("FirstName", "Eve")
    assert ra.count == rb.count == 1
    # same root key, same counter -> same derived key; counter advances
    k1 = QueryClient(employee_db, key=42)._next_key()
    k2 = QueryClient(employee_db, key=42)._next_key()
    assert np.array_equal(np.asarray(k1), np.asarray(k2))


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

def test_registry_has_builtins():
    names = available_backends()
    assert "jnp" in names and "pallas" in names
    assert get_backend("jnp").name == "jnp"
    with pytest.raises(ValueError):
        get_backend("cuda-nope")


def test_client_pallas_backend_matches_jnp(employee_db):
    rj = QueryClient(employee_db, key=1).count("FirstName", "John")
    rp = QueryClient(employee_db, key=1, backend="pallas").count(
        "FirstName", "John")
    assert rj.count == rp.count == 2
    assert rj.ledger == rp.ledger


def test_impl_alias_is_deprecated(employee_db):
    with pytest.warns(DeprecationWarning):
        cnt, _ = count_query(jax.random.PRNGKey(0), employee_db, 1, "Eve",
                             impl="jnp")
    assert cnt == 1


# ---------------------------------------------------------------------------
# MapReduce executor path
# ---------------------------------------------------------------------------

def _mr_client(db, **pool_kw):
    pool = WorkerPool(3, **pool_kw)
    runner = MapReduceRunner(pool, lease_s=5.0, max_attempts=30)
    return QueryClient(db, key=42,
                       executor=MapReduceExecutor(runner, n_splits=3))


def test_mapreduce_executor_count_and_select(employee_db):
    cl = _mr_client(employee_db)
    assert cl.backend.name == "jnp+mapreduce"
    plain = QueryClient(employee_db, key=42)
    res_mr, res = cl.count("FirstName", "John"), plain.count("FirstName",
                                                             "John")
    assert res_mr.count == res.count == 2
    assert res_mr.ledger == res.ledger      # fan-out is cost-transparent
    sel_mr = cl.select("Department", "Sale", strategy="one_round")
    sel = plain.select("Department", "Sale", strategy="one_round")
    assert sel_mr.rows == sel.rows and sel_mr.addresses == [0, 2, 3]
    assert sel_mr.ledger == sel.ledger


def test_mapreduce_executor_handles_zero_matches(employee_db):
    cl = _mr_client(employee_db)
    res = cl.select("FirstName", "Nobody", strategy="one_round")
    assert res.rows == [] and res.addresses == []


@pytest.mark.slow
def test_mapreduce_executor_survives_worker_failures(employee_db):
    cl = _mr_client(employee_db, fail_prob=0.3, seed=3)
    res = cl.count("FirstName", "John")
    assert res.count == 2
