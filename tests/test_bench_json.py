"""The benchmark harness must emit a well-formed BENCH_queries.json.

Runs a trimmed bench (one table section + a tiny batched sweep) through the
real ``collect``/``main`` path and validates the schema the CI bench-smoke
lane (and future perf-trajectory tooling) relies on.
"""
import importlib.util
import json
import pathlib

import pytest

_BENCH = pathlib.Path(__file__).resolve().parent.parent / "benchmarks" \
    / "bench_queries.py"


@pytest.fixture(scope="module")
def bq():
    spec = importlib.util.spec_from_file_location("bench_queries", _BENCH)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_bench_json_well_formed(bq, tmp_path, monkeypatch):
    monkeypatch.setattr(bq, "ALL", [bq.bench_count])
    monkeypatch.setattr(bq, "SMOKE_SIZES", {"bench_count": (16,)})
    real_sweep = bq.bench_batched_vs_sequential
    monkeypatch.setattr(
        bq, "bench_batched_vs_sequential",
        lambda **kw: real_sweep(batch_sizes=(2,), n=16))
    out = tmp_path / "BENCH_queries.json"
    bq.main(["--smoke", "--out", str(out)])

    doc = json.loads(out.read_text())
    assert doc["schema"] == "bench_queries/v1"
    assert doc["smoke"] is True
    assert doc["results"] and doc["batched"]
    for row in doc["results"]:
        assert {"bench", "name", "n", "us_per_call", "comm_bits", "rounds",
                "cloud_bits", "user_bits", "paper_claim"} <= set(row)
        assert isinstance(row["rounds"], int) and row["rounds"] >= 0
    for row in doc["batched"]:
        assert {"name", "n", "batch", "seq_us", "batch_us", "speedup",
                "rounds", "comm_bits", "ledger_equal"} <= set(row)
        assert row["ledger_equal"] is True
