"""The benchmark harness must emit a well-formed BENCH_queries.json, and
the protocol-cost comparator must gate on it.

Runs a trimmed bench (one table section + a tiny batched sweep) through the
real ``collect``/``main`` path and validates the schema the CI bench-smoke
lane (and the cross-PR ``benchmarks/compare_bench.py`` gate) relies on,
then exercises the comparator's regression verdicts on synthetic artifacts.
"""
import importlib.util
import json
import pathlib

import pytest

_BENCHDIR = pathlib.Path(__file__).resolve().parent.parent / "benchmarks"
_BENCH = _BENCHDIR / "bench_queries.py"
_COMPARE = _BENCHDIR / "compare_bench.py"
_PLOT = _BENCHDIR / "plot_history.py"


def _load_module(path):
    spec = importlib.util.spec_from_file_location(path.stem, path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def bq():
    return _load_module(_BENCH)


@pytest.fixture(scope="module")
def cb():
    return _load_module(_COMPARE)


@pytest.fixture(scope="module")
def ph():
    return _load_module(_PLOT)


def test_bench_json_well_formed(bq, tmp_path, monkeypatch):
    monkeypatch.setattr(bq, "ALL", [bq.bench_count])
    monkeypatch.setattr(bq, "SMOKE_SIZES", {"bench_count": (16,)})
    real_sweep = bq.bench_batched_vs_sequential
    monkeypatch.setattr(
        bq, "bench_batched_vs_sequential",
        lambda **kw: real_sweep(batch_sizes=(2,), n=16))
    real_sharded = bq.bench_sharded_dataplane
    monkeypatch.setattr(
        bq, "bench_sharded_dataplane",
        lambda **kw: real_sharded(n=16, batch=4, shard_counts=(1, 2)))
    real_serving = bq.bench_multi_tenant_serving
    monkeypatch.setattr(
        bq, "bench_multi_tenant_serving",
        lambda **kw: real_serving(n=16, queries=3))
    real_agg = bq.bench_aggregation
    monkeypatch.setattr(
        bq, "bench_aggregation", lambda **kw: real_agg(n=16))
    real_pattern = bq.bench_pattern
    monkeypatch.setattr(
        bq, "bench_pattern", lambda **kw: real_pattern(n=16, batch=4))
    real_embed = bq.bench_embedding
    monkeypatch.setattr(
        bq, "bench_embedding",
        lambda **kw: real_embed(vocab=128, d_model=16, n_tokens=256,
                                shard_counts=(1,)))
    out = tmp_path / "BENCH_queries.json"
    bq.main(["--smoke", "--out", str(out)])

    doc = json.loads(out.read_text())
    assert doc["schema"] == "bench_queries/v1"
    assert doc["smoke"] is True
    assert doc["results"] and doc["batched"] and doc["sharded"]
    for row in doc["results"]:
        assert {"bench", "name", "n", "us_per_call", "comm_bits", "rounds",
                "cloud_bits", "user_bits", "paper_claim"} <= set(row)
        assert isinstance(row["rounds"], int) and row["rounds"] >= 0
    for row in doc["batched"]:
        assert {"name", "n", "batch", "seq_us", "batch_us", "speedup",
                "rounds", "comm_bits", "ledger_equal"} <= set(row)
        assert row["ledger_equal"] is True
    rounds = set()
    for row in doc["sharded"]:
        assert {"name", "n", "batch", "shards", "dispatches", "steps",
                "shard_rows", "rounds", "comm_bits",
                "ledger_equal"} <= set(row)
        assert row["ledger_equal"] is True
        # S blocks of ceil(n/S) tuples, one dispatch per shard per step
        assert row["shard_rows"] == -(-row["n"] // row["shards"])
        assert row["dispatches"] == row["steps"] * row["shards"]
        rounds.add(row["rounds"])
    assert len(rounds) == 1          # rounds never move with S
    # the tiny sweep covers all three batched families
    names = {row["name"] for row in doc["batched"]}
    assert {"batched_range", "batched_join_pkfk"} <= names
    # multi-tenant serving sweep: one server over 2 relations == solo
    assert doc["serving"]
    for row in doc["serving"]:
        assert {"name", "n", "relations", "queries", "rounds", "comm_bits",
                "served_by_relation", "ledger_equal"} <= set(row)
        assert row["ledger_equal"] is True and row["relations"] == 2
        assert sum(row["served_by_relation"].values()) == row["queries"]
    # private-analytics sweep: every op priced, verification overhead > 0
    assert doc["aggregation"]
    agg_names = {row["name"] for row in doc["aggregation"]}
    assert {"agg_sum", "agg_avg_cond", "agg_min_cond",
            "agg_max"} <= agg_names
    for row in doc["aggregation"]:
        assert {"name", "n", "batch", "rounds", "comm_bits",
                "verify_rounds", "verify_comm_bits",
                "ledger_equal"} <= set(row)
        assert row["ledger_equal"] is True
        assert row["verify_rounds"] >= 1 and row["verify_comm_bits"] > 0
    # pattern engine sweep: every acceptance flag survives the real run
    assert doc["pattern"]
    pat_names = {row["name"] for row in doc["pattern"]}
    assert {"pattern_count_contains", "pattern_select_one_round",
            "pattern_like_eq_parity", "pattern_mixed_batch"} <= pat_names
    for row in doc["pattern"]:
        assert {"name", "n", "rounds", "comm_bits"} <= set(row)
        assert row.get("explain_exact", True) is True
        assert row.get("eq_parity", True) is True
        assert row.get("ledger_equal", True) is True
    # embedding fast path: the acceptance shape survives the real sweep
    assert doc["embedding"]
    for row in doc["embedding"]:
        assert {"name", "vocab", "d_model", "n_tokens", "shards",
                "tokens_per_sec", "baseline_tokens_per_sec", "speedup",
                "dispatches_per_step", "per_token_bits", "rounds",
                "comm_bits", "verify_rounds", "verify_comm_bits",
                "placed_bytes", "ledger_equal"} <= set(row)
        assert row["ledger_equal"] is True
        assert row["dispatches_per_step"] == row["shards"]
        assert row["speedup"] >= 5.0 and row["placed_bytes"] > 0


# ---------------------------------------------------------------------------
# compare_bench.py: the protocol-cost regression gate
# ---------------------------------------------------------------------------

def _doc():
    return {
        "schema": "bench_queries/v1", "smoke": True,
        "results": [
            {"bench": "bench_count", "name": "count_3.1", "n": 16,
             "us_per_call": 10, "comm_bits": 1000, "rounds": 1,
             "cloud_bits": 50, "user_bits": 5, "paper_claim": ""},
            {"bench": "bench_range", "name": "range_count_3.4", "n": 16,
             "us_per_call": 90, "comm_bits": 9000, "rounds": 13,
             "cloud_bits": 70, "user_bits": 6, "paper_claim": ""},
        ],
        "batched": [
            {"name": "batched_range", "n": 16, "batch": 4, "seq_us": 40,
             "batch_us": 10, "speedup": 4.0, "rounds": 13,
             "comm_bits": 9000, "ledger_equal": True},
        ],
    }


def _write(tmp_path, name, doc):
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def test_compare_bench_passes_on_identical_docs(cb, tmp_path, capsys):
    new = _write(tmp_path, "new.json", _doc())
    old = _write(tmp_path, "old.json", _doc())
    assert cb.main([new, old]) == 0
    assert "no protocol-cost regressions" in capsys.readouterr().out


def test_compare_bench_fails_on_round_or_bit_increase(cb, tmp_path):
    for field, delta in (("rounds", 1), ("comm_bits", 31)):
        doc = _doc()
        doc["results"][1][field] += delta
        new = _write(tmp_path, f"new_{field}.json", doc)
        old = _write(tmp_path, f"old_{field}.json", _doc())
        assert cb.main([new, old]) == 1
    # improvements (and wall-time noise) pass
    doc = _doc()
    doc["results"][1]["rounds"] -= 1
    doc["results"][1]["us_per_call"] *= 100
    assert cb.main([_write(tmp_path, "imp.json", doc),
                    _write(tmp_path, "base.json", _doc())]) == 0


def test_compare_bench_missing_and_new_configs(cb, tmp_path, capsys):
    # dropped config: fatal unless --allow-missing
    doc = _doc()
    del doc["results"][1]
    new = _write(tmp_path, "dropped.json", doc)
    old = _write(tmp_path, "full.json", _doc())
    assert cb.main([new, old]) == 1
    assert cb.main([new, old, "--allow-missing"]) == 0
    # added config: informational only
    doc = _doc()
    doc["results"].append(dict(doc["results"][0], name="new_query"))
    assert cb.main([_write(tmp_path, "added.json", doc), old]) == 0
    assert "new config" in capsys.readouterr().out


def test_compare_bench_fails_on_broken_ledger_identity(cb, tmp_path):
    doc = _doc()
    doc["batched"][0]["ledger_equal"] = False
    assert cb.main([_write(tmp_path, "bad.json", doc),
                    _write(tmp_path, "ok.json", _doc())]) == 1


def test_compare_bench_rejects_unknown_schema(cb, tmp_path):
    doc = _doc()
    doc["schema"] = "bench_queries/v0"
    assert cb.main([_write(tmp_path, "bad.json", doc),
                    _write(tmp_path, "ok.json", _doc())]) == 2


# ---------------------------------------------------------------------------
# sharded section gating
# ---------------------------------------------------------------------------

def _sharded_doc():
    doc = _doc()
    doc["sharded"] = [
        {"name": "sharded_batch", "n": 16, "batch": 4, "shards": 2,
         "dispatches": 12, "steps": 6, "shard_rows": 8, "wall_us": 10,
         "rounds": 13, "comm_bits": 9000, "ledger_equal": True},
    ]
    return doc


def test_compare_bench_gates_sharded_costs(cb, tmp_path):
    new = _write(tmp_path, "s_new.json", _sharded_doc())
    old = _write(tmp_path, "s_old.json", _sharded_doc())
    assert cb.main([new, old]) == 0
    # cost increase in the sharded sweep is a regression
    doc = _sharded_doc()
    doc["sharded"][0]["comm_bits"] += 31
    assert cb.main([_write(tmp_path, "s_up.json", doc), old]) == 1
    # broken transcript identity is a regression
    doc = _sharded_doc()
    doc["sharded"][0]["ledger_equal"] = False
    assert cb.main([_write(tmp_path, "s_bad.json", doc), old]) == 1
    # an OLD baseline without the section is not a "vanished config"
    assert cb.main([new, _write(tmp_path, "s_v1.json", _doc())]) == 0


# ---------------------------------------------------------------------------
# BENCH trajectory history (bench_history/v1)
# ---------------------------------------------------------------------------

def test_history_appends_schema_versioned_series(cb, tmp_path):
    hist_path = tmp_path / "BENCH_history.json"
    new = _write(tmp_path, "h_new.json", _sharded_doc())
    # first run: no baseline needed, file created
    assert cb.main([new, "--append-history", str(hist_path),
                    "--history-label", "pr-4"]) == 0
    # second run chains onto the same file (with a compare this time)
    old = _write(tmp_path, "h_old.json", _sharded_doc())
    assert cb.main([new, old, "--append-history", str(hist_path),
                    "--history-label", "pr-5"]) == 0
    h = json.loads(hist_path.read_text())
    assert h["schema"] == "bench_history/v1"
    assert [r["label"] for r in h["runs"]] == ["pr-4", "pr-5"]
    for run in h["runs"]:
        assert run["table"]["bench_count/count_3.1/16"] == {
            "rounds": 1, "comm_bits": 1000}
        assert run["batched"]["batched_range/4/16"]["rounds"] == 13
        assert run["sharded"]["sharded_batch/2/16"]["comm_bits"] == 9000
    cb.validate_history(h)


def test_history_validation_rejects_malformed(cb, tmp_path):
    import pytest as _pytest
    with _pytest.raises(ValueError):
        cb.validate_history({"schema": "bench_history/v0", "runs": []})
    with _pytest.raises(ValueError):
        cb.validate_history({"schema": "bench_history/v1",
                             "runs": [{"table": {}}]})   # no label
    with _pytest.raises(ValueError):
        cb.validate_history({"schema": "bench_history/v1", "runs": [
            {"label": "x", "table": {"a/b/1": {"rounds": 1}}}]})  # no bits
    # appending onto a corrupt history is refused, not silently rebuilt
    bad = tmp_path / "bad_history.json"
    bad.write_text(json.dumps({"schema": "nope", "runs": []}))
    new = _write(tmp_path, "hv_new.json", _doc())
    assert cb.main([new, "--append-history", str(bad)]) == 2


def test_history_requires_baseline_or_history_flag(cb, tmp_path):
    import pytest as _pytest
    new = _write(tmp_path, "solo.json", _doc())
    with _pytest.raises(SystemExit):
        cb.main([new])


# ---------------------------------------------------------------------------
# serving (multi-tenant) section gating
# ---------------------------------------------------------------------------

def _serving_doc():
    doc = _sharded_doc()
    doc["serving"] = [
        {"name": "multi_tenant_mixed", "n": 16, "relations": 2,
         "queries": 6, "wall_us": 10, "rounds": 12, "comm_bits": 60000,
         "served_by_relation": {"alpha": 3, "beta": 3},
         "ledger_equal": True},
    ]
    return doc


def test_compare_bench_gates_serving_costs(cb, tmp_path):
    new = _write(tmp_path, "mt_new.json", _serving_doc())
    old = _write(tmp_path, "mt_old.json", _serving_doc())
    assert cb.main([new, old]) == 0
    doc = _serving_doc()
    doc["serving"][0]["rounds"] += 1
    assert cb.main([_write(tmp_path, "mt_up.json", doc), old]) == 1
    # multi-tenant != solo-server ledger is a regression
    doc = _serving_doc()
    doc["serving"][0]["ledger_equal"] = False
    assert cb.main([_write(tmp_path, "mt_bad.json", doc), old]) == 1
    # an OLD baseline without the section is not a "vanished config"
    assert cb.main([new, _write(tmp_path, "mt_v1.json",
                                _sharded_doc())]) == 0
    # the history entry carries the serving costs too
    hist = tmp_path / "mt_history.json"
    assert cb.main([new, "--append-history", str(hist)]) == 0
    h = json.loads(hist.read_text())
    assert h["runs"][0]["serving"]["multi_tenant_mixed/2/16"] == {
        "rounds": 12, "comm_bits": 60000}


# ---------------------------------------------------------------------------
# aggregation (private analytics) section gating
# ---------------------------------------------------------------------------

def _aggregation_doc():
    doc = _serving_doc()
    doc["aggregation"] = [
        {"name": "agg_min_cond", "n": 16, "batch": 5, "rounds": 29,
         "comm_bits": 613180, "verify_rounds": 1, "verify_comm_bits": 1240,
         "batch_us": 10, "ledger_equal": True},
    ]
    return doc


def test_compare_bench_gates_aggregation_costs(cb, tmp_path):
    new = _write(tmp_path, "ag_new.json", _aggregation_doc())
    old = _write(tmp_path, "ag_old.json", _aggregation_doc())
    assert cb.main([new, old]) == 0
    # cost increases — including the *verification* overhead — regress
    for field in ("rounds", "comm_bits", "verify_rounds",
                  "verify_comm_bits"):
        doc = _aggregation_doc()
        doc["aggregation"][0][field] += 1
        assert cb.main([_write(tmp_path, f"ag_{field}.json", doc),
                        old]) == 1
    # batched != sequential ledger is a regression
    doc = _aggregation_doc()
    doc["aggregation"][0]["ledger_equal"] = False
    assert cb.main([_write(tmp_path, "ag_bad.json", doc), old]) == 1
    # an OLD baseline without the section is not a "vanished config"
    assert cb.main([new, _write(tmp_path, "ag_v1.json",
                                _serving_doc())]) == 0
    # the history entry carries the aggregation costs too
    hist = tmp_path / "ag_history.json"
    assert cb.main([new, "--append-history", str(hist)]) == 0
    h = json.loads(hist.read_text())
    assert h["runs"][0]["aggregation"]["agg_min_cond/5/16"] == {
        "rounds": 29, "comm_bits": 613180}
    cb.validate_history(h)


# ---------------------------------------------------------------------------
# pattern (LIKE/prefix/suffix/substring engine) section gating
# ---------------------------------------------------------------------------

def _pattern_doc():
    doc = _aggregation_doc()
    doc["pattern"] = [
        {"name": "pattern_count_contains", "n": 16, "us_per_call": 10,
         "rounds": 2, "comm_bits": 12000, "explain_exact": True},
        {"name": "pattern_like_eq_parity", "n": 16, "rounds": 1,
         "comm_bits": 6000, "eq_parity": True},
        {"name": "pattern_mixed_batch", "n": 16, "batch": 4, "seq_us": 40,
         "batch_us": 10, "speedup": 4.0, "rounds": 2, "comm_bits": 30000,
         "ledger_equal": True},
    ]
    return doc


def test_compare_bench_gates_pattern_costs(cb, tmp_path):
    new = _write(tmp_path, "pt_new.json", _pattern_doc())
    old = _write(tmp_path, "pt_old.json", _pattern_doc())
    assert cb.main([new, old]) == 0
    # cost increase in the pattern sweep is a regression
    doc = _pattern_doc()
    doc["pattern"][0]["comm_bits"] += 31
    assert cb.main([_write(tmp_path, "pt_up.json", doc), old]) == 1
    # a drifted cost model / broken LIKE==Eq parity / broken fusion all
    # regress even when the baseline row agrees
    for idx, flag in ((0, "explain_exact"), (1, "eq_parity"),
                      (2, "ledger_equal")):
        doc = _pattern_doc()
        doc["pattern"][idx][flag] = False
        old_doc = _pattern_doc()
        old_doc["pattern"][idx][flag] = False
        assert cb.main([_write(tmp_path, f"pt_{flag}.json", doc),
                        _write(tmp_path, f"pt_{flag}_old.json",
                               old_doc)]) == 1
    # an OLD baseline without the section is not a "vanished config"
    assert cb.main([new, _write(tmp_path, "pt_v1.json",
                                _aggregation_doc())]) == 0
    # the history entry carries the pattern costs too
    hist = tmp_path / "pt_history.json"
    assert cb.main([new, "--append-history", str(hist)]) == 0
    h = json.loads(hist.read_text())
    assert h["runs"][0]["pattern"]["pattern_count_contains/16"] == {
        "rounds": 2, "comm_bits": 12000}
    cb.validate_history(h)


# ---------------------------------------------------------------------------
# embedding (oblivious lookup fast path) section gating
# ---------------------------------------------------------------------------

def _embedding_doc():
    doc = _aggregation_doc()
    doc["embedding"] = [
        {"name": "embed_s2", "vocab": 512, "d_model": 32, "n_tokens": 256,
         "shards": 2, "tokens_per_sec": 1300.0,
         "baseline_tokens_per_sec": 65.0, "speedup": 20.0,
         "dispatches_per_step": 2, "per_token_bits": 67456, "rounds": 1,
         "comm_bits": 17268736, "verify_rounds": 1, "verify_comm_bits": 124,
         "placed_bytes": 262144, "ledger_equal": True},
    ]
    return doc


def test_compare_bench_gates_embedding_costs(cb, tmp_path):
    new = _write(tmp_path, "em_new.json", _embedding_doc())
    old = _write(tmp_path, "em_old.json", _embedding_doc())
    assert cb.main([new, old]) == 0
    # cost increases — including verify overhead, per-token bits and the
    # dispatch count per decode step — regress
    for field in ("rounds", "comm_bits", "verify_rounds",
                  "verify_comm_bits", "per_token_bits",
                  "dispatches_per_step"):
        doc = _embedding_doc()
        doc["embedding"][0][field] += 1
        assert cb.main([_write(tmp_path, f"em_{field}.json", doc),
                        old]) == 1
    # batched != sequential ledger is a regression
    doc = _embedding_doc()
    doc["embedding"][0]["ledger_equal"] = False
    assert cb.main([_write(tmp_path, "em_bad.json", doc), old]) == 1
    # speedup below the 5x acceptance floor is a regression even with a
    # clean ledger — the fast path exists for the ratio
    doc = _embedding_doc()
    doc["embedding"][0]["speedup"] = 3.9
    assert cb.main([_write(tmp_path, "em_slow.json", doc), old]) == 1
    # dispatches per step != shard count (lost fusion) is a regression
    # even when the baseline row agrees
    doc = _embedding_doc()
    doc["embedding"][0]["dispatches_per_step"] = 4
    old_doc = _embedding_doc()
    old_doc["embedding"][0]["dispatches_per_step"] = 4
    assert cb.main([_write(tmp_path, "em_fan.json", doc),
                    _write(tmp_path, "em_fan_old.json", old_doc)]) == 1
    # an OLD baseline without the section is not a "vanished config"
    assert cb.main([new, _write(tmp_path, "em_v1.json",
                                _aggregation_doc())]) == 0
    # the history entry carries the embedding costs too
    hist = tmp_path / "em_history.json"
    assert cb.main([new, "--append-history", str(hist)]) == 0
    h = json.loads(hist.read_text())
    assert h["runs"][0]["embedding"]["embed_s2/2/256"] == {
        "rounds": 1, "comm_bits": 17268736, "per_token_bits": 67456,
        "dispatches_per_step": 2, "tokens_per_sec": 1300.0,
        "speedup": 20.0}
    cb.validate_history(h)


# ---------------------------------------------------------------------------
# plot_history.py: per-config trend tables over the time series
# ---------------------------------------------------------------------------

def _history(tmp_path, cb, docs_labels):
    hist = tmp_path / "trend_history.json"
    for i, (doc, label) in enumerate(docs_labels):
        p = _write(tmp_path, f"trend_{i}.json", doc)
        assert cb.main([p, "--append-history", str(hist),
                        "--history-label", label]) == 0
    return str(hist)


def test_plot_history_flat_series(ph, cb, tmp_path, capsys):
    hist = _history(tmp_path, cb, [(_serving_doc(), "pr-4"),
                                   (_serving_doc(), "pr-5")])
    assert ph.main([hist]) == 0
    out = capsys.readouterr().out
    # one row per (config, metric), every run's value, flat verdict
    assert "bench_count/count_3.1/16" in out
    assert "sharded_batch/2/16" in out
    assert "multi_tenant_mixed/2/16" in out
    assert "pr-4" in out and "pr-5" in out
    assert "REGRESSED" not in out


def test_plot_history_flags_regression_and_improvement(ph, cb, tmp_path,
                                                       capsys):
    worse = _serving_doc()
    worse["results"][1]["rounds"] += 2          # cost crept up over time
    better = _serving_doc()
    better["batched"][0]["comm_bits"] -= 31
    hist = _history(tmp_path, cb, [(_serving_doc(), "pr-4"),
                                   (worse, "pr-5")])
    assert ph.main([hist]) == 1                 # trend regression -> fail
    out = capsys.readouterr().out
    assert "REGRESSED" in out
    hist2 = _history(tmp_path, cb, [(_serving_doc(), "a"), (better, "b")])
    assert ph.main([hist2]) == 0
    assert "improved" in capsys.readouterr().out


def test_plot_history_gate_recovers_after_accepted_increase(ph, cb,
                                                            tmp_path,
                                                            capsys):
    """The trend gate flags an increase ONCE (on the step that introduced
    it), then the series carries the new level — later runs must pass, or
    a single accepted increase would fail CI forever."""
    worse = _serving_doc()
    worse["results"][1]["rounds"] += 2
    hist = _history(tmp_path, cb, [(_serving_doc(), "r1"), (worse, "r2"),
                                   (worse, "r3")])
    assert ph.main([hist]) == 0
    assert "REGRESSED" not in capsys.readouterr().out


def test_plot_history_sections_filters_and_new_configs(ph, cb, tmp_path,
                                                       capsys):
    grown = _serving_doc()
    grown["results"].append(dict(grown["results"][0], name="new_query"))
    hist = _history(tmp_path, cb, [(_sharded_doc(), "old"),
                                   (grown, "new")])
    # a config absent from early runs shows "-" and doesn't crash
    assert ph.main([hist]) == 0
    out = capsys.readouterr().out
    assert "new_query" in out and "-" in out
    # section/metric filters narrow the table
    assert ph.main([hist, "--section", "batched", "--metric", "rounds",
                    "--format", "tsv"]) == 0
    out = capsys.readouterr().out
    assert "batched_range/4/16" in out
    assert "table" not in out.splitlines()[1]
    assert "comm_bits" not in out


def test_plot_history_rejects_malformed(ph, tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"schema": "nope", "runs": []}))
    assert ph.main([str(bad)]) == 2
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"schema": "bench_history/v1", "runs": []}))
    assert ph.main([str(empty)]) == 2
    assert ph.main([str(tmp_path / "missing.json")]) == 2


def test_plot_history_renders_aggregation_section(ph, cb, tmp_path,
                                                  capsys):
    hist = _history(tmp_path, cb, [(_aggregation_doc(), "pr-5"),
                                   (_aggregation_doc(), "pr-6")])
    assert ph.main([hist, "--section", "aggregation"]) == 0
    out = capsys.readouterr().out
    assert "agg_min_cond/5/16" in out
    assert "REGRESSED" not in out


def test_plot_history_renders_embedding_section(ph, cb, tmp_path, capsys):
    hist = _history(tmp_path, cb, [(_embedding_doc(), "pr-7"),
                                   (_embedding_doc(), "pr-8")])
    assert ph.main([hist, "--section", "embedding"]) == 0
    out = capsys.readouterr().out
    assert "embed_s2/2/256" in out
    assert "REGRESSED" not in out


def test_plot_history_renders_pattern_section(ph, cb, tmp_path, capsys):
    hist = _history(tmp_path, cb, [(_pattern_doc(), "pr-10"),
                                   (_pattern_doc(), "pr-11")])
    assert ph.main([hist, "--section", "pattern"]) == 0
    out = capsys.readouterr().out
    assert "pattern_count_contains/16" in out
    assert "REGRESSED" not in out


def test_plot_history_tolerates_unknown_sections(ph, cb, tmp_path, capsys):
    """History entries written by a NEWER compare_bench may carry section
    names this tool has never heard of (exactly how 'sharded', 'serving'
    and 'aggregation' themselves arrived). Unknown sections are skipped
    with a note — never a crash, never a silent verdict change."""
    hist = _history(tmp_path, cb, [(_serving_doc(), "pr-4"),
                                   (_serving_doc(), "pr-5")])
    h = json.loads(open(hist).read())
    h["runs"][-1]["quantum_oblivious"] = {           # future section
        "qo_thing/1/16": {"rounds": 3, "comm_bits": 42}}
    h["runs"][-1]["weird_payload"] = [1, 2, 3]       # non-dict payload
    open(hist, "w").write(json.dumps(h))
    assert ph.main([hist]) == 0
    captured = capsys.readouterr()
    assert "skipping unknown history section" in captured.err
    assert "quantum_oblivious" in captured.err
    assert "weird_payload" in captured.err
    assert "qo_thing" not in captured.out            # skipped, not rendered
    # a known section holding a non-dict degrades to "absent", not a crash
    h["runs"][-1]["batched"] = "oops"
    open(hist, "w").write(json.dumps(h))
    assert ph.main([hist]) == 0
    assert "batched_range/4/16" in capsys.readouterr().out
