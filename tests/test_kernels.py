"""Pallas kernel validation: shape sweeps vs pure-jnp oracles (exact match).

Field arithmetic is exact (no tolerance): any mismatch is a bug, so we use
array_equal, the strictest possible allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

P = 2**31 - 1
RNG = np.random.default_rng(42)


def rand_f(shape):
    return RNG.integers(0, P, size=shape, dtype=np.uint32)


# ---------------------------------------------------------------------------
# ss_matmul sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (8, 8, 8), (7, 13, 5), (128, 128, 128), (129, 127, 130),
    (3, 300, 2), (256, 64, 192), (37, 53, 29), (200, 1, 200),
])
def test_ss_matmul_shapes(m, k, n):
    a, b = rand_f((m, k)), rand_f((k, n))
    got = np.asarray(ops.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(ref.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(got, want)


def test_ss_matmul_extreme_values():
    """p-1 everywhere: worst case for limb overflow."""
    a = np.full((64, 96), P - 1, dtype=np.uint32)
    b = np.full((96, 64), P - 1, dtype=np.uint32)
    got = np.asarray(ops.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    want = (pow(P - 1, 2, P) * 96) % P
    assert np.all(got == want)


def test_ss_matmul_identity():
    n = 50
    eye = np.eye(n, dtype=np.uint32)
    x = rand_f((n, n))
    got = np.asarray(ops.ss_matmul(jnp.asarray(eye), jnp.asarray(x)))
    assert np.array_equal(got, x)


def test_ss_matmul_batched():
    a, b = rand_f((4, 17, 33)), rand_f((4, 33, 9))
    got = np.asarray(ops.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    for i in range(4):
        want = np.asarray(ref.ss_matmul(jnp.asarray(a[i]), jnp.asarray(b[i])))
        assert np.array_equal(got[i], want)


def test_ss_matmul_vs_bigint_oracle():
    """Double-check the jnp oracle itself against python bigints."""
    a, b = rand_f((9, 21)), rand_f((21, 6))
    want = (a.astype(object) @ b.astype(object)) % P
    got = np.asarray(ops.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(got.astype(object), want)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 40))
def test_ss_matmul_property(m, k, n):
    a, b = rand_f((m, k)), rand_f((k, n))
    got = np.asarray(ops.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(ref.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# aa_match sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,w,a", [
    (1, 1, 1), (8, 4, 16), (45, 6, 17), (512, 12, 69), (513, 8, 26),
    (100, 16, 128), (3, 2, 300),
])
def test_aa_match_shapes(n, w, a):
    col, pat = rand_f((n, w, a)), rand_f((w, a))
    got = np.asarray(ops.aa_match(jnp.asarray(col), jnp.asarray(pat)))
    want = np.asarray(ref.aa_match(jnp.asarray(col), jnp.asarray(pat)))
    assert np.array_equal(got, want)


def test_aa_match_onehot_semantics():
    """With real one-hots the kernel must return exact 0/1 matches."""
    from repro.core.encoding import Codec
    codec = Codec(word_length=6)
    col = codec.encode_column(["John", "Adam", "John", "Eve"])
    pat = codec.encode_word("John")
    got = np.asarray(ops.aa_match(jnp.asarray(col), jnp.asarray(pat)))
    assert got.tolist() == [1, 0, 1, 0]


def test_aa_match_batched_clouds():
    col, pat = rand_f((3, 20, 5, 11)), rand_f((3, 5, 11))
    got = np.asarray(ops.aa_match(jnp.asarray(col), jnp.asarray(pat)))
    for c in range(3):
        want = np.asarray(ref.aa_match(jnp.asarray(col[c]),
                                       jnp.asarray(pat[c])))
        assert np.array_equal(got[c], want)


# ---------------------------------------------------------------------------
# kernels wired into the query suite ≡ jnp implementation
# ---------------------------------------------------------------------------

def test_count_query_pallas_equals_jnp():
    from repro.core import outsource, Codec
    from repro.core.queries import count_query
    rows = [["a", "John"], ["b", "Eve"], ["c", "John"], ["d", "Dan"]]
    db = outsource(jax.random.PRNGKey(0), rows, codec=Codec(word_length=6),
                   n_shares=16)
    got_p, _ = count_query(jax.random.PRNGKey(1), db, 1, "John",
                           impl="pallas")
    got_j, _ = count_query(jax.random.PRNGKey(1), db, 1, "John", impl="jnp")
    assert got_p == got_j == 2


def test_select_fetch_pallas_equals_jnp():
    from repro.core import outsource, Codec
    from repro.core.queries import select_one_round
    rows = [["a", "x1"], ["b", "x2"], ["c", "x1"], ["d", "x3"]]
    db = outsource(jax.random.PRNGKey(2), rows, codec=Codec(word_length=6),
                   n_shares=16)
    rp, ap, _ = select_one_round(jax.random.PRNGKey(3), db, 1, "x1",
                                 impl="pallas")
    rj, aj, _ = select_one_round(jax.random.PRNGKey(3), db, 1, "x1",
                                 impl="jnp")
    assert rp == rj and ap == aj == [0, 2]


def test_pkfk_join_pallas_equals_jnp():
    from repro.core import outsource, Codec
    from repro.core.queries import pkfk_join
    codec = Codec(word_length=6)
    X = [["a1", "b1"], ["a2", "b2"]]
    Y = [["b1", "c1"], ["b2", "c2"], ["b2", "c3"]]
    dbX = outsource(jax.random.PRNGKey(4), X, codec=codec, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(5), Y, codec=codec, n_shares=16)
    rp, _ = pkfk_join(dbX, dbY, 1, 0, impl="pallas")
    rj, _ = pkfk_join(dbX, dbY, 1, 0, impl="jnp")
    assert rp == rj
