"""Pallas kernel validation: shape sweeps vs pure-jnp oracles (exact match).

Field arithmetic is exact (no tolerance): any mismatch is a bug, so we use
array_equal, the strictest possible allclose.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

P = 2**31 - 1
RNG = np.random.default_rng(42)


def rand_f(shape):
    return RNG.integers(0, P, size=shape, dtype=np.uint32)


# ---------------------------------------------------------------------------
# ss_matmul sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (1, 1, 1), (8, 8, 8), (7, 13, 5), (128, 128, 128), (129, 127, 130),
    (3, 300, 2), (256, 64, 192), (37, 53, 29), (200, 1, 200),
])
def test_ss_matmul_shapes(m, k, n):
    a, b = rand_f((m, k)), rand_f((k, n))
    got = np.asarray(ops.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(ref.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(got, want)


def test_ss_matmul_extreme_values():
    """p-1 everywhere: worst case for limb overflow."""
    a = np.full((64, 96), P - 1, dtype=np.uint32)
    b = np.full((96, 64), P - 1, dtype=np.uint32)
    got = np.asarray(ops.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    want = (pow(P - 1, 2, P) * 96) % P
    assert np.all(got == want)


def test_ss_matmul_identity():
    n = 50
    eye = np.eye(n, dtype=np.uint32)
    x = rand_f((n, n))
    got = np.asarray(ops.ss_matmul(jnp.asarray(eye), jnp.asarray(x)))
    assert np.array_equal(got, x)


def test_ss_matmul_batched():
    a, b = rand_f((4, 17, 33)), rand_f((4, 33, 9))
    got = np.asarray(ops.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    for i in range(4):
        want = np.asarray(ref.ss_matmul(jnp.asarray(a[i]), jnp.asarray(b[i])))
        assert np.array_equal(got[i], want)


def test_ss_matmul_vs_bigint_oracle():
    """Double-check the jnp oracle itself against python bigints."""
    a, b = rand_f((9, 21)), rand_f((21, 6))
    want = (a.astype(object) @ b.astype(object)) % P
    got = np.asarray(ops.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(got.astype(object), want)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 40))
def test_ss_matmul_property(m, k, n):
    a, b = rand_f((m, k)), rand_f((k, n))
    got = np.asarray(ops.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(ref.ss_matmul(jnp.asarray(a), jnp.asarray(b)))
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# aa_match sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,w,a", [
    (1, 1, 1), (8, 4, 16), (45, 6, 17), (512, 12, 69), (513, 8, 26),
    (100, 16, 128), (3, 2, 300),
])
def test_aa_match_shapes(n, w, a):
    col, pat = rand_f((n, w, a)), rand_f((w, a))
    got = np.asarray(ops.aa_match(jnp.asarray(col), jnp.asarray(pat)))
    want = np.asarray(ref.aa_match(jnp.asarray(col), jnp.asarray(pat)))
    assert np.array_equal(got, want)


def test_aa_match_onehot_semantics():
    """With real one-hots the kernel must return exact 0/1 matches."""
    from repro.core.encoding import Codec
    codec = Codec(word_length=6)
    col = codec.encode_column(["John", "Adam", "John", "Eve"])
    pat = codec.encode_word("John")
    got = np.asarray(ops.aa_match(jnp.asarray(col), jnp.asarray(pat)))
    assert got.tolist() == [1, 0, 1, 0]


def test_aa_match_batched_clouds():
    col, pat = rand_f((3, 20, 5, 11)), rand_f((3, 5, 11))
    got = np.asarray(ops.aa_match(jnp.asarray(col), jnp.asarray(pat)))
    for c in range(3):
        want = np.asarray(ref.aa_match(jnp.asarray(col[c]),
                                       jnp.asarray(pat[c])))
        assert np.array_equal(got[c], want)


# ---------------------------------------------------------------------------
# stacked-predicate batch kernel: 2-D grid == nested-vmap fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("c,b,n,w,a", [
    (1, 1, 1, 1, 1), (2, 3, 20, 5, 11), (3, 4, 45, 6, 17),
    (2, 2, 513, 4, 26), (4, 1, 37, 8, 26),
])
def test_aa_match_batch_grid_equals_vmap(c, b, n, w, a):
    col, pat = rand_f((c, b, n, w, a)), rand_f((c, b, w, a))
    got = np.asarray(ops.aa_match_batch(jnp.asarray(col), jnp.asarray(pat)))
    want = np.asarray(ops.aa_match_batch_vmap(jnp.asarray(col),
                                              jnp.asarray(pat)))
    assert got.shape == (c, b, n)
    assert np.array_equal(got, want)


def test_aa_match_batch_grid_vs_ref_oracle():
    c, b = 2, 3
    col, pat = rand_f((c, b, 45, 5, 11)), rand_f((c, b, 5, 11))
    got = np.asarray(ops.aa_match_batch(jnp.asarray(col), jnp.asarray(pat)))
    for i in range(c):
        for j in range(b):
            want = np.asarray(ref.aa_match(jnp.asarray(col[i, j]),
                                           jnp.asarray(pat[i, j])))
            assert np.array_equal(got[i, j], want)


# ---------------------------------------------------------------------------
# SS-SUB ripple bit step: pallas kernel == jnp reference
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1,), (7,), (3, 5, 40), (2, 6, 64)])
def test_ripple_carry_pallas_equals_jnp(shape):
    from repro.api.backends import jnp_ripple_carry
    a, b, carry = rand_f(shape), rand_f(shape), rand_f(shape)
    ja, jb, jc = jnp.asarray(a), jnp.asarray(b), jnp.asarray(carry)
    # LSB (init) step
    rb_p, co_p = ops.ripple_carry(ja, jb, None)
    rb_j, co_j = jnp_ripple_carry(ja, jb, None)
    assert np.array_equal(np.asarray(rb_p), np.asarray(rb_j))
    assert np.array_equal(np.asarray(co_p), np.asarray(co_j))
    # propagate step
    rb_p, co_p = ops.ripple_carry(ja, jb, jc)
    rb_j, co_j = jnp_ripple_carry(ja, jb, jc)
    assert np.array_equal(np.asarray(rb_p), np.asarray(rb_j))
    assert np.array_equal(np.asarray(co_p), np.asarray(co_j))


def test_ripple_carry_bigint_oracle():
    """One full ripple over both kernels must equal a python-int subtract
    sign on shares of real bit patterns (degree-0 'sharing' of the bits so
    the share-space math IS the plaintext math)."""
    t = 9
    for (x, bound) in [(12, 100), (255, 13), (5, 5), (-7, 3)]:
        xb = [(x >> i) & 1 if x >= 0 else ((x + (1 << t)) >> i) & 1
              for i in range(t)]
        bb = [(bound >> i) & 1 for i in range(t)]
        a_bits = jnp.asarray(np.asarray(xb, np.uint32)[None])   # A = x
        b_bits = jnp.asarray(np.asarray(bb, np.uint32)[None])   # B = bound
        rb, carry = ops.ripple_carry(a_bits[..., 0], b_bits[..., 0], None)
        for i in range(1, t):
            rb, carry = ops.ripple_carry(a_bits[..., i], b_bits[..., i],
                                         carry)
        want = 1 if (bound - x) < 0 else 0      # sign bit of B − A
        assert int(np.asarray(rb)[0]) == want, (x, bound)


# ---------------------------------------------------------------------------
# kernels wired into the query suite ≡ jnp implementation
# ---------------------------------------------------------------------------

def test_count_query_pallas_equals_jnp():
    from repro.core import outsource, Codec
    from repro.core.queries import count_query
    rows = [["a", "John"], ["b", "Eve"], ["c", "John"], ["d", "Dan"]]
    db = outsource(jax.random.PRNGKey(0), rows, codec=Codec(word_length=6),
                   n_shares=16)
    got_p, _ = count_query(jax.random.PRNGKey(1), db, 1, "John",
                           impl="pallas")
    got_j, _ = count_query(jax.random.PRNGKey(1), db, 1, "John", impl="jnp")
    assert got_p == got_j == 2


def test_select_fetch_pallas_equals_jnp():
    from repro.core import outsource, Codec
    from repro.core.queries import select_one_round
    rows = [["a", "x1"], ["b", "x2"], ["c", "x1"], ["d", "x3"]]
    db = outsource(jax.random.PRNGKey(2), rows, codec=Codec(word_length=6),
                   n_shares=16)
    rp, ap, _ = select_one_round(jax.random.PRNGKey(3), db, 1, "x1",
                                 impl="pallas")
    rj, aj, _ = select_one_round(jax.random.PRNGKey(3), db, 1, "x1",
                                 impl="jnp")
    assert rp == rj and ap == aj == [0, 2]


def test_pkfk_join_pallas_equals_jnp():
    from repro.core import outsource, Codec
    from repro.core.queries import pkfk_join
    codec = Codec(word_length=6)
    X = [["a1", "b1"], ["a2", "b2"]]
    Y = [["b1", "c1"], ["b2", "c2"], ["b2", "c3"]]
    dbX = outsource(jax.random.PRNGKey(4), X, codec=codec, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(5), Y, codec=codec, n_shares=16)
    rp, _ = pkfk_join(dbX, dbY, 1, 0, impl="pallas")
    rj, _ = pkfk_join(dbX, dbY, 1, 0, impl="jnp")
    assert rp == rj
