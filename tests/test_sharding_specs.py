"""Sharding-rule unit tests: specs build for every arch × shape without
touching devices (abstract mesh over 1 device is enough to validate rank
compatibility and divisibility fallbacks)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as configs
from repro import sharding as shd
from repro.models import init_params
from repro.models.config import ALL_SHAPES


@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_param_specs_rank_compatible(arch, mesh11):
    cfg = configs.smoke(arch)
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = shd.param_specs(cfg, mesh11, shapes)
    flat_s, _ = jax.tree_util.tree_flatten_with_path(shapes)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_s) == len(flat_p)
    for (path, leaf), spec in zip(flat_s, flat_p):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
@pytest.mark.parametrize("shape", ALL_SHAPES, ids=lambda s: s.name)
def test_batch_and_cache_specs_build(arch, shape, mesh11):
    cfg = configs.full(arch)
    bs = shd.batch_spec(cfg, mesh11, shape)
    assert "tokens" in bs
    cs = shd.cache_spec(cfg, mesh11, shape)
    if cfg.family == "ssm":
        assert "ssm" in cs and "kv" not in cs
    else:
        assert "kv" in cs


def test_divisibility_fallbacks_full_mesh():
    """On a 16-way model axis the documented fallbacks must trigger."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))  # shape-only checks

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    fm = FakeMesh()
    div_qwen = shd.Divisibility(configs.full("qwen1_5_4b"), fm)
    assert not div_qwen.q and div_qwen.vocab and div_qwen.ff
    div_moon = shd.Divisibility(configs.full("moonshot_v1_16b_a3b"), fm)
    assert div_moon.q and div_moon.kv and div_moon.experts
    div_mamba = shd.Divisibility(configs.full("mamba2_2_7b"), fm)
    assert div_mamba.ssm_h and not div_mamba.vocab
    div_granite = shd.Divisibility(configs.full("granite_moe_3b_a800m"), fm)
    assert not div_granite.experts and div_granite.ff


def test_decode_attention_matches_flash():
    """The §Perf chunked-LSE decode path is exact vs the flash oracle."""
    import jax.numpy as jnp
    from repro.models.layers import decode_attention, flash_attention
    rng = np.random.default_rng(0)
    B, S, HKV, G, D = 2, 1024, 2, 2, 32
    q = jnp.asarray(rng.normal(size=(B, 1, HKV * G, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, HKV, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, HKV, D)), jnp.float32)
    for kv_len in (64, 1000):
        for window in (None, jnp.int32(128)):
            a = decode_attention(q, k, v, kv_len=jnp.int32(kv_len),
                                 window=window)
            b = flash_attention(q, k, v, q_offset=kv_len - 1,
                                kv_len=jnp.int32(kv_len), window=window)
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=2e-3, rtol=1e-3)
