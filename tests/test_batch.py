"""Batched oblivious query engine: fusion + equivalence acceptance tests.

Two properties anchor this suite:

  1. *Round fusion*: ``select_tree`` issues exactly one device dispatch and
     one interpolation per Q&A round (never per block), and a
     ``run_batch`` group executes each protocol round once for the whole
     group (never per query).
  2. *Bit-identical accounting*: every query inside a batch returns the
     same rows/addresses and the same per-query ``CostLedger`` totals as
     the same plan run sequentially — batching is free in protocol cost.
"""
import jax
import pytest

from repro.api import (Between, Count, DBStats, Eq, Join, Padding,
                       QueryClient, RangeCount, RangeSelect, Select,
                       MapReduceExecutor, choose_select_strategy,
                       get_backend)
from repro.api.backends import Backend, batched_matcher, ripple_stepper
from repro.core import outsource, Codec
from repro.core.queries import CardinalityError, select_tree
from repro.core import shamir
from repro.runtime import MapReduceRunner, WorkerPool

CODEC = Codec(word_length=8)
COLUMNS = ["EmployeeId", "FirstName", "LastName", "Salary", "Department"]

EMPLOYEE = [
    ["E101", "Adam", "Smith", "1000", "Sale"],
    ["E102", "John", "Taylor", "2000", "Design"],
    ["E103", "Eve", "Smith", "500", "Sale"],
    ["E104", "John", "Williams", "5000", "Sale"],
]


@pytest.fixture(scope="module")
def employee_db():
    return outsource(jax.random.PRNGKey(7), EMPLOYEE, column_names=COLUMNS,
                     codec=CODEC, n_shares=20, degree=1,
                     numeric_columns={3: 14})


def _counting_backend(name="jnp"):
    """Wrap a registered backend so every hotspot dispatch is counted."""
    base = get_backend(name)
    calls = {"aa_match": 0, "aa_match_batch": 0, "ss_matmul": 0,
             "match_matrix": 0, "ripple_carry": 0}

    def wrap(op_name, fn):
        def run(a, b):
            calls[op_name] += 1
            return fn(a, b)
        return run

    base_ripple = ripple_stepper(base)

    def ripple(a, b, carry=None):
        calls["ripple_carry"] += 1
        return base_ripple(a, b, carry)

    be = Backend(
        name=f"{name}+counting",
        aa_match=wrap("aa_match", base.aa_match),
        ss_matmul=wrap("ss_matmul", base.ss_matmul),
        match_matrix=wrap("match_matrix", base.match_matrix),
        aa_match_batch=wrap("aa_match_batch", batched_matcher(base)),
        ripple_carry=ripple)
    return be, calls


def _count_interpolations(monkeypatch):
    counter = {"n": 0}
    real = shamir.interpolate

    def counting(shares, **kw):
        counter["n"] += 1
        return real(shares, **kw)

    monkeypatch.setattr(shamir, "interpolate", counting)
    return counter


def _assert_results_equal(a, b):
    assert a.strategy == b.strategy
    assert a.rows == b.rows
    assert a.addresses == b.addresses
    assert a.count == b.count
    assert a.ledger == b.ledger       # bit-for-bit: rounds, bits, ops


# ---------------------------------------------------------------------------
# acceptance: one dispatch + one interpolation per Q&A round
# ---------------------------------------------------------------------------

def _tree_db(n=64):
    # "John" clustered at 0,1 and 32,33 (ℓ=4). Q&A trace: round 1 splits
    # into 4 blocks of 16 (counts 2,0,2,0); round 2 splits the two live
    # blocks into 4×4 (one count-2 block each); round 3 isolates four
    # singles, all address-fetched in ONE fused round.
    rows = [[f"id{i}", "John" if i in (0, 1, 32, 33) else f"nm{i}"]
            for i in range(n)]
    return rows, outsource(jax.random.PRNGKey(3), rows, codec=CODEC,
                           n_shares=20)


def test_select_tree_one_dispatch_per_round(monkeypatch):
    _, db = _tree_db()
    be, calls = _counting_backend()
    interps = _count_interpolations(monkeypatch)
    rows, addrs, led = select_tree(jax.random.PRNGKey(5), db, 1, "John",
                                   backend=be)
    assert addrs == [0, 1, 32, 33]
    # phases: count(1) + Q&A count rounds(3) + fused address round(1)
    # -> 5 match dispatches; the fetch is 1 ss_matmul. 20 blocks were
    # counted/address-fetched in total, yet no per-block dispatch happened.
    assert calls["aa_match_batch"] == 5
    assert calls["aa_match"] == 0
    assert calls["ss_matmul"] == 1
    # one interpolation per phase: count, 3 count rounds, address, fetch
    assert interps["n"] == 6
    # ledger rounds unchanged by fusion: count + 3 Q&A + fetch
    assert led.rounds == 5


def test_select_tree_rows_and_ledger_unchanged_by_fusion():
    """The fused tree must agree with a brute-force oracle on rows and with
    the historical per-block accounting on totals."""
    rows, db = _tree_db()
    got, addrs, led = select_tree(jax.random.PRNGKey(5), db, 1, "John")
    assert got == [rows[i] for i in (0, 1, 32, 33)]
    # cloud elems (×wa): count 64 + r1 4×16 + r2 8×4 + r3 8×1 + addr 4×1,
    # then the fetch term 4 rows × n(64) × m(2) × wa.
    wa = CODEC.word_length * CODEC.alphabet_size
    assert led.cloud_ops_bits == ((64 + 64 + 32 + 8 + 4) * wa
                                  + 4 * 64 * 2 * wa) * 31


# ---------------------------------------------------------------------------
# acceptance: B=32 same-strategy batch executes each round once
# ---------------------------------------------------------------------------

def _wide_db(n=32):
    pats = ["ann", "bob", "cat", "dan"]
    rows = [[f"id{i}", pats[i % 4], str(100 + i)] for i in range(n)]
    return rows, outsource(jax.random.PRNGKey(11), rows,
                           column_names=["Id", "Name", "Val"],
                           codec=Codec(word_length=6), n_shares=16)


def test_batch32_one_round_selects_execute_rounds_once(monkeypatch):
    _, db = _wide_db()
    plans = [Select(Eq("Name", ["ann", "bob", "cat", "dan"][i % 4]),
                    strategy="one_round") for i in range(32)]
    seq = [QueryClient(db, key=9).run(p) for p in plans]

    be, calls = _counting_backend()
    interps = _count_interpolations(monkeypatch)
    bat = QueryClient(db, key=9, backend=be).run_batch(plans)

    # the whole B=32 group: ONE fused match dispatch + ONE fused fetch
    assert calls["aa_match_batch"] == 1
    assert calls["ss_matmul"] == 1
    assert calls["aa_match"] == 0
    assert interps["n"] == 2
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)


def test_batch32_tree_selects_execute_rounds_once(monkeypatch):
    _, db = _tree_db()
    plans = [Select(Eq(1, "John"), strategy="tree") for _ in range(32)]
    seq = [QueryClient(db, key=13).run(p) for p in plans]

    be, calls = _counting_backend()
    interps = _count_interpolations(monkeypatch)
    bat = QueryClient(db, key=13, backend=be).run_batch(plans)

    # same dispatch/interp count as ONE query (see the B=1 acceptance
    # test): lockstep fusion makes the group free.
    assert calls["aa_match_batch"] == 5
    assert calls["ss_matmul"] == 1
    assert interps["n"] == 6
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)


# ---------------------------------------------------------------------------
# batch == sequential across mixed strategies / families
# ---------------------------------------------------------------------------

def test_run_batch_mixed_strategies_equals_sequential(employee_db):
    plans = [
        Count(Eq("FirstName", "John")),
        Select(Eq("Department", "Sale"), strategy="tree"),
        Select(Eq("FirstName", "John"), strategy="one_round"),
        Select(Eq("FirstName", "Eve"), strategy="one_tuple"),
        Select(Eq("FirstName", "John"), strategy="one_round",
               padding=Padding.to_rows(4)),
        Select(Eq("FirstName", "Zoe"), strategy="tree"),   # ℓ = 0
        RangeCount(Between("Salary", 900, 2100), reduce_every=2),
        Select(Eq("LastName", "Smith")),                   # auto strategy
    ]
    seq = [QueryClient(employee_db, key=42).run(p) for p in plans]
    bat = QueryClient(employee_db, key=42).run_batch(plans)
    same_client_seq = []
    cl = QueryClient(employee_db, key=42)
    for p in plans:
        same_client_seq.append(cl.run(p))
    for a, b in zip(same_client_seq, bat):
        _assert_results_equal(a, b)
    # fresh-client-per-plan also agrees (keys never leak across queries)
    for a, b in zip(seq, bat):
        assert a.rows == b.rows and a.count == b.count


def test_run_batch_auto_replans_wrong_hint_like_sequential():
    big_rows = ([[f"E{i}", f"nm{i}", "X", "1", "D"] for i in range(316)]
                + EMPLOYEE)
    db = outsource(jax.random.PRNGKey(1), big_rows, column_names=COLUMNS,
                   codec=CODEC, n_shares=20)
    plans = [Select(Eq("FirstName", "John"), expected_matches=1),
             Select(Eq("FirstName", "Adam"), expected_matches=1)]
    seq_cl = QueryClient(db, key=7)
    seq = [seq_cl.run(p) for p in plans]
    bat = QueryClient(db, key=7).run_batch(plans)
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)
    assert bat[0].strategy == "one_round"      # replanned: ℓ=2
    assert bat[0].addresses == [317, 319]
    assert bat[1].strategy == "one_tuple"      # hint was right
    assert bat[1].rows == [EMPLOYEE[0]]


def test_run_batch_forced_one_tuple_wrong_cardinality_raises(employee_db):
    plans = [Select(Eq("FirstName", "John"), strategy="one_tuple")]
    with pytest.raises(CardinalityError):
        QueryClient(employee_db, key=3).run_batch(plans)


def test_run_batch_empty_and_single(employee_db):
    assert QueryClient(employee_db, key=1).run_batch([]) == []
    res = QueryClient(employee_db, key=1).run_batch(
        [Count(Eq("FirstName", "Eve"))])
    assert len(res) == 1 and res[0].count == 1


def test_run_batch_pallas_matches_jnp():
    _, db = _wide_db(n=8)
    plans = [Count(Eq("Name", "ann")),
             Select(Eq("Name", "bob"), strategy="one_round")]
    rj = QueryClient(db, key=5, backend="jnp").run_batch(plans)
    rp = QueryClient(db, key=5, backend="pallas").run_batch(plans)
    for a, b in zip(rj, rp):
        _assert_results_equal(a, b)


def test_run_batch_mapreduce_executor_splits_fused_batch():
    _, db = _wide_db()
    pool = WorkerPool(3)
    runner = MapReduceRunner(pool, lease_s=5.0, max_attempts=30)
    cl_mr = QueryClient(db, key=21,
                        executor=MapReduceExecutor(runner, n_splits=3))
    cl = QueryClient(db, key=21)
    plans = [Select(Eq("Name", p), strategy="one_round")
             for p in ("ann", "bob", "cat")]
    for a, b in zip(cl.run_batch(plans), cl_mr.run_batch(plans)):
        _assert_results_equal(a, b)


# ---------------------------------------------------------------------------
# batched ranges: one fused ripple dispatch per bit-round
# ---------------------------------------------------------------------------

def _range_db(n=32, word_length=6, t_bits=14):
    rows = [[f"id{i}", f"nm{i % 5}", str(500 + 137 * i)] for i in range(n)]
    return rows, outsource(jax.random.PRNGKey(19), rows,
                           column_names=["Id", "Name", "Val"],
                           codec=Codec(word_length=word_length), n_shares=20,
                           degree=1, numeric_columns={2: t_bits})


def _child_db(rows, k=6, word_length=6, n_shares=20, dup=False):
    """A child relation whose join column references ``rows``' Id column."""
    child = [[rows[(i // 2 if dup else i) % len(rows)][0], f"t{i}"]
             for i in range(k)]
    return outsource(jax.random.PRNGKey(23), child,
                     column_names=["Id", "Task"],
                     codec=Codec(word_length=word_length),
                     n_shares=n_shares, degree=1)


def test_batch16_ranges_one_ripple_dispatch_per_bit_round(monkeypatch):
    _, db = _range_db()
    plans = [RangeCount(Between("Val", 600, 600 + 200 * i), reduce_every=2)
             if i % 2 == 0 else
             RangeSelect(Between("Val", 500, 700 + 150 * i), reduce_every=2)
             for i in range(16)]
    seq = [QueryClient(db, key=33).run(p) for p in plans]

    be, calls = _counting_backend()
    interps = _count_interpolations(monkeypatch)
    bat = QueryClient(db, key=33, backend=be).run_batch(plans)

    # the whole B=16 group ripples in ONE carry chain: t_bits dispatches
    # (LSB + 13 steps), never B per bit; the 8 range-selects' fetches ride
    # ONE ss_matmul; counts/bits/tuples interpolate once each.
    assert calls["ripple_carry"] == 14
    assert calls["ss_matmul"] == 1
    assert calls["aa_match_batch"] == 0
    assert interps["n"] == 3
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)


def test_range_groups_split_by_reduce_every(monkeypatch):
    """Different reduce_every values cannot share a carry chain: they form
    separate groups (each fused), and results still match sequential."""
    _, db = _range_db()
    plans = [RangeCount(Between("Val", 500, 3000), reduce_every=2),
             RangeCount(Between("Val", 500, 3000), reduce_every=4),
             RangeCount(Between("Val", 600, 2000), reduce_every=2)]
    seq = [QueryClient(db, key=3).run(p) for p in plans]
    be, calls = _counting_backend()
    bat = QueryClient(db, key=3, backend=be).run_batch(plans)
    assert calls["ripple_carry"] == 28          # two groups, 14 bits each
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)


# ---------------------------------------------------------------------------
# cross-group fetch fusion: one ss_matmul for one_round+tree+range+pkfk
# ---------------------------------------------------------------------------

def test_cross_group_fetch_is_one_matmul(monkeypatch):
    rows, db = _range_db()
    child = _child_db(rows)
    plans = [Select(Eq("Name", "nm1"), strategy="one_round"),
             Select(Eq("Name", "nm2"), strategy="tree"),
             RangeSelect(Between("Val", 550, 2500), reduce_every=2),
             Join(right=child, on=("Id", "Id"), kind="pkfk")]
    seq = [QueryClient(db, key=77).run(p) for p in plans]

    be, calls = _counting_backend()
    bat = QueryClient(db, key=77, backend=be).run_batch(plans)

    # one_round + tree + range one-hot matrices AND the join's transposed
    # match matrix stack into a single fused fetch dispatch.
    assert calls["ss_matmul"] == 1
    assert calls["match_matrix"] == 1           # the join's n² string match
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)


def test_client_has_no_passthrough_path():
    """Every plan family routes through the batched round engine — the
    pre-PR-3 per-query fallback methods are gone."""
    for legacy in ("_run_range_count", "_run_range_select", "_run_join"):
        assert not hasattr(QueryClient, legacy)


# ---------------------------------------------------------------------------
# mixed Count/Select/Range/Join batches == sequential (B ≥ 16)
# ---------------------------------------------------------------------------

def test_run_batch_all_families_b16_equals_sequential():
    rows, db = _range_db()
    child_pk = _child_db(rows)
    child_dup = _child_db(rows, dup=True)
    plans = [
        Count(Eq("Name", "nm1")),
        Select(Eq("Name", "nm2"), strategy="one_round"),
        Select(Eq("Name", "nm3"), strategy="tree"),
        Select(Eq("Id", "id7"), strategy="one_tuple"),
        Select(Eq("Name", "nm4")),                       # auto
        RangeCount(Between("Val", 500, 2000), reduce_every=2),
        RangeSelect(Between("Val", 900, 1800), reduce_every=2),
        Join(right=child_pk, on=("Id", "Id"), kind="pkfk"),
        Join(right=child_dup, on=("Id", "Id"), kind="equi",
             padding=Padding.fake_values(1)),
        Select(Eq("Name", "nm0"), strategy="one_round",
               padding=Padding.to_rows(8)),
        RangeCount(Between("Val", 0, 8000), reduce_every=2),
        Select(Eq("Name", "zzz"), strategy="tree"),      # ℓ = 0
        RangeSelect(Between("Val", 4000, 5000), reduce_every=2),
        Count(Eq("Name", "nm0")),
        Join(right=child_pk, on=("Id", "Id"), kind="pkfk"),
        Select(Eq("Name", "nm1"), strategy="one_round"),
    ]
    assert len(plans) >= 16
    seq_cl = QueryClient(db, key=42)
    seq = [seq_cl.run(p) for p in plans]
    bat = QueryClient(db, key=42).run_batch(plans)
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)


def test_equijoin_no_common_values_returns_empty():
    """Disjoint join columns (and no padding) must yield zero rows, both
    standalone and inside a batch — not crash on the empty fetch stack."""
    from repro.core.queries import equijoin
    codec = Codec(word_length=6)
    dbX = outsource(jax.random.PRNGKey(1), [["a1", "b1"], ["a2", "b2"]],
                    column_names=["A", "B"], codec=codec, n_shares=16)
    dbY = outsource(jax.random.PRNGKey(2), [["b8", "c1"], ["b9", "c2"]],
                    column_names=["B", "C"], codec=codec, n_shares=16)
    rows, led = equijoin(jax.random.PRNGKey(3), dbX, dbY, 1, 0)
    assert rows == [] and led.rounds == 1       # only the column-open round
    res = QueryClient(dbX, key=4).run_batch(
        [Join(right=dbY, on=("B", "B"), kind="equi")])[0]
    assert res.rows == [] and res.count == 0


def test_run_batch_range_join_pallas_matches_jnp():
    rows, db = _range_db(n=8)
    child = _child_db(rows, k=4)
    plans = [RangeCount(Between("Val", 500, 1200), reduce_every=2),
             RangeSelect(Between("Val", 500, 900), reduce_every=2),
             Join(right=child, on=("Id", "Id"), kind="pkfk")]
    rj = QueryClient(db, key=5, backend="jnp").run_batch(plans)
    rp = QueryClient(db, key=5, backend="pallas").run_batch(plans)
    for a, b in zip(rj, rp):
        _assert_results_equal(a, b)


def test_zero_match_select_empty_fetch_stack_all_backends():
    """An unpadded zero-match select/range contributes a 0-row block to the
    fused fetch; every backend must return [] instead of choking on the
    empty matmul."""
    _, db = _range_db(n=8)
    plans = [Select(Eq("Name", "zzz"), strategy="one_round"),
             RangeSelect(Between("Val", 8000, 8100), reduce_every=2)]
    for backend in ("jnp", "pallas"):
        res = QueryClient(db, key=6, backend=backend).run_batch(plans)
        assert res[0].rows == [] and res[0].addresses == []
        assert res[1].rows == [] and res[1].addresses == []


def test_run_batch_range_join_mapreduce_matches_plain():
    rows, db = _range_db()
    child = _child_db(rows)
    pool = WorkerPool(3)
    runner = MapReduceRunner(pool, lease_s=5.0, max_attempts=30)
    cl_mr = QueryClient(db, key=21,
                        executor=MapReduceExecutor(runner, n_splits=3))
    cl = QueryClient(db, key=21)
    plans = [RangeCount(Between("Val", 500, 2500), reduce_every=2),
             RangeSelect(Between("Val", 600, 1500), reduce_every=2),
             Join(right=child, on=("Id", "Id"), kind="pkfk"),
             Select(Eq("Name", "nm1"), strategy="one_round")]
    for a, b in zip(cl.run_batch(plans), cl_mr.run_batch(plans)):
        _assert_results_equal(a, b)


# ---------------------------------------------------------------------------
# planner batching-awareness: ride a non-empty group's fused rounds
# ---------------------------------------------------------------------------

def test_planner_marginal_round_pricing_steers_borderline():
    stats = DBStats(n=64, m=5, c=20, w=8, a=128)
    solo_or = choose_select_strategy(stats, ell=4)
    assert solo_or.strategy == "one_round"      # bits-optimal at small n
    from repro.api.planner import estimate_select_cost
    bits_or = estimate_select_cost("one_round", stats, ell=4).bits
    bits_tree = estimate_select_cost("tree", stats, ell=4).bits
    assert bits_tree > bits_or                  # borderline: tree costs more
    rcb = (bits_tree - bits_or) // 2 + 1        # 2·rcb > bits gap

    # sequentially (or with no tree group) one_round still wins...
    assert choose_select_strategy(stats, ell=4,
                                  round_cost_bits=rcb).strategy == "one_round"
    # ...but when a tree group is already running, its Q&A/fetch rounds are
    # free to ride — the marginal price tips the borderline query over.
    ridden = choose_select_strategy(
        stats, ell=4, round_cost_bits=rcb,
        group_sizes={"one_tuple": 0, "one_round": 0, "tree": 8})
    assert ridden.strategy == "tree"
    # depth-aware: the same rider over a SHALLOW tree group pays the Q&A
    # rounds it would add beyond the group's deepest member — not free
    deep_rider = choose_select_strategy(
        stats, ell=4, round_cost_bits=rcb,
        group_sizes={"tree": 8}, group_rounds={"tree": 2})
    assert deep_rider.strategy == "one_round"
    # ...while a group at least as deep as the rider stays free to ride
    assert choose_select_strategy(
        stats, ell=4, round_cost_bits=rcb, group_sizes={"tree": 8},
        group_rounds={"tree": 20}).strategy == "tree"
    # with the default pricing the group never changes the choice (the
    # batch == sequential identity the equality tests rely on)
    assert choose_select_strategy(
        stats, ell=4,
        group_sizes={"tree": 8}).strategy == "one_round"


def test_estimate_batch_group_cost_pays_rounds_once():
    from repro.api import estimate_batch_group_cost
    from repro.api.planner import estimate_select_cost
    stats = DBStats(n=64, m=5, c=20, w=8, a=128)
    singles = [estimate_select_cost("tree", stats, ell=e) for e in (2, 4, 8)]
    grp = estimate_batch_group_cost(stats, "tree", ells=[2, 4, 8])
    assert grp.strategy == "tree"
    assert grp.bits == sum(e.bits for e in singles)       # bits add up...
    assert grp.rounds == max(e.rounds for e in singles)   # ...rounds fuse
    assert estimate_batch_group_cost(stats, "one_round", ells=[]).rounds == 0


def test_client_steers_auto_select_onto_running_group():
    _, db = _tree_db()
    stats = DBStats.of(db)
    from repro.api.planner import estimate_select_cost
    bits_or = estimate_select_cost("one_round", stats, ell=4).bits
    bits_tree = estimate_select_cost("tree", stats, ell=4).bits
    rcb = abs(bits_tree - bits_or) // 2 + 1
    plans = [Select(Eq(1, "John"), strategy="tree") for _ in range(4)]
    borderline = Select(Eq(1, "John"), expected_matches=4)
    cheap_strategy = choose_select_strategy(stats, ell=4,
                                            round_cost_bits=rcb).strategy
    res = QueryClient(db, key=9, round_cost_bits=rcb).run_batch(
        plans + [borderline])[-1]
    # the AUTO query rides the live tree group even though a fresh client
    # would have opened a new round chain for it
    assert res.strategy == "tree"
    assert cheap_strategy == "one_round"
    assert res.addresses == [0, 1, 32, 33]


# ---------------------------------------------------------------------------
# micro-batching QueryServer
# ---------------------------------------------------------------------------

def test_query_server_micro_batches_and_stats(employee_db):
    from repro.launch.serve import QueryRequest, QueryServer
    server = QueryServer(employee_db, key=11, max_batch=4)
    reqs = [QueryRequest(Count(Eq("FirstName", "John"))),
            QueryRequest(Select(Eq("Department", "Sale"), strategy="tree")),
            QueryRequest(Select(Eq("FirstName", "Eve"),
                                strategy="one_tuple")),
            QueryRequest(Select(Eq("FirstName", "John"),
                                strategy="one_round")),
            QueryRequest(Count(Eq("Department", "Design")))]
    done = server.serve(reqs)
    assert [r.result.count for r in done] == [2, 3, 1, 2, 1]
    assert all(r.latency_s > 0 for r in done)
    st = server.stats
    assert st.served == 5
    assert st.batches == 2                    # max_batch=4 -> 4 + 1
    assert 2.0 < st.mean_batch_size <= 4.0
    d = st.as_dict()
    assert d["p50_latency_s"] >= 0 and d["throughput_qps"] > 0
    # results identical to an unbatched client with the same root key
    cl = QueryClient(employee_db, key=11)
    direct = [cl.run(r.plan) for r in reqs]
    for r, want in zip(done, direct):
        assert r.result.rows == want.rows
        assert r.result.count == want.count


def test_query_server_isolates_failing_request(employee_db):
    """One bad plan in a micro-batch must not take its batch-mates down."""
    from repro.launch.serve import QueryRequest, QueryServer
    server = QueryServer(employee_db, key=17, max_batch=8)
    reqs = [QueryRequest(Count(Eq("FirstName", "Eve"))),
            # forced one_tuple on a 2-match predicate -> CardinalityError
            QueryRequest(Select(Eq("FirstName", "John"),
                                strategy="one_tuple")),
            QueryRequest(Select(Eq("FirstName", "John"),
                                strategy="one_round"))]
    done = server.serve(reqs)
    assert done[0].result.count == 1 and done[0].error is None
    assert done[1].result is None
    assert isinstance(done[1].error, CardinalityError)
    assert done[2].result.addresses == [1, 3] and done[2].error is None
    assert server.stats.served == 2 and server.stats.failed == 1


def test_query_server_batches_range_join_traffic(employee_db):
    """Range and join requests join the micro-batch (no passthrough) and
    the per-family breakdown shows up in ServeStats."""
    from repro.launch.serve import QueryRequest, QueryServer
    child = outsource(jax.random.PRNGKey(31),
                      [["E101", "x1"], ["E103", "x2"], ["E101", "x3"]],
                      column_names=["EmployeeId", "Tag"], codec=CODEC,
                      n_shares=20, degree=1)
    server = QueryServer(employee_db, key=19, max_batch=8)
    reqs = [QueryRequest(Count(Eq("FirstName", "John"))),
            QueryRequest(RangeCount(Between("Salary", 900, 2100),
                                    reduce_every=2)),
            QueryRequest(RangeSelect(Between("Salary", 400, 1500),
                                     reduce_every=2)),
            QueryRequest(Join(right=child, on=("EmployeeId", "EmployeeId"),
                              kind="pkfk")),
            QueryRequest(Select(Eq("Department", "Sale"), strategy="tree"))]
    done = server.serve(reqs)
    assert all(r.error is None for r in done)
    assert done[1].result.count == 2
    assert done[2].result.addresses == [0, 2]
    assert len(done[3].result.rows) == 3        # one per child tuple
    assert server.stats.batches == 1            # ONE micro-batch served all
    assert server.stats.served_by_family == {
        "count": 1, "range_count": 1, "range_select": 1, "join": 1,
        "select": 1}
    assert server.stats.as_dict()["served_by_family"]["join"] == 1
    # identical to an unbatched client with the same root key
    cl = QueryClient(employee_db, key=19)
    for r, want in zip(done, [cl.run(r.plan) for r in reqs]):
        assert r.result.rows == want.rows
        assert r.result.count == want.count


def test_query_server_pump_drains_incrementally(employee_db):
    from repro.launch.serve import QueryRequest, QueryServer
    server = QueryServer(employee_db, key=2, max_batch=8)
    assert server.pump() == []                # empty queue is a no-op
    server.submit(QueryRequest(Count(Eq("FirstName", "Eve"))))
    server.submit(QueryRequest(Count(Eq("FirstName", "John"))))
    assert server.pending() == 2
    out = server.pump()
    assert server.pending() == 0
    assert [r.result.count for r in out] == [1, 2]
    server.reset()
    assert server.stats.served == 0
