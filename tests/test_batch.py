"""Batched oblivious query engine: fusion + equivalence acceptance tests.

Two properties anchor this suite:

  1. *Round fusion*: ``select_tree`` issues exactly one device dispatch and
     one interpolation per Q&A round (never per block), and a
     ``run_batch`` group executes each protocol round once for the whole
     group (never per query).
  2. *Bit-identical accounting*: every query inside a batch returns the
     same rows/addresses and the same per-query ``CostLedger`` totals as
     the same plan run sequentially — batching is free in protocol cost.
"""
import jax
import pytest

from repro.api import (Between, Count, Eq, Padding, QueryClient, RangeCount,
                       Select, MapReduceExecutor, get_backend)
from repro.api.backends import Backend, batched_matcher
from repro.core import outsource, Codec
from repro.core.queries import CardinalityError, select_tree
from repro.core import shamir
from repro.runtime import MapReduceRunner, WorkerPool

CODEC = Codec(word_length=8)
COLUMNS = ["EmployeeId", "FirstName", "LastName", "Salary", "Department"]

EMPLOYEE = [
    ["E101", "Adam", "Smith", "1000", "Sale"],
    ["E102", "John", "Taylor", "2000", "Design"],
    ["E103", "Eve", "Smith", "500", "Sale"],
    ["E104", "John", "Williams", "5000", "Sale"],
]


@pytest.fixture(scope="module")
def employee_db():
    return outsource(jax.random.PRNGKey(7), EMPLOYEE, column_names=COLUMNS,
                     codec=CODEC, n_shares=20, degree=1,
                     numeric_columns={3: 14})


def _counting_backend(name="jnp"):
    """Wrap a registered backend so every hotspot dispatch is counted."""
    base = get_backend(name)
    calls = {"aa_match": 0, "aa_match_batch": 0, "ss_matmul": 0,
             "match_matrix": 0}

    def wrap(op_name, fn):
        def run(a, b):
            calls[op_name] += 1
            return fn(a, b)
        return run

    be = Backend(
        name=f"{name}+counting",
        aa_match=wrap("aa_match", base.aa_match),
        ss_matmul=wrap("ss_matmul", base.ss_matmul),
        match_matrix=wrap("match_matrix", base.match_matrix),
        aa_match_batch=wrap("aa_match_batch", batched_matcher(base)))
    return be, calls


def _count_interpolations(monkeypatch):
    counter = {"n": 0}
    real = shamir.interpolate

    def counting(shares, **kw):
        counter["n"] += 1
        return real(shares, **kw)

    monkeypatch.setattr(shamir, "interpolate", counting)
    return counter


def _assert_results_equal(a, b):
    assert a.strategy == b.strategy
    assert a.rows == b.rows
    assert a.addresses == b.addresses
    assert a.count == b.count
    assert a.ledger == b.ledger       # bit-for-bit: rounds, bits, ops


# ---------------------------------------------------------------------------
# acceptance: one dispatch + one interpolation per Q&A round
# ---------------------------------------------------------------------------

def _tree_db(n=64):
    # "John" clustered at 0,1 and 32,33 (ℓ=4). Q&A trace: round 1 splits
    # into 4 blocks of 16 (counts 2,0,2,0); round 2 splits the two live
    # blocks into 4×4 (one count-2 block each); round 3 isolates four
    # singles, all address-fetched in ONE fused round.
    rows = [[f"id{i}", "John" if i in (0, 1, 32, 33) else f"nm{i}"]
            for i in range(n)]
    return rows, outsource(jax.random.PRNGKey(3), rows, codec=CODEC,
                           n_shares=20)


def test_select_tree_one_dispatch_per_round(monkeypatch):
    _, db = _tree_db()
    be, calls = _counting_backend()
    interps = _count_interpolations(monkeypatch)
    rows, addrs, led = select_tree(jax.random.PRNGKey(5), db, 1, "John",
                                   backend=be)
    assert addrs == [0, 1, 32, 33]
    # phases: count(1) + Q&A count rounds(3) + fused address round(1)
    # -> 5 match dispatches; the fetch is 1 ss_matmul. 20 blocks were
    # counted/address-fetched in total, yet no per-block dispatch happened.
    assert calls["aa_match_batch"] == 5
    assert calls["aa_match"] == 0
    assert calls["ss_matmul"] == 1
    # one interpolation per phase: count, 3 count rounds, address, fetch
    assert interps["n"] == 6
    # ledger rounds unchanged by fusion: count + 3 Q&A + fetch
    assert led.rounds == 5


def test_select_tree_rows_and_ledger_unchanged_by_fusion():
    """The fused tree must agree with a brute-force oracle on rows and with
    the historical per-block accounting on totals."""
    rows, db = _tree_db()
    got, addrs, led = select_tree(jax.random.PRNGKey(5), db, 1, "John")
    assert got == [rows[i] for i in (0, 1, 32, 33)]
    # cloud elems (×wa): count 64 + r1 4×16 + r2 8×4 + r3 8×1 + addr 4×1,
    # then the fetch term 4 rows × n(64) × m(2) × wa.
    wa = CODEC.word_length * CODEC.alphabet_size
    assert led.cloud_ops_bits == ((64 + 64 + 32 + 8 + 4) * wa
                                  + 4 * 64 * 2 * wa) * 31


# ---------------------------------------------------------------------------
# acceptance: B=32 same-strategy batch executes each round once
# ---------------------------------------------------------------------------

def _wide_db(n=32):
    pats = ["ann", "bob", "cat", "dan"]
    rows = [[f"id{i}", pats[i % 4], str(100 + i)] for i in range(n)]
    return rows, outsource(jax.random.PRNGKey(11), rows,
                           column_names=["Id", "Name", "Val"],
                           codec=Codec(word_length=6), n_shares=16)


def test_batch32_one_round_selects_execute_rounds_once(monkeypatch):
    _, db = _wide_db()
    plans = [Select(Eq("Name", ["ann", "bob", "cat", "dan"][i % 4]),
                    strategy="one_round") for i in range(32)]
    seq = [QueryClient(db, key=9).run(p) for p in plans]

    be, calls = _counting_backend()
    interps = _count_interpolations(monkeypatch)
    bat = QueryClient(db, key=9, backend=be).run_batch(plans)

    # the whole B=32 group: ONE fused match dispatch + ONE fused fetch
    assert calls["aa_match_batch"] == 1
    assert calls["ss_matmul"] == 1
    assert calls["aa_match"] == 0
    assert interps["n"] == 2
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)


def test_batch32_tree_selects_execute_rounds_once(monkeypatch):
    _, db = _tree_db()
    plans = [Select(Eq(1, "John"), strategy="tree") for _ in range(32)]
    seq = [QueryClient(db, key=13).run(p) for p in plans]

    be, calls = _counting_backend()
    interps = _count_interpolations(monkeypatch)
    bat = QueryClient(db, key=13, backend=be).run_batch(plans)

    # same dispatch/interp count as ONE query (see the B=1 acceptance
    # test): lockstep fusion makes the group free.
    assert calls["aa_match_batch"] == 5
    assert calls["ss_matmul"] == 1
    assert interps["n"] == 6
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)


# ---------------------------------------------------------------------------
# batch == sequential across mixed strategies / families
# ---------------------------------------------------------------------------

def test_run_batch_mixed_strategies_equals_sequential(employee_db):
    plans = [
        Count(Eq("FirstName", "John")),
        Select(Eq("Department", "Sale"), strategy="tree"),
        Select(Eq("FirstName", "John"), strategy="one_round"),
        Select(Eq("FirstName", "Eve"), strategy="one_tuple"),
        Select(Eq("FirstName", "John"), strategy="one_round",
               padding=Padding.to_rows(4)),
        Select(Eq("FirstName", "Zoe"), strategy="tree"),   # ℓ = 0
        RangeCount(Between("Salary", 900, 2100), reduce_every=2),
        Select(Eq("LastName", "Smith")),                   # auto strategy
    ]
    seq = [QueryClient(employee_db, key=42).run(p) for p in plans]
    bat = QueryClient(employee_db, key=42).run_batch(plans)
    same_client_seq = []
    cl = QueryClient(employee_db, key=42)
    for p in plans:
        same_client_seq.append(cl.run(p))
    for a, b in zip(same_client_seq, bat):
        _assert_results_equal(a, b)
    # fresh-client-per-plan also agrees (keys never leak across queries)
    for a, b in zip(seq, bat):
        assert a.rows == b.rows and a.count == b.count


def test_run_batch_auto_replans_wrong_hint_like_sequential():
    big_rows = ([[f"E{i}", f"nm{i}", "X", "1", "D"] for i in range(316)]
                + EMPLOYEE)
    db = outsource(jax.random.PRNGKey(1), big_rows, column_names=COLUMNS,
                   codec=CODEC, n_shares=20)
    plans = [Select(Eq("FirstName", "John"), expected_matches=1),
             Select(Eq("FirstName", "Adam"), expected_matches=1)]
    seq_cl = QueryClient(db, key=7)
    seq = [seq_cl.run(p) for p in plans]
    bat = QueryClient(db, key=7).run_batch(plans)
    for a, b in zip(seq, bat):
        _assert_results_equal(a, b)
    assert bat[0].strategy == "one_round"      # replanned: ℓ=2
    assert bat[0].addresses == [317, 319]
    assert bat[1].strategy == "one_tuple"      # hint was right
    assert bat[1].rows == [EMPLOYEE[0]]


def test_run_batch_forced_one_tuple_wrong_cardinality_raises(employee_db):
    plans = [Select(Eq("FirstName", "John"), strategy="one_tuple")]
    with pytest.raises(CardinalityError):
        QueryClient(employee_db, key=3).run_batch(plans)


def test_run_batch_empty_and_single(employee_db):
    assert QueryClient(employee_db, key=1).run_batch([]) == []
    res = QueryClient(employee_db, key=1).run_batch(
        [Count(Eq("FirstName", "Eve"))])
    assert len(res) == 1 and res[0].count == 1


def test_run_batch_pallas_matches_jnp():
    _, db = _wide_db(n=8)
    plans = [Count(Eq("Name", "ann")),
             Select(Eq("Name", "bob"), strategy="one_round")]
    rj = QueryClient(db, key=5, backend="jnp").run_batch(plans)
    rp = QueryClient(db, key=5, backend="pallas").run_batch(plans)
    for a, b in zip(rj, rp):
        _assert_results_equal(a, b)


def test_run_batch_mapreduce_executor_splits_fused_batch():
    _, db = _wide_db()
    pool = WorkerPool(3)
    runner = MapReduceRunner(pool, lease_s=5.0, max_attempts=30)
    cl_mr = QueryClient(db, key=21,
                        executor=MapReduceExecutor(runner, n_splits=3))
    cl = QueryClient(db, key=21)
    plans = [Select(Eq("Name", p), strategy="one_round")
             for p in ("ann", "bob", "cat")]
    for a, b in zip(cl.run_batch(plans), cl_mr.run_batch(plans)):
        _assert_results_equal(a, b)


# ---------------------------------------------------------------------------
# micro-batching QueryServer
# ---------------------------------------------------------------------------

def test_query_server_micro_batches_and_stats(employee_db):
    from repro.launch.serve import QueryRequest, QueryServer
    server = QueryServer(employee_db, key=11, max_batch=4)
    reqs = [QueryRequest(Count(Eq("FirstName", "John"))),
            QueryRequest(Select(Eq("Department", "Sale"), strategy="tree")),
            QueryRequest(Select(Eq("FirstName", "Eve"),
                                strategy="one_tuple")),
            QueryRequest(Select(Eq("FirstName", "John"),
                                strategy="one_round")),
            QueryRequest(Count(Eq("Department", "Design")))]
    done = server.serve(reqs)
    assert [r.result.count for r in done] == [2, 3, 1, 2, 1]
    assert all(r.latency_s > 0 for r in done)
    st = server.stats
    assert st.served == 5
    assert st.batches == 2                    # max_batch=4 -> 4 + 1
    assert 2.0 < st.mean_batch_size <= 4.0
    d = st.as_dict()
    assert d["p50_latency_s"] >= 0 and d["throughput_qps"] > 0
    # results identical to an unbatched client with the same root key
    cl = QueryClient(employee_db, key=11)
    direct = [cl.run(r.plan) for r in reqs]
    for r, want in zip(done, direct):
        assert r.result.rows == want.rows
        assert r.result.count == want.count


def test_query_server_isolates_failing_request(employee_db):
    """One bad plan in a micro-batch must not take its batch-mates down."""
    from repro.launch.serve import QueryRequest, QueryServer
    server = QueryServer(employee_db, key=17, max_batch=8)
    reqs = [QueryRequest(Count(Eq("FirstName", "Eve"))),
            # forced one_tuple on a 2-match predicate -> CardinalityError
            QueryRequest(Select(Eq("FirstName", "John"),
                                strategy="one_tuple")),
            QueryRequest(Select(Eq("FirstName", "John"),
                                strategy="one_round"))]
    done = server.serve(reqs)
    assert done[0].result.count == 1 and done[0].error is None
    assert done[1].result is None
    assert isinstance(done[1].error, CardinalityError)
    assert done[2].result.addresses == [1, 3] and done[2].error is None
    assert server.stats.served == 2 and server.stats.failed == 1


def test_query_server_pump_drains_incrementally(employee_db):
    from repro.launch.serve import QueryRequest, QueryServer
    server = QueryServer(employee_db, key=2, max_batch=8)
    assert server.pump() == []                # empty queue is a no-op
    server.submit(QueryRequest(Count(Eq("FirstName", "Eve"))))
    server.submit(QueryRequest(Count(Eq("FirstName", "John"))))
    assert server.pending() == 2
    out = server.pump()
    assert server.pending() == 0
    assert [r.result.count for r in out] == [1, 2]
    server.reset()
    assert server.stats.served == 0
