# Launchers: make_production_mesh (mesh.py), the multi-pod dry-run
# (dryrun.py — sets XLA device-count flag FIRST), training/serving drivers.
