"""ShapeDtypeStruct input builders for every (arch × shape × mesh) cell.

Shape-only stand-ins (no device allocation), each carrying its
NamedSharding, so ``jit(step).lower(*specs).compile()`` exercises the full
production sharding without touching memory — the shannon/kernels dry-run
pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import sharding as shd
from ..models import init_params, init_cache
from ..models.config import ModelConfig, ShapeConfig
from ..train.optim import init_state

ENC_LEN = 1024            # audio-encoder frame count (stub frontend)


def _sds(tree_shapes, tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s, spec: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, spec)),
        tree_shapes, tree_specs)


def _rep_sds(shape, dtype, mesh):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, P()))


def params_sds(cfg: ModelConfig, mesh: Mesh):
    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.random.PRNGKey(0))
    specs = shd.param_specs(cfg, mesh, shapes)
    return _sds(shapes, specs, mesh)


def opt_state_sds(cfg: ModelConfig, mesh: Mesh, p_sds):
    shapes = jax.eval_shape(init_state, p_sds)
    # m / v inherit the param specs; step replicated
    pspecs = shd.param_specs(cfg, mesh, p_sds)
    specs = type(shapes)(step=P(), m=pspecs, v=pspecs)
    return _sds(shapes, specs, mesh)


def batch_sds(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
              grad_accum: int = 1) -> Dict[str, Any]:
    """Training batches are MICROBATCH-MAJOR: (accum, B/accum, ...) with the
    accum axis unsharded, so the grad-accum scan slices an unsharded axis
    (slicing a sharded axis would all-gather the batch — see train/step.py).
    """
    specs = shd.batch_spec(cfg, mesh, shape)
    b = shape.global_batch
    out: Dict[str, Any] = {}
    if shape.kind == "decode":
        t_text = 1
    else:
        t_text = shape.seq_len - (cfg.n_prefix if cfg.frontend == "vit"
                                  else 0)

    def mk(shape_suffix, spec, dtype):
        if shape.kind == "train" and grad_accum > 1:
            full = (grad_accum, b // grad_accum) + shape_suffix
            spec = P(None, *spec)
        else:
            full = (b,) + shape_suffix
        return jax.ShapeDtypeStruct(full, dtype,
                                    sharding=NamedSharding(mesh, spec))

    out["tokens"] = mk((t_text,), specs["tokens"], jnp.int32)
    if shape.kind == "train":
        out["labels"] = mk((t_text,), specs["labels"], jnp.int32)
    if cfg.frontend == "vit" and shape.kind != "decode":
        out["patches"] = mk((cfg.n_prefix, cfg.frontend_dim),
                            specs["patches"], jnp.float32)
    if cfg.frontend == "audio" and shape.kind != "decode":
        out["frames"] = mk((ENC_LEN, cfg.frontend_dim), specs["frames"],
                           jnp.float32)
    return out


def cache_sds(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    shapes = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len,
                           enc_len=ENC_LEN if cfg.n_enc_layers else 0))
    specs = shd.cache_spec(cfg, mesh, shape)
    return _sds(shapes, specs, mesh)


def input_specs(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig, *,
                grad_accum: int = 1) -> Tuple[Any, ...]:
    """Positional SDS args for the step function of this cell kind."""
    p = params_sds(cfg, mesh)
    if shape.kind == "train":
        o = opt_state_sds(cfg, mesh, p)
        return (p, o, batch_sds(cfg, mesh, shape, grad_accum=grad_accum))
    if shape.kind == "prefill":
        return (p, batch_sds(cfg, mesh, shape))
    # decode
    c = cache_sds(cfg, mesh, shape)
    cache_len = _rep_sds((), jnp.int32, mesh)
    return (p, c, cache_len, batch_sds(cfg, mesh, shape))


# ---------------------------------------------------------------------------
# the paper's own workload cell (count + oblivious fetch + join-match)
# ---------------------------------------------------------------------------

def paper_db_step(relation, pattern, fetch_matrix, join_col_x, join_col_y):
    """One oblivious query mix over a sharded share-relation.

    relation:     (c, n, m, W, A) uint32 shares, n sharded over data
    pattern:      (c, W, A) shares of the predicate
    fetch_matrix: (c, l', n) shares of the one-hot fetch rows
    join_col_*:   (c, nx|ny, W, A) join columns (ny sharded over model)
    Returns (count_shares, fetched_shares, match_matrix_shares).
    """
    from ..core import automata, field
    from ..core.shamir import Shares
    rel = Shares(relation, 1)
    pat = Shares(pattern, 1)
    col0 = Shares(relation[:, :, 0], 1)
    counts = automata.count_column(col0, pat)          # (c,)
    c, n, m, w, a = relation.shape
    fetched = field.matmul(fetch_matrix,
                           relation.reshape(c, n, m * w * a))
    mm = automata.match_matrix(Shares(join_col_x, 1),
                               Shares(join_col_y, 1), method="aggregate")
    return counts.values, fetched, mm.values


def paper_db_specs(db_cfg, mesh: Mesh):
    dp = shd.dp_axes(mesh)
    c = db_cfg.n_shares
    n, m = db_cfg.n_tuples, db_cfg.n_attrs
    w, a = db_cfg.word_length, db_cfg.alphabet_size
    nj = max(4096, n // 16)                      # join-column length
    mk = lambda shape, spec: jax.ShapeDtypeStruct(
        shape, jnp.uint32, sharding=NamedSharding(mesh, spec))
    return (
        mk((c, n, m, w, a), P(None, dp, None, None, None)),
        mk((c, w, a), P()),
        mk((c, db_cfg.fetch_rows, n), P(None, None, dp)),
        mk((c, nj, w, a), P(None, dp, None, None)),
        mk((c, nj, w, a), P(None, "model", None, None)),
    )
