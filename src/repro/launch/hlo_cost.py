"""Loop-aware HLO cost model — the dry-run's profiler.

XLA's ``compiled.cost_analysis()`` visits a ``while`` body ONCE, so any
scanned program (scan-over-layers, flash-attention KV blocks, grad-accum
microbatches) is undercounted by its trip counts. This walker parses the
optimized per-device HLO text and accumulates

  * FLOPs        — dot ops: 2·|out|·K from ``lhs_contracting_dims``;
                   elementwise/reduce ops: |out| (integer ALU ops of the
                   secret-sharing field arithmetic count here too);
  * HBM bytes    — operands+outputs of *top-level* (unfused) instructions;
                   fusion internals are VMEM-resident by construction;
  * collective bytes — per kind, output-shape sized;

multiplying every ``while`` body by its trip count (largest integer constant
in the loop condition — exact for lax.scan/fori lowerings, which compare the
induction variable against a literal).

The numbers are per-device (the HLO is the SPMD-partitioned module).
Accounting is intentionally simple and *stable*: its job is to compare a
baseline against an optimized rewrite of the same program, not to match
hardware counters.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "cosine", "sine", "logistic", "expm1", "log1p", "atan2",
    # integer ALU ops of the mod-p share arithmetic: the Mersenne fold is
    # and + shifts, ``field.sum_`` keeps a real ``remainder``, comparisons
    # and selects carry the borrow logic. Counted as FLOPs like any other
    # elementwise op — verified against real lowered kernels in
    # tests/test_hlo_cost_field.py.
    "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "and", "or", "xor", "not", "popcnt",
    "count-leading-zeros", "compare", "select", "clamp", "convert",
}

_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "add-dependency", "partition-id", "replica-id",
}

_SHAPE_TOKEN = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_info(shape_str: str) -> Tuple[int, int]:
    """-> (total elements, total bytes) across (possibly tuple) shape."""
    elems = 0
    byts = 0
    for dtype, dims in _SHAPE_TOKEN.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dtype]
    return elems, byts


def _dims(shape_str: str) -> List[int]:
    m = _SHAPE_TOKEN.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    attrs: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symbols: Dict[str, str]          # %name -> shape string


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+?)\s+([\w\-]+)"
    r"\((.*?)\)(.*)$")
_REF = re.compile(r"%([\w.\-]+)")
_CALL_ATTR = re.compile(r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_ATTR = re.compile(r"condition=%?([\w.\-]+)")
_CONST_INT = re.compile(r"constant\((\d+)\)")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER.match(line.strip())
            if m:
                cur = Computation(m.group(2), [], {})
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shape, opcode, args, attrs = m.groups()
        operands = _REF.findall(args)
        cur.instrs.append(Instr(name, shape, opcode, operands, attrs, line))
        cur.symbols[name] = shape
    return comps, entry


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        for k in _COLLECTIVES:
            self.collectives[k] += other.collectives[k]
        return self

    def scaled(self, mult: float) -> "Cost":
        return Cost(self.flops * mult, self.hbm_bytes * mult,
                    {k: v * mult for k, v in self.collectives.items()})

    @property
    def collective_bytes(self) -> float:
        return sum(self.collectives.values())


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_elems, _ = _shape_info(instr.shape)
    m = re.search(r"lhs_contracting_dims={([0-9,]*)}", instr.attrs)
    k = 1
    if m and instr.operands:
        lhs_shape = comp.symbols.get(instr.operands[0], "")
        ldims = _dims(lhs_shape)
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(ldims):
                k *= ldims[int(idx)]
    return 2.0 * out_elems * k


def _trip_count(cond: Computation) -> int:
    best = 1
    for instr in cond.instrs:
        for c in _CONST_INT.findall(instr.line):
            best = max(best, int(c))
    return best


class HloCostModel:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    def total(self) -> Cost:
        if not self.entry:
            return Cost()
        return self._comp_cost(self.entry, top_level=True)

    # -- internals -----------------------------------------------------------
    def _comp_cost(self, name: str, top_level: bool) -> Cost:
        key = (name, top_level)
        if key in self._memo:
            return self._memo[key]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            self._memo[key] = total
            return total
        self._memo[key] = total          # break cycles defensively
        for instr in comp.instrs:
            total += self._instr_cost(instr, comp, top_level)
        return total

    def _io_bytes(self, instr: Instr, comp: Computation) -> float:
        _, out_b = _shape_info(instr.shape)
        b = float(out_b)
        for op in instr.operands:
            _, ob = _shape_info(comp.symbols.get(op, ""))
            b += ob
        return b

    def _fusion_io_bytes(self, instr: Instr, comp: Computation,
                         called: Optional[Computation]) -> float:
        """HBM traffic of a fusion node: output + per-operand reads.

        A fusion parameter consumed ONLY by slice-type ops reads just the
        slices (XLA fuses dynamic-slice into consumers — counting the full
        operand would overstate e.g. flash-attention KV block reads by the
        trip count)."""
        _, out_b = _shape_info(instr.shape)
        b = float(out_b)
        if called is None:
            return b + sum(_shape_info(comp.symbols.get(op, ""))[1]
                           for op in instr.operands)
        # map parameter index -> instr name in the fused computation
        params = {}
        for fi in called.instrs:
            if fi.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", fi.line)
                if m:
                    params[int(m.group(1))] = fi.name
        slice_ops = {"dynamic-slice", "slice", "gather",
                     "dynamic-update-slice"}
        passthrough = {"convert", "bitcast", "copy", "reshape"}

        def terminal_consumers(name, depth=0):
            """Follow elementwise/layout chains to the ops that actually
            consume the data (TPU fusions slice before converting)."""
            outs = []
            for fi in called.instrs:
                if name not in fi.operands:
                    continue
                if fi.opcode in passthrough and depth < 6:
                    outs.extend(terminal_consumers(fi.name, depth + 1))
                else:
                    outs.append(fi)
            return outs

        touched = 0.0
        for idx, op in enumerate(instr.operands):
            _, full_b = _shape_info(comp.symbols.get(op, ""))
            pname = params.get(idx)
            if pname is None:
                b += full_b
                continue
            consumers = terminal_consumers(pname)
            if consumers and all(fi.opcode in slice_ops
                                 for fi in consumers):
                for fi in consumers:
                    if fi.opcode == "dynamic-update-slice":
                        # in-place: traffic = the update region only
                        upd = (fi.operands[1] if len(fi.operands) > 1
                               else fi.operands[0])
                        touched += _shape_info(
                            called.symbols.get(upd, ""))[1]
                    else:
                        touched += _shape_info(fi.shape)[1]
            else:
                touched += full_b
        b += touched
        # a fusion whose ROOT is a DUS writes the update region, not the
        # full result buffer (aliased in-place on TPU)
        root = called.instrs[-1] if called.instrs else None
        if root is not None and root.opcode == "dynamic-update-slice":
            _, out_b = _shape_info(instr.shape)
            upd = (root.operands[1] if len(root.operands) > 1
                   else root.operands[0])
            upd_b = _shape_info(called.symbols.get(upd, ""))[1]
            b -= out_b
            b += upd_b
        return b

    def _instr_cost(self, instr: Instr, comp: Computation,
                    top_level: bool) -> Cost:
        op = instr.opcode
        c = Cost()
        if op == "while":
            body = _CALL_ATTR.search(instr.attrs)
            cond = _COND_ATTR.search(instr.attrs)
            # prefer XLA's own annotation, fall back to the condition const
            m = re.search(r'known_trip_count..:.\s*.n.:."?(\d+)', instr.attrs)
            if m:
                trips = int(m.group(1))
            elif cond and cond.group(1) in self.comps:
                trips = _trip_count(self.comps[cond.group(1)])
            else:
                trips = 1
            if body:
                c += self._comp_cost(body.group(1), top_level).scaled(trips)
            if cond:
                c += self._comp_cost(cond.group(1), False).scaled(trips)
            return c
        if op == "fusion":
            m = _CALL_ATTR.search(instr.attrs)
            called = self.comps.get(m.group(1)) if m else None
            if m:
                inner = self._comp_cost(m.group(1), False)
                c.flops += inner.flops
                for k in _COLLECTIVES:
                    c.collectives[k] += inner.collectives[k]
            if top_level:
                c.hbm_bytes += self._fusion_io_bytes(instr, comp, called)
            return c
        if op in ("call", "async-start", "custom-call"):
            m = _CALL_ATTR.search(instr.attrs)
            if m:
                c += self._comp_cost(m.group(1), top_level)
            if top_level and op == "custom-call":
                c.hbm_bytes += self._io_bytes(instr, comp)
            return c
        if op == "conditional":
            for branch in re.findall(r"branch_computations={([^}]*)}",
                                     instr.attrs):
                for b in _REF.findall(branch):
                    c += self._comp_cost(b, top_level)
            m2 = re.findall(r"(?:true|false)_computation=%?([\w.\-]+)",
                            instr.attrs)
            for b in m2:
                c += self._comp_cost(b, top_level)
            return c
        # leaf ops
        is_coll = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-start"):
                is_coll = k
                break
        if is_coll:
            _, out_b = _shape_info(instr.shape)
            c.collectives[is_coll] += out_b
            if top_level:
                c.hbm_bytes += self._io_bytes(instr, comp)
            return c
        if op == "dot":
            c.flops += _dot_flops(instr, comp)
            if top_level:
                c.hbm_bytes += self._io_bytes(instr, comp)
            return c
        if op == "convolution":
            out_elems, _ = _shape_info(instr.shape)
            kdims = _dims(comp.symbols.get(instr.operands[1], "")) \
                if len(instr.operands) > 1 else []
            kflop = 1
            for d in kdims:
                kflop *= d
            c.flops += 2.0 * out_elems * max(kflop, 1)
            if top_level:
                c.hbm_bytes += self._io_bytes(instr, comp)
            return c
        if op in ("reduce", "reduce-window"):
            in_elems, _ = _shape_info(comp.symbols.get(
                instr.operands[0], "")) if instr.operands else (0, 0)
            c.flops += float(in_elems)
            if top_level:
                c.hbm_bytes += self._io_bytes(instr, comp)
            return c
        if op == "sort":
            n_elems, _ = _shape_info(instr.shape)
            c.flops += n_elems * max(1.0, math.log2(max(n_elems, 2)))
            if top_level:
                c.hbm_bytes += self._io_bytes(instr, comp)
            return c
        if op in _ELEMENTWISE:
            out_elems, _ = _shape_info(instr.shape)
            c.flops += float(out_elems)
            if top_level:
                c.hbm_bytes += self._io_bytes(instr, comp)
            return c
        if op in _NO_TRAFFIC:
            return c
        if top_level:
            if op in ("dynamic-slice", "slice", "gather"):
                # reads the slice, not the whole operand
                _, out_b = _shape_info(instr.shape)
                c.hbm_bytes += 2.0 * out_b
            elif op == "dynamic-update-slice":
                # read-modify-write of the update region only
                upd = (instr.operands[1] if len(instr.operands) > 1
                       else instr.operands[0])
                _, upd_b = _shape_info(comp.symbols.get(upd, ""))
                c.hbm_bytes += 2.0 * upd_b
            elif op in ("broadcast", "iota"):
                _, out_b = _shape_info(instr.shape)
                c.hbm_bytes += out_b
            else:
                # copy, reshape, transpose, pad, concatenate, scatter, ...
                c.hbm_bytes += self._io_bytes(instr, comp)
        return c


def analyze_text(text: str) -> Cost:
    return HloCostModel(text).total()
