"""Training driver: mesh + sharded params + fault-tolerant loop.

Runs real steps on whatever devices exist (CPU smoke configs here; the same
code path drives the production mesh on TPU). Features exercised:
checkpoint/restart (resume from latest valid step), async checkpointing,
deterministic restartable data (batch index == step), gradient accumulation,
optional secret-shared (paper-integrated) private embedding.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-4b --smoke \
      --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro import sharding as shd
from repro.checkpoint import CheckpointManager
from repro.data import make_lm_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import init_params
from repro.train import AdamWConfig, init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = configs.smoke(args.arch) if args.smoke else configs.full(args.arch)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                          total_steps=args.steps)
    step_fn = make_train_step(cfg, opt_cfg, grad_accum=args.grad_accum)

    with jax.set_mesh(mesh):
        params = init_params(jax.random.PRNGKey(args.seed), cfg)
        p_shard = shd.param_shardings(cfg, mesh, params)
        params = jax.tree.map(jax.device_put, params, p_shard)
        opt_state = init_state(params)

        start_step = 0
        mgr = None
        if args.ckpt_dir:
            mgr = CheckpointManager(args.ckpt_dir, keep_last_n=3)
            try:
                from repro.checkpoint import restore_checkpoint
                from repro.train.optim import AdamWState
                o_shard = AdamWState(
                    step=NamedSharding(mesh, P()),
                    m=p_shard, v=jax.tree.map(lambda s: s, p_shard))
                start_step, (params, opt_state) = restore_checkpoint(
                    args.ckpt_dir, (params, opt_state),
                    shardings=(p_shard, o_shard))
                print(f"[train] resumed from step {start_step}")
            except FileNotFoundError:
                pass

        stream = make_lm_batches(cfg, args.batch, args.seq, seed=args.seed)
        jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
        if args.grad_accum > 1:
            dp = NamedSharding(mesh, P(None, shd.dp_axes(mesh), None))
        else:
            dp = NamedSharding(mesh, P(shd.dp_axes(mesh), None))

        t0 = time.time()
        for step in range(start_step, args.steps):
            batch = stream.batch_at(step)
            if args.grad_accum > 1:  # microbatch-major (see train/step.py)
                batch = jax.tree.map(
                    lambda a: a.reshape((args.grad_accum, -1)
                                        + a.shape[1:]), batch)
            batch = jax.tree.map(lambda a: jax.device_put(a, dp), batch)
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                print(f"[train] step={step} loss={m['loss']:.4f} "
                      f"lr={m['lr']:.2e} gnorm={m['grad_norm']:.3f} "
                      f"({time.time() - t0:.1f}s)", flush=True)
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, (params, opt_state))
        if mgr:
            mgr.save(args.steps, (params, opt_state))
            mgr.wait()
        final_loss = float(metrics["loss"])
        print(json.dumps({"final_loss": final_loss,
                          "steps": args.steps - start_step}))
        return final_loss


if __name__ == "__main__":
    main()
