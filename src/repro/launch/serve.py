"""Serving drivers: batched LM inference and oblivious query serving.

``BatchServer`` — continuous prefill+decode over a request queue. Minimal
but real: fixed-capacity batch slots, greedy sampling, per-slot lengths,
jitted prefill and decode steps. The decode step is the same function the
dry-run lowers for the decode_32k / long_500k cells.

``QueryServer`` — the paper-workload analog rebuilt as a *micro-batching
scheduler*: logical query plans (``repro.api.plans``) are enqueued with
``submit``; each ``pump`` drains up to ``max_batch`` waiting requests and
hands them to ``QueryClient.run_batch``, which groups compatible strategies
and executes every protocol round once for the whole group — including
range traffic (one fused SS-SUB ripple per (bit-width, reduce_every)
group) and join traffic (PK/FK match matrices ride the batch's single
cross-group fetch ``ss_matmul``; equijoins fuse per phase), so a mixed
live queue pays one dispatch per round, not one per request. Per-request
latency (enqueue → result), batch/throughput counters and a per-family
served breakdown are kept in ``ServeStats``. Per-request keys derive from
the client's root key; an optional ``MapReduceExecutor`` fans each
cloud-side map phase (including the fused batch dispatch) out over
fault-tolerant worker splits.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..api import MapReduceExecutor, Plan, QueryClient, QueryResult
from ..core.engine import SecretSharedDB
from ..models import decode_step, init_cache, prefill
from ..models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray             # (T,) int32
    max_new: int = 16
    out: Optional[np.ndarray] = None
    latency_s: float = 0.0


class BatchServer:
    """Serves equal-length-prompt batches (the common benchmark setting)."""

    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 256):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, n, b: decode_step(p, cfg, c, n, b),
            donate_argnums=(1,))

    def serve(self, requests: List[Request]) -> List[Request]:
        t0 = time.time()
        prompts = np.stack([r.prompt for r in requests])   # (B, T)
        b, t = prompts.shape
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(prompts)})
        max_new = max(r.max_new for r in requests)
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs = [toks]
        for i in range(max_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         jnp.int32(t + i),
                                         {"tokens": toks})
            toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            outs.append(toks)
        gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
        dt = time.time() - t0
        for i, r in enumerate(requests):
            r.out = gen[i, :r.max_new]
            r.latency_s = dt
        return requests


# ---------------------------------------------------------------------------
# oblivious query serving (the paper's workload behind the same queue idiom)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QueryRequest:
    plan: Plan
    result: Optional[QueryResult] = None
    error: Optional[Exception] = None
    latency_s: float = 0.0           # enqueue -> result available
    enqueued_at: float = 0.0


#: latency samples kept for quantile estimates (a sliding window, so a
#: long-running server stays O(1) memory; counters remain exact).
LATENCY_WINDOW = 4096


def plan_family(plan: Plan) -> str:
    """Telemetry bucket for a logical plan (count/select/range_*/join)."""
    name = type(plan).__name__
    return {"Count": "count", "Select": "select",
            "RangeCount": "range_count", "RangeSelect": "range_select",
            "Join": "join"}.get(name, name.lower())


@dataclasses.dataclass
class ServeStats:
    """Aggregate micro-batching telemetry (reset with ``QueryServer.reset``)."""
    served: int = 0
    failed: int = 0
    batches: int = 0
    busy_s: float = 0.0              # wall time spent inside run_batch
    latencies_s: "Deque[float]" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW))
    served_by_family: Dict[str, int] = dataclasses.field(
        default_factory=dict)       # which protocol groups the traffic hits

    @property
    def mean_batch_size(self) -> float:
        return self.served / self.batches if self.batches else 0.0

    @property
    def throughput_qps(self) -> float:
        return self.served / self.busy_s if self.busy_s > 0 else 0.0

    def latency_quantile(self, q: float) -> float:
        if not self.latencies_s:
            return 0.0
        xs = sorted(self.latencies_s)
        return xs[min(len(xs) - 1, int(q * len(xs)))]

    def as_dict(self) -> dict:
        return dict(served=self.served, failed=self.failed,
                    batches=self.batches,
                    mean_batch_size=self.mean_batch_size,
                    busy_s=self.busy_s, throughput_qps=self.throughput_qps,
                    p50_latency_s=self.latency_quantile(0.50),
                    p95_latency_s=self.latency_quantile(0.95),
                    served_by_family=dict(self.served_by_family))


class QueryServer:
    """Micro-batching scheduler for query plans over one shared relation.

    ``submit`` enqueues; ``pump`` drains one micro-batch (≤ ``max_batch``)
    through ``QueryClient.run_batch`` — the client groups compatible
    strategies so each protocol round is issued once per group, not once
    per request. ``serve`` is the synchronous convenience loop: enqueue
    everything, pump until the queue is dry.
    """

    def __init__(self, db: SecretSharedDB, key, *, backend="jnp",
                 executor: Optional[MapReduceExecutor] = None,
                 max_batch: int = 32):
        self.client = QueryClient(db, key, backend=backend,
                                  executor=executor)
        self.max_batch = max(1, max_batch)
        self.stats = ServeStats()
        self._queue: Deque[QueryRequest] = collections.deque()

    # -- scheduling ---------------------------------------------------------
    def submit(self, request: QueryRequest) -> QueryRequest:
        request.enqueued_at = time.time()
        self._queue.append(request)
        return request

    def pending(self) -> int:
        return len(self._queue)

    def pump(self) -> List[QueryRequest]:
        """Drain one micro-batch and execute it; returns finished requests.

        Fault isolation: a plan that raises (bad cardinality hint, invalid
        padding, …) must not take its batch-mates down, so on a batch
        failure the micro-batch is re-run per request and only the
        offending request(s) carry ``error`` (result stays None).
        """
        batch: List[QueryRequest] = []
        while self._queue and len(batch) < self.max_batch:
            batch.append(self._queue.popleft())
        if not batch:
            return []
        t0 = time.time()
        try:
            outcomes = self.client.run_batch([r.plan for r in batch])
        except Exception:  # noqa: BLE001 — isolate the failing request(s)
            outcomes = []
            for r in batch:
                try:
                    outcomes.append(self.client.run_batch([r.plan])[0])
                except Exception as e:  # noqa: BLE001
                    outcomes.append(e)
        t1 = time.time()
        for r, res in zip(batch, outcomes):
            if isinstance(res, Exception):
                r.error = res
                self.stats.failed += 1
            else:
                r.result = res
                self.stats.served += 1
                fam = plan_family(r.plan)
                self.stats.served_by_family[fam] = \
                    self.stats.served_by_family.get(fam, 0) + 1
            r.latency_s = t1 - (r.enqueued_at or t0)
            self.stats.latencies_s.append(r.latency_s)
        self.stats.batches += 1
        self.stats.busy_s += t1 - t0
        return batch

    def serve(self, requests: Sequence[QueryRequest]) -> List[QueryRequest]:
        """Enqueue ``requests`` and pump until everything is answered."""
        for r in requests:
            self.submit(r)
        done: List[QueryRequest] = []
        while self._queue:
            done += self.pump()
        return done

    def reset(self) -> None:
        self.stats = ServeStats()
