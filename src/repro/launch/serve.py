"""Serving drivers: batched LM inference and oblivious query serving.

``BatchServer`` — continuous prefill+decode over a request queue. Minimal
but real: fixed-capacity batch slots, greedy sampling, per-slot lengths,
jitted prefill and decode steps. The decode step is the same function the
dry-run lowers for the decode_32k / long_500k cells.

``QueryServer`` — the paper-workload analog rebuilt as a *deadline-batched
async scheduler over a sharded dataplane*: logical query plans
(``repro.api.plans``) are enqueued with ``submit`` (thread-safe; each
request carries a ``wait()``-able completion event); the background
scheduler thread (``start``/``stop``) parks submissions up to
``max_wait_ms`` to fill ``max_batch``, then closes the batch — by *fill*
when the queue reaches ``max_batch``, by *deadline* when the oldest
request's wait expires — and runs the whole group through
``QueryClient.run_batch``, which groups compatible strategies and executes
every protocol round once for the whole group — including range traffic
(one fused SS-SUB ripple segment per degree-reduction interval per
(bit-width, reduce_every) group) and join traffic (equal-size PK/FK match
matrices stack into one batched dispatch and ride the batch's single
cross-group fetch ``ss_matmul``; equijoins fuse per phase), so a mixed
live queue pays one dispatch set per round, not one per request. With
``shards=S`` the relation is attached as a ``ShardedRelation`` and every
cloud step fans out S tuple-axis shard dispatches, executed concurrently
on a thread pool (results stay bit-identical — mod-p reduction is exact).

Per-request latency (enqueue → result), queue-wait and batch-fill
histograms, close-reason counters, batch/throughput counters and a
per-family served breakdown are kept in ``ServeStats``. Per-request keys
derive from the client's root key in pop order; an optional
``MapReduceExecutor`` fans each cloud-side map phase (including the fused
batch dispatch) out over fault-tolerant worker splits. The synchronous
``pump``/``serve`` surface is unchanged — the scheduler thread is the same
``pump`` driven by a deadline instead of by the caller.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Deque, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..api import MapReduceExecutor, Plan, QueryClient, QueryResult
from ..core.dataplane import (Dispatcher, ShardedRelation,
                              ThreadedDispatcher)
from ..core.engine import SecretSharedDB
from ..models import decode_step, init_cache, prefill
from ..models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray             # (T,) int32
    max_new: int = 16
    out: Optional[np.ndarray] = None
    latency_s: float = 0.0


class BatchServer:
    """Serves equal-length-prompt batches (the common benchmark setting)."""

    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 256):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, n, b: decode_step(p, cfg, c, n, b),
            donate_argnums=(1,))

    def serve(self, requests: List[Request]) -> List[Request]:
        t0 = time.time()
        prompts = np.stack([r.prompt for r in requests])   # (B, T)
        b, t = prompts.shape
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(prompts)})
        max_new = max(r.max_new for r in requests)
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs = [toks]
        for i in range(max_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         jnp.int32(t + i),
                                         {"tokens": toks})
            toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            outs.append(toks)
        gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
        dt = time.time() - t0
        for i, r in enumerate(requests):
            r.out = gen[i, :r.max_new]
            r.latency_s = dt
        return requests


# ---------------------------------------------------------------------------
# oblivious query serving (the paper's workload behind the same queue idiom)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QueryRequest:
    plan: Plan
    result: Optional[QueryResult] = None
    error: Optional[Exception] = None
    latency_s: float = 0.0           # enqueue -> result available
    enqueued_at: float = 0.0
    queue_wait_s: float = 0.0        # enqueue -> batch close
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> "QueryRequest":
        """Block until the scheduler finished this request (async mode)."""
        if not self._done.wait(timeout):
            raise TimeoutError(f"request not served within {timeout}s")
        return self


#: latency samples kept for quantile estimates (a sliding window, so a
#: long-running server stays O(1) memory; counters remain exact).
LATENCY_WINDOW = 4096


def plan_family(plan: Plan) -> str:
    """Telemetry bucket for a logical plan (count/select/range_*/join)."""
    name = type(plan).__name__
    return {"Count": "count", "Select": "select",
            "RangeCount": "range_count", "RangeSelect": "range_select",
            "Join": "join"}.get(name, name.lower())


def _quantile(xs, q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, int(q * len(s)))]


@dataclasses.dataclass
class ServeStats:
    """Aggregate scheduling telemetry (reset with ``QueryServer.reset``)."""
    served: int = 0
    failed: int = 0
    batches: int = 0
    busy_s: float = 0.0              # wall time spent inside run_batch
    latencies_s: "Deque[float]" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW))
    queue_waits_s: "Deque[float]" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=LATENCY_WINDOW))
    batch_fill: Dict[int, int] = dataclasses.field(
        default_factory=dict)       # batch size -> how many batches
    closes: Dict[str, int] = dataclasses.field(
        default_factory=dict)       # why batches closed: full/deadline/...
    served_by_family: Dict[str, int] = dataclasses.field(
        default_factory=dict)       # which protocol groups the traffic hits

    @property
    def mean_batch_size(self) -> float:
        return self.served / self.batches if self.batches else 0.0

    @property
    def throughput_qps(self) -> float:
        return self.served / self.busy_s if self.busy_s > 0 else 0.0

    def latency_quantile(self, q: float) -> float:
        return _quantile(self.latencies_s, q)

    def queue_wait_quantile(self, q: float) -> float:
        return _quantile(self.queue_waits_s, q)

    def record_batch(self, fill: int, reason: str) -> None:
        self.batches += 1
        self.batch_fill[fill] = self.batch_fill.get(fill, 0) + 1
        self.closes[reason] = self.closes.get(reason, 0) + 1

    def as_dict(self) -> dict:
        return dict(served=self.served, failed=self.failed,
                    batches=self.batches,
                    mean_batch_size=self.mean_batch_size,
                    busy_s=self.busy_s, throughput_qps=self.throughput_qps,
                    p50_latency_s=self.latency_quantile(0.50),
                    p95_latency_s=self.latency_quantile(0.95),
                    p50_queue_wait_s=self.queue_wait_quantile(0.50),
                    p95_queue_wait_s=self.queue_wait_quantile(0.95),
                    batch_fill=dict(self.batch_fill),
                    closes=dict(self.closes),
                    served_by_family=dict(self.served_by_family))


class QueryServer:
    """Deadline-batched scheduler for query plans over one shared relation.

    ``submit`` enqueues (thread-safe; the returned request is
    ``wait()``-able); ``pump`` drains one micro-batch (≤ ``max_batch``)
    through ``QueryClient.run_batch`` — the client groups compatible
    strategies so each protocol round is issued once per group, not once
    per request. Two driving modes:

      * synchronous — the caller pumps (``serve`` is the convenience loop:
        enqueue everything, pump until the queue is dry);
      * async — ``start()`` spawns the scheduler thread: submissions park
        up to ``max_wait_ms`` to fill ``max_batch``, then the batch closes
        (by *fill* or by *deadline* — counted in ``stats.closes``) and
        runs. ``stop()`` drains and joins. The server is a context
        manager: ``with QueryServer(..., max_wait_ms=5) as srv: ...``.

    ``shards=S`` attaches the relation as a tuple-axis
    :class:`ShardedRelation` whose per-shard cloud dispatches run
    concurrently on a thread pool (pass ``dispatcher=`` to override the
    placement policy, e.g. ``MapReduceExecutor.dispatcher()``). Sharding
    and batching are both pure execution policy — results and ledgers are
    bit-identical to a solo, unsharded client.
    """

    def __init__(self, db: Union[SecretSharedDB, ShardedRelation], key, *,
                 backend="jnp",
                 executor: Optional[MapReduceExecutor] = None,
                 max_batch: int = 32,
                 max_wait_ms: float = 20.0,
                 shards: int = 1,
                 dispatcher: Optional[Dispatcher] = None):
        self.client = QueryClient(db, key, backend=backend,
                                  executor=executor)
        self._owned_dispatcher: Optional[ThreadedDispatcher] = None
        if shards > 1 or dispatcher is not None:
            if dispatcher is None:
                plane = self.client.dataplane
                workers = max(shards, plane.n_shards if plane else 1)
                dispatcher = self._owned_dispatcher = ThreadedDispatcher(
                    max_workers=workers)
            self.client.attach(shards=shards, dispatcher=dispatcher)
        self.max_batch = max(1, max_batch)
        self.max_wait_ms = max(0.0, max_wait_ms)
        self.stats = ServeStats()
        self._queue: Deque[QueryRequest] = collections.deque()
        self._cond = threading.Condition()
        self._pump_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stopping = False

    @property
    def dataplane(self) -> Optional[ShardedRelation]:
        return self.client.dataplane

    # -- scheduling ---------------------------------------------------------
    def submit(self, request: QueryRequest) -> QueryRequest:
        request.enqueued_at = time.time()
        with self._cond:
            self._queue.append(request)
            self._cond.notify_all()
        return request

    def pending(self) -> int:
        return len(self._queue)

    def pump(self, reason: str = "manual") -> List[QueryRequest]:
        """Drain one micro-batch and execute it; returns finished requests.

        Fault isolation: a plan that raises (bad cardinality hint, invalid
        padding, …) must not take its batch-mates down, so on a batch
        failure the micro-batch is re-run per request and only the
        offending request(s) carry ``error`` (result stays None).
        """
        with self._pump_lock:
            with self._cond:
                batch: List[QueryRequest] = []
                while self._queue and len(batch) < self.max_batch:
                    batch.append(self._queue.popleft())
            if not batch:
                return []
            t0 = time.time()
            for r in batch:
                r.queue_wait_s = t0 - (r.enqueued_at or t0)
                self.stats.queue_waits_s.append(r.queue_wait_s)
            try:
                outcomes = self.client.run_batch([r.plan for r in batch])
            except Exception:  # noqa: BLE001 — isolate failing request(s)
                outcomes = []
                for r in batch:
                    try:
                        outcomes.append(self.client.run_batch([r.plan])[0])
                    except Exception as e:  # noqa: BLE001
                        outcomes.append(e)
            t1 = time.time()
            for r, res in zip(batch, outcomes):
                if isinstance(res, Exception):
                    r.error = res
                    self.stats.failed += 1
                else:
                    r.result = res
                    self.stats.served += 1
                    fam = plan_family(r.plan)
                    self.stats.served_by_family[fam] = \
                        self.stats.served_by_family.get(fam, 0) + 1
                r.latency_s = t1 - (r.enqueued_at or t0)
                self.stats.latencies_s.append(r.latency_s)
                r._done.set()
            self.stats.record_batch(len(batch), reason)
            self.stats.busy_s += t1 - t0
            return batch

    # -- async driver -------------------------------------------------------
    def start(self) -> "QueryServer":
        """Spawn the deadline-batching scheduler thread (idempotent)."""
        with self._cond:
            if self._thread is not None:
                return self
            self._stopping = False
            self._thread = threading.Thread(target=self._scheduler_loop,
                                            name="query-server",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the scheduler thread; ``drain`` pumps the queue dry first."""
        with self._cond:
            thread = self._thread
            self._stopping = True
            self._cond.notify_all()
        if thread is not None:
            thread.join()
        with self._cond:
            self._thread = None
        while drain and self._queue:
            self.pump("drain")

    def close(self) -> None:
        """Stop the scheduler and release the server-owned shard pool.

        Terminal: after ``close()`` the server's own ThreadedDispatcher
        falls back to serial shard execution (still correct) if reused.
        """
        self.stop()
        if self._owned_dispatcher is not None:
            self._owned_dispatcher.close()

    def __enter__(self) -> "QueryServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def _scheduler_loop(self) -> None:
        wait_s = self.max_wait_ms / 1e3
        while True:
            with self._cond:
                while not self._queue and not self._stopping:
                    self._cond.wait()       # submit()/stop() notify
                if self._stopping:
                    return
                # park until the batch fills or the OLDEST submission's
                # deadline expires — latency is bounded by max_wait_ms,
                # fusion is bounded by max_batch.
                deadline = self._queue[0].enqueued_at + wait_s
                while (len(self._queue) < self.max_batch
                       and not self._stopping):
                    remaining = deadline - time.time()
                    if remaining <= 0:
                        break
                    self._cond.wait(remaining)
                reason = ("full" if len(self._queue) >= self.max_batch
                          else "deadline")
            self.pump(reason)

    def serve(self, requests: Sequence[QueryRequest]) -> List[QueryRequest]:
        """Enqueue ``requests`` and finish them all.

        With the scheduler running this blocks on the requests' completion
        events; otherwise it pumps inline until the queue is dry.
        """
        for r in requests:
            self.submit(r)
        if self._thread is not None:
            for r in requests:
                r.wait()
            return list(requests)
        done: List[QueryRequest] = []
        while self._queue:
            done += self.pump()
        return done

    def reset(self) -> None:
        self.stats = ServeStats()
