"""Serving drivers: batched LM inference and oblivious query serving.

``BatchServer`` — continuous prefill+decode over a request queue. Minimal
but real: fixed-capacity batch slots, greedy sampling, per-slot lengths,
jitted prefill and decode steps. The decode step is the same function the
dry-run lowers for the decode_32k / long_500k cells.

``QueryServer`` — the paper-workload analog: drains a queue of logical
query plans (``repro.api.plans``) through one ``QueryClient`` over a
secret-shared relation. Per-request keys derive from the client's root key;
an optional ``MapReduceExecutor`` fans each cloud-side map phase out over
fault-tolerant worker splits.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..api import MapReduceExecutor, Plan, QueryClient, QueryResult
from ..core.engine import SecretSharedDB
from ..models import decode_step, init_cache, prefill
from ..models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray             # (T,) int32
    max_new: int = 16
    out: Optional[np.ndarray] = None
    latency_s: float = 0.0


class BatchServer:
    """Serves equal-length-prompt batches (the common benchmark setting)."""

    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 256):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, n, b: decode_step(p, cfg, c, n, b),
            donate_argnums=(1,))

    def serve(self, requests: List[Request]) -> List[Request]:
        t0 = time.time()
        prompts = np.stack([r.prompt for r in requests])   # (B, T)
        b, t = prompts.shape
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(prompts)})
        max_new = max(r.max_new for r in requests)
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs = [toks]
        for i in range(max_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         jnp.int32(t + i),
                                         {"tokens": toks})
            toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            outs.append(toks)
        gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
        dt = time.time() - t0
        for i, r in enumerate(requests):
            r.out = gen[i, :r.max_new]
            r.latency_s = dt
        return requests


# ---------------------------------------------------------------------------
# oblivious query serving (the paper's workload behind the same queue idiom)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QueryRequest:
    plan: Plan
    result: Optional[QueryResult] = None
    latency_s: float = 0.0


class QueryServer:
    """Serves logical query plans against one secret-shared relation."""

    def __init__(self, db: SecretSharedDB, key, *, backend="jnp",
                 executor: Optional[MapReduceExecutor] = None):
        self.client = QueryClient(db, key, backend=backend,
                                  executor=executor)

    def serve(self, requests: List[QueryRequest]) -> List[QueryRequest]:
        for r in requests:
            t0 = time.time()
            r.result = self.client.run(r.plan)
            r.latency_s = time.time() - t0
        return requests
