"""Serving drivers: batched LM inference and oblivious query serving.

``BatchServer`` — continuous prefill+decode over a request queue. Minimal
but real: fixed-capacity batch slots, greedy sampling, per-slot lengths,
jitted prefill and decode steps. The decode step is the same function the
dry-run lowers for the decode_32k / long_500k cells.

``QueryServer`` — the paper-workload analog rebuilt as a *multi-tenant
deadline-batched async scheduler over sharded dataplanes*: ``attach(name,
relation, shards=S)`` registers any number of secret-shared relations (the
paper's owner distributes a *database* — plural relations — once; users
then query any of them), each with its own dataplane, batching policy and
per-relation query-key stream; logical query plans (``repro.api.plans``)
are enqueued with ``submit(plan, relation=...)`` (thread-safe; each
request carries a ``wait()``-able completion event) into the target
relation's FIFO batch group. ONE background scheduler thread
(``start``/``stop``) closes each relation's group independently — by
*fill* when that queue reaches its ``max_batch``, by *deadline* when its
oldest request's *steered* wait expires — and runs the group through
``QueryClient.run_batch(plans, relation=...)``, which groups compatible
strategies and executes every protocol round once for the whole group —
including range traffic (one fused SS-SUB ripple segment per
degree-reduction interval per (bit-width, reduce_every) group) and join
traffic (equal-size PK/FK match matrices stack into one batched dispatch
and ride the batch's single cross-group fetch ``ss_matmul``; equijoins
fuse per phase), so a mixed live queue pays one dispatch set per round,
not one per request. With ``shards=S`` a relation is attached as a
``ShardedRelation`` and every cloud step fans out S tuple-axis shard
dispatches — all relations share ONE server-owned thread pool via
detachable handles, so the global fan-out stays bounded (results stay
bit-identical — mod-p reduction is exact, and batches never mix
relations).

Three overload behaviours are self-tuning:

  * **adaptive deadline steering** — each relation's effective wait is
    driven by its own close history: a batch that closes *full* shrinks
    the wait (``STEER_SHRINK``, traffic is hot — close sooner, keep
    latency flat), a batch that closes by *deadline underfilled* grows it
    back (``STEER_GROW``) up to the configured ``max_wait_ms`` cap. The
    steered value plus its recent trajectory are exposed per relation in
    ``snapshot()`` (``steered_wait_ms`` / ``wait_trajectory_ms``), so
    monitoring code can watch a hot tenant's deadline dive while a cold
    neighbour's stays parked at the cap.
  * **weighted fair pool quotas** — ``attach(..., weight=w)`` gives the
    relation's shard handle a deficit-round-robin weight on the shared
    pool, so a flooding tenant is bounded to its share of the fan-out
    instead of starving neighbours (see ``core.dataplane.PoolHandle``).
  * **cross-relation fused closes** — when several relations' batches
    close in the same scheduler scan they run as ONE
    ``QueryClient.run_batch_multi`` wave: the per-relation fetch
    ``ss_matmul`` dispatches co-schedule on the shared pool (batches
    still never mix — each relation keeps its own key stream, rounds and
    ledger, so rows and ledgers stay bit-identical to solo serving).

Per-request latency (enqueue → result), queue-wait and batch-fill
histograms, close-reason counters, batch/throughput counters, a
per-family served breakdown, and per-relation ``queue_depth`` /
``steered_wait_ms`` gauges are kept in ``ServeStats``, both in aggregate
and per relation; ``snapshot()`` reads it all consistently under the stats
lock. Per-request keys derive from the target relation's root key in pop
order (streams are per relation, so tenants never perturb each other's
transcripts); an optional ``MapReduceExecutor`` fans each cloud-side map
phase (including the fused batch dispatch) out over fault-tolerant worker
splits. The synchronous ``pump``/``serve`` surface is unchanged — the
scheduler thread is the same ``pump`` driven by deadlines instead of by
the caller.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..api import (DEFAULT_RELATION, MapReduceExecutor, Plan, QueryClient,
                   QueryResult)
from ..api.plans import PATTERN_PREDICATES
from ..core.dataplane import (Dispatcher, ShardedRelation,
                              ThreadedDispatcher)
from ..core.engine import SecretSharedDB
from ..models import decode_step, init_cache, prefill
from ..models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: np.ndarray             # (T,) int32
    max_new: int = 16
    out: Optional[np.ndarray] = None
    latency_s: float = 0.0


class BatchServer:
    """Serves equal-length-prompt batches (the common benchmark setting)."""

    def __init__(self, params, cfg: ModelConfig, *, max_len: int = 256):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b, max_len=max_len))
        self._decode = jax.jit(
            lambda p, c, n, b: decode_step(p, cfg, c, n, b),
            donate_argnums=(1,))

    def serve(self, requests: List[Request]) -> List[Request]:
        t0 = time.time()
        prompts = np.stack([r.prompt for r in requests])   # (B, T)
        b, t = prompts.shape
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(prompts)})
        max_new = max(r.max_new for r in requests)
        toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        outs = [toks]
        for i in range(max_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         jnp.int32(t + i),
                                         {"tokens": toks})
            toks = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            outs.append(toks)
        gen = np.concatenate([np.asarray(o) for o in outs], axis=1)
        dt = time.time() - t0
        for i, r in enumerate(requests):
            r.out = gen[i, :r.max_new]
            r.latency_s = dt
        return requests



# ---------------------------------------------------------------------------
# oblivious query serving (the paper's workload behind the same queue idiom)
# ---------------------------------------------------------------------------

class ServerStopped(RuntimeError):
    """The server was stopped before this request could be served.

    Raised by :meth:`QueryRequest.wait` when ``QueryServer.stop`` dropped
    the still-queued request (``drain=False``) — a dropped submission must
    fail loudly, never hang its waiter.
    """


@dataclasses.dataclass
class QueryRequest:
    plan: Plan
    relation: Optional[str] = None   # registry name; filled in by submit()
    result: Optional[QueryResult] = None
    error: Optional[Exception] = None
    latency_s: float = 0.0           # enqueue -> result available
    enqueued_at: float = 0.0
    queue_wait_s: float = 0.0        # enqueue -> batch close
    _done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: Optional[float] = None) -> "QueryRequest":
        """Block until the scheduler finished this request (async mode).

        A request the server dropped on shutdown raises
        :class:`ServerStopped`; protocol-level failures (bad cardinality
        hint, invalid padding, …) stay on :attr:`error` for the caller to
        inspect, exactly as before.
        """
        if not self._done.wait(timeout):
            raise TimeoutError(f"request not served within {timeout}s")
        if isinstance(self.error, ServerStopped):
            raise self.error
        return self


#: latency samples kept for quantile estimates (a sliding window, so a
#: long-running server stays O(1) memory; counters remain exact).
LATENCY_WINDOW = 4096

#: adaptive deadline steering: multiplicative shrink on a *full* close
#: (traffic hot — stop waiting for stragglers), gentler grow on a
#: *deadline underfilled* close (traffic cooled — park longer, refill),
#: AIMD-style so a hot tenant's deadline converges down fast and recovers
#: smoothly. The steered wait never exceeds the configured ``max_wait_ms``
#: (the cap) and never drops below ``MIN_STEER_WAIT_S``.
STEER_SHRINK = 0.7
STEER_GROW = 1.3
MIN_STEER_WAIT_S = 1e-4

#: steered-wait samples kept per relation (the snapshot trajectory).
TRAJECTORY_WINDOW = 64

#: floor on the scheduler's timed condition-variable park. Without it a
#: sub-millisecond (or steered-to-tiny) deadline turns the scheduler loop
#: into a busy-spin: wait(~0) returns immediately, the scan re-runs, the
#: deadline is still a hair away, repeat at MHz. Flooring trades ≤ 1 ms of
#: deadline overshoot for a quiescent loop.
MIN_PARK_S = 1e-3


def plan_family(plan: Plan) -> str:
    """Telemetry bucket for a logical plan (count/select/range_*/join/
    aggregate/embed; Count/Select under a LIKE/prefix/suffix/substring
    predicate bucket as pattern_count/pattern_select — the pattern engine
    shares the families' fused rounds, but an operator watching
    served_by_family wants to see the matcher mix)."""
    name = type(plan).__name__
    base = {"Count": "count", "Select": "select",
            "RangeCount": "range_count", "RangeSelect": "range_select",
            "Join": "join", "Aggregate": "aggregate",
            "EmbedLookup": "embed"}.get(name, name.lower())
    if base in ("count", "select") and isinstance(
            getattr(plan, "where", None), PATTERN_PREDICATES):
        return f"pattern_{base}"
    return base


def _quantile(xs, q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    return s[min(len(s) - 1, int(q * len(s)))]


def _window() -> "Deque[float]":
    return collections.deque(maxlen=LATENCY_WINDOW)


@dataclasses.dataclass
class RelationStats:
    """One relation's slice of the serving telemetry.

    ``dispatches`` / ``dispatch_s`` / ``transfer_bytes`` mirror the
    relation dataplane's :class:`~repro.core.dataplane.DispatchStats`
    deltas, accumulated per served batch — so the measured cloud-step
    wall-time and staged bytes (zero after placement for a device-resident
    dispatcher) are visible to monitoring code, not only dispatch counts.

    ``queue_depth`` and ``steered_wait_ms`` are *gauges* (last observed
    value, refreshed each served batch, not accumulated):
    ``queue_depth`` is how many requests were still parked right after the
    batch closed, ``steered_wait_ms`` the relation's adaptively-steered
    effective deadline; ``wait_trajectory_ms`` keeps the recent steering
    history so a monitor can see the deadline dive under load and recover.
    """
    served: int = 0
    failed: int = 0
    batches: int = 0
    busy_s: float = 0.0
    dispatches: int = 0
    dispatch_s: float = 0.0
    transfer_bytes: int = 0
    queue_depth: int = 0
    steered_wait_ms: float = 0.0
    wait_trajectory_ms: "Deque[float]" = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=TRAJECTORY_WINDOW))
    latencies_s: "Deque[float]" = dataclasses.field(default_factory=_window)
    queue_waits_s: "Deque[float]" = dataclasses.field(
        default_factory=_window)
    batch_fill: Dict[int, int] = dataclasses.field(default_factory=dict)
    closes: Dict[str, int] = dataclasses.field(default_factory=dict)
    served_by_family: Dict[str, int] = dataclasses.field(
        default_factory=dict)

    def as_dict(self) -> dict:
        return dict(served=self.served, failed=self.failed,
                    batches=self.batches, busy_s=self.busy_s,
                    dispatches=self.dispatches,
                    dispatch_s=self.dispatch_s,
                    transfer_bytes=self.transfer_bytes,
                    queue_depth=self.queue_depth,
                    steered_wait_ms=self.steered_wait_ms,
                    wait_trajectory_ms=list(self.wait_trajectory_ms),
                    p50_latency_s=_quantile(list(self.latencies_s), 0.50),
                    p95_latency_s=_quantile(list(self.latencies_s), 0.95),
                    p50_queue_wait_s=_quantile(list(self.queue_waits_s),
                                               0.50),
                    p95_queue_wait_s=_quantile(list(self.queue_waits_s),
                                               0.95),
                    batch_fill=dict(self.batch_fill),
                    closes=dict(self.closes),
                    served_by_family=dict(self.served_by_family))


@dataclasses.dataclass
class ServeStats:
    """Aggregate scheduling telemetry (reset with ``QueryServer.reset``).

    Top-level counters/histograms aggregate over every relation (the
    pre-multi-tenant surface, unchanged); :attr:`relations` carries the
    per-relation breakdown — served_by_family, queue-wait and batch-fill
    histograms keyed by registry name.

    Writers and readers run on different threads (scheduler vs monitoring
    code), so every mutation goes through the ``note_*``/``record_batch``
    helpers and every read that touches a histogram goes through
    :meth:`snapshot`/the quantile helpers — all serialized on one internal
    lock. Bare field reads of the integer counters stay safe (atomic
    loads) and monotone.
    """
    served: int = 0
    failed: int = 0
    batches: int = 0
    busy_s: float = 0.0              # wall time spent inside run_batch
    dispatches: int = 0              # shard dispatches (dataplane deltas)
    dispatch_s: float = 0.0          # cloud-step wall-time (dataplane)
    transfer_bytes: int = 0          # staged bytes (dataplane)
    latencies_s: "Deque[float]" = dataclasses.field(default_factory=_window)
    queue_waits_s: "Deque[float]" = dataclasses.field(
        default_factory=_window)
    batch_fill: Dict[int, int] = dataclasses.field(
        default_factory=dict)       # batch size -> how many batches
    closes: Dict[str, int] = dataclasses.field(
        default_factory=dict)       # why batches closed: full/deadline/...
    served_by_family: Dict[str, int] = dataclasses.field(
        default_factory=dict)       # which protocol groups the traffic hits
    relations: Dict[str, RelationStats] = dataclasses.field(
        default_factory=dict)       # per-relation breakdown
    _lock: threading.Lock = dataclasses.field(
        default_factory=threading.Lock, repr=False, compare=False)

    @property
    def mean_batch_size(self) -> float:
        return self.served / self.batches if self.batches else 0.0

    @property
    def throughput_qps(self) -> float:
        return self.served / self.busy_s if self.busy_s > 0 else 0.0

    def _rel_locked(self, relation: Optional[str]) -> RelationStats:
        rs = self.relations.get(relation or "")
        if rs is None:
            rs = self.relations[relation or ""] = RelationStats()
        return rs

    # -- locked writers (called from the pump, any thread) ------------------
    def note_queue_wait(self, wait_s: float,
                        relation: Optional[str] = None) -> None:
        with self._lock:
            self.queue_waits_s.append(wait_s)
            if relation is not None:
                self._rel_locked(relation).queue_waits_s.append(wait_s)

    def note_result(self, latency_s: float, family: Optional[str],
                    relation: Optional[str] = None) -> None:
        """One finished request: ``family`` is its plan family, or None
        for a failure."""
        with self._lock:
            rs = (self._rel_locked(relation) if relation is not None
                  else None)
            self.latencies_s.append(latency_s)
            if rs is not None:
                rs.latencies_s.append(latency_s)
            if family is None:
                self.failed += 1
                if rs is not None:
                    rs.failed += 1
                return
            self.served += 1
            self.served_by_family[family] = \
                self.served_by_family.get(family, 0) + 1
            if rs is not None:
                rs.served += 1
                rs.served_by_family[family] = \
                    rs.served_by_family.get(family, 0) + 1

    def note_dropped(self, relation: Optional[str] = None) -> None:
        """A request dropped unserved on shutdown (counts as failed)."""
        with self._lock:
            self.failed += 1
            if relation is not None:
                self._rel_locked(relation).failed += 1

    def record_batch(self, fill: int, reason: str,
                     relation: Optional[str] = None,
                     busy_s: float = 0.0, dispatches: int = 0,
                     dispatch_s: float = 0.0,
                     transfer_bytes: int = 0,
                     queue_depth: Optional[int] = None,
                     steered_wait_ms: Optional[float] = None) -> None:
        """One closed batch. ``queue_depth``/``steered_wait_ms`` refresh
        the relation's gauges (and the steering trajectory) when given."""
        with self._lock:
            for st in ([self] if relation is None
                       else [self, self._rel_locked(relation)]):
                st.batches += 1
                st.busy_s += busy_s
                st.batch_fill[fill] = st.batch_fill.get(fill, 0) + 1
                st.closes[reason] = st.closes.get(reason, 0) + 1
                st.dispatches += dispatches
                st.dispatch_s += dispatch_s
                st.transfer_bytes += transfer_bytes
            if relation is not None:
                rs = self._rel_locked(relation)
                if queue_depth is not None:
                    rs.queue_depth = queue_depth
                if steered_wait_ms is not None:
                    rs.steered_wait_ms = steered_wait_ms
                    rs.wait_trajectory_ms.append(steered_wait_ms)

    # -- locked readers -----------------------------------------------------
    def latency_quantile(self, q: float,
                         relation: Optional[str] = None) -> float:
        with self._lock:
            xs = (self.latencies_s if relation is None else
                  self.relations.get(relation, _EMPTY_REL).latencies_s)
            return _quantile(list(xs), q)

    def queue_wait_quantile(self, q: float,
                            relation: Optional[str] = None) -> float:
        """Queue-wait quantile; an empty (or unknown-relation) histogram
        is 0.0, never an error."""
        with self._lock:
            xs = (self.queue_waits_s if relation is None else
                  self.relations.get(relation, _EMPTY_REL).queue_waits_s)
            return _quantile(list(xs), q)

    def snapshot(self) -> dict:
        """A consistent deep copy of every counter and histogram.

        Taken under the stats lock, so a monitoring thread never observes
        a torn histogram (a deque mid-append, a dict mid-insert) while the
        scheduler records a batch — the concurrent-submitter soak test
        reads this under load.
        """
        with self._lock:
            return dict(served=self.served, failed=self.failed,
                        batches=self.batches,
                        mean_batch_size=self.mean_batch_size,
                        busy_s=self.busy_s,
                        dispatches=self.dispatches,
                        dispatch_s=self.dispatch_s,
                        transfer_bytes=self.transfer_bytes,
                        throughput_qps=self.throughput_qps,
                        p50_latency_s=_quantile(list(self.latencies_s),
                                                0.50),
                        p95_latency_s=_quantile(list(self.latencies_s),
                                                0.95),
                        p50_queue_wait_s=_quantile(
                            list(self.queue_waits_s), 0.50),
                        p95_queue_wait_s=_quantile(
                            list(self.queue_waits_s), 0.95),
                        batch_fill=dict(self.batch_fill),
                        closes=dict(self.closes),
                        served_by_family=dict(self.served_by_family),
                        relations={name: rs.as_dict()
                                   for name, rs in self.relations.items()})

    def as_dict(self) -> dict:
        return self.snapshot()


_EMPTY_REL = RelationStats()


@dataclasses.dataclass
class _Tenant:
    """Scheduler-side state of one attached relation.

    ``wait_s`` is the *effective* (adaptively steered) deadline the
    scheduler parks on; ``base_wait_s`` the configured cap it may grow
    back to. Both mutate only under the server's condition lock.
    """
    name: str
    queue: "Deque[QueryRequest]"
    max_batch: int
    wait_s: float
    base_wait_s: float = -1.0       # <0: default to the initial wait_s
    weight: float = 1.0             # shared-pool DRR weight (attach())

    def __post_init__(self) -> None:
        if self.base_wait_s < 0:
            self.base_wait_s = self.wait_s

    def steer(self, reason: str, fill: int) -> float:
        """Update the effective wait after a close; returns it in ms.

        AIMD-flavoured: a *full* close means traffic filled ``max_batch``
        before the deadline — waiting longer only adds latency, so shrink
        multiplicatively. A *deadline* close below ``max_batch`` means the
        wait was too short to fill a batch — grow back toward (never past)
        the configured cap. Manual/drain pumps don't steer.
        """
        if self.base_wait_s > 0:
            if reason == "full":
                self.wait_s = max(MIN_STEER_WAIT_S,
                                  self.wait_s * STEER_SHRINK)
            elif reason == "deadline" and fill < self.max_batch:
                self.wait_s = min(self.base_wait_s,
                                  self.wait_s * STEER_GROW)
        return self.wait_s * 1e3


class QueryServer:
    """Deadline-batched scheduler for query plans over attached relations.

    The server is **multi-tenant**: :meth:`attach` registers any number of
    relations (the paper's data owner shares a *database*; users then
    query any relation without the owner), each with its own dataplane,
    plan namespace and per-relation batching policy, all driven by ONE
    scheduler thread. ``QueryServer(db, key)`` is the single-relation
    surface — it attaches ``db`` under the default name and behaves
    exactly as before.

    ``submit`` enqueues (thread-safe; the returned request is
    ``wait()``-able) into the target relation's FIFO queue — pass a bare
    plan plus ``relation="orders"``, or a :class:`QueryRequest`; ``pump``
    drains one micro-batch (≤ the relation's ``max_batch``) through
    ``QueryClient.run_batch(plans, relation=...)`` — the client groups
    compatible strategies so each protocol round is issued once per group,
    not once per request. Two driving modes:

      * synchronous — the caller pumps (``serve`` is the convenience loop:
        enqueue everything, pump until every queue is dry);
      * async — ``start()`` spawns the scheduler thread: each relation's
        submissions park up to its ``max_wait_ms`` to fill its
        ``max_batch``, then that relation's batch closes (by *fill* or by
        *deadline* — counted in ``stats.closes``, also per relation) and
        runs. Relations close independently: a deep queue on "orders"
        never delays a deadline on "users", and requests never batch
        across relations. ``stop()`` drains every queue (closing a final
        batch per relation) *before* the thread exits; ``stop(
        drain=False)`` instead fails still-parked requests with
        :class:`ServerStopped` so no waiter ever hangs. The server is a
        context manager: ``with QueryServer(..., max_wait_ms=5) as srv``.

    ``shards=S`` (per attach) partitions that relation as a tuple-axis
    :class:`ShardedRelation`; all relations' shard dispatches share ONE
    server-owned thread pool (``pool_workers`` bounds the global fan-out),
    each through its own detachable :class:`~repro.core.dataplane.
    PoolHandle` — pass ``dispatcher=`` to override placement per relation
    (e.g. ``MapReduceExecutor.dispatcher()``). Sharding and batching are
    both pure execution policy, and per-relation key streams are
    independent, so every relation's rows and ledgers are bit-identical
    to a solo single-relation server (the multi-tenant acceptance test).
    """

    def __init__(self, db: Union[SecretSharedDB, ShardedRelation,
                                 None] = None, key=None, *,
                 backend="jnp",
                 executor: Optional[MapReduceExecutor] = None,
                 max_batch: int = 32,
                 max_wait_ms: float = 20.0,
                 shards: int = 1,
                 dispatcher: Optional[Dispatcher] = None,
                 pool_workers: Optional[int] = None):
        self.max_batch = max(1, max_batch)
        self.max_wait_ms = max(0.0, max_wait_ms)
        self.client = QueryClient(db, 0 if key is None else key,
                                  backend=backend, executor=executor)
        self._owned_dispatcher: Optional[ThreadedDispatcher] = None
        self._pool_workers = pool_workers
        self._tenants: Dict[str, _Tenant] = {}
        self._rr_last: Optional[str] = None     # round-robin pump cursor
        self.stats = ServeStats()
        self._cond = threading.Condition()
        self._pump_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._stopping = False
        self._drain_on_stop = True
        self._rejecting = False     # stop(drain=False) .. next start()
        if db is None and (shards > 1 or dispatcher is not None):
            raise ValueError(
                "shards=/dispatcher= are per-relation policies — with no "
                "db to attach they would be silently dropped; pass them "
                "to attach(name, relation, shards=..., dispatcher=...) "
                "instead")
        if db is not None:
            if shards > 1 or dispatcher is not None:
                if dispatcher is None:
                    plane = self.client.dataplane
                    workers = max(shards,
                                  plane.n_shards if plane else 1)
                    dispatcher = self._pool_handle(workers)
                self.client.attach(shards=shards, dispatcher=dispatcher)
            self._tenants[DEFAULT_RELATION] = _Tenant(
                DEFAULT_RELATION, collections.deque(), self.max_batch,
                self.max_wait_ms / 1e3)

    # -- relation registry --------------------------------------------------
    def _pool_handle(self, want_workers: int,
                     weight: float = 1.0) -> Dispatcher:
        """A per-relation handle on the ONE server-owned shard pool.

        The pool is created on first demand, sized by ``pool_workers``
        (falling back to the first requester's shard count), and shared by
        every relation attached afterwards — the global dispatch fan-out
        stays bounded no matter how many tenants are registered.
        ``weight`` is the handle's deficit-round-robin share of that
        bounded fan-out (see :class:`~repro.core.dataplane.PoolHandle`).
        """
        if self._owned_dispatcher is None:
            self._owned_dispatcher = ThreadedDispatcher(
                max_workers=self._pool_workers or max(1, want_workers))
        return self._owned_dispatcher.handle(weight=weight)

    def attach(self, name: str,
               relation: Union[SecretSharedDB, ShardedRelation,
                               None] = None, *,
               shards: int = 1,
               dispatcher: Optional[Dispatcher] = None,
               key=None,
               max_batch: Optional[int] = None,
               max_wait_ms: Optional[float] = None,
               weight: float = 1.0) -> "QueryServer":
        """Register (or re-shard) relation ``name`` on this server.

        ``relation`` may be omitted to re-configure an already-attached
        name. ``key`` seeds the relation's private query-key stream (so a
        tenant replays a solo server bit-for-bit); ``max_batch`` /
        ``max_wait_ms`` override the server defaults for this relation's
        batch group only (``max_wait_ms`` also resets the steering cap).
        With ``shards > 1`` and no explicit ``dispatcher``, the relation's
        shard dispatches join the shared server pool through their own
        detachable handle, weighted ``weight`` in the pool's
        deficit-round-robin (a tenant with weight 2 gets twice the shard
        slots of a weight-1 neighbour under contention; fairness is pure
        scheduling policy, transcripts stay bit-identical).
        """
        if weight <= 0:
            raise ValueError(f"weight must be > 0, got {weight}")
        if shards > 1 and dispatcher is None:
            dispatcher = self._pool_handle(shards, weight)
        self.client.attach(relation, name=name, shards=shards,
                           dispatcher=dispatcher, key=key)
        with self._cond:
            t = self._tenants.get(name)
            if t is None:
                t = self._tenants[name] = _Tenant(
                    name, collections.deque(), self.max_batch,
                    self.max_wait_ms / 1e3)
            if max_batch is not None:
                t.max_batch = max(1, max_batch)
            if max_wait_ms is not None:
                t.wait_s = t.base_wait_s = max(0.0, max_wait_ms) / 1e3
            t.weight = float(weight)
            self._cond.notify_all()
        return self

    @property
    def relations(self) -> Tuple[str, ...]:
        """Attached relation names, in registration order."""
        with self._cond:                # vs a racing live attach()
            return tuple(self._tenants)

    @property
    def dataplane(self) -> Optional[ShardedRelation]:
        return self.client.dataplane

    def dataplane_of(self, relation: str) -> Optional[ShardedRelation]:
        return self.client.dataplane_of(relation)

    def _tenant(self, relation: Optional[str]) -> _Tenant:
        if relation is None:
            t = self._tenants.get(DEFAULT_RELATION)
            if t is not None:
                return t
            if len(self._tenants) == 1:
                return next(iter(self._tenants.values()))
            if not self._tenants:
                raise ValueError("no relation attached — construct with a "
                                 "db or call attach(name, db)")
            raise ValueError(f"several relations attached "
                             f"({list(self._tenants)}) — pass relation=")
        try:
            return self._tenants[relation]
        except KeyError:
            raise KeyError(f"unknown relation {relation!r}; attached: "
                           f"{list(self._tenants)}") from None

    # -- scheduling ---------------------------------------------------------
    def submit(self, request: Union[QueryRequest, Plan],
               relation: Optional[str] = None) -> QueryRequest:
        """Enqueue one request (thread-safe) into its relation's queue.

        Accepts a bare :class:`~repro.api.plans.Plan` for convenience;
        ``relation`` (or ``request.relation``) routes it — omitted, the
        default/sole relation takes it.

        From the moment ``stop(drain=False)`` begins until the next
        ``start()``, submissions are failed immediately with
        :class:`ServerStopped` (their ``wait()`` raises) — a racer must
        never be parked on a queue nothing will ever pump.
        """
        if isinstance(request, Plan):
            request = QueryRequest(request)
        tenant = self._tenant(relation if relation is not None
                              else request.relation)
        request.relation = tenant.name
        request.enqueued_at = time.time()
        with self._cond:
            if self._rejecting:
                request.error = ServerStopped(
                    f"QueryServer stopped (drain=False) — not accepting "
                    f"submissions for relation {tenant.name!r} until "
                    f"start()")
                request._done.set()
            else:
                tenant.queue.append(request)
                self._cond.notify_all()
        if request.error is not None:
            self.stats.note_dropped(tenant.name)
        return request

    def pending(self, relation: Optional[str] = None) -> int:
        with self._cond:                # vs a racing live attach()
            if relation is not None:
                return len(self._tenant(relation).queue)
            return sum(len(t.queue) for t in self._tenants.values())

    def _rotation(self) -> List[str]:
        """Tenant names rotated past the last-pumped one — the shared
        round-robin order of the sync pump and the async scheduler scan
        (so a chatty relation cannot starve its neighbours)."""
        names = list(self._tenants)
        start = (names.index(self._rr_last) + 1
                 if self._rr_last in names else 0)
        return names[start:] + names[:start]

    def _next_tenant(self) -> Optional[_Tenant]:
        for name in self._rotation():
            if self._tenants[name].queue:
                return self._tenants[name]
        return None

    def pump(self, reason: str = "manual",
             relation: Optional[str] = None) -> List[QueryRequest]:
        """Drain one relation's micro-batch and execute it.

        ``relation`` picks the batch group; omitted, the round-robin
        cursor finds the next relation with queued work. Batches NEVER mix
        relations — each closes and runs against its own dataplane with
        its own key stream, so per-relation results are independent of
        neighbour traffic.

        Fault isolation: a plan that raises (bad cardinality hint, invalid
        padding, …) must not take its batch-mates down, so on a batch
        failure the micro-batch is re-run per request and only the
        offending request(s) carry ``error`` (result stays None).
        """
        with self._pump_lock:
            with self._cond:
                tenant = (self._tenant(relation) if relation is not None
                          else self._next_tenant())
                if tenant is None:
                    return []
                self._rr_last = tenant.name
                batch = self._close_locked(tenant)
            if not batch:
                return []
            self._run_closed([(tenant, reason, batch)])
            return batch

    @staticmethod
    def _close_locked(tenant: _Tenant) -> List[QueryRequest]:
        """Pop one micro-batch (≤ max_batch) off a tenant's queue.

        Caller holds ``_cond`` — the pop and the close decision that
        triggered it are one atomic scheduling step.
        """
        batch: List[QueryRequest] = []
        while tenant.queue and len(batch) < tenant.max_batch:
            batch.append(tenant.queue.popleft())
        return batch

    def _run_closed(self, closed: List[Tuple[_Tenant, str,
                                             List[QueryRequest]]]) -> None:
        """Execute already-closed batches (caller holds ``_pump_lock``).

        One entry runs the classic ``run_batch`` path. Several entries —
        the scheduler found several relations due in ONE scan — run as one
        ``QueryClient.run_batch_multi`` wave: per-relation rounds stay
        separate (keys, rounds, ledgers untouched, results bit-identical
        to solo closes) but every batch's cloud-side fetch ``ss_matmul``
        co-schedules on the shared pool as a single fused dispatch wave.
        Fault isolation is layered: a failing fused wave falls back per
        relation, a failing relation batch per request, so only the
        offending request(s) carry ``error``.

        After each batch the tenant's deadline is steered
        (:meth:`_Tenant.steer`) and its ``queue_depth`` /
        ``steered_wait_ms`` gauges are refreshed.
        """
        t0 = time.time()
        for tenant, _reason, batch in closed:
            for r in batch:
                r.queue_wait_s = t0 - (r.enqueued_at or t0)
                self.stats.note_queue_wait(r.queue_wait_s, tenant.name)
        planes = {t.name: self.client.dataplane_of(t.name)
                  for t, _, _ in closed}
        d0s = {name: dataclasses.replace(p.stats) if p else None
               for name, p in planes.items()}
        fused: Optional[List[List[QueryResult]]] = None
        if len(closed) > 1:
            try:
                fused = self.client.run_batch_multi(
                    [(t.name, [r.plan for r in batch])
                     for t, _, batch in closed])
            except Exception:  # noqa: BLE001 — isolate failing relation(s)
                fused = None
        t_prev = t0
        for i, (tenant, reason, batch) in enumerate(closed):
            if fused is not None:
                outcomes: List[Union[QueryResult, Exception]] = \
                    list(fused[i])
            else:
                try:
                    outcomes = list(self.client.run_batch(
                        [r.plan for r in batch], relation=tenant.name))
                except Exception:  # noqa: BLE001 — isolate request(s)
                    outcomes = []
                    for r in batch:
                        try:
                            outcomes.append(self.client.run_batch(
                                [r.plan], relation=tenant.name)[0])
                        except Exception as e:  # noqa: BLE001
                            outcomes.append(e)
            t1 = time.time()
            # busy accounting: a fused wave's wall is split across its
            # relations (the aggregate stays the wall actually spent);
            # sequential fallbacks charge their own span.
            busy = ((t1 - t0) / len(closed) if fused is not None
                    else t1 - t_prev)
            t_prev = t1
            for r, res in zip(batch, outcomes):
                r.latency_s = t1 - (r.enqueued_at or t0)
                if isinstance(res, Exception):
                    r.error = res
                    self.stats.note_result(r.latency_s, None, tenant.name)
                else:
                    r.result = res
                    self.stats.note_result(r.latency_s,
                                           plan_family(r.plan), tenant.name)
                r._done.set()
            plane, d0 = planes[tenant.name], d0s[tenant.name]
            d = plane.stats if plane else None
            with self._cond:
                depth = len(tenant.queue)
                steered = tenant.steer(reason, len(batch))
            self.stats.record_batch(
                len(batch), reason, tenant.name, busy_s=busy,
                dispatches=(d.dispatches - d0.dispatches) if d else 0,
                dispatch_s=(d.dispatch_s - d0.dispatch_s) if d else 0.0,
                transfer_bytes=(d.transfer_bytes - d0.transfer_bytes)
                if d else 0,
                queue_depth=depth, steered_wait_ms=steered)

    # -- async driver -------------------------------------------------------
    def start(self) -> "QueryServer":
        """Spawn the deadline-batching scheduler thread (idempotent)."""
        with self._cond:
            if self._thread is not None:
                return self
            self._stopping = False
            self._drain_on_stop = True
            self._rejecting = False
            self._thread = threading.Thread(target=self._scheduler_loop,
                                            name="query-server",
                                            daemon=True)
            self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the scheduler thread.

        ``drain=True`` (default): the scheduler closes a final batch per
        relation — pending submissions are *served*, then the thread
        joins; a late racer still in a queue after the join is pumped
        inline. ``drain=False``: still-parked requests are failed with
        :class:`ServerStopped` (their ``wait()`` raises instead of
        hanging forever).
        """
        with self._cond:
            thread = self._thread
            self._stopping = True
            self._drain_on_stop = drain
            if not drain:
                # close the race window NOW: anything already queued is
                # swept by _fail_pending below; anything submitted after
                # this point fails fast inside submit().
                self._rejecting = True
            self._cond.notify_all()
        if thread is not None:
            thread.join()
        with self._cond:
            self._thread = None
        if drain:
            while self.pending():
                self.pump("drain")
        else:
            self._fail_pending()

    def _fail_pending(self) -> None:
        """Drop every queued request with a loud ServerStopped error."""
        with self._cond:
            dropped = [(t.name, r) for t in self._tenants.values()
                       for r in t.queue]
            for t in self._tenants.values():
                t.queue.clear()
        for name, r in dropped:
            r.error = ServerStopped(
                f"QueryServer stopped (drain=False) before serving this "
                f"request (relation {name!r})")
            self.stats.note_dropped(name)
            r._done.set()

    def close(self) -> None:
        """Stop the scheduler and release the server-owned shard pool.

        Terminal: after ``close()`` the shared pool's handles fall back to
        serial shard execution (still correct) if reused.
        """
        self.stop()
        if self._owned_dispatcher is not None:
            self._owned_dispatcher.close()

    def __enter__(self) -> "QueryServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def _pump_due(self, todos: List[Tuple[str, str]]) -> None:
        """Close and run every due ``(relation, reason)`` from one scan.

        A single due relation takes the classic pump path; several close
        together and run as one fused dispatch wave.
        """
        if len(todos) == 1:
            self.pump(todos[0][1], relation=todos[0][0])
            return
        with self._pump_lock:
            closed: List[Tuple[_Tenant, str, List[QueryRequest]]] = []
            with self._cond:
                for name, reason in todos:
                    t = self._tenants.get(name)
                    if t is None:        # racing live detach/re-attach
                        continue
                    batch = self._close_locked(t)
                    if batch:
                        self._rr_last = t.name
                        closed.append((t, reason, batch))
            if closed:
                self._run_closed(closed)

    def _scheduler_loop(self) -> None:
        while True:
            todos: List[Tuple[str, str]] = []
            with self._cond:
                while not self._stopping and not any(
                        t.queue for t in self._tenants.values()):
                    self._cond.wait()       # submit()/stop()/attach notify
                if self._stopping:
                    break
                # per-relation close decisions: a batch group closes by
                # *fill* when its queue reaches the relation's max_batch,
                # by *deadline* when its OLDEST submission's (steered)
                # wait expires — latency is bounded per relation by
                # max_wait_ms, fusion by max_batch; relations never delay
                # one another. The scan ROTATES past the last-pumped
                # tenant (same cursor as the sync pump) so a tenant kept
                # permanently full by hot traffic cannot starve a
                # neighbour's expired deadline. EVERY relation due in the
                # same scan closes together — the batches then run as one
                # fused dispatch wave (see _run_closed).
                now = time.time()
                earliest: Optional[float] = None
                for name in self._rotation():
                    t = self._tenants[name]
                    if not t.queue:
                        continue
                    if len(t.queue) >= t.max_batch:
                        todos.append((t.name, "full"))
                        continue
                    deadline = t.queue[0].enqueued_at + t.wait_s
                    if deadline <= now:
                        todos.append((t.name, "deadline"))
                        continue
                    earliest = (deadline if earliest is None
                                else min(earliest, deadline))
                if not todos:
                    # floored park: a sub-ms (or steered-to-tiny) deadline
                    # must not degrade the loop into a busy-spin.
                    self._cond.wait(max(MIN_PARK_S, earliest - now))
                    continue
            self._pump_due(todos)
        # drain-before-exit: close a final batch per relation so stop()
        # never drops parked submissions on the floor (drain=False skips
        # this — stop() then fails them loudly instead).
        if self._drain_on_stop:
            while self.pending():
                self.pump("drain")

    def serve(self, requests: Sequence[QueryRequest]) -> List[QueryRequest]:
        """Enqueue ``requests`` and finish them all.

        With the scheduler running this blocks on the requests' completion
        events; otherwise it pumps inline until every queue is dry.
        """
        for r in requests:
            self.submit(r)
        if self._thread is not None:
            for r in requests:
                r.wait()
            return list(requests)
        done: List[QueryRequest] = []
        while self.pending():
            done += self.pump()
        return done

    def reset(self) -> None:
        self.stats = ServeStats()
