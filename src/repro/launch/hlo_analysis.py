"""Roofline-term extraction from compiled XLA artifacts.

``cost_analysis()`` provides HLO_FLOPs and HLO bytes-accessed; collective
bytes are NOT in cost_analysis, so we parse the optimized HLO text and sum
the output-shape bytes of every collective op (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute).

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. "bf16[16,4096,512]{2,1,0}" — dtype + dims
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9a-z]+)?)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum output bytes per collective kind over the optimized HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        # "%name = TYPE[...] all-reduce(...)" — opcode after the '=' sign
        m = re.match(r"%?[\w.\-]+\s*=\s*(\([^)]*\)|[^ ]+)\s+([\w\-]+)", s)
        if not m:
            continue
        shape_str, opcode = m.group(1), m.group(2)
        kind = None
        for k in _COLLECTIVES:
            if opcode == k or opcode.startswith(k + "-start"):
                kind = k
                break
        if kind is None:
            continue
        out[kind] += _shape_bytes(shape_str)
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    """Per-device quantities (the HLO is the SPMD-partitioned module), so
    each term divides by a single chip's peak. Global totals are
    per-device × n_chips."""
    flops: float
    bytes_accessed: float
    collective_bytes: float
    n_chips: int
    collective_detail: Dict[str, int]
    peak_memory_per_device: Optional[float] = None
    xla_flops_once: float = 0.0      # XLA cost_analysis (loop bodies ×1)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return dict(flops=self.flops, bytes_accessed=self.bytes_accessed,
                    collective_bytes=self.collective_bytes,
                    n_chips=self.n_chips,
                    t_compute=self.t_compute, t_memory=self.t_memory,
                    t_collective=self.t_collective,
                    bottleneck=self.bottleneck,
                    peak_memory_per_device=self.peak_memory_per_device,
                    xla_flops_once=self.xla_flops_once,
                    collective_detail=self.collective_detail)


def analyze(compiled, n_chips: int) -> Roofline:
    """Roofline terms from the compiled per-device module.

    Uses the loop-aware walker (hlo_cost.py) — XLA's own cost_analysis
    counts while-loop bodies once, which undercounts scanned programs by
    their trip counts (layers × microbatches × KV blocks). The walker's
    numbers are per-device; terms divide by per-chip peaks only.
    """
    from . import hlo_cost
    text = compiled.as_text()
    cost = hlo_cost.analyze_text(text)
    xla_cost = compiled.cost_analysis()
    if isinstance(xla_cost, list):
        xla_cost = xla_cost[0]
    coll = {k: v for k, v in cost.collectives.items()}
    coll["total"] = cost.collective_bytes
    coll["count"] = collective_bytes(text)["count"]
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(getattr(ma, "temp_size_in_bytes", 0)
                    + getattr(ma, "argument_size_in_bytes", 0)
                    + getattr(ma, "output_size_in_bytes", 0)
                    - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    return Roofline(flops=cost.flops, bytes_accessed=cost.hbm_bytes,
                    collective_bytes=cost.collective_bytes, n_chips=n_chips,
                    collective_detail=coll, peak_memory_per_device=mem,
                    xla_flops_once=float(xla_cost.get("flops", 0.0)))
