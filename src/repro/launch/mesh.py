"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state. The dry-run entrypoint sets ``XLA_FLAGS=--xla_force_host_platform_
device_count=512`` before any jax import; everything else sees 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Elastic variant: any (shape, axes) — used by checkpoint resharding
    tests and the elastic-scaling path."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Single-device mesh for smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_dispatch_mesh(n_model: int = 1):
    """All visible devices as a ``("data", "model")`` mesh for the
    device-resident query dispatcher (``repro.core.mesh_dispatch``):
    tuple-axis shards spread over ``data``, the c Shamir share planes over
    ``model``. ``n_model`` must divide the device count; the default keeps
    every device on the data axis (the CI smoke lane forces 8 host devices
    via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)."""
    n = jax.device_count()
    if n % n_model != 0:
        raise ValueError(f"n_model={n_model} does not divide the "
                         f"{n}-device platform")
    return jax.make_mesh((n // n_model, n_model), ("data", "model"))
