import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_EXTRA_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh) cell.

For each cell this driver
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. constructs ShapeDtypeStruct inputs with their NamedShardings (no
     allocation),
  3. lowers + compiles the real step function (train_step with AdamW +
     grad-accum for train cells; prefill/serve step for inference cells),
  4. records memory_analysis, cost_analysis, per-collective HLO bytes and
     the three roofline terms into a JSON results file (incremental —
     re-running skips completed cells unless --force).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b \
      --shape decode_32k --mesh single
"""
import argparse     # noqa: E402
import json         # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import numpy as np  # noqa: E402

import repro        # noqa: E402  (enables x64)
import repro.configs as configs                      # noqa: E402
from repro.launch import hlo_analysis, specs         # noqa: E402
from repro.launch.mesh import make_production_mesh   # noqa: E402
from repro.models.config import ALL_SHAPES           # noqa: E402
from repro.train import AdamWConfig, make_train_step, make_serve_steps  # noqa: E402

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "dryrun_results.json")

# long_500k runs only for sub-quadratic archs (SSM / hybrid / sliding-window
# local-global); full-attention archs skip it (DESIGN.md §Arch-applicability).
LONG_OK = {"mamba2_2_7b", "hymba_1_5b", "gemma3_1b"}


def grad_accum_for(cfg) -> int:
    if cfg.n_experts:
        return 8
    if cfg.d_model >= 8192:
        return 16
    if cfg.d_model >= 2560:
        return 8
    return 4


def model_flops(cfg, shape) -> float:
    """Analytic useful FLOPs: 6·N_active·tokens (train), 2·N_active·tokens
    (prefill), 2·N_active·B (decode)."""
    n_total = cfg.param_count()
    if cfg.n_experts:
        inactive = (cfg.n_layers * (cfg.n_experts - cfg.top_k)
                    * 3 * cfg.d_model * cfg.d_ff)
        n_active = n_total - inactive
    else:
        n_active = n_total
    if shape.kind == "train":
        return 6.0 * n_active * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.seq_len * shape.global_batch
    return 2.0 * n_active * shape.global_batch


def lower_cell(arch: str, shape, mesh, mesh_name: str) -> dict:
    cfg = configs.full(arch)
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    with jax.default_device(jax.devices()[0]):
        if shape.kind == "train":
            from repro import sharding as shd
            # microbatch must stay shardable over the dp axes
            ga = min(grad_accum_for(cfg),
                     shape.global_batch // shd.dp_size(mesh))
            step = make_train_step(cfg, AdamWConfig(), grad_accum=ga)
            args = specs.input_specs(cfg, mesh, shape, grad_accum=ga)
            lowered = jax.jit(step).lower(*args)
        elif shape.kind == "prefill":
            prefill_fn, _ = make_serve_steps(cfg)
            args = specs.input_specs(cfg, mesh, shape)
            lowered = jax.jit(prefill_fn).lower(*args)
        else:
            _, decode_fn = make_serve_steps(cfg)
            args = specs.input_specs(cfg, mesh, shape)
            # donate the cache: decode loops update KV in place (XLA would
            # otherwise copy the whole cache every step)
            lowered = jax.jit(decode_fn, donate_argnums=(1,)).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    roof = hlo_analysis.analyze(compiled, n_chips)
    mf = model_flops(cfg, shape)
    mem = compiled.memory_analysis()
    rec = {
        "arch": arch, "shape": shape.name, "mesh": mesh_name,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "model_flops": mf,
        "useful_ratio": (mf / (roof.flops * n_chips)
                         if roof.flops else None),
        "memory": {
            "argument_gb": getattr(mem, "argument_size_in_bytes", 0) / 2**30,
            "output_gb": getattr(mem, "output_size_in_bytes", 0) / 2**30,
            "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 2**30,
            "alias_gb": getattr(mem, "alias_size_in_bytes", 0) / 2**30,
        },
        **roof.as_dict(),
    }
    return rec


def lower_paper_db(mesh, mesh_name: str) -> dict:
    db_cfg = configs.get("paper_db").full()
    n_chips = int(np.prod(list(mesh.shape.values())))
    args = specs.paper_db_specs(db_cfg, mesh)
    t0 = time.time()
    lowered = jax.jit(specs.paper_db_step).lower(*args)
    compiled = lowered.compile()
    roof = hlo_analysis.analyze(compiled, n_chips)
    mem = compiled.memory_analysis()
    return {"arch": "paper_db", "shape": "query_mix", "mesh": mesh_name,
            "status": "ok", "compile_s": round(time.time() - t0, 1),
            "model_flops": None, "useful_ratio": None,
            "memory": {
                "argument_gb": getattr(mem, "argument_size_in_bytes", 0)
                / 2**30,
                "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 2**30},
            **roof.as_dict()}


def cell_key(arch, shape_name, mesh_name):
    return f"{arch}|{shape_name}|{mesh_name}"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS))
    args = ap.parse_args()

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_256", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_512", make_production_mesh(multi_pod=True)))

    arch_list = ([args.arch.replace("-", "_").replace(".", "_")]
                 if args.arch else configs.ARCH_IDS + ["paper_db"])
    shape_list = ([s for s in ALL_SHAPES if s.name == args.shape]
                  if args.shape else list(ALL_SHAPES))

    for mesh_name, mesh in meshes:
        for arch in arch_list:
            if arch == "paper_db":
                key = cell_key(arch, "query_mix", mesh_name)
                if key in results and not args.force:
                    continue
                try:
                    rec = lower_paper_db(mesh, mesh_name)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": "query_mix",
                           "mesh": mesh_name, "status": f"error: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                results[key] = rec
                _flush(results, args.out, rec)
                continue
            for shape in shape_list:
                key = cell_key(arch, shape.name, mesh_name)
                if key in results and not args.force:
                    continue
                if shape.name == "long_500k" and arch not in LONG_OK:
                    results[key] = {
                        "arch": arch, "shape": shape.name, "mesh": mesh_name,
                        "status": "skipped: full quadratic attention at 500k"
                                  " (DESIGN.md §Arch-applicability)"}
                    _flush(results, args.out, results[key])
                    continue
                try:
                    rec = lower_cell(arch, shape, mesh, mesh_name)
                except Exception as e:  # noqa: BLE001
                    rec = {"arch": arch, "shape": shape.name,
                           "mesh": mesh_name, "status": f"error: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                results[key] = rec
                _flush(results, args.out, rec)


def _flush(results, path, last):
    with open(path, "w") as f:
        json.dump(results, f, indent=1, default=str)
    status = last.get("status", "?")
    extra = ""
    if status == "ok":
        extra = (f" bottleneck={last.get('bottleneck')}"
                 f" t_comp={last.get('t_compute', 0):.3e}"
                 f" t_mem={last.get('t_memory', 0):.3e}"
                 f" t_coll={last.get('t_collective', 0):.3e}"
                 f" compile={last.get('compile_s')}s")
    print(f"[dryrun] {last['arch']}×{last['shape']}×{last['mesh']}: "
          f"{status}{extra}", flush=True)


if __name__ == "__main__":
    main()
