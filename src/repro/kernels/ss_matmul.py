"""Pallas TPU kernel: mod-p matmul over F_p, p = 2³¹−1 (Mersenne-31).

TPU adaptation (see DESIGN.md §2): the MXU multiplies bf16/int8 — it cannot
form 62-bit integer products — so modular matmul on TPU is a **VPU**
(vector-unit) workload in 32-bit lanes. We therefore:

  * decompose each 31-bit operand into 16-bit limbs
    ``x = x1·2¹⁶ + x0`` (x1 < 2¹⁵, x0 < 2¹⁶), so every partial product fits
    a 32-bit lane:  ``x·y = x1y1·2³² + (x1y0 + x0y1)·2¹⁶ + x0y0``;
  * exploit the Mersenne congruences ``2³¹ ≡ 1, 2³² ≡ 2 (mod p)`` to fold
    the limb products back into [0, p) with shifts/adds only — no division;
  * tile (bm × bk) · (bk × bn) blocks into VMEM with an explicit BlockSpec
    grid, accumulating mod-p in a VMEM scratch across the K grid axis
    (K is the innermost/fastest grid dimension, so the scratch carries).

VMEM budget per grid cell (defaults bm = bn = bk = 128, uint32):
  a-tile 64 KiB + b-tile 64 KiB + scratch 64 KiB + out 64 KiB = 256 KiB ≪ 16 MiB,
leaving room for double-buffered pipelining of the next a/b tiles.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

P32 = np.uint32(2**31 - 1)
MASK16 = np.uint32(0xFFFF)
MASK15 = np.uint32(0x7FFF)


def _fold32(x: jax.Array) -> jax.Array:
    """uint32 -> [0, p): one Mersenne fold + conditional subtract."""
    x = (x & P32) + (x >> np.uint32(31))                  # < p + 2
    return x - jnp.where(x >= P32, P32, np.uint32(0))


def _addmod(a: jax.Array, b: jax.Array) -> jax.Array:
    """(a + b) mod p for a, b < p. a+b < 2p < 2³², no wrap."""
    s = a + b
    return s - jnp.where(s >= P32, P32, np.uint32(0))


def _mulmod(x: jax.Array, y: jax.Array) -> jax.Array:
    """(x · y) mod p for x, y < p, entirely in 32-bit lanes."""
    x0 = x & MASK16
    x1 = x >> np.uint32(16)          # < 2^15
    y0 = y & MASK16
    y1 = y >> np.uint32(16)
    lo = x0 * y0                     # < 2^32, exact in uint32
    mid = x1 * y0 + x0 * y1          # each < 2^31, sum < 2^32
    hi = x1 * y1                     # < 2^30
    # mid·2¹⁶ mod p: mid = mh·2¹⁵ + ml  ⇒  mh·2³¹ + ml·2¹⁶ ≡ mh + ml·2¹⁶
    t_mid = (mid >> np.uint32(15)) + ((mid & MASK15) << np.uint32(16))
    # lo mod p: lo = lh·2³¹ + ll ⇒ lh + ll
    t_lo = (lo >> np.uint32(31)) + (lo & P32)
    # hi·2³² ≡ 2·hi
    t_hi = hi << np.uint32(1)
    return _addmod(_addmod(_fold32(t_mid), _fold32(t_lo)), _fold32(t_hi))


def _ss_matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, bk: int, nk: int):
    """One (i, j, k) grid cell: acc += A[i,k] ·ₚ B[k,j]; emit at last k."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                                  # (bm, bk) uint32
    b = b_ref[...]                                  # (bk, bn)

    def body(k, acc):
        prod = _mulmod(a[:, k][:, None], b[k, :][None, :])   # (bm, bn)
        return _addmod(acc, prod)

    acc_ref[...] = jax.lax.fori_loop(0, bk, body, acc_ref[...])

    @pl.when(pl.program_id(2) == nk - 1)
    def _emit():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def ss_matmul_pallas(a: jax.Array, b: jax.Array, *, bm: int = 128,
                     bn: int = 128, bk: int = 128,
                     interpret: Optional[bool] = None) -> jax.Array:
    """(M,K) @ (K,N) mod p. Pads to block multiples (zeros are absorbing).

    ``interpret=None`` auto-detects: compiled lowering on a real TPU
    backend, the Pallas interpreter everywhere else (CPU/GPU have no
    Mosaic lowering for these kernels).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    if m == 0 or n == 0:        # empty fetch stack / empty relation slice
        return jnp.zeros((m, n), jnp.uint32)
    bm = min(bm, _round_up(max(m, 1), 8))
    bn = min(bn, _round_up(max(n, 1), 128))
    bk = min(bk, _round_up(max(k, 1), 128))
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_ss_matmul_kernel, bk=bk, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.uint32)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


# ---------------------------------------------------------------------------
# embedding fast path: tall-skinny contraction + fused share generation
# ---------------------------------------------------------------------------

#: heuristic gate for the tall-skinny tiling: M (tokens) is small enough to
#: keep resident as one block, K (vocab) dwarfs both other dims.
TALL_MAX_M = 256
TALL_MIN_K = 1024


def is_tall_skinny(m: int, k: int, n: int) -> bool:
    """Does (M,K)@(K,N) look like an embedding lookup? Small M = tokens,
    huge K = vocab, lane-sized N = model dim."""
    return m <= TALL_MAX_M and k >= TALL_MIN_K and k >= 8 * max(m, n)


def ss_matmul_tall_pallas(a: jax.Array, b: jax.Array, *,
                          interpret: Optional[bool] = None) -> jax.Array:
    """Shape-tuned ``ss_matmul_pallas`` for the embedding contraction.

    The one-hot stack is tall-skinny: M = batch×seq tokens (tens to a few
    hundred rows), K = vocab (tens of thousands), N = D (≈128-lane model
    dim). The default square 128³ tiling walks K in 128-element steps —
    hundreds of grid cells whose (bm, bn) scratch round-trips dominate.
    Here the whole token block stays resident (bm covers M up to 256 rows)
    and K streams in 512-wide tiles, 4× fewer grid steps along the one
    huge axis; VMEM is still tiny (256·512·4 B = 512 KiB a-tile).
    """
    m, k = a.shape
    n = b.shape[1]
    bm = min(_round_up(max(m, 1), 8), TALL_MAX_M)
    bn = min(_round_up(max(n, 1), 128), 128)
    bk = min(_round_up(max(k, 1), 128), 512)
    return ss_matmul_pallas(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)


def _share_onehot_kernel(tok_ref, a1_ref, o_ref, *, bm: int, bv: int):
    """One (cloud k, token tile i, vocab tile j) grid cell of the fused
    share generator: o[k, i, j] = onehot(tok_i)[j] + a1[i, j] · x_k mod p.

    The plaintext one-hot is never materialized in HBM — it exists only as
    an iota==token compare inside the kernel, fused with the degree-1
    polynomial evaluation at x_k = k+1.
    """
    kc = pl.program_id(0)
    j = pl.program_id(2)
    tok = tok_ref[...]                              # (bm, 1) int32
    a1 = a1_ref[...]                                # (bm, bv) uint32 < p
    v_ids = (jax.lax.broadcasted_iota(jnp.int32, (bm, bv), 1)
             + j * np.int32(bv))
    onehot = jnp.where(v_ids == tok, np.uint32(1), np.uint32(0))
    xk = (kc + 1).astype(jnp.uint32)                # eval point, < c+1 ≪ p
    o_ref[...] = _addmod(onehot, _mulmod(a1, xk))[None]


@functools.partial(jax.jit,
                   static_argnames=("n_shares", "bm", "bv", "interpret"))
def share_onehot_pallas(tokens: jax.Array, a1: jax.Array, *, n_shares: int,
                        bm: int = 64, bv: int = 512,
                        interpret: Optional[bool] = None) -> jax.Array:
    """Fused degree-1 one-hot share generation.

    tokens: (M,) int32 token ids; a1: (M, V) uint32 per-token random
    coefficients (``core.queries.embed.token_coeffs``). Returns
    uint32 (n_shares, M, V) with share[k, i, v] = [v == tok_i] + a1[i,v]·x_k
    — bit-identical to the jnp reference program given the same a1.

    Padding: token rows pad with -1 (matches no vocab id ⇒ zero one-hot),
    coefficients pad with 0 ⇒ padded share cells are 0 and slice away.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    (m,) = tokens.shape
    m2, v = a1.shape
    assert m == m2, (tokens.shape, a1.shape)
    bm = min(bm, _round_up(max(m, 1), 8))
    bv = min(bv, _round_up(max(v, 1), 128))
    mp, vp = _round_up(m, bm), _round_up(v, bv)
    tok_p = jnp.pad(tokens.astype(jnp.int32), (0, mp - m),
                    constant_values=-1).reshape(mp, 1)
    a1_p = jnp.pad(a1, ((0, mp - m), (0, vp - v)))
    out = pl.pallas_call(
        functools.partial(_share_onehot_kernel, bm=bm, bv=bv),
        grid=(n_shares, mp // bm, vp // bv),
        in_specs=[
            pl.BlockSpec((bm, 1), lambda kc, i, j: (i, 0)),
            pl.BlockSpec((bm, bv), lambda kc, i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bv), lambda kc, i, j: (kc, i, j)),
        out_shape=jax.ShapeDtypeStruct((n_shares, mp, vp), jnp.uint32),
        interpret=interpret,
    )(tok_p, a1_p)
    return out[:, :m, :v]
