"""Public jit'd wrappers for the Pallas kernels.

Handle cloud-axis batching (vmap), interpret-mode selection (interpret=True
everywhere except a real TPU backend), and the join-oriented composite
``match_matrix``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .aa_match import aa_match_pallas
from .ss_matmul import ss_matmul_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.jit
def ss_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched mod-p matmul. a: ([c,] M, K), b: ([c,] K, N) uint32."""
    interp = _interpret()
    fn = functools.partial(ss_matmul_pallas, interpret=interp)
    if a.ndim == 2 and b.ndim == 2:
        return fn(a, b)
    if a.ndim == 3 and b.ndim == 3:
        return jax.vmap(fn)(a, b)
    if a.ndim == 3 and b.ndim == 2:
        return jax.vmap(lambda x: fn(x, b))(a)
    raise ValueError(f"unsupported ranks: {a.shape} @ {b.shape}")


@jax.jit
def aa_match(col: jax.Array, pat: jax.Array) -> jax.Array:
    """Batched AA match. col: ([c,] n, W, A), pat: ([c,] W, A) -> ([c,] n)."""
    interp = _interpret()
    fn = functools.partial(aa_match_pallas, interpret=interp)
    if col.ndim == 3:
        return fn(col, pat)
    if col.ndim == 4:
        return jax.vmap(fn)(col, pat)
    raise ValueError(f"unsupported rank: {col.shape}")


@jax.jit
def aa_match_batch(col: jax.Array, pat: jax.Array) -> jax.Array:
    """Stacked-predicate AA match: col (c, B, n, W, A), pat (c, B, W, A)
    -> (c, B, n). One kernel launch per (c, B) cell via nested vmap — the
    batched query engine's single dispatch per protocol round."""
    interp = _interpret()
    fn = functools.partial(aa_match_pallas, interpret=interp)
    if col.ndim != 5:
        raise ValueError(f"unsupported rank: {col.shape}")
    return jax.vmap(jax.vmap(fn))(col, pat)


@jax.jit
def match_matrix(col_x: jax.Array, col_y: jax.Array) -> jax.Array:
    """All-pairs word match (join §3.3.1 hotspot) via per-position ss_matmul.

    col_x: (c, nx, W, A), col_y: (c, ny, W, A) -> (c, nx, ny).
    """
    from ..core import field  # local import to avoid cycle
    c, nx, w, a = col_x.shape
    ny = col_y.shape[1]
    acc = None
    for j in range(w):
        pj = ss_matmul(col_x[:, :, j, :],
                       jnp.swapaxes(col_y[:, :, j, :], -1, -2))
        acc = pj if acc is None else field.mul(acc, pj)
    return acc


def as_backend():
    """Bundle these kernels as the ``"pallas"`` entry of the backend
    registry (``repro.api.backends``) — the query suite selects them with
    ``backend="pallas"`` instead of the old ``impl=`` strings."""
    from ..api.backends import Backend  # local import to avoid cycle
    return Backend(name="pallas", aa_match=aa_match, ss_matmul=ss_matmul,
                   match_matrix=match_matrix, aa_match_batch=aa_match_batch)
