"""Public jit'd wrappers for the Pallas kernels.

Handle cloud-axis batching (vmap), interpret-mode selection (interpret=True
everywhere except a real TPU backend), and the join-oriented composite
``match_matrix``.
"""
from __future__ import annotations

import functools
import warnings

import jax
import jax.numpy as jnp

from .aa_match import (aa_match_batch_pallas, aa_match_pallas,
                       aa_slide_batch_pallas)
from .ripple import ripple_carry_pallas, ripple_segment_pallas
from .ss_matmul import (is_tall_skinny, share_onehot_pallas, ss_matmul_pallas,
                        ss_matmul_tall_pallas)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@jax.jit
def ss_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched mod-p matmul. a: ([c,] M, K), b: ([c,] K, N) uint32.

    Tall-skinny operands (small M = tokens, huge K = vocab — the embedding
    contraction) route to the shape-tuned tiling; everything else takes the
    square 128³ tiles. Both are the same kernel body, so results are
    bit-identical either way.
    """
    interp = _interpret()

    def fn(x, y):
        if is_tall_skinny(x.shape[0], x.shape[1], y.shape[1]):
            return ss_matmul_tall_pallas(x, y, interpret=interp)
        return ss_matmul_pallas(x, y, interpret=interp)

    if a.ndim == 2 and b.ndim == 2:
        return fn(a, b)
    if a.ndim == 3 and b.ndim == 3:
        return jax.vmap(fn)(a, b)
    if a.ndim == 3 and b.ndim == 2:
        return jax.vmap(lambda x: fn(x, b))(a)
    raise ValueError(f"unsupported ranks: {a.shape} @ {b.shape}")


def share_onehot(tokens: jax.Array, a1: jax.Array, *,
                 n_shares: int) -> jax.Array:
    """Fused degree-1 one-hot share generation (embedding fast path):
    tokens (M,) int32 + per-token coefficients a1 (M, V) uint32 ->
    share tensor (n_shares, M, V), never materializing the one-hot."""
    return share_onehot_pallas(tokens, a1, n_shares=n_shares,
                               interpret=_interpret())


@jax.jit
def aa_match(col: jax.Array, pat: jax.Array) -> jax.Array:
    """Batched AA match. col: ([c,] n, W, A), pat: ([c,] W, A) -> ([c,] n)."""
    interp = _interpret()
    fn = functools.partial(aa_match_pallas, interpret=interp)
    if col.ndim == 3:
        return fn(col, pat)
    if col.ndim == 4:
        return jax.vmap(fn)(col, pat)
    raise ValueError(f"unsupported rank: {col.shape}")


@jax.jit
def aa_match_batch_vmap(col: jax.Array, pat: jax.Array) -> jax.Array:
    """Nested-vmap fallback for the stacked-predicate AA match: one kernel
    launch per (c, B) cell. Kept as the safety net (and the parity oracle)
    for the 2-D grid kernel below."""
    interp = _interpret()
    fn = functools.partial(aa_match_pallas, interpret=interp)
    if col.ndim != 5:
        raise ValueError(f"unsupported rank: {col.shape}")
    return jax.vmap(jax.vmap(fn))(col, pat)


@jax.jit
def _aa_match_batch_grid(col: jax.Array, pat: jax.Array) -> jax.Array:
    c, b, n, w, a = col.shape
    out = aa_match_batch_pallas(col.reshape(c * b, n, w, a),
                                pat.reshape(c * b, w, a),
                                interpret=_interpret())
    return out.reshape(c, b, n)


_GRID_KERNEL_BROKEN = False


def aa_match_batch(col: jax.Array, pat: jax.Array) -> jax.Array:
    """Stacked-predicate AA match: col (c, B, n, W, A), pat (c, B, W, A)
    -> (c, B, n). The cloud and batch axes fold into ONE 2-D grid
    ``pallas_call`` — a (c·B, n-tile) grid whose pattern tile stays
    resident in VMEM across a row's n-tiles — so the batched query engine
    really issues a single device dispatch per protocol round. If the grid
    kernel fails to lower on this backend, the failure is logged once and
    all later calls take the nested-vmap path directly (a failed jit trace
    is not cached, so retrying every round would re-pay the trace)."""
    global _GRID_KERNEL_BROKEN
    if col.ndim != 5:
        raise ValueError(f"unsupported rank: {col.shape}")
    c, b, _, w, a = col.shape
    if pat.shape != (c, b, w, a):   # caller bugs must propagate, not latch
        raise ValueError(f"pattern shape {pat.shape} does not match "
                         f"column stack {col.shape}")
    if not _GRID_KERNEL_BROKEN:
        try:
            return _aa_match_batch_grid(col, pat)
        except Exception as e:   # pragma: no cover — exotic backends only
            _GRID_KERNEL_BROKEN = True
            warnings.warn(f"aa_match_batch 2-D grid kernel failed to build "
                          f"({e!r}); using the nested-vmap fallback for "
                          f"the rest of this process", RuntimeWarning)
    return aa_match_batch_vmap(col, pat)


@jax.jit
def _aa_slide_batch_grid(cols: jax.Array, pats: jax.Array) -> jax.Array:
    c, b, n, w, a = cols.shape
    k = pats.shape[-2]
    out = aa_slide_batch_pallas(cols.reshape(c * b, n, w, a),
                                pats.reshape(c * b, k, a),
                                interpret=_interpret())
    return out.reshape(c, b, n, w - k + 1)


_SLIDE_KERNEL_BROKEN = False


def aa_slide_batch(cols: jax.Array, pats: jax.Array) -> jax.Array:
    """Stacked sliding-window AA match: cols (c, B, n, W, A), pats
    (c, B, k, A) -> (c, B, n, M) raw window-chain products, M = W−k+1.
    Cloud and batch axes fold into one (c·B, n-tile) 2-D grid
    ``pallas_call`` reusing the ``aa_match_batch`` VMEM pattern-tile
    layout. On lowering failure the jnp reference program takes over for
    the rest of the process (same latch protocol as ``aa_match_batch``)."""
    global _SLIDE_KERNEL_BROKEN
    if cols.ndim != 5 or pats.ndim != 4:
        raise ValueError(f"unsupported ranks: {cols.shape}, {pats.shape}")
    c, b, _, w, a = cols.shape
    k = pats.shape[-2]
    if (pats.shape[0], pats.shape[1], pats.shape[3]) != (c, b, a) \
            or not 1 <= k <= w:  # caller bugs must propagate, not latch
        raise ValueError(f"pattern tile shape {pats.shape} does not match "
                         f"column stack {cols.shape}")
    if not _SLIDE_KERNEL_BROKEN:
        try:
            return _aa_slide_batch_grid(cols, pats)
        except Exception as e:   # pragma: no cover — exotic backends only
            _SLIDE_KERNEL_BROKEN = True
            warnings.warn(f"aa_slide_batch 2-D grid kernel failed to build "
                          f"({e!r}); using the jnp reference for the rest "
                          f"of this process", RuntimeWarning)
    from ..api.backends import jnp_aa_slide   # reference fallback
    return jnp_aa_slide(cols, pats)


def ripple_carry(a: jax.Array, b: jax.Array, carry=None):
    """One fused SS-SUB bit step (Alg 6) over any share-plane shape.

    a, b: (...,) uint32 bit planes; carry: same shape or ``None`` for the
    LSB step. Returns ``(rb, carry')``. Flattens to one 1-D elementwise
    pallas dispatch regardless of how many queries are stacked."""
    interp = _interpret()
    shape = a.shape
    flat_a = a.reshape(-1)
    flat_b = b.reshape(-1)
    init = carry is None
    flat_c = (jnp.zeros_like(flat_a) if init
              else carry.reshape(-1))
    rb, co = ripple_carry_pallas(flat_a, flat_b, flat_c, init=init,
                                 interpret=interp)
    return rb.reshape(shape), co.reshape(shape)


def ripple_segment(a: jax.Array, b: jax.Array, carry=None):
    """k chained SS-SUB bit steps (Alg 6) in ONE pallas dispatch.

    a, b: (..., k) uint32 bit planes (last axis = consecutive bit
    positions); carry: (...) or ``None`` when the chain starts at the LSB.
    Returns the final ``(rb, carry')`` after k steps, each shaped (...).
    The carry chains in registers inside the kernel, so a degree-reduction
    interval of k bits costs one launch instead of k."""
    interp = _interpret()
    shape = a.shape[:-1]
    k = a.shape[-1]
    flat_a = jnp.moveaxis(a.reshape(-1, k), -1, 0)     # (k, N)
    flat_b = jnp.moveaxis(b.reshape(-1, k), -1, 0)
    init = carry is None
    flat_c = (jnp.zeros(flat_a.shape[1:], flat_a.dtype) if init
              else carry.reshape(-1))
    rb, co = ripple_segment_pallas(flat_a, flat_b, flat_c, init=init,
                                   interpret=interp)
    return rb.reshape(shape), co.reshape(shape)


@jax.jit
def match_matrix(col_x: jax.Array, col_y: jax.Array) -> jax.Array:
    """All-pairs word match (join §3.3.1 hotspot) via per-position ss_matmul.

    col_x: (c, nx, W, A), col_y: (c, ny, W, A) -> (c, nx, ny).
    """
    from ..core import field  # local import to avoid cycle
    c, nx, w, a = col_x.shape
    ny = col_y.shape[1]
    acc = None
    for j in range(w):
        pj = ss_matmul(col_x[:, :, j, :],
                       jnp.swapaxes(col_y[:, :, j, :], -1, -2))
        acc = pj if acc is None else field.mul(acc, pj)
    return acc


@jax.jit
def match_matrix_batch(col_x: jax.Array, col_y: jax.Array) -> jax.Array:
    """Stacked all-pairs match for a join group: col_x (c, B, nx, W, A),
    col_y (c, B, ny, W, A) -> (c, B, nx, ny). One vmapped composite over
    the group's B column pairs (each inner hop is the ss_matmul kernel), so
    equal-size right relations ride one dispatch like ``aa_match_batch``
    does for predicates."""
    if col_x.ndim != 5 or col_y.ndim != 5:
        raise ValueError(f"unsupported ranks: {col_x.shape}, {col_y.shape}")
    return jax.vmap(match_matrix, in_axes=1, out_axes=1)(col_x, col_y)


def as_backend():
    """Bundle these kernels as the ``"pallas"`` entry of the backend
    registry (``repro.api.backends``) — the query suite selects them with
    ``backend="pallas"`` instead of the old ``impl=`` strings."""
    from ..api.backends import Backend  # local import to avoid cycle
    return Backend(name="pallas", aa_match=aa_match, ss_matmul=ss_matmul,
                   match_matrix=match_matrix, aa_match_batch=aa_match_batch,
                   ripple_carry=ripple_carry,
                   ripple_segment=ripple_segment,
                   match_matrix_batch=match_matrix_batch,
                   aa_slide_batch=aa_slide_batch,
                   share_onehot=share_onehot)
