"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

These mirror the semantics of ``repro.core.field`` but are kept standalone so
kernel tests do not depend on the core library's internals.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

P64 = np.uint64(2**31 - 1)


def _fold64(x):
    x = (x & P64) + (x >> np.uint64(31))
    x = (x & P64) + (x >> np.uint64(31))
    return x - jnp.where(x >= P64, P64, np.uint64(0))


def ss_matmul(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """(M,K) @ (K,N) mod p, uint32 operands in [0, p)."""
    a64 = a.astype(jnp.uint64)
    b64 = b.astype(jnp.uint64)
    prod = _fold64(jnp.einsum("mk,kn->mkn", a64, b64))
    return (jnp.sum(prod, axis=1) % P64).astype(jnp.uint32)


def aa_match(col: jnp.ndarray, pat: jnp.ndarray) -> jnp.ndarray:
    """Accumulating-automata match.

    col: (n, W, A) one-hot shares; pat: (W, A).
    out[i] = Π_j ( Σ_α col[i,j,α]·pat[j,α] )  mod p.
    """
    col64 = col.astype(jnp.uint64)
    pat64 = pat.astype(jnp.uint64)
    v = (jnp.sum(_fold64(col64 * pat64[None]), axis=-1) % P64)   # (n, W)
    acc = v[:, 0]
    for j in range(1, v.shape[1]):
        acc = _fold64(acc * v[:, j])
    return acc.astype(jnp.uint32)
