"""Pallas TPU kernel: fused SS-SUB ripple bit step (paper §3.4, Alg 6).

One bit position of the two's-complement ripple subtract over secret-shared
bit planes. For every lane (one share of one query-direction of one tuple):

    aᵢ = 1 − Aᵢ                      (invert the subtrahend bit)
    x  = aᵢ ⊕ bᵢ = aᵢ + bᵢ − 2aᵢbᵢ
    c' = aᵢbᵢ + c·x                  (carry propagate/generate)
    rb = x + c − 2cx                 (result bit = x ⊕ c)

all mod p. The LSB step (``init=True``) instead computes the +1-absorbing
carry ``c = OR(1 − A₀, B₀)`` and ``rb = (1 − A₀) + B₀ − 2c`` (the
subtrahend bit is inverted there too).

Six fused elementwise mod-p ops per lane — unbatched, B queries would pay B
tiny dispatches per bit; the batched range engine stacks the whole query
batch (both subtraction directions of Eq. 2) into one (c·2B·n) plane and
issues this kernel ONCE per bit-round. Purely a VPU workload: same
16-bit-limb Mersenne-31 arithmetic as ss_matmul, 1-D grid over flattened
lanes, both outputs written in the same pass (the carry never round-trips
to HBM between the xor/propagate sub-steps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ss_matmul import P32, _addmod, _mulmod, _round_up


def _submod(x: jax.Array, y: jax.Array) -> jax.Array:
    """(x − y) mod p for x, y < p, in 32-bit lanes."""
    return _addmod(x, jnp.where(y == 0, y, P32 - y))


def _ripple_kernel(a_ref, b_ref, c_ref, rb_ref, co_ref, *, init: bool):
    a = a_ref[...]
    b = b_ref[...]
    ai = _submod(jnp.ones_like(a), a)
    ab = _mulmod(ai, b)
    s = _addmod(ai, b)
    if init:
        carry = _submod(s, ab)
        rb = _submod(s, _addmod(carry, carry))
    else:
        carry_in = c_ref[...]
        x = _submod(s, _addmod(ab, ab))
        cx = _mulmod(carry_in, x)
        carry = _addmod(ab, cx)
        rb = _submod(_addmod(x, carry_in), _addmod(cx, cx))
    rb_ref[...] = rb
    co_ref[...] = carry


@functools.partial(jax.jit, static_argnames=("bn", "init", "interpret"))
def ripple_carry_pallas(a: jax.Array, b: jax.Array, carry: jax.Array, *,
                        bn: int = 4096, init: bool = False,
                        interpret: bool = True):
    """a, b, carry: flat (N,) uint32 share planes -> (rb, carry') each (N,).

    ``init=True`` runs the LSB step (``carry`` is ignored but must be
    passed — zeros are fine — so both variants share one call signature).
    """
    n = a.shape[0]
    bn = min(bn, _round_up(max(n, 1), 8))
    n_pad = _round_up(max(n, 1), bn)
    pad = ((0, n_pad - n),)
    out = pl.pallas_call(
        functools.partial(_ripple_kernel, init=init),
        grid=(n_pad // bn,),
        in_specs=[pl.BlockSpec((bn,), lambda i: (i,))] * 3,
        out_specs=[pl.BlockSpec((bn,), lambda i: (i,))] * 2,
        out_shape=[jax.ShapeDtypeStruct((n_pad,), jnp.uint32)] * 2,
        interpret=interpret,
    )(jnp.pad(a, pad), jnp.pad(b, pad), jnp.pad(carry, pad))
    return out[0][:n], out[1][:n]
