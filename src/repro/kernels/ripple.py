"""Pallas TPU kernels: fused SS-SUB ripple steps (paper §3.4, Alg 6).

One bit position of the two's-complement ripple subtract over secret-shared
bit planes. For every lane (one share of one query-direction of one tuple):

    aᵢ = 1 − Aᵢ                      (invert the subtrahend bit)
    x  = aᵢ ⊕ bᵢ = aᵢ + bᵢ − 2aᵢbᵢ
    c' = aᵢbᵢ + c·x                  (carry propagate/generate)
    rb = x + c − 2cx                 (result bit = x ⊕ c)

all mod p. The LSB step (``init=True``) instead computes the +1-absorbing
carry ``c = OR(1 − A₀, B₀)`` and ``rb = (1 − A₀) + B₀ − 2c`` (the
subtrahend bit is inverted there too).

Six fused elementwise mod-p ops per lane — unbatched, B queries would pay B
tiny dispatches per bit; the batched range engine stacks the whole query
batch (both subtraction directions of Eq. 2) into one (c·2B·n) plane and
issues :func:`ripple_carry_pallas` ONCE per bit-round. Purely a VPU
workload: same 16-bit-limb Mersenne-31 arithmetic as ss_matmul, 1-D grid
over flattened lanes, both outputs written in the same pass (the carry
never round-trips to HBM between the xor/propagate sub-steps).

:func:`ripple_segment_pallas` goes one step further: the k bit positions
*between* two degree-reduction boundaries chain inside ONE kernel — the
carry lives in registers across all k steps and only the final (rb, carry)
pair is written back, so a ``reduce_every=k`` range group pays ~t/k
dispatches instead of t. Layout is (k, N): bit position on the sublane
axis, flattened lanes on the 128-wide lane axis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ss_matmul import P32, _addmod, _mulmod, _round_up


def _submod(x: jax.Array, y: jax.Array) -> jax.Array:
    """(x − y) mod p for x, y < p, in 32-bit lanes."""
    return _addmod(x, jnp.where(y == 0, y, P32 - y))


def _ripple_kernel(a_ref, b_ref, c_ref, rb_ref, co_ref, *, init: bool):
    a = a_ref[...]
    b = b_ref[...]
    ai = _submod(jnp.ones_like(a), a)
    ab = _mulmod(ai, b)
    s = _addmod(ai, b)
    if init:
        carry = _submod(s, ab)
        rb = _submod(s, _addmod(carry, carry))
    else:
        carry_in = c_ref[...]
        x = _submod(s, _addmod(ab, ab))
        cx = _mulmod(carry_in, x)
        carry = _addmod(ab, cx)
        rb = _submod(_addmod(x, carry_in), _addmod(cx, cx))
    rb_ref[...] = rb
    co_ref[...] = carry


@functools.partial(jax.jit, static_argnames=("bn", "init", "interpret"))
def ripple_carry_pallas(a: jax.Array, b: jax.Array, carry: jax.Array, *,
                        bn: int = 4096, init: bool = False,
                        interpret: bool = True):
    """a, b, carry: flat (N,) uint32 share planes -> (rb, carry') each (N,).

    ``init=True`` runs the LSB step (``carry`` is ignored but must be
    passed — zeros are fine — so both variants share one call signature).
    """
    n = a.shape[0]
    bn = min(bn, _round_up(max(n, 1), 8))
    n_pad = _round_up(max(n, 1), bn)
    pad = ((0, n_pad - n),)
    out = pl.pallas_call(
        functools.partial(_ripple_kernel, init=init),
        grid=(n_pad // bn,),
        in_specs=[pl.BlockSpec((bn,), lambda i: (i,))] * 3,
        out_specs=[pl.BlockSpec((bn,), lambda i: (i,))] * 2,
        out_shape=[jax.ShapeDtypeStruct((n_pad,), jnp.uint32)] * 2,
        interpret=interpret,
    )(jnp.pad(a, pad), jnp.pad(b, pad), jnp.pad(carry, pad))
    return out[0][:n], out[1][:n]


def _ripple_segment_kernel(a_ref, b_ref, c_ref, rb_ref, co_ref, *,
                           k: int, init: bool):
    """Chain k ripple bit steps; carry stays in registers between steps."""
    carry = c_ref[0, :]
    rb = carry
    for i in range(k):
        a = a_ref[i, :]
        b = b_ref[i, :]
        ai = _submod(jnp.ones_like(a), a)
        ab = _mulmod(ai, b)
        s = _addmod(ai, b)
        if init and i == 0:
            carry = _submod(s, ab)
            rb = _submod(s, _addmod(carry, carry))
        else:
            x = _submod(s, _addmod(ab, ab))
            cx = _mulmod(carry, x)
            rb = _submod(_addmod(x, carry), _addmod(cx, cx))
            carry = _addmod(ab, cx)
    rb_ref[0, :] = rb
    co_ref[0, :] = carry


@functools.partial(jax.jit, static_argnames=("bn", "init", "interpret"))
def ripple_segment_pallas(a: jax.Array, b: jax.Array, carry: jax.Array, *,
                          bn: int = 4096, init: bool = False,
                          interpret: bool = True):
    """a, b: (k, N) bit planes (k = consecutive bit positions, N flattened
    lanes); carry: (N,) -> final ``(rb, carry')`` each (N,) after k chained
    steps in ONE kernel launch.

    ``init=True`` makes step 0 the LSB two's-complement step (``carry`` is
    ignored but must be passed — zeros are fine)."""
    k, n = a.shape
    bn = min(bn, _round_up(max(n, 1), 8))
    n_pad = _round_up(max(n, 1), bn)
    pad2 = ((0, 0), (0, n_pad - n))
    pad1 = ((0, n_pad - n),)
    out = pl.pallas_call(
        functools.partial(_ripple_segment_kernel, k=k, init=init),
        grid=(n_pad // bn,),
        in_specs=[pl.BlockSpec((k, bn), lambda i: (0, i)),
                  pl.BlockSpec((k, bn), lambda i: (0, i)),
                  pl.BlockSpec((1, bn), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, bn), lambda i: (0, i))] * 2,
        out_shape=[jax.ShapeDtypeStruct((1, n_pad), jnp.uint32)] * 2,
        interpret=interpret,
    )(jnp.pad(a, pad2), jnp.pad(b, pad2),
      jnp.pad(carry, pad1).reshape(1, n_pad))
    return out[0][0, :n], out[1][0, :n]
