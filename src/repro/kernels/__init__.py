# Pallas TPU kernels for the paper's compute hot-spots:
#   ss_matmul — mod-p (Mersenne-31) matmul: share generation, one-hot fetch
#               matrices × relations (§3.2.2 Phase 2), PK/FK join contraction
#               (§3.3.1) — the O(ℓnmw)/O(n²mw) terms of Table 1.
#   aa_match  — fused accumulating-automata string match (§3.1 Table 3):
#               per-position one-hot inner products chained multiplicatively.
# Each kernel ships ops.py (jit'd public wrapper) and ref.py (pure-jnp oracle)
# and is validated in interpret mode over a shape/dtype sweep.
from . import ops, ref

__all__ = ["ops", "ref"]
