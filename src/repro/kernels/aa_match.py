"""Pallas TPU kernel: fused accumulating-automata string match (paper §3.1).

For every tuple i of a share-column the automaton of Table 3 computes

    out[i] = Π_{j<W} ( Σ_{α<A} col[i,j,α] · pat[j,α] )   (mod p)

i.e. W one-hot inner products chained by modular multiplication. The naive
path materializes the (n, W) inner-product tensor in HBM; this kernel fuses
inner product + chain so each column tile is read once and only (n,) results
are written — turning an HBM-bound O(n·W·A + n·W) pipeline into a single
O(n·W·A)-read pass (the §Perf "memory term" win for the count query).

Tiling: grid over n. Block (bn, W, A) of the column + the full (W, A) pattern
live in VMEM. Same 16-bit-limb Mersenne-31 arithmetic as ss_matmul (VPU
workload; see that module's docstring for the TPU adaptation rationale).
VMEM at bn=512, W=16, A=128: 512·16·128·4 B = 4 MiB — fits with double
buffering; ops.py shrinks bn automatically for wider codecs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ss_matmul import _addmod, _mulmod


def _aa_body(col, pat):
    """The fused automaton: col (bn, W, A), pat (W, A) -> (bn,) shares."""
    w = col.shape[1]

    def inner(j, _):
        prod = _mulmod(col[:, j, :], pat[j, :][None, :])      # (bn, A)
        # modular tree-reduce over the alphabet axis
        def red(k, acc):
            return _addmod(acc, prod[:, k])
        return jax.lax.fori_loop(1, prod.shape[1], red, prod[:, 0])

    acc = inner(0, None)                      # v_0
    def chain(j, acc):
        return _mulmod(acc, inner(j, None))   # N_{j+1} = N_j · v_j
    return jax.lax.fori_loop(1, w, chain, acc)


def _aa_kernel(col_ref, pat_ref, o_ref):
    o_ref[...] = _aa_body(col_ref[...], pat_ref[0])


def _aa_batch_kernel(col_ref, pat_ref, o_ref):
    # one (b, i) grid cell: batch row b's pattern against its i-th n-tile
    o_ref[0, :] = _aa_body(col_ref[0], pat_ref[0])


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def aa_match_pallas(col: jax.Array, pat: jax.Array, *, bn: int = 512,
                    interpret: bool = True) -> jax.Array:
    """col: (n, W, A) uint32 shares; pat: (W, A). Returns (n,) match shares."""
    n, w, a = col.shape
    assert pat.shape == (w, a), (pat.shape, (w, a))
    bn = min(bn, _round_up(n, 8))
    n_pad = _round_up(n, bn)
    col_p = jnp.pad(col, ((0, n_pad - n), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _aa_kernel,
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((bn, w, a), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, w, a), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.uint32),
        interpret=interpret,
    )(col_p, pat[None])
    return out[:n]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def aa_match_batch_pallas(col: jax.Array, pat: jax.Array, *, bn: int = 512,
                          interpret: bool = True) -> jax.Array:
    """Stacked-predicate AA match as a true 2-D grid kernel.

    col: (B, n, W, A) uint32 shares; pat: (B, W, A). Returns (B, n).

    Grid is (B, n-tiles) with the tile axis innermost, so while row b's
    tiles stream through, its (W, A) pattern block keeps the same index —
    Pallas leaves it resident in VMEM instead of re-fetching it per tile
    (the win over ``vmap(vmap(aa_match_pallas))``, which launches one
    kernel per (cloud, batch-row) cell and re-stages the pattern each
    time).
    """
    b, n, w, a = col.shape
    assert pat.shape == (b, w, a), (pat.shape, (b, w, a))
    bn = min(bn, _round_up(n, 8))
    n_pad = _round_up(n, bn)
    col_p = jnp.pad(col, ((0, 0), (0, n_pad - n), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _aa_batch_kernel,
        grid=(b, n_pad // bn),
        in_specs=[
            pl.BlockSpec((1, bn, w, a), lambda bi, i: (bi, i, 0, 0)),
            pl.BlockSpec((1, w, a), lambda bi, i: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda bi, i: (bi, i)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad), jnp.uint32),
        interpret=interpret,
    )(col_p, pat)
    return out[:, :n]


def _slide_body(col, pat, m):
    """The fused sliding-window automaton: col (bn, W, A), pat (k, A) ->
    (bn, M) raw window-chain products, M = W−k+1.

    Pattern row j contributes one (bn, M) inner-product plane — its one-hot
    dotted against column positions j..j+M−1 — and the k planes chain by
    modular multiplication. Each column tile is read once; only (bn, M)
    results are written (the same fusion win as :func:`_aa_body`, per
    window)."""
    k = pat.shape[0]

    def inner(j):
        sl = jax.lax.dynamic_slice_in_dim(col, j, m, axis=1)    # (bn, M, A)
        pj = jax.lax.dynamic_slice_in_dim(pat, j, 1, axis=0)    # (1, A)
        prod = _mulmod(sl, pj[None, :, :])                      # (bn, M, A)
        # modular tree-reduce over the alphabet axis
        def red(t, acc):
            return _addmod(acc, prod[:, :, t])
        return jax.lax.fori_loop(1, prod.shape[2], red, prod[:, :, 0])

    acc = inner(0)
    def chain(j, acc):
        return _mulmod(acc, inner(j))
    return jax.lax.fori_loop(1, k, chain, acc)


def _slide_batch_kernel(col_ref, pat_ref, o_ref, *, m):
    # one (b, i) grid cell: batch row b's pattern tile against its i-th
    # n-tile, all M windows at once
    o_ref[0] = _slide_body(col_ref[0], pat_ref[0], m)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def aa_slide_batch_pallas(col: jax.Array, pat: jax.Array, *, bn: int = 512,
                          interpret: bool = True) -> jax.Array:
    """Stacked sliding-window AA match as a 2-D grid kernel.

    col: (B, n, W, A) uint32 shares; pat: (B, k, A) pattern tiles.
    Returns (B, n, M) raw window-chain products, M = W−k+1.

    Same grid/VMEM layout as :func:`aa_match_batch_pallas`: (B, n-tiles)
    with the tile axis innermost so row b's (k, A) pattern tile stays
    resident in VMEM while its n-tiles stream through. The suffix
    terminator factor and the CONTAINS window count are linear
    post-processing outside the kernel, so one launch serves a whole
    suffix+substring group of the same k.
    """
    b, n, w, a = col.shape
    k = pat.shape[-2]
    assert pat.shape == (b, k, a), (pat.shape, (b, k, a))
    assert 1 <= k <= w, (k, w)
    m = w - k + 1
    bn = min(bn, _round_up(n, 8))
    n_pad = _round_up(n, bn)
    col_p = jnp.pad(col, ((0, 0), (0, n_pad - n), (0, 0), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_slide_batch_kernel, m=m),
        grid=(b, n_pad // bn),
        in_specs=[
            pl.BlockSpec((1, bn, w, a), lambda bi, i: (bi, i, 0, 0)),
            pl.BlockSpec((1, k, a), lambda bi, i: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, m), lambda bi, i: (bi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad, m), jnp.uint32),
        interpret=interpret,
    )(col_p, pat)
    return out[:, :n]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
