"""repro: privacy-preserving secret-shared computations using MapReduce, in JAX.

Implements Dolev, Li, Sharma, "Privacy-Preserving Secret Shared Computations
using MapReduce" (2018) as a production-grade JAX framework: Shamir
secret-sharing over F_p (Mersenne-31), accumulating-automata string matching,
oblivious count/selection/join/range queries behind the unified
``repro.api.QueryClient`` (logical plans, cost-based strategy planner,
backend registry), a fault-tolerant MapReduce runtime, and a
10-architecture LM zoo with multi-pod pjit sharding.
"""
import jax

# Field arithmetic (core/field.py) multiplies uint32 values in uint64 lanes;
# x64 must be on before any jax computation. Model code is dtype-explicit
# (bf16/f32/int32) everywhere, so this does not change LM numerics.
jax.config.update("jax_enable_x64", True)

__version__ = "1.0.0"
