"""mamba2-2.7b [ssm]: 64L d=2560 (attention-free) vocab=50280,
ssm_state=128 — SSD state-space duality [arXiv:2405.21060]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=50280,
        attn_type="none", ssm_state=128, ssm_head_dim=64,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=256,
        attn_type="none", ssm_state=16, ssm_head_dim=16,
    )
