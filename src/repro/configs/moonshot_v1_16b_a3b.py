"""moonshot-v1-16b-a3b [moe]: 48L d=2048 16H (kv=16) expert d_ff=1408
vocab=163840 — 64 experts top-6 + 2 shared experts (Moonlight recipe)
[hf:moonshotai/Moonlight-16B-A3B]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="moonshot-v1-16b-a3b", family="moe", n_layers=48,
        d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
        vocab_size=163840, n_experts=64, top_k=6, n_shared_experts=2,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="moonshot-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=32, vocab_size=256, n_experts=4,
        top_k=2, n_shared_experts=1,
    )
