"""chatglm3-6b [dense]: 28L d=4096 32H (GQA kv=2) d_ff=13696 vocab=65024 —
partial ("2d") RoPE on half the head dims, QKV bias [arXiv:2406.12793; hf]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense", n_layers=28, d_model=4096,
        n_heads=32, n_kv_heads=2, d_ff=13696, vocab_size=65024,
        rope_fraction=0.5, qkv_bias=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
        rope_fraction=0.5, qkv_bias=True,
    )
