"""Assigned-architecture registry: ``get(name)`` -> module with
``full()`` (exact published config) and ``smoke()`` (reduced same-family
config for CPU tests). ``paper_db`` is the paper's own workload
(secret-shared query engine at production scale)."""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "hymba_1_5b",
    "internvl2_76b",
    "seamless_m4t_medium",
    "qwen1_5_4b",
    "chatglm3_6b",
    "minicpm3_4b",
    "gemma3_1b",
    "granite_moe_3b_a800m",
    "moonshot_v1_16b_a3b",
    "mamba2_2_7b",
]

ALIASES = {
    "hymba-1.5b": "hymba_1_5b",
    "internvl2-76b": "internvl2_76b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "qwen1.5-4b": "qwen1_5_4b",
    "chatglm3-6b": "chatglm3_6b",
    "minicpm3-4b": "minicpm3_4b",
    "gemma3-1b": "gemma3_1b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mamba2-2.7b": "mamba2_2_7b",
}


def get(name: str):
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod_name}")


def full(name: str):
    return get(name).full()


def smoke(name: str):
    return get(name).smoke()
