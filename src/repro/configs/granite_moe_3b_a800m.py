"""granite-moe-3b-a800m [moe]: 32L d=1536 24H (GQA kv=8) expert d_ff=512
vocab=49155 — 40 experts, top-8 routing
[hf:ibm-granite/granite-3.0-3b-a800m-base]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m", family="moe", n_layers=32,
        d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
        vocab_size=49155, n_experts=40, top_k=8,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=32, vocab_size=256, n_experts=4,
        top_k=2,
    )
