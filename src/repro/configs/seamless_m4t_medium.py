"""seamless-m4t-medium [audio]: 12L enc + 12L dec, d=1024 16H (kv=16)
d_ff=4096 vocab=256206 — encoder-decoder; audio frontend STUB provides
precomputed fbank frame embeddings [arXiv:2308.11596; hf]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="encdec", n_layers=12,
        n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=256206, frontend="audio", frontend_dim=160,
        act="gelu",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke", family="encdec", n_layers=2, n_enc_layers=2,
        d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        frontend="audio", frontend_dim=32, act="gelu",
    )
