"""gemma3-1b [dense]: 26L d=1152 4H (GQA kv=1) head_dim=256 d_ff=6912
vocab=262144 — 5:1 local:global sliding window (512), QK-norm, GeGLU, tied
embeddings, 128k context [hf:google/gemma-3-1b-pt]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b", family="dense", n_layers=26, d_model=1152,
        n_heads=4, n_kv_heads=1, head_dim=256, d_ff=6912,
        vocab_size=262144, sliding_window=512, global_every=6,
        qk_norm=True, embed_scale=True, tie_embeddings=True, act="geglu",
        rope_theta=1_000_000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=256,
        sliding_window=8, global_every=2, qk_norm=True, embed_scale=True,
        tie_embeddings=True, act="geglu",
    )
