"""minicpm3-4b [dense]: 62L d=2560 40H d_ff=6400 vocab=73448 — MLA
(multi-head latent attention: q_lora=768, kv_lora=256, nope=64, rope=32,
v=64) [hf:openbmb/MiniCPM3-4B]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b", family="dense", n_layers=62, d_model=2560,
        n_heads=40, n_kv_heads=40, d_ff=6400, vocab_size=73448,
        attn_type="mla", q_lora_rank=768, kv_lora_rank=256,
        qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        attn_type="mla", q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
    )
