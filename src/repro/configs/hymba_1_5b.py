"""hymba-1.5b [hybrid]: 32L d=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16 — parallel attention + mamba heads [arXiv:2411.13676; hf].

Meta-tokens and cross-layer KV sharing of the full Hymba recipe are omitted
(noted in DESIGN.md §Arch-applicability); the parallel attn+SSM mixer — the
architecture's defining feature — is implemented."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="dense", n_layers=32, d_model=1600,
        n_heads=25, n_kv_heads=5, d_ff=5504, vocab_size=32001,
        hybrid_ssm=True, ssm_state=16, ssm_head_dim=64,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
        hybrid_ssm=True, ssm_state=8, ssm_head_dim=16,
    )
