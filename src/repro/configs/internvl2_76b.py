"""internvl2-76b [vlm]: 80L d=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 —
InternViT frontend (STUB: precomputed patch embeddings) + LLaMA-3-70B-style
backbone [arXiv:2404.16821]."""
from repro.models.config import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-76b", family="vlm", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=28672, vocab_size=128256,
        frontend="vit", n_prefix=256, frontend_dim=3200,
        rope_theta=500000.0,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
        frontend="vit", n_prefix=4, frontend_dim=32,
    )
