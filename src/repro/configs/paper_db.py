"""paper_db: the paper's OWN workload at production scale — the oblivious
query engine (count / select / PK-FK join) over a secret-shared relation,
tuples sharded across the data axis, alphabet/attribute work on the model
axis. Used by the dry-run as the paper-representative cell."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperDBConfig:
    name: str = "paper_db"
    n_tuples: int = 1 << 20        # 1M tuples
    n_attrs: int = 8
    word_length: int = 12
    alphabet_size: int = 64
    n_shares: int = 4              # clouds simulated per program
    degree: int = 1
    fetch_rows: int = 256          # ℓ' padded fetch-matrix rows


def full() -> PaperDBConfig:
    return PaperDBConfig()


def smoke() -> PaperDBConfig:
    return PaperDBConfig(n_tuples=64, n_attrs=3, word_length=6,
                         alphabet_size=16, fetch_rows=4)
