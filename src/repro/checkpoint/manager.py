"""Fault-tolerant sharded checkpointing.

Guarantees:
  * **atomicity** — leaves are written to ``step_N.tmp/`` then renamed;
    a crash mid-write can never produce a "latest" that fails to restore;
  * **integrity** — every leaf carries a SHA-256 in the manifest; restore
    verifies and falls back to the newest *valid* step (torn/corrupt
    checkpoints are skipped, matching the restart-after-node-failure story);
  * **elastic resharding** — restore takes an optional (mesh, specs): leaves
    are ``device_put`` with the *new* NamedSharding, so a job can restart on
    a different mesh shape (elastic scaling);
  * **async** — ``save(..., blocking=False)`` snapshots to host, then a
    writer thread persists while training continues (one step of copy
    overlap, the standard async-checkpoint pattern).

Storage layout:  <dir>/step_<N>/<leaf-idx>.npy + manifest.json
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize ML dtypes — store as same-width integer views and
# restore from the manifest's dtype record.
_EXOTIC = {
    "bfloat16": (ml_dtypes.bfloat16, np.uint16),
    "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
    "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8),
}


def _to_savable(arr: np.ndarray) -> np.ndarray:
    name = str(arr.dtype)
    if name in _EXOTIC:
        return arr.view(_EXOTIC[name][1])
    return arr


def _from_savable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC:
        return arr.view(_EXOTIC[dtype_name][0])
    return arr


def _leaf_paths(tree) -> list:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return ["/".join(str(getattr(k, "key", getattr(k, "idx",
                                                   getattr(k, "name", k))))
                     for k in path) for path, _ in flat]


def _sha(arr: np.ndarray) -> str:
    return hashlib.sha256(arr.tobytes()).hexdigest()[:16]


def save_checkpoint(ckpt_dir: str, step: int, tree: Any, *,
                    blocking: bool = True) -> Optional[threading.Thread]:
    """Persist a pytree. Non-blocking mode returns the writer thread."""
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
        final = os.path.join(ckpt_dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        flat, treedef = jax.tree_util.tree_flatten(host)
        names = _leaf_paths(host)
        manifest = {"step": step, "leaves": []}
        for i, (leaf, name) in enumerate(zip(flat, names)):
            fn = f"{i}.npy"
            np.save(os.path.join(tmp, fn), _to_savable(leaf))
            manifest["leaves"].append({
                "name": name, "file": fn, "shape": list(leaf.shape),
                "dtype": str(leaf.dtype), "sha": _sha(leaf)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)           # atomic commit

    if blocking:
        _write()
        return None
    th = threading.Thread(target=_write, daemon=True)
    th.start()
    return th


def _steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            try:
                out.append(int(d.split("_")[1]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    s = _steps(ckpt_dir)
    return s[-1] if s else None


def _load_step(ckpt_dir: str, step: int, template: Any, *,
               verify: bool = True) -> Any:
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = jax.tree_util.tree_flatten(template)
    if len(manifest["leaves"]) != len(flat_t):
        raise ValueError("manifest/template leaf-count mismatch")
    leaves = []
    for meta, t in zip(manifest["leaves"], flat_t):
        arr = np.load(os.path.join(d, meta["file"]))
        if verify and _sha(arr) != meta["sha"]:
            raise ValueError(f"checksum mismatch in {meta['name']}")
        arr = _from_savable(arr, meta["dtype"])
        if list(arr.shape) != list(t.shape):
            raise ValueError(f"shape mismatch in {meta['name']}: "
                             f"{arr.shape} vs {t.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def restore_checkpoint(ckpt_dir: str, template: Any, *,
                       step: Optional[int] = None,
                       shardings: Optional[Any] = None,
                       verify: bool = True) -> tuple:
    """Restore newest valid checkpoint (or a specific step).

    shardings: optional pytree of NamedSharding — leaves are placed with the
    NEW sharding (elastic restart on a different mesh).
    Returns (step, tree). Raises FileNotFoundError if nothing valid exists.
    """
    candidates = [step] if step is not None else list(reversed(_steps(
        ckpt_dir)))
    last_err: Optional[Exception] = None
    for s in candidates:
        try:
            host = _load_step(ckpt_dir, s, template, verify=verify)
        except Exception as e:  # torn/corrupt -> try older
            last_err = e
            continue
        if shardings is not None:
            host = jax.tree.map(
                lambda a, sh: jax.device_put(a, sh), host, shardings)
        return s, host
    raise FileNotFoundError(
        f"no valid checkpoint under {ckpt_dir}: {last_err}")


class CheckpointManager:
    """keep_last_n retention + async writer + restore-or-init."""

    def __init__(self, ckpt_dir: str, *, keep_last_n: int = 3,
                 async_save: bool = True):
        self.dir = ckpt_dir
        self.keep = keep_last_n
        self.async_save = async_save
        self._pending: Optional[threading.Thread] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        self._pending = save_checkpoint(self.dir, step, tree,
                                        blocking=not self.async_save)
        if not self.async_save:
            self._gc()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
            self._gc()

    def _gc(self) -> None:
        steps = _steps(self.dir)
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def restore_or_init(self, template: Any, init_fn, *,
                        shardings: Optional[Any] = None) -> tuple:
        try:
            return restore_checkpoint(self.dir, template,
                                      shardings=shardings)
        except FileNotFoundError:
            return 0, init_fn()
