"""Cost-based selection-strategy planner (paper §3.2 / Table 1 formulas).

The paper gives per-algorithm communication/round costs; this module turns
them into static ``(bits, rounds)`` estimates in exactly the units
``CostLedger`` records (field elements × 31 bits), so the planner's numbers
are directly comparable with measured ledgers:

  one_tuple  (§3.2.1, Alg 3): count + pattern + one m·w·A tuple; 2 rounds.
               Only valid when the predicate hits exactly ℓ = 1 tuple.
  one_round  (§3.2.2):        pattern + n match bits + ℓ'×n fetch; 2 rounds.
  tree       (§3.2.2, Alg 4): count + pattern + per-round block counts +
               ℓ address-fetches + ℓ'×n fetch;
               rounds ≤ ⌊log_ℓ n⌋ + ⌊log₂ ℓ⌋ + 1 (+ count + fetch).

The crossover the planner captures is the paper's own: ``one_round`` ships
(and the user interpolates) all n match bits — unbeatable for small n, linear
pain for large n — while ``tree`` replaces that n-vector with O(ℓ·log n)
block counts at the price of extra rounds. Estimates are pure functions of
the public relation statistics (n, m, w, A, c′) plus the cardinality hint ℓ,
so the planner runs without touching shares.

Estimates also price the *execution* axis: ``DBStats.shards`` carries the
attached dataplane's shard count and every :class:`CostEstimate` reports
``dispatches`` — the number of per-shard device dispatches the sharded
round engine will emit (each sharded cloud step fans out S ways; tree Q&A
rounds gather blocks from the full relation and stay at one dispatch).
Dispatches are an execution cost, never a protocol cost: bits and rounds
are independent of S by construction.

:func:`explain_batch_groups` assembles per-group estimates into the
:class:`BatchExplanation` that ``QueryClient.explain(plans)`` returns — a
predicted ``run_batch`` ledger (bits sum per query, rounds fuse to the
deepest member, the cross-group fetch is priced ONCE) without running
anything.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Mapping, Optional, Sequence, Tuple

from ..core.costs import WORD_BITS
from ..core.dataplane import ShardedRelation
from ..core.encoding import PatternSpec
from ..core.engine import SecretSharedDB
from ..core.queries.rounds import match_phase_cost

#: ℓ assumed when the plan carries no ``expected_matches`` hint. Two is the
#: smallest multi-match cardinality: it keeps ``one_tuple`` out of the
#: running (which would raise on ℓ≠1) without inflating tree-round counts.
DEFAULT_ELL = 2


class PlanNotSupported(TypeError):
    """A plan object no estimator/executor knows how to price or run.

    Subclasses TypeError so existing ``except TypeError`` callers keep
    working, but carries the offending plan's type name instead of the
    opaque ``KeyError``/``AttributeError`` an unknown class used to hit.
    """

    def __init__(self, plan, context: str = "plan"):
        self.plan = plan
        super().__init__(
            f"unsupported {context}: {type(plan).__name__!r} "
            f"({plan!r}) is not a known logical plan class")


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Planner-side (bits, rounds, per-shard dispatches) prediction."""
    strategy: str
    bits: int
    rounds: int
    dispatches: int = 0

    def score(self, round_cost_bits: int = 0) -> int:
        """Total cost with rounds priced at ``round_cost_bits`` each."""
        return self.bits + round_cost_bits * self.rounds


@dataclasses.dataclass(frozen=True)
class DBStats:
    """The public statistics the planner works from (§2.3: the adversary —
    and hence the planner — may know n, m and the schema). ``shards`` is
    the attached dataplane's tuple-axis shard count (execution, not
    protocol: it scales dispatch estimates, never bits or rounds).
    ``relation`` names the registry entry these statistics describe — with
    several relations attached, every estimate (and in particular its
    ``dispatches``) is priced per *target* relation at that relation's own
    n and shard count, never at a neighbour's."""
    n: int          # tuples
    m: int          # attributes
    c: int          # clouds / shares
    w: int          # word length
    a: int          # alphabet size
    shards: int = 1
    relation: str = ""

    @classmethod
    def of(cls, db, shards: Optional[int] = None,
           relation: str = "") -> "DBStats":
        if isinstance(db, ShardedRelation):
            shards = db.n_shards if shards is None else shards
            db = db.db
        return cls(n=db.n_tuples, m=db.n_attrs, c=db.n_shares,
                   w=db.codec.word_length, a=db.codec.alphabet_size,
                   shards=shards or 1, relation=relation)


def _pattern_elems(s: DBStats) -> int:
    return s.c * s.w * s.a


def _count_elems(s: DBStats) -> int:
    # Alg 2: pattern up, one word per cloud down.
    return _pattern_elems(s) + s.c


def _fetch_elems(s: DBStats, ell: int, padded_rows: Optional[int]) -> int:
    # ℓ'×n one-hot matrix up, ℓ' tuples down (fetch_by_addresses).
    ellp = max(padded_rows or ell, ell)
    return s.c * ellp * s.n + s.c * ellp * s.m * s.w * s.a


def estimate_select_cost(strategy: str, stats: DBStats, *,
                         ell: int = DEFAULT_ELL,
                         padded_rows: Optional[int] = None) -> CostEstimate:
    """(bits, rounds, dispatches) for one §3.2 strategy at cardinality ℓ.

    Dispatches count the sharded round engine's per-shard device fan-out:
    count / match / fetch steps slice the tuple axis (S dispatches each),
    and tree Q&A / address rounds are shard-aligned — each round's block
    gathers clip to shard bounds and fan out per shard too (S dispatches
    per Q&A round; the public block partition itself never moves with S).
    """
    s = stats
    S = max(1, min(s.shards, max(s.n, 1)))
    if strategy == "one_tuple":
        if ell != 1:
            raise ValueError("one_tuple requires ℓ = 1")
        elems = _count_elems(s) + _pattern_elems(s) + s.c * s.m * s.w * s.a
        return CostEstimate("one_tuple", elems * WORD_BITS, rounds=2,
                            dispatches=2 * S)    # count step + map step
    if strategy == "one_round":
        elems = _pattern_elems(s) + s.c * s.n + _fetch_elems(s, ell,
                                                             padded_rows)
        return CostEstimate("one_round", elems * WORD_BITS, rounds=2,
                            dispatches=2 * S)    # match step + fetch step
    if strategy == "tree":
        if ell <= 1:
            # Alg 4 line 2: count, one whole-table Address_fetch, fetch.
            elems = (_count_elems(s) + _pattern_elems(s) + s.c
                     + _fetch_elems(s, max(ell, 1), padded_rows))
            return CostEstimate("tree", elems * WORD_BITS, rounds=3,
                                dispatches=3 * S)
        qa_rounds = (math.floor(math.log(max(s.n, 2), ell))
                     + math.floor(math.log2(ell)) + 1)       # Theorem 4
        elems = (_count_elems(s) + _pattern_elems(s)
                 + qa_rounds * ell * s.c                     # block counts
                 + ell * s.c                                 # address fetches
                 + _fetch_elems(s, ell, padded_rows))
        return CostEstimate("tree", elems * WORD_BITS,
                            rounds=1 + qa_rounds + 1,
                            dispatches=(2 + qa_rounds + 1) * S)
    raise ValueError(f"unknown selection strategy {strategy!r}")


def estimate_count_cost(stats: DBStats) -> CostEstimate:
    """§3.1 Algorithm 2: one round, O(1) comm, one count step per shard."""
    S = max(1, min(stats.shards, max(stats.n, 1)))
    return CostEstimate("count", _count_elems(stats) * WORD_BITS, rounds=1,
                        dispatches=S)


def estimate_pattern_cost(stats: DBStats, spec: Optional[PatternSpec], *,
                          select: Optional[str] = None,
                          ell: int = DEFAULT_ELL,
                          padded_rows: Optional[int] = None) -> CostEstimate:
    """Price a pattern-predicate COUNT (``select=None``) or SELECT
    (``select="one_round" | "tree"``) from the very same Table-1-style
    atoms the round engine charges (:func:`~repro.core.queries.rounds.
    match_phase_cost` is the single source for both), so the prediction is
    *exact* against the measured ledger for pattern counts and one-round
    selects, and a Theorem-4-style bound for the tree.

    ``spec=None`` is the wildcard-free case — the predicate lowered onto
    the exact-equality path — and the estimate degenerates, field for
    field, to :func:`estimate_count_cost` / :func:`estimate_select_cost`
    (the planner-level statement that a wildcard-free LIKE costs exactly
    what an Eq costs; asserted by the planner tests).

    The CONTAINS family's degree-reduction re-share adds its round and its
    c² + n·M elements wherever the match phase runs (count, one_round
    Phase 1, tree Phase 0 *and* tree prelude — hence twice for a CONTAINS
    tree). ``one_tuple`` is the §3.2.1 exact-equality special case and is
    deliberately absent here.
    """
    s = stats
    S = max(1, min(s.shards, max(s.n, 1)))
    cost = match_phase_cost(spec, n=s.n, c=s.c, w=s.w, a=s.a)
    match_elems = cost["send"] + cost["reduce_send"]
    rr = cost["reduce_rounds"]
    if select is None:
        return CostEstimate("count", (match_elems + s.c) * WORD_BITS,
                            rounds=1 + rr, dispatches=S)
    ell = max(ell, 1)
    if select == "one_round":
        elems = match_elems + s.c * s.n + _fetch_elems(s, ell, padded_rows)
        return CostEstimate("one_round", elems * WORD_BITS,
                            rounds=2 + rr, dispatches=2 * S)
    if select == "tree":
        count_elems = match_elems + s.c          # Phase 0 runs the pattern
        if ell <= 1:
            elems = (count_elems + match_elems + s.c
                     + _fetch_elems(s, 1, padded_rows))
            return CostEstimate("tree", elems * WORD_BITS,
                                rounds=3 + 2 * rr, dispatches=3 * S)
        qa_rounds = (math.floor(math.log(max(s.n, 2), ell))
                     + math.floor(math.log2(ell)) + 1)       # Theorem 4
        elems = (count_elems + match_elems
                 + qa_rounds * ell * s.c                     # block counts
                 + ell * s.c                                 # address fetches
                 + _fetch_elems(s, ell, padded_rows))
        return CostEstimate("tree", elems * WORD_BITS,
                            rounds=1 + qa_rounds + 1 + 2 * rr,
                            dispatches=(2 + qa_rounds + 1) * S)
    raise ValueError(f"pattern selects support one_round/tree, "
                     f"not {select!r}")


def candidate_pattern_estimates(stats: DBStats,
                                spec: Optional[PatternSpec], *,
                                ell: Optional[int] = None,
                                padded_rows: Optional[int] = None
                                ) -> List[CostEstimate]:
    """Eligible strategies for a pattern select — ``one_round`` and
    ``tree`` only: ``one_tuple`` is the exact-equality special case, even
    at an ℓ = 1 hint."""
    ell_eff = DEFAULT_ELL if ell is None else max(ell, 1)
    return [estimate_pattern_cost(stats, spec, select=strat, ell=ell_eff,
                                  padded_rows=padded_rows)
            for strat in ("one_round", "tree")]


#: backend launches one PK/FK match-matrix evaluation needs per method:
#: the §3.1.2 chain walks the word one dot-set per position; the aggregate
#: form flattens all W·A products into ONE contraction plus the Lagrange
#: equality indicator (2 launches, any W).
MATCH_METHOD_LAUNCHES = {"chain": lambda w: w, "aggregate": lambda w: 2}


def estimate_match_method_launches(stats: DBStats, method: str) -> int:
    """Device launches for one match-matrix evaluation under ``method``."""
    try:
        return MATCH_METHOD_LAUNCHES[method](stats.w)
    except KeyError:
        raise ValueError(f"unknown match_method {method!r}; choose from "
                         f"('chain', 'aggregate')") from None


def choose_match_method(stats: DBStats, method: str = "auto") -> str:
    """Resolve a ``Join.match_method`` — the §3.1.2 chain-vs-aggregate
    execution knob. Both methods open the same match matrix at the same
    degree (2tW) with identical ledgers, so bits and rounds never
    discriminate; the planner prices the remaining axis — backend launch
    count — and AUTO takes the cheaper evaluation (``aggregate`` whenever
    the word is longer than its two fixed launches, i.e. any real W)."""
    if method != "auto":
        estimate_match_method_launches(stats, method)   # validate
        return method
    return min(("chain", "aggregate"),
               key=lambda m: estimate_match_method_launches(stats, m))


def estimate_range_cost(stats: DBStats, *, t_bits: int,
                        reduce_every: int = 0, want_addresses: bool = False,
                        ell: int = DEFAULT_ELL,
                        padded_rows: Optional[int] = None) -> CostEstimate:
    """§3.4 Algorithms 5/6: the SS-SUB ripple over a t-bit column.

    Bits mirror the measured ledger: both endpoints up (2·c·t elements),
    one 2c² re-share per degree-reduction boundary (each boundary is two
    logical rounds, one per subtraction direction), the count (c) or the n
    indicator bits plus the oblivious fetch down. Dispatches: one fused
    ripple *segment* per boundary interval per shard, plus the fetch step.
    """
    s = stats
    S = max(1, min(s.shards, max(s.n, 1)))
    n_red = (t_bits - 1) // reduce_every if reduce_every > 0 else 0
    segments = n_red + 1
    elems = s.c * 2 * t_bits + n_red * 2 * s.c * s.c
    rounds = 1 + 2 * n_red
    dispatches = segments * S
    if want_addresses:
        elems += s.c * s.n + _fetch_elems(s, ell, padded_rows)
        rounds += 1
        dispatches += S                              # the oblivious fetch
        name = "range_select"
    else:
        elems += s.c
        name = "range_count"
    return CostEstimate(name, elems * WORD_BITS, rounds=rounds,
                        dispatches=dispatches)


def estimate_aggregate_cost(stats: DBStats, op: str, *, t_bits: int,
                            conditional: bool = False, verify: bool = False,
                            reduce_every: int = 0) -> CostEstimate:
    """OBSCURE-style aggregation over a t-bit numeric column.

    sum:     one contraction round — pattern up (conditional only), the
             scalar sum share back from each cloud.
    avg:     the sum plus (conditional only) the §3.1 count round for the
             denominator; an unconditional AVG divides by the public n.
    min/max: knockout tournament of ⌈log₂ n⌉ SS-SUB comparator levels —
             each level pays its ``reduce_every`` carry reductions (one c²
             re-share round each) and every level but the last one
             inter-level re-share; conditional jobs add the sentinel-mask
             re-share round and open the match count alongside the value.
    verify:  +1 round and c checksum elements per opened tensor
             (value, and the count for a conditional min/max).

    Bits mirror the measured ledger exactly in ``CostLedger`` units.
    """
    s = stats
    S = max(1, min(s.shards, max(s.n, 1)))
    if op in ("sum", "avg"):
        elems = s.c + (s.c * s.w * s.a if conditional else 0)
        rounds, dispatches = 1, S
        if op == "avg" and conditional:
            elems += _count_elems(s)
            rounds += 1
            dispatches += S
        if verify:
            rounds += 1
            elems += s.c
        return CostEstimate(f"agg_{op}", elems * WORD_BITS, rounds=rounds,
                            dispatches=dispatches)
    if op in ("min", "max"):
        levels = math.ceil(math.log2(s.n)) if s.n > 1 else 0
        n_red = (t_bits - 1) // reduce_every if reduce_every > 0 else 0
        elems = (levels * n_red * s.c * s.c          # carry reductions
                 + max(levels - 1, 0) * s.c * s.c    # inter-level re-shares
                 + s.c * t_bits)                     # final value opening
        rounds = 1 + levels * n_red + max(levels - 1, 0)
        dispatches = levels * (n_red + 1)
        if conditional:
            elems += s.c * s.w * s.a + s.c * s.c + s.c
            rounds += 1
            dispatches += S
        if verify:
            rounds += 1
            elems += s.c * (2 if conditional else 1)
        return CostEstimate(f"agg_{op}", elems * WORD_BITS, rounds=rounds,
                            dispatches=dispatches)
    raise ValueError(f"unknown aggregate op {op!r}")


def estimate_embed_cost(stats: DBStats, *, n_tokens: int,
                        verify: bool = False) -> CostEstimate:
    """§3.2.1 as the LM embedding layer: one fused lookup round.

    The relation is the shared ``(c, V, D)`` table (``n`` = V vocab rows,
    ``m`` = D model dims). The step's ``n_tokens`` shared one-hots go up
    (c·n_tok·V), the picked embedding share rows come down (c·n_tok·D),
    all in ONE contraction — dispatches = S (one ``ss_matmul`` per shard).
    ``verify=`` adds the OBSCURE consistency round and c checksum elements.

    Bits mirror the measured ledger exactly in ``CostLedger`` units.
    """
    s = stats
    S = max(1, min(s.shards, max(s.n, 1)))
    elems = s.c * n_tokens * s.n + s.c * n_tokens * s.m
    rounds = 1
    if verify:
        rounds += 1
        elems += s.c
    return CostEstimate("embed", elems * WORD_BITS, rounds=rounds,
                        dispatches=S)


def estimate_pkfk_cost(stats: DBStats, right: DBStats) -> CostEstimate:
    """§3.3.1: match-matrix step (per shard) + the shared fetch + one round
    shipping every reducer's (parent ⊕ child) concatenation."""
    s = stats
    S = max(1, min(s.shards, max(s.n, 1)))
    elems = s.c * right.n * (s.m + right.m) * s.w * s.a
    return CostEstimate("pkfk", elems * WORD_BITS, rounds=1,
                        dispatches=2 * S)            # match + fetch steps


def estimate_equijoin_cost(stats: DBStats, right: DBStats, *,
                           values: int = 1,
                           fake_values: int = 0) -> CostEstimate:
    """§3.3.2 (Thm 6): column-open round + 2 rounds per (fake) common
    value. ``values`` is the caller's guess at k (the true count is data
    the planner cannot see); value groups are assumed singletons, the
    asymptotically common PK-ish case. Dispatches: the X-side layer-1
    matmul fans per shard, the Y-side runs against the (unsharded) right."""
    s = stats
    S = max(1, min(s.shards, max(s.n, 1)))
    k = max(0, values) + max(0, fake_values)
    elems = (s.c * s.n * s.w * s.a + right.c * right.n * s.w * s.a  # open
             + k * (s.c * s.n + right.c * right.n)       # layer-1 one-hots
             + k * s.c * (s.m + right.m) * s.w * s.a)    # layer-2 pairs
    return CostEstimate("equi", elems * WORD_BITS, rounds=1 + 2 * k,
                        dispatches=S + 1)



def candidate_estimates(stats: DBStats, *, ell: Optional[int] = None,
                        padded_rows: Optional[int] = None
                        ) -> List[CostEstimate]:
    """All eligible strategies for cardinality hint ℓ (None = unknown)."""
    known_one = ell == 1
    ell_eff = DEFAULT_ELL if ell is None else max(ell, 1)
    out = []
    if known_one and not padded_rows:
        out.append(estimate_select_cost("one_tuple", stats, ell=1))
    for strat in ("one_round", "tree"):
        out.append(estimate_select_cost(strat, stats, ell=ell_eff,
                                        padded_rows=padded_rows))
    return out


def choose_select_strategy(stats: DBStats, *, ell: Optional[int] = None,
                           padded_rows: Optional[int] = None,
                           round_cost_bits: int = 0,
                           group_sizes: Optional[Mapping[str, int]] = None,
                           group_rounds: Optional[Mapping[str, int]] = None
                           ) -> CostEstimate:
    """Pick the paper-optimal strategy: min bits, rounds as tie-break
    (price a round via ``round_cost_bits`` to trade bandwidth for latency).

    ``group_sizes`` makes the choice *batching-aware*: it maps strategy name
    to the number of batch-mates already executing that strategy in the
    current ``run_batch``. The batched round engine fuses a group's protocol
    rounds into one dispatch/interpolation each, so a query that joins a
    non-empty group pays its bits but rides the group's existing rounds for
    free — its **marginal** round cost is only the depth it adds beyond the
    group's deepest member (``group_rounds``: strategy -> estimated rounds
    of that deepest member; without it a non-empty group is assumed at
    least as deep as the rider). With ``round_cost_bits > 0`` that steers a
    borderline query onto the strategy of an already-running (typically the
    larger) group whenever riding its fused rounds is cheaper than opening
    a new round chain. With the default pricing (``round_cost_bits = 0``)
    rounds never enter the score, so the choice — and therefore every
    row/ledger — is identical to sequential planning.
    """
    cands = candidate_estimates(stats, ell=ell, padded_rows=padded_rows)
    return min(cands, key=_riding_key(round_cost_bits, group_sizes,
                                      group_rounds))


def _riding_key(round_cost_bits: int,
                group_sizes: Optional[Mapping[str, int]],
                group_rounds: Optional[Mapping[str, int]]):
    """Batching-aware scoring: a strategy whose group is already running
    pays only its *marginal* rounds beyond the group's deepest member."""
    def key(e: CostEstimate):
        riding = bool(group_sizes) and group_sizes.get(e.strategy, 0) > 0
        if riding:
            depth = (group_rounds or {}).get(e.strategy)
            marginal_rounds = (0 if depth is None
                               else max(0, e.rounds - depth))
        else:
            marginal_rounds = e.rounds
        return (e.bits + round_cost_bits * marginal_rounds, e.rounds)
    return key


def choose_pattern_strategy(stats: DBStats, spec: Optional[PatternSpec], *,
                            ell: Optional[int] = None,
                            padded_rows: Optional[int] = None,
                            round_cost_bits: int = 0,
                            group_sizes: Optional[Mapping[str, int]] = None,
                            group_rounds: Optional[Mapping[str, int]] = None
                            ) -> CostEstimate:
    """:func:`choose_select_strategy` for a pattern predicate: the same
    min-bits / marginal-rounds scoring over the pattern-eligible
    candidates (``one_round``/``tree`` — never ``one_tuple``)."""
    cands = candidate_pattern_estimates(stats, spec, ell=ell,
                                        padded_rows=padded_rows)
    return min(cands, key=_riding_key(round_cost_bits, group_sizes,
                                      group_rounds))


def estimate_batch_group_cost(stats: DBStats, strategy: str, *,
                              ells: Sequence[Optional[int]],
                              padded_rows: Optional[int] = None,
                              specs: Optional[Sequence[
                                  Optional[PatternSpec]]] = None
                              ) -> CostEstimate:
    """Price a whole ``run_batch`` group: bits add up query by query, but
    the lockstep engine pays each protocol round — and each per-shard
    dispatch — once for the group, so the group's round and dispatch counts
    are its deepest member's (not the sum). This is the per-group ledger
    shape ``tests/test_batch.py`` asserts, exposed as a planner-side
    estimate. ``specs`` aligns with ``ells`` and prices pattern-predicate
    members through :func:`estimate_pattern_cost` (a ``None`` entry is an
    exact-equality member; both estimators agree there, field for field)."""
    specs = specs if specs is not None else [None] * len(ells)
    ests = [estimate_pattern_cost(
        stats, spec, select=strategy,
        ell=DEFAULT_ELL if e is None else max(e, 1),
        padded_rows=padded_rows)
        if (spec is not None and strategy != "one_tuple")
        else estimate_select_cost(
            strategy, stats, ell=DEFAULT_ELL if e is None else max(e, 1),
            padded_rows=padded_rows)
        for e, spec in zip(ells, specs)]
    return CostEstimate(strategy,
                        bits=sum(e.bits for e in ests),
                        rounds=max((e.rounds for e in ests), default=0),
                        dispatches=max((e.dispatches for e in ests),
                                       default=0))


#: group families whose oblivious fetch rides the single cross-group
#: ``ss_matmul`` of ``run_batch`` (their solo estimates each include one
#: fetch step; the batch pays it once).
FETCH_RIDERS = ("one_round", "tree", "range_select", "pkfk")


@dataclasses.dataclass(frozen=True)
class GroupEstimate:
    """One ``run_batch`` group's predicted ledger."""
    family: str                 # count/one_tuple/one_round/tree/range_*/…
    size: int                   # member queries
    estimate: CostEstimate      # bits summed, rounds/dispatches fused


@dataclasses.dataclass(frozen=True)
class BatchExplanation:
    """Predicted ``run_batch`` ledger for a prospective batch.

    bits sum over every member query (protocol bits are per query, fusion
    never changes them); rounds are the deepest group's (groups share the
    batch's fused round structure); dispatches total the per-shard device
    fan-out with the cross-group fetch counted ONCE (each rider group's
    solo estimate prices its own fetch step — the assembly removes the
    duplicates).
    """
    groups: Tuple[GroupEstimate, ...]
    bits: int
    rounds: int
    dispatches: int
    shards: int
    relation: str = ""


def explain_batch_groups(stats: DBStats,
                         groups: Sequence[GroupEstimate]
                         ) -> BatchExplanation:
    """Assemble per-group estimates into the batch-level prediction."""
    S = max(1, min(stats.shards, max(stats.n, 1)))
    riders = sum(1 for g in groups
                 if g.family in FETCH_RIDERS and g.size > 0)
    dispatches = sum(g.estimate.dispatches for g in groups)
    if riders > 1:
        dispatches -= (riders - 1) * S      # ONE shared fetch dispatch set
    return BatchExplanation(
        groups=tuple(groups),
        bits=sum(g.estimate.bits for g in groups),
        rounds=max((g.estimate.rounds for g in groups), default=0),
        dispatches=dispatches,
        shards=S,
        relation=stats.relation)


def _has_fetch(part: BatchExplanation) -> bool:
    return any(g.family in FETCH_RIDERS and g.size > 0 for g in part.groups)


@dataclasses.dataclass(frozen=True)
class MultiBatchExplanation:
    """Predicted ledgers for a fused multi-relation ``run_batch_multi``.

    Per-relation predictions are the untouched solo
    :class:`BatchExplanation`\\ s — cross-relation fusion co-schedules the
    already-independent shard dispatches, so no relation's bits, rounds or
    dispatch fan-out moves. What fusion buys is waves: the
    ``fetch_parts`` relations that would each close with their own fetch
    dispatch wave share ONE (``fetch_waves``); the wave's total dispatch
    fan-out stays Σ of the per-relation shard counts.
    """
    parts: Tuple[BatchExplanation, ...]
    bits: int                   # Σ parts — protocol bits are per relation
    rounds: int                 # deepest part (waves run side by side)
    dispatches: int             # Σ parts — fan-out is per relation's shards
    fetch_parts: int            # relations riding the shared fetch wave
    fetch_waves: int            # 1 when >= 2 parts fuse, else fetch_parts


def explain_multi_batches(parts: Sequence[BatchExplanation]
                          ) -> MultiBatchExplanation:
    """Price a prospective ``run_batch_multi`` from its solo predictions."""
    fetch_parts = sum(1 for p in parts if _has_fetch(p))
    return MultiBatchExplanation(
        parts=tuple(parts),
        bits=sum(p.bits for p in parts),
        rounds=max((p.rounds for p in parts), default=0),
        dispatches=sum(p.dispatches for p in parts),
        fetch_parts=fetch_parts,
        fetch_waves=1 if fetch_parts > 1 else fetch_parts)
