"""Cost-based selection-strategy planner (paper §3.2 / Table 1 formulas).

The paper gives per-algorithm communication/round costs; this module turns
them into static ``(bits, rounds)`` estimates in exactly the units
``CostLedger`` records (field elements × 31 bits), so the planner's numbers
are directly comparable with measured ledgers:

  one_tuple  (§3.2.1, Alg 3): count + pattern + one m·w·A tuple; 2 rounds.
               Only valid when the predicate hits exactly ℓ = 1 tuple.
  one_round  (§3.2.2):        pattern + n match bits + ℓ'×n fetch; 2 rounds.
  tree       (§3.2.2, Alg 4): count + pattern + per-round block counts +
               ℓ address-fetches + ℓ'×n fetch;
               rounds ≤ ⌊log_ℓ n⌋ + ⌊log₂ ℓ⌋ + 1 (+ count + fetch).

The crossover the planner captures is the paper's own: ``one_round`` ships
(and the user interpolates) all n match bits — unbeatable for small n, linear
pain for large n — while ``tree`` replaces that n-vector with O(ℓ·log n)
block counts at the price of extra rounds. Estimates are pure functions of
the public relation statistics (n, m, w, A, c′) plus the cardinality hint ℓ,
so the planner runs without touching shares.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Mapping, Optional, Sequence

from ..core.costs import WORD_BITS
from ..core.engine import SecretSharedDB

#: ℓ assumed when the plan carries no ``expected_matches`` hint. Two is the
#: smallest multi-match cardinality: it keeps ``one_tuple`` out of the
#: running (which would raise on ℓ≠1) without inflating tree-round counts.
DEFAULT_ELL = 2


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Planner-side (bits, rounds) prediction for one strategy."""
    strategy: str
    bits: int
    rounds: int

    def score(self, round_cost_bits: int = 0) -> int:
        """Total cost with rounds priced at ``round_cost_bits`` each."""
        return self.bits + round_cost_bits * self.rounds


@dataclasses.dataclass(frozen=True)
class DBStats:
    """The public statistics the planner works from (§2.3: the adversary —
    and hence the planner — may know n, m and the schema)."""
    n: int          # tuples
    m: int          # attributes
    c: int          # clouds / shares
    w: int          # word length
    a: int          # alphabet size

    @classmethod
    def of(cls, db: SecretSharedDB) -> "DBStats":
        return cls(n=db.n_tuples, m=db.n_attrs, c=db.n_shares,
                   w=db.codec.word_length, a=db.codec.alphabet_size)


def _pattern_elems(s: DBStats) -> int:
    return s.c * s.w * s.a


def _count_elems(s: DBStats) -> int:
    # Alg 2: pattern up, one word per cloud down.
    return _pattern_elems(s) + s.c


def _fetch_elems(s: DBStats, ell: int, padded_rows: Optional[int]) -> int:
    # ℓ'×n one-hot matrix up, ℓ' tuples down (fetch_by_addresses).
    ellp = max(padded_rows or ell, ell)
    return s.c * ellp * s.n + s.c * ellp * s.m * s.w * s.a


def estimate_select_cost(strategy: str, stats: DBStats, *,
                         ell: int = DEFAULT_ELL,
                         padded_rows: Optional[int] = None) -> CostEstimate:
    """(bits, rounds) for one §3.2 strategy at cardinality ℓ."""
    s = stats
    if strategy == "one_tuple":
        if ell != 1:
            raise ValueError("one_tuple requires ℓ = 1")
        elems = _count_elems(s) + _pattern_elems(s) + s.c * s.m * s.w * s.a
        return CostEstimate("one_tuple", elems * WORD_BITS, rounds=2)
    if strategy == "one_round":
        elems = _pattern_elems(s) + s.c * s.n + _fetch_elems(s, ell,
                                                             padded_rows)
        return CostEstimate("one_round", elems * WORD_BITS, rounds=2)
    if strategy == "tree":
        if ell <= 1:
            # Alg 4 line 2: count, one whole-table Address_fetch, fetch.
            elems = (_count_elems(s) + _pattern_elems(s) + s.c
                     + _fetch_elems(s, max(ell, 1), padded_rows))
            return CostEstimate("tree", elems * WORD_BITS, rounds=3)
        qa_rounds = (math.floor(math.log(max(s.n, 2), ell))
                     + math.floor(math.log2(ell)) + 1)       # Theorem 4
        elems = (_count_elems(s) + _pattern_elems(s)
                 + qa_rounds * ell * s.c                     # block counts
                 + ell * s.c                                 # address fetches
                 + _fetch_elems(s, ell, padded_rows))
        return CostEstimate("tree", elems * WORD_BITS,
                            rounds=1 + qa_rounds + 1)
    raise ValueError(f"unknown selection strategy {strategy!r}")


def candidate_estimates(stats: DBStats, *, ell: Optional[int] = None,
                        padded_rows: Optional[int] = None
                        ) -> List[CostEstimate]:
    """All eligible strategies for cardinality hint ℓ (None = unknown)."""
    known_one = ell == 1
    ell_eff = DEFAULT_ELL if ell is None else max(ell, 1)
    out = []
    if known_one and not padded_rows:
        out.append(estimate_select_cost("one_tuple", stats, ell=1))
    for strat in ("one_round", "tree"):
        out.append(estimate_select_cost(strat, stats, ell=ell_eff,
                                        padded_rows=padded_rows))
    return out


def choose_select_strategy(stats: DBStats, *, ell: Optional[int] = None,
                           padded_rows: Optional[int] = None,
                           round_cost_bits: int = 0,
                           group_sizes: Optional[Mapping[str, int]] = None,
                           group_rounds: Optional[Mapping[str, int]] = None
                           ) -> CostEstimate:
    """Pick the paper-optimal strategy: min bits, rounds as tie-break
    (price a round via ``round_cost_bits`` to trade bandwidth for latency).

    ``group_sizes`` makes the choice *batching-aware*: it maps strategy name
    to the number of batch-mates already executing that strategy in the
    current ``run_batch``. The batched round engine fuses a group's protocol
    rounds into one dispatch/interpolation each, so a query that joins a
    non-empty group pays its bits but rides the group's existing rounds for
    free — its **marginal** round cost is only the depth it adds beyond the
    group's deepest member (``group_rounds``: strategy -> estimated rounds
    of that deepest member; without it a non-empty group is assumed at
    least as deep as the rider). With ``round_cost_bits > 0`` that steers a
    borderline query onto the strategy of an already-running (typically the
    larger) group whenever riding its fused rounds is cheaper than opening
    a new round chain. With the default pricing (``round_cost_bits = 0``)
    rounds never enter the score, so the choice — and therefore every
    row/ledger — is identical to sequential planning.
    """
    cands = candidate_estimates(stats, ell=ell, padded_rows=padded_rows)

    def key(e: CostEstimate):
        riding = bool(group_sizes) and group_sizes.get(e.strategy, 0) > 0
        if riding:
            depth = (group_rounds or {}).get(e.strategy)
            marginal_rounds = (0 if depth is None
                               else max(0, e.rounds - depth))
        else:
            marginal_rounds = e.rounds
        return (e.bits + round_cost_bits * marginal_rounds, e.rounds)

    return min(cands, key=key)


def estimate_batch_group_cost(stats: DBStats, strategy: str, *,
                              ells: Sequence[Optional[int]],
                              padded_rows: Optional[int] = None
                              ) -> CostEstimate:
    """Price a whole ``run_batch`` group: bits add up query by query, but
    the lockstep engine pays each protocol round once for the group, so the
    group's round count is its deepest member's (not the sum). This is the
    per-group ledger shape ``tests/test_batch.py`` asserts, exposed as a
    planner-side estimate."""
    ests = [estimate_select_cost(
        strategy, stats, ell=DEFAULT_ELL if e is None else max(e, 1),
        padded_rows=padded_rows) for e in ells]
    return CostEstimate(strategy,
                        bits=sum(e.bits for e in ests),
                        rounds=max((e.rounds for e in ests), default=0))
