"""MapReduce execution of the oblivious map phase (paper title, delivered).

:class:`MapReduceExecutor` wraps any registered :class:`~.backends.Backend`
so each cloud-side hotspot fans out over input splits driven by the
fault-tolerant :class:`repro.runtime.MapReduceRunner` — re-execution of lost
tasks and speculative straggler backups included. Because share-space map
tasks are pure (no side effects), duplicate execution is safe, exactly the
property the original MapReduce fault model relies on.

The split axis is always a *data* axis (tuples / fetch rows), never the
cloud axis, so the non-communication property is preserved: a worker only
ever sees whole share-columns of its slice. Results are bit-identical to the
unsplit backend because every op is elementwise or a row-block of a matmul.

Two composable roles:

  * :meth:`MapReduceExecutor.wrap` — the historical *backend* wrapper:
    every hot op splits its own data axis into ``n_splits`` runner tasks.
  * :class:`MapReduceDispatcher` — the executor as a *placement policy* of
    the sharded dataplane (``repro.core.dataplane``): the round engine
    already emitted one dispatch per tuple-axis shard; the dispatcher
    places each shard dispatch as one fault-tolerant MapReduce task
    instead of running it inline. ``MapReduceExecutor.dispatcher()``
    builds one over the executor's runner.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from ..core.dataplane import Dispatcher
from ..core.partition import split_bounds
from ..runtime.mapreduce import MapReduceRunner
from .backends import Backend


class MapReduceDispatcher(Dispatcher):
    """Run each shard dispatch as one MapReduce task (re-execution and
    speculative straggler backups included — shard dispatches are pure
    share-space programs, so duplicate execution is safe)."""

    def __init__(self, runner: MapReduceRunner):
        self.runner = runner

    def run_all(self, thunks: Sequence[Callable[[], Any]]) -> List[Any]:
        if len(thunks) <= 1:
            return [t() for t in thunks]
        return self.runner.run(lambda t: t(), list(thunks))


def _bounds(total: int, n_splits: int) -> List[Tuple[int, int]]:
    """Non-empty, contiguous [lo, hi) split bounds covering [0, total)."""
    return split_bounds(0, total, n_splits)


@dataclasses.dataclass
class MapReduceExecutor:
    """Fan a backend's map phase out over ``runner`` with ``n_splits``."""
    runner: MapReduceRunner
    n_splits: int = 4

    def dispatcher(self) -> MapReduceDispatcher:
        """This executor as a dataplane placement policy: one shard
        dispatch = one fault-tolerant MapReduce task."""
        return MapReduceDispatcher(self.runner)

    def wrap(self, base: Backend) -> Backend:
        def aa_match(col, pat):
            # col: (c, n, W, A) — split the tuple axis.
            if col.shape[1] == 0:
                return base.aa_match(col, pat)
            splits = _bounds(col.shape[1], self.n_splits)
            parts = self.runner.run(
                lambda s: np.asarray(base.aa_match(col[:, s[0]:s[1]], pat)),
                splits)
            return jnp.concatenate([jnp.asarray(p) for p in parts], axis=1)

        def ss_matmul(a, b):
            # a: ([c,] M, K) — split the output-row axis M. A zero-row
            # matrix (fully-padded / empty fetch) runs unsplit.
            row_axis = a.ndim - 2
            if a.shape[row_axis] == 0:
                return base.ss_matmul(a, b)
            splits = _bounds(a.shape[row_axis], self.n_splits)

            def one(s):
                sl = [slice(None)] * a.ndim
                sl[row_axis] = slice(s[0], s[1])
                return np.asarray(base.ss_matmul(a[tuple(sl)], b))
            parts = self.runner.run(one, splits)
            return jnp.concatenate([jnp.asarray(p) for p in parts],
                                   axis=row_axis)

        def match_matrix(bx, by):
            # bx: (c, nx, W, A) — split the left-tuple axis.
            if bx.shape[1] == 0:
                return base.match_matrix(bx, by)
            splits = _bounds(bx.shape[1], self.n_splits)
            parts = self.runner.run(
                lambda s: np.asarray(
                    base.match_matrix(bx[:, s[0]:s[1]], by)),
                splits)
            return jnp.concatenate([jnp.asarray(p) for p in parts], axis=1)

        from .backends import (batched_match_matrix, batched_matcher,
                               ripple_segmenter, ripple_stepper,
                               slide_matcher)
        base_batch = batched_matcher(base)
        base_ripple = ripple_stepper(base)
        base_mm_batch = batched_match_matrix(base)
        base_segment = ripple_segmenter(base)
        base_slide = slide_matcher(base)

        def ripple_carry(a, b, carry=None):
            # a: (c, S, n) bit planes — split the tuple axis (last), like
            # every other map phase; the stacked-query axis stays fused in
            # each task. Both outputs (result bit, carry) concatenate back.
            if a.shape[-1] == 0:
                return base_ripple(a, b, carry)
            splits = _bounds(a.shape[-1], self.n_splits)

            def one(s):
                sl = (Ellipsis, slice(s[0], s[1]))
                rb, co = base_ripple(a[sl], b[sl],
                                     None if carry is None else carry[sl])
                return np.asarray(rb), np.asarray(co)
            parts = self.runner.run(one, splits)
            return (jnp.concatenate([jnp.asarray(p[0]) for p in parts],
                                    axis=-1),
                    jnp.concatenate([jnp.asarray(p[1]) for p in parts],
                                    axis=-1))

        def aa_match_batch(col, pat):
            # col: (c, B, n, W, A) — one fused dispatch per protocol round
            # for B stacked predicates. Split the *tuple* axis (a data axis,
            # like aa_match) so each map task still sees every predicate but
            # only a slice of the relation; the batch axis stays fused inside
            # each task.
            if col.shape[2] == 0 or col.shape[1] == 0:
                return base_batch(col, pat)
            splits = _bounds(col.shape[2], self.n_splits)
            parts = self.runner.run(
                lambda s: np.asarray(base_batch(col[:, :, s[0]:s[1]], pat)),
                splits)
            return jnp.concatenate([jnp.asarray(p) for p in parts], axis=2)

        def aa_slide_batch(col, pat):
            # col: (c, B, n, W, A) — same tuple-axis split as
            # aa_match_batch; every map task sees the whole pattern-tile
            # stack but only a slice of the relation, and the (c, B, n_s,
            # M) window products concatenate back along tuples.
            if col.shape[2] == 0 or col.shape[1] == 0:
                return base_slide(col, pat)
            splits = _bounds(col.shape[2], self.n_splits)
            parts = self.runner.run(
                lambda s: np.asarray(base_slide(col[:, :, s[0]:s[1]], pat)),
                splits)
            return jnp.concatenate([jnp.asarray(p) for p in parts], axis=2)

        def ripple_segment(a, b, carry=None):
            # a: (..., n, k) bit planes — the tuple axis is second-to-last
            # (the last axis is the fused bit-position run). Split tuples;
            # the whole segment chains inside each map task.
            if a.shape[-2] == 0:
                return base_segment(a, b, carry)
            splits = _bounds(a.shape[-2], self.n_splits)

            def one(s):
                sl = (Ellipsis, slice(s[0], s[1]), slice(None))
                cl = (Ellipsis, slice(s[0], s[1]))
                rb, co = base_segment(a[sl], b[sl],
                                      None if carry is None else carry[cl])
                return np.asarray(rb), np.asarray(co)
            parts = self.runner.run(one, splits)
            return (jnp.concatenate([jnp.asarray(p[0]) for p in parts],
                                    axis=-1),
                    jnp.concatenate([jnp.asarray(p[1]) for p in parts],
                                    axis=-1))

        def match_matrix_batch(bx, by):
            # bx: (c, B, nx, W, A) — split the left-tuple axis; the join
            # group's B column pairs stay fused inside each task.
            if bx.shape[2] == 0 or bx.shape[1] == 0:
                return base_mm_batch(bx, by)
            splits = _bounds(bx.shape[2], self.n_splits)
            parts = self.runner.run(
                lambda s: np.asarray(
                    base_mm_batch(bx[:, :, s[0]:s[1]], by)), splits)
            return jnp.concatenate([jnp.asarray(p) for p in parts], axis=2)

        return Backend(name=f"{base.name}+mapreduce", aa_match=aa_match,
                       ss_matmul=ss_matmul, match_matrix=match_matrix,
                       aa_match_batch=aa_match_batch,
                       ripple_carry=ripple_carry,
                       ripple_segment=ripple_segment,
                       match_matrix_batch=match_matrix_batch,
                       aa_slide_batch=aa_slide_batch)
