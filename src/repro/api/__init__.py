"""repro.api — the stable, composable client surface for the query suite.

OBSCURE-style framing: the user holds a :class:`QueryClient` over the
secret-shared clouds; queries are *logical plans* (``Count``, ``Select``,
``RangeCount``, ``RangeSelect``, ``Join``) with columns by name, predicate
objects and an explicit padding policy; a cost-based planner picks the
paper-optimal selection strategy; backends are looked up in a registry
(``jnp``, ``pallas``, or anything registered); and every query returns one
:class:`QueryResult`.

    from repro.api import QueryClient, Eq, Select
    client = QueryClient(db, key=7, backend="jnp")
    res = client.select("FirstName", "John")      # planner picks strategy
    res.rows, res.count, res.ledger, res.strategy

The legacy free functions in ``repro.core.queries`` remain as thin
deprecated wrappers; new code should go through this package.
"""
from ..core.dataplane import (Dispatcher, PoolHandle, ShardedRelation,
                              ThreadedDispatcher)
from ..core.encoding import PatternSpec, parse_like
from ..core.mesh_dispatch import MeshDispatcher
from ..core.queries import VerificationError
from .backends import (Backend, available_backends, batched_match_matrix,
                       batched_matcher, get_backend, register_backend,
                       ripple_segmenter, ripple_stepper, slide_matcher)
from .client import DEFAULT_RELATION, AttachedRelation, QueryClient
from .executor import MapReduceDispatcher, MapReduceExecutor
from .planner import (DEFAULT_ELL, BatchExplanation, CostEstimate, DBStats,
                      GroupEstimate, PlanNotSupported, candidate_estimates,
                      candidate_pattern_estimates, choose_match_method,
                      choose_pattern_strategy, choose_select_strategy,
                      estimate_aggregate_cost, estimate_batch_group_cost,
                      estimate_count_cost, estimate_embed_cost,
                      estimate_equijoin_cost, estimate_match_method_launches,
                      estimate_pattern_cost, estimate_pkfk_cost,
                      estimate_range_cost, estimate_select_cost,
                      explain_batch_groups)
from .plans import (AUTO, Aggregate, Between, ColumnRef, Contains, Count,
                    EmbedLookup, Eq, Join, Like, Padding, Plan, Prefix,
                    QueryResult, RangeCount, RangeSelect, Select, Suffix,
                    resolve_column)

__all__ = [
    "Backend", "available_backends", "batched_matcher",
    "batched_match_matrix", "get_backend", "register_backend",
    "ripple_segmenter", "ripple_stepper", "slide_matcher", "QueryClient",
    "DEFAULT_RELATION", "AttachedRelation",
    "MapReduceDispatcher", "MapReduceExecutor", "MeshDispatcher",
    "Dispatcher", "PoolHandle", "ShardedRelation", "ThreadedDispatcher",
    "DEFAULT_ELL", "BatchExplanation", "CostEstimate", "DBStats",
    "GroupEstimate", "PlanNotSupported", "candidate_estimates",
    "candidate_pattern_estimates", "choose_match_method",
    "choose_pattern_strategy", "choose_select_strategy",
    "estimate_aggregate_cost", "estimate_batch_group_cost",
    "estimate_count_cost", "estimate_embed_cost", "estimate_equijoin_cost",
    "estimate_match_method_launches", "estimate_pattern_cost",
    "estimate_pkfk_cost", "estimate_range_cost", "estimate_select_cost",
    "explain_batch_groups",
    "AUTO", "Aggregate", "Between", "ColumnRef", "Contains", "Count",
    "EmbedLookup", "Eq", "Join", "Like", "Padding", "PatternSpec", "Plan",
    "Prefix", "QueryResult", "RangeCount", "RangeSelect", "Select",
    "Suffix", "VerificationError", "parse_like", "resolve_column",
]
