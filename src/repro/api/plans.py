"""Logical query plans — the user-facing half of the OBSCURE-style API.

A plan is a small frozen dataclass describing *what* to compute (predicate,
columns by name, padding policy); the :class:`repro.api.QueryClient` decides
*how* (strategy, backend, keys) and returns a uniform :class:`QueryResult`.
Plans never touch shares: they are plain data, cheap to build, hash and log.

Padding is explicit because it is a security knob, not a tuning knob: the
paper's output-size attack (§3.2.2 / §3.3.2 leakage discussion) is defeated
by fetching ``Padding.rows`` fake rows (selection) or running
``Padding.values`` fake join jobs (equijoin) so the clouds cannot learn the
true result size ℓ.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple, Union

from ..core.costs import CostLedger
from ..core.engine import SecretSharedDB

ColumnRef = Union[str, int]

AUTO = "auto"
SELECT_STRATEGIES = ("one_tuple", "one_round", "tree")
JOIN_KINDS = ("pkfk", "equi")
AGG_OPS = ("sum", "avg", "min", "max")


def resolve_column(db: SecretSharedDB, column: ColumnRef) -> int:
    """Name-or-index -> validated column index of ``db``."""
    names = list(db.column_names)
    if isinstance(column, int):
        if not 0 <= column < db.n_attrs:
            raise IndexError(f"column index {column} out of range "
                             f"(relation has {db.n_attrs} attributes)")
        return column
    try:
        return names.index(column)
    except ValueError:
        raise KeyError(f"unknown column {column!r}; relation has "
                       f"{names}") from None


# ---------------------------------------------------------------------------
# predicates
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Eq:
    """Equality predicate: ``column = pattern`` (exact word, §3.1.2)."""
    column: ColumnRef
    pattern: str


@dataclasses.dataclass(frozen=True)
class Between:
    """Inclusive range predicate: ``lo <= column <= hi`` (§3.4)."""
    column: ColumnRef
    lo: int
    hi: int

    def __post_init__(self):
        if self.lo > self.hi:
            raise ValueError(f"empty range: lo={self.lo} > hi={self.hi}")


@dataclasses.dataclass(frozen=True)
class Like:
    """SQL-ish pattern predicate: ``column LIKE pattern`` (§3.1 general AA).

    ``%`` matches any run of characters at either end of the pattern
    (``lit%`` / ``%lit`` / ``%lit%``); ``_`` matches any ONE symbol —
    including the pad terminator, so ``_`` is a don't-care, not a length
    constraint (documented deviation from SQL). A wildcard-free pattern
    lowers to the exact-match :class:`Eq` path; interior ``%`` runs and
    ``_`` under a ``%``-shifted window raise ``PlanNotSupported``.
    """
    column: ColumnRef
    pattern: str


@dataclasses.dataclass(frozen=True)
class Prefix:
    """Prefix predicate: ``column`` starts with ``literal`` (verbatim —
    no wildcard characters; use :class:`Like` for ``_`` don't-cares).
    Lowers to a truncated k-position AA chain."""
    column: ColumnRef
    literal: str


@dataclasses.dataclass(frozen=True)
class Suffix:
    """Suffix predicate: ``column`` ends with ``literal`` (verbatim).
    Lowers to the sliding-window automata step with a terminator factor."""
    column: ColumnRef
    literal: str


@dataclasses.dataclass(frozen=True)
class Contains:
    """Substring predicate: ``column`` contains ``literal`` (verbatim).
    Lowers to the sliding-window automata step + a degree-reduction
    re-share + the window-count zero-test."""
    column: ColumnRef
    literal: str


#: predicate classes the pattern engine lowers (besides plain Eq).
PATTERN_PREDICATES = (Like, Prefix, Suffix, Contains)
#: every predicate class Count/Select accept.
MATCH_PREDICATES = (Eq,) + PATTERN_PREDICATES


# ---------------------------------------------------------------------------
# padding policy
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Padding:
    """Output-size-attack resistance policy.

    rows:   pad the oblivious fetch to this many rows (≥ true ℓ); the extra
            rows carry all-zero one-hots and fetch nothing.
    values: number of fake (no-op) equijoin jobs, hiding the number of
            common join values k.
    """
    rows: Optional[int] = None
    values: int = 0

    def __post_init__(self):
        if self.rows is not None and self.rows < 0:
            raise ValueError("Padding.rows must be >= 0")
        if self.values < 0:
            raise ValueError("Padding.values must be >= 0")

    @classmethod
    def to_rows(cls, rows: int) -> "Padding":
        return cls(rows=rows)

    @classmethod
    def fake_values(cls, values: int) -> "Padding":
        return cls(values=values)


Padding.NONE = Padding()


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------

class Plan:
    """Marker base class for logical plans."""


@dataclasses.dataclass(frozen=True)
class Count(Plan):
    """COUNT(*) WHERE <predicate> (§3.1, Algorithm 2).

    ``where`` is :class:`Eq` or any pattern predicate
    (:class:`Like`/:class:`Prefix`/:class:`Suffix`/:class:`Contains`);
    unknown predicate types raise ``PlanNotSupported`` at plan time.
    """
    where: Union[Eq, Like, Prefix, Suffix, Contains]


@dataclasses.dataclass(frozen=True)
class Select(Plan):
    """SELECT * WHERE col = pattern (§3.2, Algorithms 3 & 4).

    strategy: ``"auto"`` lets the cost-based planner pick among the paper's
    three algorithms using the §3.2 bit/round formulas; or force one of
    ``"one_tuple" | "one_round" | "tree"``. ``expected_matches`` is the
    planner's cardinality hint (ℓ); ``one_tuple`` is only eligible when the
    hint says ℓ = 1 (the algorithm itself verifies and raises otherwise).

    ``where`` may also be a pattern predicate (Like/Prefix/Suffix/Contains);
    pattern selects run ``one_round`` or ``tree`` (``one_tuple`` is the
    §3.2.1 exact-equality special case).
    """
    where: Union[Eq, Like, Prefix, Suffix, Contains]
    strategy: str = AUTO
    expected_matches: Optional[int] = None
    padding: Padding = Padding.NONE
    branching: Optional[int] = None     # tree fan-out override

    def __post_init__(self):
        if self.strategy not in (AUTO,) + SELECT_STRATEGIES:
            raise ValueError(f"unknown strategy {self.strategy!r}; choose "
                             f"from {(AUTO,) + SELECT_STRATEGIES}")
        if self.expected_matches is not None and self.expected_matches < 0:
            raise ValueError("expected_matches must be >= 0")
        if self.padding.values:
            raise ValueError("selection hides the result size with "
                             "Padding.rows (fake fetch rows); "
                             "Padding.fake_values applies to equijoins")


@dataclasses.dataclass(frozen=True)
class RangeCount(Plan):
    """COUNT(*) WHERE lo <= col <= hi (§3.4, Algorithm 5).

    reduce_every > 0 inserts the paper's degree-reduction (re-sharing) round
    every that many SS-SUB bit positions, trading rounds for cloud count.
    """
    where: Between
    reduce_every: int = 0


@dataclasses.dataclass(frozen=True)
class RangeSelect(Plan):
    """Fetch all tuples with col in [lo, hi] (§3.4 + §3.2 fetch)."""
    where: Between
    reduce_every: int = 0
    padding: Padding = Padding.NONE

    def __post_init__(self):
        if self.padding.values:
            raise ValueError("selection hides the result size with "
                             "Padding.rows (fake fetch rows); "
                             "Padding.fake_values applies to equijoins")


@dataclasses.dataclass(frozen=True)
class Join(Plan):
    """Oblivious join of the client's relation with ``right`` (§3.3).

    on:   (left column, right column) — names or indices.
    kind: ``"pkfk"`` (§3.3.1, left column is a primary key) or ``"equi"``
          (§3.3.2, join values may repeat on both sides).
    match_method: how the PK/FK match matrix is evaluated — ``"chain"``
          (W sequential dot-sets, §3.1.2), ``"aggregate"`` (ONE flattened
          W·A dot + the Lagrange equality indicator, §3.1.2 aggregate
          form) or ``"auto"`` (planner-priced). Both produce the same
          secrets at the same degree; the choice is a backend-execution
          knob the planner prices by launch count. Defaults to ``"chain"``
          (the paper's dispatch shape — one ``match_matrix`` per group);
          pass ``"auto"`` to let the planner pick the cheaper launch plan.
    """
    right: SecretSharedDB
    on: Tuple[ColumnRef, ColumnRef]
    kind: str = "pkfk"
    padding: Padding = Padding.NONE
    match_method: str = "chain"

    def __post_init__(self):
        if self.kind not in JOIN_KINDS:
            raise ValueError(f"unknown join kind {self.kind!r}; choose from "
                             f"{JOIN_KINDS}")
        if len(self.on) != 2:
            raise ValueError("Join.on must be a (left, right) column pair")
        if self.match_method not in (AUTO, "chain", "aggregate"):
            raise ValueError(
                f"unknown match_method {self.match_method!r}; choose from "
                f"('auto', 'chain', 'aggregate')")


@dataclasses.dataclass(frozen=True)
class Aggregate(Plan):
    """SUM/AVG/MIN/MAX(column) [WHERE col = pattern] (OBSCURE-style).

    column: the numeric value column (must have been outsourced in binary
            form via ``numeric_columns``).
    where:  optional equality predicate restricting the aggregate to the
            matching tuples (None = whole relation).
    verify: run the OBSCURE-style consistency round on every opened
            aggregate tensor and raise ``VerificationError`` if a cloud's
            response share is inconsistent. Needs c >= degree + 2 clouds;
            the extra round/bits are priced in ``explain()``.
    reduce_every: MIN/MAX only — insert a degree-reduction round every
            this many comparator bit positions (same knob as range plans).
    """
    op: str
    column: ColumnRef
    where: Optional[Eq] = None
    verify: bool = False
    reduce_every: int = 0

    def __post_init__(self):
        if self.op not in AGG_OPS:
            raise ValueError(f"unknown aggregate op {self.op!r}; choose "
                             f"from {AGG_OPS}")
        if self.reduce_every < 0:
            raise ValueError("reduce_every must be >= 0")
        if self.reduce_every and self.op in ("sum", "avg"):
            raise ValueError("reduce_every is a MIN/MAX comparator knob; "
                             "SUM/AVG run in one contraction round")


@dataclasses.dataclass(frozen=True)
class EmbedLookup(Plan):
    """Oblivious embedding lookup of a step's token ids (§3.2.1 as an LM
    layer; the embedding-table relation is attached via
    ``models.private_embed.as_embed_relation``).

    tokens: the step's token ids (batch×seq, flattened to a tuple — plans
            are plain hashable data; the result keeps the flat order).
    verify: OBSCURE-style consistency round over the opened embeddings
            (needs c >= degree+3 clouds); priced in ``explain()``.
    """
    tokens: Tuple[int, ...]
    verify: bool = False

    def __post_init__(self):
        object.__setattr__(self, "tokens",
                           tuple(int(t) for t in self.tokens))
        if not self.tokens:
            raise ValueError("EmbedLookup needs at least one token id")
        if min(self.tokens) < 0:
            raise ValueError("token ids must be >= 0")


# ---------------------------------------------------------------------------
# result
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Uniform result for every query family.

    rows/addresses are None for pure counting queries; count is the number
    of satisfying tuples whenever it is known. ``strategy`` echoes the
    executed algorithm (planner-chosen or forced) and ``plan`` echoes the
    logical plan for logging/replay. ``value`` carries an aggregation
    plan's opened scalar (int for SUM/MIN/MAX, float for AVG; None when a
    conditional MIN/MAX/AVG matched no tuples). ``embeddings`` carries an
    ``EmbedLookup``'s opened float32 ``(n_tokens, D)`` matrix.
    """
    plan: Plan
    ledger: CostLedger
    strategy: str
    rows: Optional[List[List[str]]] = None
    count: Optional[int] = None
    addresses: Optional[List[int]] = None
    value: Optional[float] = None
    embeddings: Optional[object] = None     # np.ndarray; typed loosely to
    #                                         keep plans free of numpy

    def __post_init__(self):
        if self.count is None and self.rows is not None:
            object.__setattr__(self, "count", len(self.rows))
