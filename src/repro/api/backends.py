"""Backend registry: named implementations of the cloud-side hot ops.

Replaces the ad-hoc ``impl="jnp"|"pallas"`` strings that used to be threaded
through every query function. A :class:`Backend` bundles the share-space
hotspots every query is built from:

  * ``aa_match``       — accumulating-automata word match (§3.1, Table 3),
  * ``ss_matmul``      — share-space mod-p matmul (the oblivious-fetch and
                         embedding-lookup hotspot),
  * ``match_matrix``   — all-pairs word match (the §3.3.1 join inner loop),
  * ``aa_match_batch`` — AA match over a *stack* of predicates, one per
                         batch row. This is the primitive the batched query
                         engine (``repro.core.queries.rounds``) issues once
                         per protocol round: B concurrent queries (or B
                         padded blocks of one tree-selection round) become a
                         single device dispatch instead of B.
  * ``ripple_carry``   — one bit position of the §3.4 SS-SUB ripple
                         (Algorithm 6) over a *stack* of subtractions:
                         given the bit-i share planes of A and B and the
                         incoming carry (``None`` selects the LSB
                         two's-complement step), returns ``(rb, carry')``.
                         The batched range engine issues it once per
                         bit-round for the whole query batch.

All operate on *raw* uint32 share arrays (cloud axis first where batched);
polynomial-degree bookkeeping stays at the query layer. Queries resolve a
backend by name via :func:`get_backend`; ``repro.api.QueryClient`` exposes
the choice as a constructor argument. Third parties can plug in alternatives
(a GPU kernel set, a distributed runner) with :func:`register_backend` — see
``repro.api.executor.MapReduceExecutor`` for a wrapping backend that fans
the map phase (including the fused batch) out over MapReduce splits. A
backend that omits ``aa_match_batch`` still works: :func:`batched_matcher`
falls back to ``vmap`` over its ``aa_match`` when that is traceable.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Array = jax.Array
_Op = Callable[[Array, Array], Array]
_RippleOp = Callable[[Array, Array, Optional[Array]], Tuple[Array, Array]]


@dataclasses.dataclass(frozen=True)
class Backend:
    """Named bundle of cloud-side primitives on raw uint32 share arrays.

    aa_match:       (c, n, W, A), (c, W, A)       -> (c, n)
    ss_matmul:      ([c,] M, K),  ([c,] K, N)     -> ([c,] M, N)
    match_matrix:   (c, nx, W, A), (c, ny, W, A)  -> (c, nx, ny)
    aa_match_batch: (c, B, n, W, A), (c, B, W, A) -> (c, B, n)
    ripple_carry:   (c, S, n), (c, S, n), carry|None -> (rb, carry')
    """
    name: str
    aa_match: _Op
    ss_matmul: _Op
    match_matrix: _Op
    aa_match_batch: Optional[_Op] = None
    ripple_carry: Optional[_RippleOp] = None


def batched_matcher(backend: Backend) -> _Op:
    """The backend's batched AA match, or a vmap fallback over ``aa_match``.

    The fallback covers third-party backends whose ``aa_match`` is a
    traceable jax function; backends built from host-side callables (e.g.
    the MapReduce executor wrapper) must provide ``aa_match_batch``.
    """
    if backend.aa_match_batch is not None:
        return backend.aa_match_batch
    return jax.vmap(backend.aa_match, in_axes=1, out_axes=1)


def ripple_stepper(backend: Backend) -> _RippleOp:
    """The backend's SS-SUB bit step, or the reference jnp implementation.

    Unlike the matcher there is no per-backend shape contract to adapt —
    the step is elementwise share arithmetic — so any backend without its
    own fused kernel transparently gets the jnp one.
    """
    if backend.ripple_carry is not None:
        return backend.ripple_carry
    return jnp_ripple_carry


def _make_jnp_ripple():
    """Reference fused ripple step (Algorithm 6 lines 1-4, one bit)."""
    from ..core import field

    @jax.jit
    def _init(a, b):
        # LSB handles the +1 of two's complement: carry = OR(1−a, b)
        ai = field.sub(jnp.ones_like(a), a)
        ab = field.mul(ai, b)
        s = field.add(ai, b)
        carry = field.sub(s, ab)
        rb = field.sub(s, field.add(carry, carry))
        return rb, carry

    @jax.jit
    def _step(a, b, carry):
        ai = field.sub(jnp.ones_like(a), a)
        ab = field.mul(ai, b)
        x = field.sub(field.add(ai, b), field.add(ab, ab))   # ai ⊕ b
        cx = field.mul(carry, x)
        new_carry = field.add(ab, cx)
        rb = field.sub(field.add(x, carry), field.add(cx, cx))
        return rb, new_carry

    def ripple_carry(a, b, carry=None):
        return _init(a, b) if carry is None else _step(a, b, carry)

    return ripple_carry


jnp_ripple_carry: _RippleOp = _make_jnp_ripple()


_REGISTRY: Dict[str, Backend] = {}

BackendLike = Union[str, Backend]


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(backend: BackendLike) -> Backend:
    """Resolve a backend name (a ``Backend`` instance passes through)."""
    if isinstance(backend, Backend):
        return backend
    _ensure_builtins()
    if backend == "pallas" and not _try_register_pallas():
        raise ValueError("backend 'pallas' is unavailable: the Pallas "
                         "kernel import failed on this jax build")
    try:
        return _REGISTRY[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; available: "
                         f"{available_backends()}") from None


def available_backends() -> Tuple[str, ...]:
    _ensure_builtins()
    _try_register_pallas()
    return tuple(sorted(_REGISTRY))


def _ensure_builtins() -> None:
    """Register the pure-jnp backend (import-cycle safe, no kernel deps)."""
    if "jnp" in _REGISTRY:
        return
    from ..core import automata, field
    from ..core.shamir import Shares

    def _raw(op):                       # Shares-level op -> raw-array op
        def run(a: Array, b: Array) -> Array:
            return op(Shares(a, 0), Shares(b, 0)).values
        return run

    aa_match = _raw(automata.match_words)

    register_backend(Backend(
        "jnp",
        aa_match=aa_match,
        ss_matmul=field.matmul,
        match_matrix=_raw(automata.match_matrix),
        aa_match_batch=jax.jit(jax.vmap(aa_match, in_axes=1, out_axes=1)),
        ripple_carry=jnp_ripple_carry))


def _try_register_pallas() -> bool:
    """Register the Pallas kernels on first request; the pure-jnp query
    suite must keep working on builds where the kernel import fails."""
    if "pallas" in _REGISTRY:
        return True
    try:
        from ..kernels import ops as kops
    except ImportError:
        return False
    register_backend(kops.as_backend())
    return True
