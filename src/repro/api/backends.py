"""Backend registry: named implementations of the cloud-side hot ops.

Replaces the ad-hoc ``impl="jnp"|"pallas"`` strings that used to be threaded
through every query function. A :class:`Backend` bundles the share-space
hotspots every query is built from:

  * ``aa_match``       — accumulating-automata word match (§3.1, Table 3),
  * ``ss_matmul``      — share-space mod-p matmul (the oblivious-fetch and
                         embedding-lookup hotspot),
  * ``match_matrix``   — all-pairs word match (the §3.3.1 join inner loop),
  * ``aa_match_batch`` — AA match over a *stack* of predicates, one per
                         batch row. This is the primitive the batched query
                         engine (``repro.core.queries.rounds``) issues once
                         per protocol round: B concurrent queries (or B
                         padded blocks of one tree-selection round) become a
                         single device dispatch instead of B.
  * ``ripple_carry``   — one bit position of the §3.4 SS-SUB ripple
                         (Algorithm 6) over a *stack* of subtractions:
                         given the bit-i share planes of A and B and the
                         incoming carry (``None`` selects the LSB
                         two's-complement step), returns ``(rb, carry')``.
                         The batched range engine issues it once per
                         bit-round for the whole query batch.
  * ``ripple_segment`` — k consecutive SS-SUB bit positions fused into one
                         dispatch: given ``(…, n, k)`` bit planes of A and
                         B and the incoming carry (``None`` = the chain
                         starts at the LSB step), returns the *final*
                         ``(rb, carry')`` after k steps. The range engine
                         issues one segment per degree-reduction boundary
                         (≈ t_bits/reduce_every dispatches) instead of one
                         ``ripple_carry`` per bit.
  * ``match_matrix_batch`` — all-pairs match over a stack of B column
                         pairs, ``(c, B, nx, W, A) × (c, B, ny, W, A) ->
                         (c, B, nx, ny)``: a join group's equal-size right
                         relations become ONE dispatch, mirroring what
                         ``aa_match_batch`` does for predicates.
  * ``aa_slide_batch`` — the sliding-window automata step over a stack of
                         B pattern tiles, ``(c, B, n, W, A) × (c, B, k, A)
                         -> (c, B, n, M)`` with M = W−k+1 raw window-chain
                         products: one dispatch per protocol round for a
                         whole group of suffix/substring predicates. The
                         suffix terminator factor and the CONTAINS window
                         count are linear post-processing at the round
                         engine, so one dispatch serves both kinds.

All operate on *raw* uint32 share arrays (cloud axis first where batched);
polynomial-degree bookkeeping stays at the query layer. Queries resolve a
backend by name via :func:`get_backend`; ``repro.api.QueryClient`` exposes
the choice as a constructor argument. Third parties can plug in alternatives
(a GPU kernel set, a distributed runner) with :func:`register_backend` — see
``repro.api.executor.MapReduceExecutor`` for a wrapping backend that fans
the map phase (including the fused batch) out over MapReduce splits. A
backend that omits ``aa_match_batch`` still works: :func:`batched_matcher`
falls back to ``vmap`` over its ``aa_match`` when that is traceable.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple, Union

import jax
import jax.numpy as jnp

Array = jax.Array
_Op = Callable[[Array, Array], Array]
_RippleOp = Callable[[Array, Array, Optional[Array]], Tuple[Array, Array]]


@dataclasses.dataclass(frozen=True)
class Backend:
    """Named bundle of cloud-side primitives on raw uint32 share arrays.

    aa_match:       (c, n, W, A), (c, W, A)       -> (c, n)
    ss_matmul:      ([c,] M, K),  ([c,] K, N)     -> ([c,] M, N)
    match_matrix:   (c, nx, W, A), (c, ny, W, A)  -> (c, nx, ny)
    aa_match_batch: (c, B, n, W, A), (c, B, W, A) -> (c, B, n)
    ripple_carry:   (c, S, n), (c, S, n), carry|None -> (rb, carry')
    ripple_segment: (c, S, n, k), (c, S, n, k), carry|None -> (rb, carry')
    match_matrix_batch: (c, B, nx, W, A), (c, B, ny, W, A) -> (c, B, nx, ny)
    aa_slide_batch: (c, B, n, W, A), (c, B, k, A) -> (c, B, n, W-k+1)
    share_onehot:   tokens (M,) int32, a1 (M, V), n_shares= -> (c, M, V)
                    fused one-hot share generation (embedding fast path);
                    None falls back to the jnp reference program.
    """
    name: str
    aa_match: _Op
    ss_matmul: _Op
    match_matrix: _Op
    aa_match_batch: Optional[_Op] = None
    ripple_carry: Optional[_RippleOp] = None
    ripple_segment: Optional[_RippleOp] = None
    match_matrix_batch: Optional[_Op] = None
    aa_slide_batch: Optional[_Op] = None
    share_onehot: Optional[Callable[..., Array]] = None


def batched_matcher(backend: Backend) -> _Op:
    """The backend's batched AA match, or a vmap fallback over ``aa_match``.

    The fallback covers third-party backends whose ``aa_match`` is a
    traceable jax function; backends built from host-side callables (e.g.
    the MapReduce executor wrapper) must provide ``aa_match_batch``.
    """
    if backend.aa_match_batch is not None:
        return backend.aa_match_batch
    return jax.vmap(backend.aa_match, in_axes=1, out_axes=1)


def ripple_stepper(backend: Backend) -> _RippleOp:
    """The backend's SS-SUB bit step, or the reference jnp implementation.

    Unlike the matcher there is no per-backend shape contract to adapt —
    the step is elementwise share arithmetic — so any backend without its
    own fused kernel transparently gets the jnp one.
    """
    if backend.ripple_carry is not None:
        return backend.ripple_carry
    return jnp_ripple_carry


def ripple_segmenter(backend: Backend) -> _RippleOp:
    """The backend's fused k-bit SS-SUB segment, or a per-bit fallback.

    The fallback steps the backend's own ``ripple_carry`` once per bit
    position — bit-identical output (the fused kernel runs the same six
    mod-p ops per lane), just k dispatches instead of one — so third-party
    backends keep working and counting/test backends still observe the
    per-bit op stream.
    """
    if backend.ripple_segment is not None:
        return backend.ripple_segment
    step = ripple_stepper(backend)

    def segment(a: Array, b: Array, carry: Optional[Array] = None):
        rb = None
        for i in range(a.shape[-1]):
            rb, carry = step(a[..., i], b[..., i], carry)
        return rb, carry

    return segment


def batched_match_matrix(backend: Backend) -> _Op:
    """The backend's stacked all-pairs matcher, or a vmap fallback.

    As with :func:`batched_matcher`, backends built from host-side
    callables (the MapReduce executor wrapper) must provide the batched op
    themselves; any traceable ``match_matrix`` gets the vmap for free.
    """
    if backend.match_matrix_batch is not None:
        return backend.match_matrix_batch
    return jax.vmap(backend.match_matrix, in_axes=1, out_axes=1)


def slide_matcher(backend: Backend) -> _Op:
    """The backend's batched sliding-window matcher, or the jnp reference.

    As with :func:`ripple_stepper`, the fallback is backend-agnostic: the
    op is pure share arithmetic on raw arrays, so any backend without its
    own fused kernel transparently gets the reference program.
    """
    if backend.aa_slide_batch is not None:
        return backend.aa_slide_batch
    return jnp_aa_slide


def aggregate_match_matrix(backend: Backend) -> _Op:
    """Batched all-pairs matcher in the AGGREGATE form (§3.1.2): ONE
    flattened (W·A) ``ss_matmul`` gives P = #matching positions per pair;
    the Lagrange equality indicator ``1[P==W]`` is a share-local
    elementwise chain. Same secrets and same final degree as the chain
    matcher — 1 dot-set instead of W — so the planner may pick either
    per join group (``Join.match_method``).
    """
    def run(bx: Array, by: Array) -> Array:
        from ..core import automata
        c, b, nx, w, a = bx.shape
        ny = by.shape[2]
        xf = bx.reshape(c * b, nx, w * a)
        yf = jnp.swapaxes(by.reshape(c * b, ny, w * a), -1, -2)
        p_cnt = backend.ss_matmul(xf, yf).reshape(c, b, nx, ny)
        return automata.equality_indicator(p_cnt, w)
    return run


def _make_jnp_slide():
    """Reference batched sliding-window chain (gather windows, dot the
    alphabet axis, chain the k positions — all under one jit; retraces
    per distinct (k, shape) group, which the round engine groups by
    anyway)."""
    from ..core import field

    @jax.jit
    def aa_slide(cols: Array, pats: Array) -> Array:
        # cols (c, B, n, W, A), pats (c, B, k, A) -> (c, B, n, M)
        k = pats.shape[-2]
        w = cols.shape[-2]
        m = w - k + 1
        idx = jnp.arange(m)[:, None] + jnp.arange(k)[None, :]
        win = cols[..., idx, :]                      # (c, B, n, M, k, A)
        v = field.dot(win, pats[:, :, None, None], axis=-1)
        acc = v[..., 0]
        for j in range(1, k):                        # k static: unrolled
            acc = field.mul(acc, v[..., j])
        return acc

    return aa_slide


jnp_aa_slide: _Op = _make_jnp_slide()


def _make_jnp_ripple():
    """Reference fused ripple step (Algorithm 6 lines 1-4, one bit)."""
    from ..core import field

    @jax.jit
    def _init(a, b):
        # LSB handles the +1 of two's complement: carry = OR(1−a, b)
        ai = field.sub(jnp.ones_like(a), a)
        ab = field.mul(ai, b)
        s = field.add(ai, b)
        carry = field.sub(s, ab)
        rb = field.sub(s, field.add(carry, carry))
        return rb, carry

    @jax.jit
    def _step(a, b, carry):
        ai = field.sub(jnp.ones_like(a), a)
        ab = field.mul(ai, b)
        x = field.sub(field.add(ai, b), field.add(ab, ab))   # ai ⊕ b
        cx = field.mul(carry, x)
        new_carry = field.add(ab, cx)
        rb = field.sub(field.add(x, carry), field.add(cx, cx))
        return rb, new_carry

    def ripple_carry(a, b, carry=None):
        return _init(a, b) if carry is None else _step(a, b, carry)

    return ripple_carry


jnp_ripple_carry: _RippleOp = _make_jnp_ripple()


def _make_jnp_ripple_segment():
    """Reference fused k-bit segment: the per-bit chain under ONE jit, so a
    whole degree-reduction-free run of bits is a single device dispatch.
    The loop body is exactly :data:`jnp_ripple_carry`'s math, hence
    bit-identical to stepping."""
    import functools

    @functools.partial(jax.jit, static_argnames=("init",))
    def _seg(a, b, carry, init):
        rb = None
        for i in range(a.shape[-1]):
            rb, carry = jnp_ripple_carry(a[..., i], b[..., i],
                                         None if (init and i == 0)
                                         else carry)
        return rb, carry

    def ripple_segment(a, b, carry=None):
        init = carry is None
        c0 = jnp.zeros_like(a[..., 0]) if init else carry
        return _seg(a, b, c0, init)

    return ripple_segment


jnp_ripple_segment: _RippleOp = _make_jnp_ripple_segment()


_REGISTRY: Dict[str, Backend] = {}

BackendLike = Union[str, Backend]


def register_backend(backend: Backend, *, overwrite: bool = False) -> Backend:
    if backend.name in _REGISTRY and not overwrite:
        raise ValueError(f"backend {backend.name!r} already registered")
    _REGISTRY[backend.name] = backend
    return backend


def get_backend(backend: BackendLike) -> Backend:
    """Resolve a backend name (a ``Backend`` instance passes through)."""
    if isinstance(backend, Backend):
        return backend
    _ensure_builtins()
    if backend == "pallas" and not _try_register_pallas():
        raise ValueError("backend 'pallas' is unavailable: the Pallas "
                         "kernel import failed on this jax build")
    try:
        return _REGISTRY[backend]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; available: "
                         f"{available_backends()}") from None


def available_backends() -> Tuple[str, ...]:
    _ensure_builtins()
    _try_register_pallas()
    return tuple(sorted(_REGISTRY))


def _ensure_builtins() -> None:
    """Register the pure-jnp backend (import-cycle safe, no kernel deps)."""
    if "jnp" in _REGISTRY:
        return
    from ..core import automata, field
    from ..core.shamir import Shares

    def _raw(op):                       # Shares-level op -> raw-array op
        def run(a: Array, b: Array) -> Array:
            return op(Shares(a, 0), Shares(b, 0)).values
        return run

    aa_match = _raw(automata.match_words)

    match_matrix = _raw(automata.match_matrix)

    register_backend(Backend(
        "jnp",
        aa_match=aa_match,
        ss_matmul=field.matmul,
        match_matrix=match_matrix,
        aa_match_batch=jax.jit(jax.vmap(aa_match, in_axes=1, out_axes=1)),
        ripple_carry=jnp_ripple_carry,
        ripple_segment=jnp_ripple_segment,
        match_matrix_batch=jax.jit(jax.vmap(match_matrix, in_axes=1,
                                            out_axes=1)),
        aa_slide_batch=jnp_aa_slide))


def _try_register_pallas() -> bool:
    """Register the Pallas kernels on first request; the pure-jnp query
    suite must keep working on builds where the kernel import fails."""
    if "pallas" in _REGISTRY:
        return True
    try:
        from ..kernels import ops as kops
    except ImportError:
        return False
    register_backend(kops.as_backend())
    return True
