"""QueryClient — the unified user-side facade over the secret-shared clouds.

One object replaces the nine free query functions: it owns the root PRNG key
(per-query keys derive via ``jax.random.fold_in``, no manual threading), the
backend choice (``repro.api.backends`` registry), the optional MapReduce
executor, and the cost-based selection planner (``repro.api.planner``).
Every query family returns the same :class:`~.plans.QueryResult`.

Count and selection plans execute through the round-structured batch engine
(``repro.core.queries.rounds``): :meth:`QueryClient.run_batch` cost-plans
each query, groups compatible strategies, stacks their shared predicates and
executes each protocol round *once for the whole group* — one fused device
dispatch + one interpolation per round instead of one per query (or per
block). :meth:`QueryClient.run` is the B = 1 case of the same machinery, so
per-query rows and ``CostLedger`` totals are bit-identical between a batch
and the equivalent sequential calls (asserted by ``tests/test_batch.py``).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax

from ..core.costs import CostLedger
from ..core.engine import SecretSharedDB
from ..core.queries import (CardinalityError, equijoin, pkfk_join,
                            range_count, range_select, rounds)
from . import planner as _planner
from .backends import BackendLike, get_backend
from .executor import MapReduceExecutor
from .plans import (AUTO, Between, ColumnRef, Count, Eq, Join, Padding, Plan,
                    QueryResult, RangeCount, RangeSelect, Select,
                    resolve_column)


@dataclasses.dataclass
class _Slot:
    """One plan's execution state inside a batch."""
    idx: int
    plan: Plan
    key: jax.Array
    ledger: CostLedger = dataclasses.field(default_factory=CostLedger)
    strategy: str = ""
    known_count: Optional[int] = None
    column: int = -1


class QueryClient:
    """Authorized-user facade over one outsourced relation.

    db:              the user's secret-shared relation (``core.outsource``).
    key:             root PRNG key (or int seed); per-query keys derive via
                     ``fold_in`` so identical plans replay identically.
    backend:         registered backend name or Backend instance.
    executor:        optional :class:`MapReduceExecutor` — fans every
                     cloud-side map phase out over fault-tolerant splits.
    round_cost_bits: planner latency weight — how many communication bits
                     one extra protocol round is worth to this user.
    """

    def __init__(self, db: SecretSharedDB, key, *,
                 backend: BackendLike = "jnp",
                 executor: Optional[MapReduceExecutor] = None,
                 round_cost_bits: int = 0):
        self.db = db
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self._root_key = key
        self.backend = get_backend(backend)
        if executor is not None:
            self.backend = executor.wrap(self.backend)
        self.executor = executor
        self.round_cost_bits = round_cost_bits
        self._query_counter = itertools.count()

    # -- keys ---------------------------------------------------------------
    def _next_key(self) -> jax.Array:
        return jax.random.fold_in(self._root_key, next(self._query_counter))

    # -- planning -----------------------------------------------------------
    def stats(self) -> _planner.DBStats:
        return _planner.DBStats.of(self.db)

    def explain(self, plan: Select):
        """Planner's eligible strategies for ``plan``, cheapest first."""
        cands = _planner.candidate_estimates(
            self.stats(), ell=plan.expected_matches,
            padded_rows=plan.padding.rows)
        return sorted(cands,
                      key=lambda e: (e.score(self.round_cost_bits), e.rounds))

    # -- execution ----------------------------------------------------------
    def run(self, plan: Plan) -> QueryResult:
        """Execute one logical plan (the B = 1 case of :meth:`run_batch`)."""
        return self.run_batch([plan])[0]

    def run_batch(self, plans: Sequence[Plan]) -> List[QueryResult]:
        """Execute B logical plans, fusing each protocol round per group.

        Per-plan keys derive from the root key in list order; every plan is
        cost-planned exactly as :meth:`run` would, then Count/Select plans
        with a *compatible strategy* are grouped and executed through the
        batched round engine — the group's predicates are stacked and each
        protocol round (count, match, Q&A, address-fetch, oblivious fetch)
        is one fused device dispatch + one interpolation for the whole
        group. Families without a batched protocol (range, join) run
        per-query. Results come back in plan order; each query's rows and
        ``CostLedger`` are bit-identical to running it sequentially (ledgers
        record the query's own protocol cost, never a groupmate's padding).

        A forced ``one_tuple`` whose predicate turns out to hit ℓ ≠ 1 tuples
        raises :class:`CardinalityError` (as sequentially); with
        ``strategy="auto"`` the query replans onto one_round/tree inside the
        batch, reusing the learned count.
        """
        results: Dict[int, QueryResult] = {}
        count_grp: List[_Slot] = []
        sel_grp: Dict[str, List[_Slot]] = {"one_tuple": [], "one_round": [],
                                           "tree": []}
        passthrough: List[_Slot] = []
        for idx, plan in enumerate(plans):
            slot = _Slot(idx, plan, self._next_key())
            if isinstance(plan, Count):
                slot.column = resolve_column(self.db, plan.where.column)
                count_grp.append(slot)
            elif isinstance(plan, Select):
                slot.column = resolve_column(self.db, plan.where.column)
                strategy = plan.strategy
                if strategy == AUTO:
                    strategy = _planner.choose_select_strategy(
                        self.stats(), ell=plan.expected_matches,
                        padded_rows=plan.padding.rows,
                        round_cost_bits=self.round_cost_bits).strategy
                if strategy == "one_tuple" and plan.padding.rows:
                    raise ValueError(
                        "one_tuple returns the single tuple directly and "
                        "cannot pad its output size — use one_round/tree "
                        "(or auto, which excludes one_tuple when padding is "
                        "requested)")
                slot.strategy = strategy
                sel_grp[strategy].append(slot)
            elif isinstance(plan, (RangeCount, RangeSelect, Join)):
                passthrough.append(slot)
            else:
                raise TypeError(f"not a logical plan: {plan!r}")

        be = self.backend
        if count_grp:
            counts = rounds.count_phase(be, self.db, [
                rounds.MatchJob(s.column, s.plan.where.pattern, s.key,
                                s.ledger) for s in count_grp])
            for s, cnt in zip(count_grp, counts):
                results[s.idx] = QueryResult(plan=s.plan, ledger=s.ledger,
                                             strategy="count", count=cnt)

        # -- one_tuple: batched count phase, then the Alg 3 map round -------
        if sel_grp["one_tuple"]:
            group = sel_grp["one_tuple"]
            keys = [jax.random.split(s.key) for s in group]
            ells = rounds.count_phase(be, self.db, [
                rounds.MatchJob(s.column, s.plan.where.pattern, kc, s.ledger)
                for s, (kc, _) in zip(group, keys)])
            verified: List[Tuple[_Slot, jax.Array]] = []
            for s, (_, k_sel), ell in zip(group, keys, ells):
                if ell == 1:
                    verified.append((s, k_sel))
                    continue
                if s.plan.strategy != AUTO:
                    raise CardinalityError(
                        f"select_one_tuple needs ℓ=1, predicate has {ell}"
                        " — use select_one_round/select_tree", count=ell)
                # hint was wrong: replan with the learned ℓ on a fresh key;
                # the slot's ledger keeps the aborted count-phase cost.
                s.strategy = _planner.choose_select_strategy(
                    self.stats(), ell=ell, padded_rows=s.plan.padding.rows,
                    round_cost_bits=self.round_cost_bits).strategy
                s.key, s.known_count = self._next_key(), ell
                sel_grp[s.strategy].append(s)
            if verified:
                rows = rounds.one_tuple_round(be, self.db, [
                    rounds.MatchJob(s.column, s.plan.where.pattern, k_sel,
                                    s.ledger) for s, k_sel in verified])
                for (s, _), row in zip(verified, rows):
                    results[s.idx] = QueryResult(
                        plan=s.plan, ledger=s.ledger, strategy="one_tuple",
                        rows=[row])

        # -- one_round: fused Phase 1, then the group-fused fetch -----------
        if sel_grp["one_round"]:
            group = sel_grp["one_round"]
            keys = [jax.random.split(s.key) for s in group]
            addrs = rounds.match_all_round(be, self.db, [
                rounds.MatchJob(s.column, s.plan.where.pattern, kp, s.ledger)
                for s, (kp, _) in zip(group, keys)])
            rows = rounds.fetch_round(be, self.db, [
                rounds.FetchJob(kf, a, s.ledger, s.plan.padding.rows)
                for s, (_, kf), a in zip(group, keys, addrs)])
            for s, a, r in zip(group, addrs, rows):
                results[s.idx] = QueryResult(plan=s.plan, ledger=s.ledger,
                                             strategy="one_round", rows=r,
                                             addresses=a)

        # -- tree: batched count phase, lockstep Q&A rounds, fused fetch ----
        if sel_grp["tree"]:
            group = sel_grp["tree"]
            keys = [jax.random.split(s.key, 3) for s in group]
            need = [(s, kc) for s, (kc, _, _) in zip(group, keys)
                    if s.known_count is None]
            ells = rounds.count_phase(be, self.db, [
                rounds.MatchJob(s.column, s.plan.where.pattern, kc, s.ledger)
                for s, kc in need])
            for (s, _), ell in zip(need, ells):
                s.known_count = ell
            live: List[Tuple[_Slot, jax.Array, jax.Array]] = []
            for s, (_, kp, kf) in zip(group, keys):
                if s.known_count == 0:
                    results[s.idx] = QueryResult(
                        plan=s.plan, ledger=s.ledger, strategy="tree",
                        rows=[], addresses=[])
                else:
                    live.append((s, kp, kf))
            if live:
                addrs = rounds.tree_rounds(be, self.db, [
                    rounds.TreeJob(s.column, s.plan.where.pattern, kp,
                                   s.ledger, ell=s.known_count,
                                   branching=s.plan.branching)
                    for s, kp, _ in live])
                rows = rounds.fetch_round(be, self.db, [
                    rounds.FetchJob(kf, a, s.ledger, s.plan.padding.rows)
                    for (s, _, kf), a in zip(live, addrs)])
                for (s, _, _), a, r in zip(live, addrs, rows):
                    results[s.idx] = QueryResult(
                        plan=s.plan, ledger=s.ledger, strategy="tree",
                        rows=r, addresses=a)

        # -- families without a batched protocol run per-query --------------
        for s in passthrough:
            if isinstance(s.plan, RangeCount):
                results[s.idx] = self._run_range_count(s.plan, s.key)
            elif isinstance(s.plan, RangeSelect):
                results[s.idx] = self._run_range_select(s.plan, s.key)
            else:
                results[s.idx] = self._run_join(s.plan, s.key)
        return [results[i] for i in range(len(plans))]

    def _run_range_count(self, plan: RangeCount, key) -> QueryResult:
        # Range counting is pure element-wise share arithmetic (SS-SUB
        # ripple + sum) — it has no registry hotspot, so the client's
        # backend/executor choice does not apply to this family.
        col = resolve_column(self.db, plan.where.column)
        cnt, led = range_count(key, self.db, col, plan.where.lo,
                               plan.where.hi, reduce_every=plan.reduce_every)
        return QueryResult(plan=plan, ledger=led, strategy="range_count",
                           count=cnt)

    def _run_range_select(self, plan: RangeSelect, key) -> QueryResult:
        col = resolve_column(self.db, plan.where.column)
        rows, addrs, led = range_select(
            key, self.db, col, plan.where.lo, plan.where.hi,
            reduce_every=plan.reduce_every, padded_rows=plan.padding.rows,
            backend=self.backend)
        return QueryResult(plan=plan, ledger=led, strategy="range_select",
                           rows=rows, addresses=addrs)

    def _run_join(self, plan: Join, key) -> QueryResult:
        col_l = resolve_column(self.db, plan.on[0])
        col_r = resolve_column(plan.right, plan.on[1])
        if plan.padding.rows:
            raise ValueError("joins take Padding.fake_values (fake join "
                             "jobs), not Padding.rows")
        if plan.kind == "pkfk":
            if plan.padding.values:
                raise ValueError(
                    "pkfk_join's output size is always n_y (one reducer per "
                    "child tuple) — nothing to hide; Padding.fake_values "
                    "applies to kind='equi' only")
            rows, led = pkfk_join(key, self.db, plan.right, col_l, col_r,
                                  backend=self.backend)
        else:
            rows, led = equijoin(key, self.db, plan.right, col_l, col_r,
                                 padded_values=plan.padding.values,
                                 backend=self.backend)
        return QueryResult(plan=plan, ledger=led, strategy=plan.kind,
                           rows=rows)

    # -- conveniences (build the plan, run it) ------------------------------
    def count(self, column: ColumnRef, pattern: str) -> QueryResult:
        return self.run(Count(Eq(column, pattern)))

    def select(self, column: ColumnRef, pattern: str, *,
               strategy: str = AUTO, expected_matches: Optional[int] = None,
               padding: Padding = Padding.NONE,
               branching: Optional[int] = None) -> QueryResult:
        return self.run(Select(Eq(column, pattern), strategy=strategy,
                               expected_matches=expected_matches,
                               padding=padding, branching=branching))

    def range_count(self, column: ColumnRef, lo: int, hi: int, *,
                    reduce_every: int = 0) -> QueryResult:
        return self.run(RangeCount(Between(column, lo, hi),
                                   reduce_every=reduce_every))

    def range_select(self, column: ColumnRef, lo: int, hi: int, *,
                     reduce_every: int = 0,
                     padding: Padding = Padding.NONE) -> QueryResult:
        return self.run(RangeSelect(Between(column, lo, hi),
                                    reduce_every=reduce_every,
                                    padding=padding))

    def join(self, right: SecretSharedDB,
             on: Tuple[ColumnRef, ColumnRef], *, kind: str = "pkfk",
             padding: Padding = Padding.NONE) -> QueryResult:
        return self.run(Join(right=right, on=on, kind=kind, padding=padding))
