"""QueryClient — the unified user-side facade over the secret-shared clouds.

One object replaces the nine free query functions: it owns the root PRNG key
(per-query keys derive via ``jax.random.fold_in``, no manual threading), the
backend choice (``repro.api.backends`` registry), the optional MapReduce
executor, and the cost-based selection planner (``repro.api.planner``).
Every query family returns the same :class:`~.plans.QueryResult`.

The client fronts a *registry* of attached relations, matching the paper's
deployment model (§2: the owner outsources secret-shares of a database —
plural relations — once; users then query any of them without the owner in
the loop). ``QueryClient(db, key)`` registers ``db`` under the default
name; ``attach(other_db, name="orders", shards=S)`` registers more, each
with its own sharded dataplane, its own planner statistics and — crucially
— its own root key and query counter, so the per-query key stream of one
relation never depends on traffic to another: a plan sequence submitted to
relation "orders" opens bit-identical rows and ledgers whether or not
"users" traffic interleaves with it (the multi-tenant serving acceptance).

Every plan family executes through the round-structured batch engine
(``repro.core.queries.rounds``): :meth:`QueryClient.run_batch` cost-plans
each query, groups compatible strategies — Count/Select by selection
algorithm, ranges by (bit-width, ``reduce_every``), joins by kind — stacks
their shared predicates and executes each protocol round *once for the
whole group*: one fused device dispatch + one interpolation per match or
Q&A round, one ``ripple_carry`` dispatch per SS-SUB bit-round, and ONE
cross-group ``ss_matmul`` for every oblivious fetch (one_round, tree and
range one-hot matrices *and* PK/FK match matrices stack row-wise).
:meth:`QueryClient.run` is the B = 1 case of the same machinery, so
per-query rows and ``CostLedger`` totals are bit-identical between a batch
and the equivalent sequential calls (asserted by ``tests/test_batch.py``).
"""
from __future__ import annotations

import dataclasses
import itertools
import zlib
from typing import Dict, Iterator, List, Optional, Sequence, Tuple, Union

import jax

from ..core import encoding
from ..core.costs import CostLedger
from ..core.dataplane import Dispatcher, RelationLike, ShardedRelation
from ..core.engine import SecretSharedDB
from ..core.queries import CardinalityError, aggregate, rounds
from ..core.queries import embed as embed_q
from . import planner as _planner
from .backends import BackendLike, get_backend
from .executor import MapReduceExecutor
from .plans import (AUTO, Aggregate, Between, ColumnRef, Contains, Count,
                    EmbedLookup, Eq, Join, Like, Padding, Plan, Prefix,
                    QueryResult, RangeCount, RangeSelect, Select, Suffix,
                    resolve_column)

#: registry name a bare ``QueryClient(db, key)`` attaches its relation
#: under; single-relation callers never need to spell it.
DEFAULT_RELATION = "default"

#: explanation-cache entries kept per client (FIFO eviction) — a serving
#: frontend explains a bounded set of recurring plan shapes; anything
#: beyond this just recomputes.
EXPLAIN_CACHE_MAX = 128


@dataclasses.dataclass
class AttachedRelation:
    """One registered relation: its shares, dataplane and key stream."""
    name: str
    db: SecretSharedDB
    dataplane: Optional[ShardedRelation]
    root_key: jax.Array
    counter: Iterator[int]

    @property
    def rel(self) -> Union[SecretSharedDB, ShardedRelation]:
        """What the round engine executes against (plane if attached)."""
        return self.dataplane if self.dataplane is not None else self.db

    @property
    def n_shards(self) -> int:
        return self.dataplane.n_shards if self.dataplane is not None else 1


def _as_key(key) -> jax.Array:
    return jax.random.PRNGKey(key) if isinstance(key, int) else key


#: surface shapes of the literal-tile predicates (for error display).
_TILE_SOURCES = {Prefix: "{0}%", Suffix: "%{0}", Contains: "%{0}%"}


def _lower_match(db: SecretSharedDB, where, context: str
                 ) -> Tuple[int, str, Optional[encoding.PatternSpec]]:
    """Lower a Count/Select predicate -> (column, body, spec).

    ``Eq`` — and any wildcard-free ``Like``, provably — lower to the exact
    path (``spec=None``); the other shapes build their
    :class:`~repro.core.encoding.PatternSpec` and validate it against the
    relation's codec here, at plan time, so malformed patterns (interior
    ``%``, ``_`` under a shifted window, tiles longer than the word, empty
    bodies, out-of-alphabet characters) surface as a typed
    :class:`~.planner.PlanNotSupported` before any share moves. Unknown
    predicate classes raise the same — never an ``AttributeError`` from
    duck-typed field access.
    """
    if isinstance(where, Eq):
        return resolve_column(db, where.column), where.pattern, None
    if isinstance(where, Like):
        try:
            kind, body, wild = encoding.parse_like(where.pattern)
            if kind == "exact":
                return resolve_column(db, where.column), body, None
            spec = encoding.PatternSpec(kind, body, wild, where.pattern)
            encoding.encode_pattern_tile(db.codec, spec)
        except (KeyError, ValueError) as e:
            raise _planner.PlanNotSupported(
                where, f"{context} ({e})") from None
        return resolve_column(db, where.column), body, spec
    if isinstance(where, (Prefix, Suffix, Contains)):
        source = _TILE_SOURCES[type(where)].format(where.literal)
        try:
            spec = encoding.PatternSpec(type(where).__name__.lower(),
                                        where.literal, (), source)
            encoding.encode_pattern_tile(db.codec, spec)
        except (KeyError, ValueError) as e:
            raise _planner.PlanNotSupported(
                where, f"{context} ({e})") from None
        return resolve_column(db, where.column), where.literal, spec
    raise _planner.PlanNotSupported(where, context)


def _plan_signature(plan: Plan) -> tuple:
    """Structural cache key for one plan (Join rights key by identity —
    two different share sets are different plans even if equal-valued)."""
    if isinstance(plan, Join):
        return ("Join", id(plan.right), tuple(plan.on), plan.kind,
                plan.padding.rows, plan.padding.values)
    if not dataclasses.is_dataclass(plan):
        # unknown plan classes fail HERE with the clear error, not with
        # dataclasses.fields' opaque TypeError
        raise _planner.PlanNotSupported(plan)
    return (type(plan).__name__,) + tuple(
        getattr(plan, f.name) for f in dataclasses.fields(plan))


@dataclasses.dataclass
class _Slot:
    """One plan's execution state inside a batch."""
    idx: int
    plan: Plan
    key: jax.Array
    ledger: CostLedger = dataclasses.field(default_factory=CostLedger)
    strategy: str = ""
    known_count: Optional[int] = None
    column: int = -1
    pattern: str = ""
    spec: Optional[encoding.PatternSpec] = None
    pred_column: Optional[int] = None
    fetch_key: Optional[jax.Array] = None


@dataclasses.dataclass
class _BatchWork:
    """One relation's in-flight ``run_batch`` state, split at the fetch.

    ``_prepare_batch`` runs every pre-fetch round and parks the deferred
    cross-group fetch jobs here; ``_finish_batch`` consumes the fused
    fetch output and the post-fetch rounds. The split lets
    :meth:`QueryClient.run_batch_multi` drive several relations' batches
    to the fetch boundary and fuse their cloud-side matmuls into one
    dispatch wave.
    """
    plans: Sequence[Plan]
    db: SecretSharedDB
    rel: "RelationLike"
    results: Dict[int, QueryResult]
    fetch_jobs: List[rounds.FetchJob]
    fetch_meta: List[Tuple[_Slot, str, List[int]]]
    join_jobs: List[rounds.JoinJob]
    join_entries: List[rounds.FetchEntry]
    pkfk_grp: List[_Slot]
    equi_grp: List[_Slot]


class QueryClient:
    """Authorized-user facade over the outsourced relation registry.

    db:              the user's secret-shared relation (``core.outsource``)
                     — registered under :data:`DEFAULT_RELATION`; pass
                     ``None`` to start with an empty registry and
                     ``attach(..., name=...)`` relations explicitly.
    key:             root PRNG key (or int seed); per-query keys derive via
                     ``fold_in`` so identical plans replay identically.
                     Each attached relation gets its own independent key
                     stream (seeded from this root unless ``attach`` is
                     given an explicit ``key=``).
    backend:         registered backend name or Backend instance.
    executor:        optional :class:`MapReduceExecutor` — fans every
                     cloud-side map phase out over fault-tolerant splits.
    round_cost_bits: planner latency weight — how many communication bits
                     one extra protocol round is worth to this user.
    """

    def __init__(self, db: Union[SecretSharedDB, ShardedRelation,
                                 None] = None, key=0, *,
                 backend: BackendLike = "jnp",
                 executor: Optional[MapReduceExecutor] = None,
                 round_cost_bits: int = 0):
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self._root_key = key
        self._relations: Dict[str, AttachedRelation] = {}
        # sig -> (BatchExplanation, pinned Join right relations)
        self._explanations: Dict[tuple, tuple] = {}
        if db is not None:
            plane = db if isinstance(db, ShardedRelation) else None
            self._relations[DEFAULT_RELATION] = AttachedRelation(
                DEFAULT_RELATION, plane.db if plane is not None else db,
                plane, key, itertools.count())
        self.backend = get_backend(backend)
        if executor is not None:
            self.backend = executor.wrap(self.backend)
        self.executor = executor
        self.round_cost_bits = round_cost_bits

    # -- registry -----------------------------------------------------------
    @property
    def relations(self) -> Tuple[str, ...]:
        """Attached relation names, in registration order."""
        return tuple(self._relations)

    def _entry(self, relation: Optional[str] = None) -> AttachedRelation:
        if relation is None:
            ent = self._relations.get(DEFAULT_RELATION)
            if ent is not None:
                return ent
            if len(self._relations) == 1:
                return next(iter(self._relations.values()))
            if not self._relations:
                raise ValueError("no relation attached — pass a db to "
                                 "QueryClient(...) or call attach(db, "
                                 "name=...)")
            raise ValueError(f"several relations attached "
                             f"({list(self._relations)}) and none is "
                             f"{DEFAULT_RELATION!r} — pass relation=")
        try:
            return self._relations[relation]
        except KeyError:
            raise KeyError(f"unknown relation {relation!r}; attached: "
                           f"{list(self._relations)}") from None

    @property
    def db(self) -> Optional[SecretSharedDB]:
        """The default relation's shares (None with an empty registry)."""
        ent = (self._relations.get(DEFAULT_RELATION)
               or next(iter(self._relations.values()), None))
        return ent.db if ent is not None else None

    @property
    def dataplane(self) -> Optional[ShardedRelation]:
        """The default relation's dataplane (None until sharded/attached)."""
        ent = (self._relations.get(DEFAULT_RELATION)
               or next(iter(self._relations.values()), None))
        return ent.dataplane if ent is not None else None

    def dataplane_of(self, relation: str) -> Optional[ShardedRelation]:
        return self._entry(relation).dataplane

    # -- keys ---------------------------------------------------------------
    def _next_key(self,
                  ent: Optional[AttachedRelation] = None) -> jax.Array:
        ent = ent if ent is not None else self._entry()
        return jax.random.fold_in(ent.root_key, next(ent.counter))

    # -- dataplane ----------------------------------------------------------
    def attach(self, relation: Union[SecretSharedDB, ShardedRelation,
                                     None] = None, *,
               name: Optional[str] = None,
               shards: int = 1,
               dispatcher: Optional[Dispatcher] = None,
               key=None) -> ShardedRelation:
        """Attach (or re-shard) a serving relation as a sharded dataplane.

        ``name`` addresses the registry slot (default:
        :data:`DEFAULT_RELATION`, the single-relation surface). A new name
        registers ``relation`` as an additional tenant with its own key
        stream — ``key`` seeds it explicitly (so a multi-tenant server can
        replay a solo client bit-for-bit); otherwise the stream derives
        from the client root key and the name, order-independently.

        Every cloud step of every subsequent query against this relation
        fans out as one dispatch per tuple-axis shard, executed by
        ``dispatcher`` (serial by default; pass a ``ThreadedDispatcher`` —
        or a shared pool's ``handle()`` — for concurrent shards, or
        ``MapReduceExecutor.dispatcher()`` for fault-tolerant placement).
        Sharding is pure execution policy: rows, opened values and ledgers
        stay bit-identical to the unsharded relation, and the planner
        prices the per-shard dispatch counts through ``stats().shards``.

        Re-attaching invalidates cached :class:`~.planner.BatchExplanation`
        estimates — their ``dispatches`` are priced per target relation at
        its shard count, so they go stale the moment the dataplane moves.
        """
        name = DEFAULT_RELATION if name is None else name
        ent = self._relations.get(name)
        if relation is None:
            if ent is None:
                raise ValueError(f"no relation registered under {name!r} — "
                                 f"pass the db to attach")
            rel = ent.dataplane if ent.dataplane is not None else ent.db
        else:
            rel = relation
        if isinstance(rel, ShardedRelation):
            if shards <= 1 and dispatcher is None:
                plane = rel                      # adopt as-is
            else:
                # re-shard only on an explicit shards>1; a new dispatcher
                # alone must not collapse the existing partitioning
                plane = ShardedRelation(
                    rel.db, shards=(shards if shards > 1 else rel.n_shards),
                    dispatcher=dispatcher or rel.dispatcher)
        else:
            plane = ShardedRelation(rel, shards=shards,
                                    dispatcher=dispatcher)
        # a device-resident dispatcher (MeshDispatcher) pre-places the
        # share arrays on its mesh at attach time — before the entry below
        # captures plane.db — so every subsequent round runs zero-copy
        bind = getattr(plane.dispatcher, "bind_plane", None)
        if bind is not None:
            bind(plane)
        if ent is None:
            if key is not None:
                root = _as_key(key)
            else:
                # derive the relation's key stream from the client root and
                # the NAME ALONE (two independent 31-bit folds), so the
                # stream is order-independent — attaching the same names in
                # any order replays identically. Distinct tenants MUST get
                # distinct streams (the protocol's masking randomness must
                # be independent), so the astronomically unlikely double
                # collision is checked and refused, never absorbed.
                raw = name.encode()
                root = jax.random.fold_in(
                    jax.random.fold_in(self._root_key,
                                       zlib.crc32(raw) & 0x7fffffff),
                    zlib.crc32(raw[::-1] + b"\x00") & 0x7fffffff)
                for other in self._relations.values():
                    if bool((other.root_key == root).all()):
                        raise ValueError(
                            f"derived key stream for {name!r} collides "
                            f"with relation {other.name!r} — pass an "
                            f"explicit key= for one of them")
            ent = AttachedRelation(name, plane.db, plane, root,
                                   itertools.count())
            self._relations[name] = ent
        else:
            ent.db, ent.dataplane = plane.db, plane
            if key is not None:                  # explicit re-key: restart
                ent.root_key = _as_key(key)
                ent.counter = itertools.count()
        # stale-estimate bugfix: cached explanations price dispatches at
        # the OLD shard count — drop them all (cheap; they re-compute).
        self._explanations.clear()
        return plane

    # -- planning -----------------------------------------------------------
    def stats(self, relation: Optional[str] = None) -> _planner.DBStats:
        ent = self._entry(relation)
        return _planner.DBStats.of(ent.db, shards=ent.n_shards,
                                   relation=ent.name)

    def explain(self, plan: Union[Select, Sequence[Plan]], *,
                relation: Optional[str] = None):
        """Planner predictions without touching shares.

        One ``Select`` -> its eligible strategy estimates, cheapest first
        (each carries bits, rounds and per-shard dispatches). Any other
        single plan -> its batch-of-one :class:`~.planner.BatchExplanation`
        (those families have one strategy each — nothing to rank).

        A *sequence of plans* -> a :class:`~.planner.BatchExplanation`: the
        plans are grouped exactly as :meth:`run_batch` would group them and
        each group is priced with ``estimate_batch_group_cost`` (bits sum,
        rounds/dispatches fuse, the cross-group fetch priced once) — a
        predicted ``run_batch`` ledger for the target relation.
        Explanations are cached per (relation, plan signature) and
        invalidated by :meth:`attach` — a re-shard re-prices dispatches.
        """
        ent = self._entry(relation)
        if isinstance(plan, Select):
            spec = _lower_match(ent.db, plan.where, "Select predicate")[2]
            if spec is not None and plan.strategy == "one_tuple":
                raise _planner.PlanNotSupported(
                    plan.where, "one_tuple select (pattern predicates "
                    "run one_round or tree)")
            if spec is not None:
                cands = _planner.candidate_pattern_estimates(
                    self.stats(ent.name), spec, ell=plan.expected_matches,
                    padded_rows=plan.padding.rows)
            else:
                cands = _planner.candidate_estimates(
                    self.stats(ent.name), ell=plan.expected_matches,
                    padded_rows=plan.padding.rows)
            return sorted(cands,
                          key=lambda e: (e.score(self.round_cost_bits),
                                         e.rounds))
        if isinstance(plan, Plan):
            # single-strategy families: the batch-of-one prediction
            return self.explain([plan], relation=ent.name)
        try:
            plans = list(plan)
        except TypeError:
            raise _planner.PlanNotSupported(
                plan, "explain() argument") from None
        sig = (ent.name, tuple(_plan_signature(p) for p in plans))
        hit = self._explanations.get(sig)
        if hit is not None:
            return hit[0]
        exp = self._explain_batch(plans, ent)
        if len(self._explanations) >= EXPLAIN_CACHE_MAX:
            self._explanations.pop(next(iter(self._explanations)))
        # the entry pins every Join right relation: its id() is part of
        # the signature, so the object must stay alive (un-reusable) for
        # as long as the cached explanation can be served.
        self._explanations[sig] = (exp, tuple(
            p.right for p in plans if isinstance(p, Join)))
        return exp

    def explain_multi(self, batches: Sequence[
            Tuple[Optional[str], Sequence[Plan]]]
            ) -> _planner.MultiBatchExplanation:
        """Predicted ledgers for a prospective :meth:`run_batch_multi`.

        Each ``(relation, plans)`` batch is priced exactly as
        :meth:`explain` would price it solo (fusion never moves a
        relation's bits, rounds or dispatch fan-out); the assembly adds
        the shared-dispatch view — ``fetch_parts`` relations closing with
        fetch work share ``fetch_waves`` (== 1 when at least two fuse)
        cloud-side dispatch waves instead of one wave each.
        """
        return _planner.explain_multi_batches(
            [self.explain(list(plans), relation=relation)
             for relation, plans in batches])

    def _explain_batch(self, plans: List[Plan],
                       ent: AttachedRelation) -> _planner.BatchExplanation:
        """Group ``plans`` exactly as :meth:`run_batch` would (AUTO plans
        see the same live group sizes/depths) and price each group."""
        db = ent.db
        stats = self.stats(ent.name)
        sel_ells: Dict[str, List[Optional[int]]] = {"one_tuple": [],
                                                    "one_round": [],
                                                    "tree": []}
        sel_specs: Dict[str, List[Optional[encoding.PatternSpec]]] = {
            s: [] for s in sel_ells}
        sel_pad: Dict[str, Optional[int]] = {s: None for s in sel_ells}
        group_sizes: Dict[str, int] = {s: 0 for s in sel_ells}
        group_rounds: Dict[str, int] = {}
        count_ests: List[_planner.CostEstimate] = []
        range_grps: Dict[Tuple[int, int], List[Tuple[bool, Optional[int],
                                                     Optional[int]]]] = {}
        joins: Dict[str, List[Plan]] = {"pkfk": [], "equi": []}
        agg_grps: Dict[tuple, List[_planner.CostEstimate]] = {}
        embed_ests: List[_planner.CostEstimate] = []
        auto_plans: List[Tuple[Select, Optional[encoding.PatternSpec]]] = []

        def add_select(plan: Select, strategy: str,
                       spec: Optional[encoding.PatternSpec]) -> None:
            ell = 1 if strategy == "one_tuple" else plan.expected_matches
            sel_ells[strategy].append(ell)
            sel_specs[strategy].append(spec)
            sel_pad[strategy] = sel_pad[strategy] or plan.padding.rows
            group_sizes[strategy] += 1
            ell_eff = (1 if strategy == "one_tuple" else
                       _planner.DEFAULT_ELL if ell is None else max(ell, 1))
            if spec is not None:
                est = _planner.estimate_pattern_cost(
                    stats, spec, select=strategy, ell=ell_eff,
                    padded_rows=plan.padding.rows)
            else:
                est = _planner.estimate_select_cost(
                    strategy, stats, ell=ell_eff,
                    padded_rows=plan.padding.rows)
            group_rounds[strategy] = max(group_rounds.get(strategy, 0),
                                         est.rounds)

        for plan in plans:
            if isinstance(plan, Count):
                spec = _lower_match(db, plan.where, "Count predicate")[2]
                count_ests.append(
                    _planner.estimate_pattern_cost(stats, spec))
            elif isinstance(plan, Select):
                spec = _lower_match(db, plan.where, "Select predicate")[2]
                if spec is not None and plan.strategy == "one_tuple":
                    raise _planner.PlanNotSupported(
                        plan.where, "one_tuple select (pattern predicates "
                        "run one_round or tree)")
                if plan.strategy == AUTO:
                    auto_plans.append((plan, spec))
                else:
                    add_select(plan, plan.strategy, spec)
            elif isinstance(plan, (RangeCount, RangeSelect)):
                col = resolve_column(db, plan.where.column)
                if col not in db.numeric_bits:   # as range_phase would
                    raise ValueError(f"column {col} was not outsourced in "
                                     f"binary form")
                gk = (db.numeric_bits[col], plan.reduce_every)
                want = isinstance(plan, RangeSelect)
                range_grps.setdefault(gk, []).append(
                    (want, None, plan.padding.rows if want else None))
            elif isinstance(plan, Aggregate):
                col = resolve_column(db, plan.column)
                if col not in db.numeric_bits:   # as the agg phases would
                    raise ValueError(f"column {col} was not outsourced in "
                                     f"binary form")
                t_bits = db.numeric_bits[col]
                est = _planner.estimate_aggregate_cost(
                    stats, plan.op, t_bits=t_bits,
                    conditional=plan.where is not None,
                    verify=plan.verify, reduce_every=plan.reduce_every)
                # mirror run_batch grouping: SUM/AVG fuse per bit-width,
                # MIN/MAX per (bit-width, reduce_every)
                gk = (("agg_sum", t_bits) if plan.op in ("sum", "avg")
                      else ("agg_minmax", t_bits, plan.reduce_every))
                agg_grps.setdefault(gk, []).append(est)
            elif isinstance(plan, EmbedLookup):
                embed_ests.append(_planner.estimate_embed_cost(
                    stats, n_tokens=len(plan.tokens), verify=plan.verify))
            elif isinstance(plan, Join):
                self._validate_join(plan)
                joins[plan.kind].append(plan)
            else:
                raise _planner.PlanNotSupported(plan)
        for plan, spec in auto_plans:
            chooser = (_planner.choose_pattern_strategy if spec is not None
                       else _planner.choose_select_strategy)
            args = (stats, spec) if spec is not None else (stats,)
            chosen = chooser(
                *args, ell=plan.expected_matches,
                padded_rows=plan.padding.rows,
                round_cost_bits=self.round_cost_bits,
                group_sizes=group_sizes, group_rounds=group_rounds).strategy
            add_select(plan, chosen, spec)

        groups: List[_planner.GroupEstimate] = []
        if count_ests:
            groups.append(_planner.GroupEstimate(
                "count", len(count_ests), _planner.CostEstimate(
                    "count", bits=sum(e.bits for e in count_ests),
                    rounds=max(e.rounds for e in count_ests),
                    dispatches=max(e.dispatches for e in count_ests))))
        for strategy, ells in sel_ells.items():
            if ells:
                groups.append(_planner.GroupEstimate(
                    strategy, len(ells),
                    _planner.estimate_batch_group_cost(
                        stats, strategy, ells=ells,
                        padded_rows=sel_pad[strategy],
                        specs=sel_specs[strategy])))
        for (t_bits, reduce_every), members in range_grps.items():
            ests = [_planner.estimate_range_cost(
                stats, t_bits=t_bits, reduce_every=reduce_every,
                want_addresses=want,
                ell=_planner.DEFAULT_ELL if ell is None else max(ell, 1),
                padded_rows=pad) for (want, ell, pad) in members]
            family = ("range_select" if any(m[0] for m in members)
                      else "range_count")
            groups.append(_planner.GroupEstimate(
                family, len(members), _planner.CostEstimate(
                    family, bits=sum(e.bits for e in ests),
                    rounds=max(e.rounds for e in ests),
                    dispatches=max(e.dispatches for e in ests))))
        for ests in agg_grps.values():
            groups.append(_planner.GroupEstimate(
                "aggregate", len(ests), _planner.CostEstimate(
                    "aggregate", bits=sum(e.bits for e in ests),
                    rounds=max(e.rounds for e in ests),
                    dispatches=max(e.dispatches for e in ests))))
        if embed_ests:      # one fused contraction: dispatches don't stack
            groups.append(_planner.GroupEstimate(
                "embed", len(embed_ests), _planner.CostEstimate(
                    "embed", bits=sum(e.bits for e in embed_ests),
                    rounds=max(e.rounds for e in embed_ests),
                    dispatches=max(e.dispatches for e in embed_ests))))
        if joins["pkfk"]:       # one fused group: batched match matrices
            ests = [_planner.estimate_pkfk_cost(
                stats, _planner.DBStats.of(p.right))
                for p in joins["pkfk"]]
            groups.append(_planner.GroupEstimate(
                "pkfk", len(ests), _planner.CostEstimate(
                    "pkfk", bits=sum(e.bits for e in ests),
                    rounds=max(e.rounds for e in ests),
                    dispatches=max(e.dispatches for e in ests))))
        if joins["equi"]:       # phases fuse; per-value rounds stay per job
            ests = [_planner.estimate_equijoin_cost(
                stats, _planner.DBStats.of(p.right),
                fake_values=p.padding.values) for p in joins["equi"]]
            groups.append(_planner.GroupEstimate(
                "equi", len(ests), _planner.CostEstimate(
                    "equi", bits=sum(e.bits for e in ests),
                    rounds=max(e.rounds for e in ests),
                    dispatches=max(e.dispatches for e in ests))))
        return _planner.explain_batch_groups(stats, groups)

    # -- execution ----------------------------------------------------------
    def run(self, plan: Plan, *,
            relation: Optional[str] = None) -> QueryResult:
        """Execute one logical plan (the B = 1 case of :meth:`run_batch`)."""
        return self.run_batch([plan], relation=relation)[0]

    def run_batch(self, plans: Sequence[Plan], *,
                  relation: Optional[str] = None) -> List[QueryResult]:
        """Execute B logical plans, fusing each protocol round per group.

        ``relation`` picks the registry entry the batch runs against (the
        default relation when omitted). Per-plan keys derive from THAT
        relation's root key in list order — key streams are per relation,
        so batches against different relations never perturb each other's
        transcripts. Every plan is cost-planned exactly as :meth:`run`
        would (AUTO selections see the batch's live group sizes, so with
        ``round_cost_bits > 0`` a borderline query is steered onto a group
        whose fused rounds it can ride for free), then compatible plans
        are grouped and executed through the batched round engine:

        * Count/Select groups stack their shared predicates — each match,
          Q&A and address round is one fused dispatch + one interpolation.
        * Range plans group by (bit-width, ``reduce_every``); the whole
          group's SS-SUB bit-vectors ripple in ONE ``(c, 2B, n, t)`` carry
          chain — one ``ripple_carry`` dispatch per bit-round, one
          degree-reduction re-share per boundary for the batch.
        * Every oblivious fetch in the batch — one_round, tree and range
          one-hot matrices plus PK/FK join match matrices (a zero-match
          one_round/range query contributes a 0-row block; a tree query
          that counted ℓ=0 skips the fetch, as sequentially) — stacks
          into a single cross-group ``ss_matmul``.
        * Equijoins fuse per phase: one column-open interpolation, one
          X-side layer-1 matmul for the group, Y-side per distinct right
          relation.
        * Aggregates fuse per family: SUM/AVG numerators share ONE masked
          contraction per bit-width (conditional AVG denominators ride the
          batch's count phase), MIN/MAX tournaments march in lockstep per
          (bit-width, ``reduce_every``) group.

        Results come back in plan order; each query's rows and
        ``CostLedger`` are bit-identical to running it sequentially (ledgers
        record the query's own protocol cost, never a groupmate's padding).

        A forced ``one_tuple`` whose predicate turns out to hit ℓ ≠ 1 tuples
        raises :class:`CardinalityError` (as sequentially); with
        ``strategy="auto"`` the query replans onto one_round/tree inside the
        batch, reusing the learned count.
        """
        (out,) = self.run_batch_multi([(relation, plans)])
        return out

    def run_batch_multi(self, batches: Sequence[
            Tuple[Optional[str], Sequence[Plan]]]) -> List[List[QueryResult]]:
        """Execute several relations' batches with ONE fused fetch wave.

        ``batches`` is a sequence of ``(relation, plans)`` pairs — the
        scheduler's simultaneously-closing batch groups. Each batch runs
        exactly as :meth:`run_batch` would (its own relation's key stream,
        its own grouping, its own ledgers — batches are never mixed), but
        all batches advance to the cross-group fetch boundary first and
        their cloud-side fetch ``ss_matmul``s execute as ONE dispatch wave
        when the relations' dataplanes share a dispatch pool
        (:func:`repro.core.queries.rounds.fetch_fusion_multi`). Results and
        ledgers are bit-identical to running the batches back-to-back;
        returns one result list per batch, in ``batches`` order.
        """
        works = [self._prepare_batch(list(plans), self._entry(relation))
                 for relation, plans in batches]
        fetched = rounds.fetch_fusion_multi(
            self.backend,
            [(w.rel, w.fetch_jobs, w.join_entries) for w in works])
        return [self._finish_batch(w, f) for w, f in zip(works, fetched)]

    def _prepare_batch(self, plans: Sequence[Plan],
                       ent: AttachedRelation) -> _BatchWork:
        """Group, plan and run every pre-fetch round of one batch."""
        db, rel = ent.db, ent.rel
        stats = self.stats(ent.name)
        results: Dict[int, QueryResult] = {}
        count_grp: List[_Slot] = []
        sel_grp: Dict[str, List[_Slot]] = {"one_tuple": [], "one_round": [],
                                           "tree": []}
        range_grps: Dict[Tuple[int, int], List[_Slot]] = {}
        agg_sum_grps: Dict[int, List[_Slot]] = {}
        agg_mm_grps: Dict[Tuple[int, int], List[_Slot]] = {}
        embed_grp: List[_Slot] = []
        pkfk_grp: List[_Slot] = []
        equi_grp: List[_Slot] = []
        auto_slots: List[_Slot] = []
        group_sizes: Dict[str, int] = {s: 0 for s in sel_grp}
        group_rounds: Dict[str, int] = {}

        def join_group(slot: _Slot, strategy: str,
                       ell: Optional[int]) -> None:
            """Track a group's size and deepest member's estimated rounds
            so later AUTO riders are priced at their true marginal depth."""
            slot.strategy = strategy
            group_sizes[strategy] += 1
            ell_eff = (1 if strategy == "one_tuple" else
                       _planner.DEFAULT_ELL if ell is None else max(ell, 1))
            if slot.spec is not None:
                est = _planner.estimate_pattern_cost(
                    stats, slot.spec, select=strategy, ell=ell_eff,
                    padded_rows=slot.plan.padding.rows)
            else:
                est = _planner.estimate_select_cost(
                    strategy, stats, ell=ell_eff,
                    padded_rows=slot.plan.padding.rows)
            group_rounds[strategy] = max(group_rounds.get(strategy, 0),
                                         est.rounds)
            sel_grp[strategy].append(slot)

        for idx, plan in enumerate(plans):
            slot = _Slot(idx, plan, self._next_key(ent))
            if isinstance(plan, Count):
                slot.column, slot.pattern, slot.spec = _lower_match(
                    db, plan.where, "Count predicate")
                count_grp.append(slot)
            elif isinstance(plan, Select):
                slot.column, slot.pattern, slot.spec = _lower_match(
                    db, plan.where, "Select predicate")
                if slot.spec is not None and plan.strategy == "one_tuple":
                    raise _planner.PlanNotSupported(
                        plan.where, "one_tuple select (the §3.2.1 single-"
                        "tuple map is the exact-equality special case — "
                        "pattern predicates run one_round or tree)")
                if plan.strategy == AUTO:
                    auto_slots.append(slot)   # assigned once groups known
                    continue
                if plan.strategy == "one_tuple" and plan.padding.rows:
                    raise ValueError(
                        "one_tuple returns the single tuple directly and "
                        "cannot pad its output size — use one_round/tree "
                        "(or auto, which excludes one_tuple when padding is "
                        "requested)")
                join_group(slot, plan.strategy, plan.expected_matches)
            elif isinstance(plan, (RangeCount, RangeSelect)):
                slot.column = resolve_column(db, plan.where.column)
                gk = (db.numeric_bits.get(slot.column, -1),
                      plan.reduce_every)
                range_grps.setdefault(gk, []).append(slot)
            elif isinstance(plan, Aggregate):
                slot.column = resolve_column(db, plan.column)
                if plan.where is not None:
                    slot.pred_column = resolve_column(db, plan.where.column)
                t_bits = db.numeric_bits.get(slot.column, -1)
                if plan.op in ("sum", "avg"):
                    agg_sum_grps.setdefault(t_bits, []).append(slot)
                else:
                    agg_mm_grps.setdefault((t_bits, plan.reduce_every),
                                           []).append(slot)
            elif isinstance(plan, EmbedLookup):
                embed_grp.append(slot)
            elif isinstance(plan, Join):
                self._validate_join(plan)
                (pkfk_grp if plan.kind == "pkfk" else equi_grp).append(slot)
            else:
                raise _planner.PlanNotSupported(plan)

        # AUTO selections plan against the batch's live group sizes and
        # depths (riding a non-empty group costs only the rounds the rider
        # adds beyond its deepest member — marginal round pricing; with
        # round_cost_bits=0 this reduces to sequential planning). Pattern
        # predicates choose among their eligible strategies only.
        for slot in auto_slots:
            if slot.spec is not None:
                chosen = _planner.choose_pattern_strategy(
                    stats, slot.spec, ell=slot.plan.expected_matches,
                    padded_rows=slot.plan.padding.rows,
                    round_cost_bits=self.round_cost_bits,
                    group_sizes=group_sizes,
                    group_rounds=group_rounds).strategy
            else:
                chosen = _planner.choose_select_strategy(
                    stats, ell=slot.plan.expected_matches,
                    padded_rows=slot.plan.padding.rows,
                    round_cost_bits=self.round_cost_bits,
                    group_sizes=group_sizes,
                    group_rounds=group_rounds).strategy
            join_group(slot, chosen, slot.plan.expected_matches)

        be = self.backend
        # deferred cross-group fetch: (slot, strategy, addresses) per job
        fetch_jobs: List[rounds.FetchJob] = []
        fetch_meta: List[Tuple[_Slot, str, List[int]]] = []

        # conditional AVG denominators ride the batch's §3.1 count phase:
        # their MatchJobs fuse into the same dispatch as explicit Counts.
        avg_cnt_slots: List[_Slot] = []
        for group in agg_sum_grps.values():
            for s in group:
                if s.plan.op == "avg" and s.plan.where is not None:
                    s.key, s.fetch_key = jax.random.split(s.key)
                    avg_cnt_slots.append(s)

        if count_grp or avg_cnt_slots:
            counts = rounds.count_phase(be, rel, [
                rounds.MatchJob(s.column, s.pattern, s.key,
                                s.ledger, s.spec) for s in count_grp] + [
                rounds.MatchJob(s.pred_column, s.plan.where.pattern,
                                s.fetch_key, s.ledger)
                for s in avg_cnt_slots])
            for s, cnt in zip(count_grp, counts):
                results[s.idx] = QueryResult(plan=s.plan, ledger=s.ledger,
                                             strategy="count", count=cnt)
            for s, cnt in zip(avg_cnt_slots, counts[len(count_grp):]):
                s.known_count = cnt

        # -- embedding lookups: every job's one-hots share in one program
        # and the whole group contracts in ONE ss_matmul per shard ---------
        if embed_grp:
            embs = embed_q.embed_phase(be, rel, [
                embed_q.EmbedJob(tokens=s.plan.tokens, key=s.key,
                                 ledger=s.ledger, verify=s.plan.verify)
                for s in embed_grp])
            for s, emb in zip(embed_grp, embs):
                results[s.idx] = QueryResult(plan=s.plan, ledger=s.ledger,
                                             strategy="embed",
                                             embeddings=emb)

        # -- aggregation: SUM/AVG numerators fuse per bit-width, MIN/MAX
        # tournaments per (bit-width, reduce_every) ------------------------
        for group in agg_sum_grps.values():
            sums = aggregate.agg_sum_phase(be, rel, [
                aggregate.SumJob(
                    value_column=s.column, key=s.key, ledger=s.ledger,
                    pred_column=s.pred_column,
                    pattern=(s.plan.where.pattern if s.plan.where is not None
                             else None),
                    verify=s.plan.verify) for s in group])
            for s, total in zip(group, sums):
                if s.plan.op == "sum":
                    results[s.idx] = QueryResult(
                        plan=s.plan, ledger=s.ledger, strategy="agg_sum",
                        value=total)
                elif s.plan.where is not None:
                    results[s.idx] = QueryResult(
                        plan=s.plan, ledger=s.ledger, strategy="agg_avg",
                        value=(total / s.known_count
                               if s.known_count else None),
                        count=s.known_count)
                else:                   # denominator is the public n
                    results[s.idx] = QueryResult(
                        plan=s.plan, ledger=s.ledger, strategy="agg_avg",
                        value=(total / db.n_tuples if db.n_tuples
                               else None))
        for (_, reduce_every), group in agg_mm_grps.items():
            outs = aggregate.agg_minmax_rounds(be, rel, [
                aggregate.MinMaxJob(
                    value_column=s.column, key=s.key, ledger=s.ledger,
                    pred_column=s.pred_column,
                    pattern=(s.plan.where.pattern if s.plan.where is not None
                             else None),
                    verify=s.plan.verify, op=s.plan.op,
                    reduce_every=reduce_every) for s in group])
            for s, (val, cnt) in zip(group, outs):
                results[s.idx] = QueryResult(
                    plan=s.plan, ledger=s.ledger,
                    strategy=f"agg_{s.plan.op}", value=val, count=cnt)

        # -- one_tuple: batched count phase, then the Alg 3 map round -------
        if sel_grp["one_tuple"]:
            group = sel_grp["one_tuple"]
            keys = [jax.random.split(s.key) for s in group]
            ells = rounds.count_phase(be, rel, [
                rounds.MatchJob(s.column, s.pattern, kc, s.ledger)
                for s, (kc, _) in zip(group, keys)])
            verified: List[Tuple[_Slot, jax.Array]] = []
            for s, (_, k_sel), ell in zip(group, keys, ells):
                if ell == 1:
                    verified.append((s, k_sel))
                    continue
                if s.plan.strategy != AUTO:
                    raise CardinalityError(
                        f"select_one_tuple needs ℓ=1, predicate has {ell}"
                        " — use select_one_round/select_tree", count=ell)
                # hint was wrong: replan with the learned ℓ on a fresh key;
                # the slot's ledger keeps the aborted count-phase cost.
                chosen = _planner.choose_select_strategy(
                    stats, ell=ell, padded_rows=s.plan.padding.rows,
                    round_cost_bits=self.round_cost_bits,
                    group_sizes=group_sizes,
                    group_rounds=group_rounds).strategy
                s.key, s.known_count = self._next_key(ent), ell
                join_group(s, chosen, ell)
            if verified:
                rows = rounds.one_tuple_round(be, rel, [
                    rounds.MatchJob(s.column, s.pattern, k_sel,
                                    s.ledger) for s, k_sel in verified])
                for (s, _), row in zip(verified, rows):
                    results[s.idx] = QueryResult(
                        plan=s.plan, ledger=s.ledger, strategy="one_tuple",
                        rows=[row])

        # -- one_round: fused Phase 1; fetch joins the cross-group matmul ---
        if sel_grp["one_round"]:
            group = sel_grp["one_round"]
            keys = [jax.random.split(s.key) for s in group]
            addrs = rounds.match_all_round(be, rel, [
                rounds.MatchJob(s.column, s.pattern, kp, s.ledger, s.spec)
                for s, (kp, _) in zip(group, keys)])
            for s, (_, kf), a in zip(group, keys, addrs):
                fetch_jobs.append(rounds.FetchJob(kf, a, s.ledger,
                                                  s.plan.padding.rows))
                fetch_meta.append((s, "one_round", a))

        # -- tree: batched count phase, lockstep Q&A rounds -----------------
        if sel_grp["tree"]:
            group = sel_grp["tree"]
            keys = [jax.random.split(s.key, 3) for s in group]
            need = [(s, kc) for s, (kc, _, _) in zip(group, keys)
                    if s.known_count is None]
            ells = rounds.count_phase(be, rel, [
                rounds.MatchJob(s.column, s.pattern, kc, s.ledger, s.spec)
                for s, kc in need])
            for (s, _), ell in zip(need, ells):
                s.known_count = ell
            live: List[Tuple[_Slot, jax.Array, jax.Array]] = []
            for s, (_, kp, kf) in zip(group, keys):
                if s.known_count == 0:
                    results[s.idx] = QueryResult(
                        plan=s.plan, ledger=s.ledger, strategy="tree",
                        rows=[], addresses=[])
                else:
                    live.append((s, kp, kf))
            if live:
                addrs = rounds.tree_rounds(be, rel, [
                    rounds.TreeJob(s.column, s.pattern, kp,
                                   s.ledger, s.spec, ell=s.known_count,
                                   branching=s.plan.branching)
                    for s, kp, _ in live])
                for (s, _, kf), a in zip(live, addrs):
                    fetch_jobs.append(rounds.FetchJob(kf, a, s.ledger,
                                                      s.plan.padding.rows))
                    fetch_meta.append((s, "tree", a))

        # -- ranges: one fused ripple per (bit-width, reduce_every) group ---
        for (_, reduce_every), group in range_grps.items():
            jobs = []
            for s in group:
                if isinstance(s.plan, RangeSelect):
                    k_ind, s.fetch_key = jax.random.split(s.key)
                else:
                    k_ind = s.key
                jobs.append(rounds.RangeJob(
                    s.column, s.plan.where.lo, s.plan.where.hi, k_ind,
                    s.ledger, reduce_every=reduce_every,
                    want_addresses=isinstance(s.plan, RangeSelect)))
            for s, out in zip(group, rounds.range_rounds(be, rel, jobs)):
                if isinstance(s.plan, RangeCount):
                    results[s.idx] = QueryResult(
                        plan=s.plan, ledger=s.ledger,
                        strategy="range_count", count=out)
                else:
                    fetch_jobs.append(rounds.FetchJob(
                        s.fetch_key, out, s.ledger, s.plan.padding.rows))
                    fetch_meta.append((s, "range_select", out))

        # -- pkfk joins: match matrices become rows of the shared fetch -----
        join_jobs: List[rounds.JoinJob] = []
        join_entries: List[rounds.FetchEntry] = []
        if pkfk_grp:
            join_jobs = [rounds.JoinJob(
                s.plan.right, resolve_column(db, s.plan.on[0]),
                resolve_column(s.plan.right, s.plan.on[1]), s.key, s.ledger,
                match_method=_planner.choose_match_method(
                    stats, s.plan.match_method))
                for s in pkfk_grp]
            join_entries = rounds.join_match_round(be, rel, join_jobs)

        return _BatchWork(plans=plans, db=db, rel=rel, results=results,
                          fetch_jobs=fetch_jobs, fetch_meta=fetch_meta,
                          join_jobs=join_jobs, join_entries=join_entries,
                          pkfk_grp=pkfk_grp, equi_grp=equi_grp)

    def _finish_batch(self, work: _BatchWork,
                      fetched: Tuple[List[List[List[str]]], List["rounds.Shares"]]
                      ) -> List[QueryResult]:
        """Consume the fused fetch output and run the post-fetch rounds."""
        be = self.backend
        db, results = work.db, work.results
        rows_list, extra_sh = fetched
        for (s, strat, a), r in zip(work.fetch_meta, rows_list):
            results[s.idx] = QueryResult(plan=s.plan, ledger=s.ledger,
                                         strategy=strat, rows=r,
                                         addresses=a)
        if work.pkfk_grp:
            join_rows = rounds.join_emit_round(db, work.join_jobs,
                                               extra_sh)
            for s, r in zip(work.pkfk_grp, join_rows):
                results[s.idx] = QueryResult(plan=s.plan,
                                             ledger=s.ledger,
                                             strategy="pkfk", rows=r)

        # -- equijoins: phases fused across the group -----------------------
        if work.equi_grp:
            equi_rows = rounds.equijoin_rounds(be, work.rel, [
                rounds.EquiJob(
                    s.plan.right, resolve_column(db, s.plan.on[0]),
                    resolve_column(s.plan.right, s.plan.on[1]), s.key,
                    s.ledger, padded_values=s.plan.padding.values)
                for s in work.equi_grp])
            for s, r in zip(work.equi_grp, equi_rows):
                results[s.idx] = QueryResult(plan=s.plan, ledger=s.ledger,
                                             strategy="equi", rows=r)
        return [results[i] for i in range(len(work.plans))]

    @staticmethod
    def _validate_join(plan: Join) -> None:
        if plan.padding.rows:
            raise ValueError("joins take Padding.fake_values (fake join "
                             "jobs), not Padding.rows")
        if plan.kind == "pkfk" and plan.padding.values:
            raise ValueError(
                "pkfk_join's output size is always n_y (one reducer per "
                "child tuple) — nothing to hide; Padding.fake_values "
                "applies to kind='equi' only")

    # -- conveniences (build the plan, run it) ------------------------------
    def count(self, column: ColumnRef, pattern: str, *,
              relation: Optional[str] = None) -> QueryResult:
        return self.run(Count(Eq(column, pattern)), relation=relation)

    def select(self, column: ColumnRef, pattern: str, *,
               strategy: str = AUTO, expected_matches: Optional[int] = None,
               padding: Padding = Padding.NONE,
               branching: Optional[int] = None,
               relation: Optional[str] = None) -> QueryResult:
        return self.run(Select(Eq(column, pattern), strategy=strategy,
                               expected_matches=expected_matches,
                               padding=padding, branching=branching),
                        relation=relation)

    def like(self, column: ColumnRef, pattern: str, *,
             count_only: bool = False, strategy: str = AUTO,
             expected_matches: Optional[int] = None,
             padding: Padding = Padding.NONE,
             relation: Optional[str] = None) -> QueryResult:
        """``column LIKE pattern`` — a pattern-engine Select (or Count
        with ``count_only=True``). Wildcard-free patterns lower to the
        exact Eq path; ``lit%``/``%lit``/``%lit%``/``l_t`` run the
        prefix / suffix / substring / masked matchers."""
        where = Like(column, pattern)
        if count_only:
            return self.run(Count(where), relation=relation)
        return self.run(Select(where, strategy=strategy,
                               expected_matches=expected_matches,
                               padding=padding), relation=relation)

    def range_count(self, column: ColumnRef, lo: int, hi: int, *,
                    reduce_every: int = 0,
                    relation: Optional[str] = None) -> QueryResult:
        return self.run(RangeCount(Between(column, lo, hi),
                                   reduce_every=reduce_every),
                        relation=relation)

    def range_select(self, column: ColumnRef, lo: int, hi: int, *,
                     reduce_every: int = 0,
                     padding: Padding = Padding.NONE,
                     relation: Optional[str] = None) -> QueryResult:
        return self.run(RangeSelect(Between(column, lo, hi),
                                    reduce_every=reduce_every,
                                    padding=padding), relation=relation)

    def aggregate(self, op: str, column: ColumnRef, *,
                  where: Optional[Eq] = None, verify: bool = False,
                  reduce_every: int = 0,
                  relation: Optional[str] = None) -> QueryResult:
        return self.run(Aggregate(op, column, where=where, verify=verify,
                                  reduce_every=reduce_every),
                        relation=relation)

    def join(self, right: SecretSharedDB,
             on: Tuple[ColumnRef, ColumnRef], *, kind: str = "pkfk",
             padding: Padding = Padding.NONE,
             relation: Optional[str] = None) -> QueryResult:
        return self.run(Join(right=right, on=on, kind=kind, padding=padding),
                        relation=relation)
