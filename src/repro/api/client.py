"""QueryClient — the unified user-side facade over the secret-shared clouds.

One object replaces the nine free query functions: it owns the root PRNG key
(per-query keys derive via ``jax.random.fold_in``, no manual threading), the
backend choice (``repro.api.backends`` registry), the optional MapReduce
executor, and the cost-based selection planner (``repro.api.planner``).
Every query family returns the same :class:`~.plans.QueryResult`.

The client *delegates* to the original protocol implementations in
``repro.core.queries`` — it adds planning and ergonomics, never new protocol
steps — so a client-run query produces exactly the rows and ``CostLedger``
of the equivalent legacy call (asserted by ``tests/test_api.py``).
"""
from __future__ import annotations

import itertools
from typing import Optional, Tuple, Union

import jax

from ..core.costs import CostLedger
from ..core.engine import SecretSharedDB
from ..core.queries import (CardinalityError, count_query, equijoin,
                            pkfk_join, range_count, range_select,
                            select_one_round, select_one_tuple, select_tree)
from . import planner as _planner
from .backends import BackendLike, get_backend
from .executor import MapReduceExecutor
from .plans import (AUTO, Between, ColumnRef, Count, Eq, Join, Padding, Plan,
                    QueryResult, RangeCount, RangeSelect, Select,
                    resolve_column)


class QueryClient:
    """Authorized-user facade over one outsourced relation.

    db:              the user's secret-shared relation (``core.outsource``).
    key:             root PRNG key (or int seed); per-query keys derive via
                     ``fold_in`` so identical plans replay identically.
    backend:         registered backend name or Backend instance.
    executor:        optional :class:`MapReduceExecutor` — fans every
                     cloud-side map phase out over fault-tolerant splits.
    round_cost_bits: planner latency weight — how many communication bits
                     one extra protocol round is worth to this user.
    """

    def __init__(self, db: SecretSharedDB, key, *,
                 backend: BackendLike = "jnp",
                 executor: Optional[MapReduceExecutor] = None,
                 round_cost_bits: int = 0):
        self.db = db
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        self._root_key = key
        self.backend = get_backend(backend)
        if executor is not None:
            self.backend = executor.wrap(self.backend)
        self.executor = executor
        self.round_cost_bits = round_cost_bits
        self._query_counter = itertools.count()

    # -- keys ---------------------------------------------------------------
    def _next_key(self) -> jax.Array:
        return jax.random.fold_in(self._root_key, next(self._query_counter))

    # -- planning -----------------------------------------------------------
    def stats(self) -> _planner.DBStats:
        return _planner.DBStats.of(self.db)

    def explain(self, plan: Select):
        """Planner's eligible strategies for ``plan``, cheapest first."""
        cands = _planner.candidate_estimates(
            self.stats(), ell=plan.expected_matches,
            padded_rows=plan.padding.rows)
        return sorted(cands,
                      key=lambda e: (e.score(self.round_cost_bits), e.rounds))

    # -- execution ----------------------------------------------------------
    def run(self, plan: Plan) -> QueryResult:
        if isinstance(plan, Count):
            return self._run_count(plan)
        if isinstance(plan, Select):
            return self._run_select(plan)
        if isinstance(plan, RangeCount):
            return self._run_range_count(plan)
        if isinstance(plan, RangeSelect):
            return self._run_range_select(plan)
        if isinstance(plan, Join):
            return self._run_join(plan)
        raise TypeError(f"not a logical plan: {plan!r}")

    def _run_count(self, plan: Count) -> QueryResult:
        col = resolve_column(self.db, plan.where.column)
        cnt, led = count_query(self._next_key(), self.db, col,
                               plan.where.pattern, backend=self.backend)
        return QueryResult(plan=plan, ledger=led, strategy="count", count=cnt)

    def _run_select(self, plan: Select) -> QueryResult:
        col = resolve_column(self.db, plan.where.column)
        pat = plan.where.pattern
        key = self._next_key()
        strategy = plan.strategy
        if strategy == AUTO:
            strategy = _planner.choose_select_strategy(
                self.stats(), ell=plan.expected_matches,
                padded_rows=plan.padding.rows,
                round_cost_bits=self.round_cost_bits).strategy

        led = CostLedger()
        if strategy == "one_tuple":
            if plan.padding.rows:
                raise ValueError(
                    "one_tuple returns the single tuple directly and cannot "
                    "pad its output size — use one_round/tree (or auto, "
                    "which excludes one_tuple when padding is requested)")
            try:
                rows, led = select_one_tuple(key, self.db, col, pat,
                                             ledger=led,
                                             backend=self.backend)
                return QueryResult(plan=plan, ledger=led,
                                   strategy="one_tuple", rows=rows)
            except CardinalityError as e:
                if plan.strategy != AUTO:
                    raise
                # cardinality hint was wrong (ℓ ≠ 1): replan with the true ℓ
                # the aborted count phase just learned, on a fresh key.
                # ``led`` keeps the aborted attempt's count-phase cost so the
                # result's ledger reports everything the protocol spent.
                strategy = _planner.choose_select_strategy(
                    self.stats(), ell=e.count,
                    padded_rows=plan.padding.rows,
                    round_cost_bits=self.round_cost_bits).strategy
                key, known_count = self._next_key(), e.count
        else:
            known_count = None

        if strategy == "one_round":
            rows, addrs, led = select_one_round(
                key, self.db, col, pat, ledger=led,
                padded_rows=plan.padding.rows, backend=self.backend)
        else:                                   # tree
            rows, addrs, led = select_tree(
                key, self.db, col, pat, ledger=led, branching=plan.branching,
                padded_rows=plan.padding.rows, known_count=known_count,
                backend=self.backend)
        return QueryResult(plan=plan, ledger=led, strategy=strategy,
                           rows=rows, addresses=addrs)

    def _run_range_count(self, plan: RangeCount) -> QueryResult:
        # Range counting is pure element-wise share arithmetic (SS-SUB
        # ripple + sum) — it has no registry hotspot, so the client's
        # backend/executor choice does not apply to this family.
        col = resolve_column(self.db, plan.where.column)
        cnt, led = range_count(self._next_key(), self.db, col, plan.where.lo,
                               plan.where.hi, reduce_every=plan.reduce_every)
        return QueryResult(plan=plan, ledger=led, strategy="range_count",
                           count=cnt)

    def _run_range_select(self, plan: RangeSelect) -> QueryResult:
        col = resolve_column(self.db, plan.where.column)
        rows, addrs, led = range_select(
            self._next_key(), self.db, col, plan.where.lo, plan.where.hi,
            reduce_every=plan.reduce_every, padded_rows=plan.padding.rows,
            backend=self.backend)
        return QueryResult(plan=plan, ledger=led, strategy="range_select",
                           rows=rows, addresses=addrs)

    def _run_join(self, plan: Join) -> QueryResult:
        col_l = resolve_column(self.db, plan.on[0])
        col_r = resolve_column(plan.right, plan.on[1])
        if plan.padding.rows:
            raise ValueError("joins take Padding.fake_values (fake join "
                             "jobs), not Padding.rows")
        key = self._next_key()
        if plan.kind == "pkfk":
            if plan.padding.values:
                raise ValueError(
                    "pkfk_join's output size is always n_y (one reducer per "
                    "child tuple) — nothing to hide; Padding.fake_values "
                    "applies to kind='equi' only")
            rows, led = pkfk_join(key, self.db, plan.right, col_l, col_r,
                                  backend=self.backend)
        else:
            rows, led = equijoin(key, self.db, plan.right, col_l, col_r,
                                 padded_values=plan.padding.values,
                                 backend=self.backend)
        return QueryResult(plan=plan, ledger=led, strategy=plan.kind,
                           rows=rows)

    # -- conveniences (build the plan, run it) ------------------------------
    def count(self, column: ColumnRef, pattern: str) -> QueryResult:
        return self.run(Count(Eq(column, pattern)))

    def select(self, column: ColumnRef, pattern: str, *,
               strategy: str = AUTO, expected_matches: Optional[int] = None,
               padding: Padding = Padding.NONE,
               branching: Optional[int] = None) -> QueryResult:
        return self.run(Select(Eq(column, pattern), strategy=strategy,
                               expected_matches=expected_matches,
                               padding=padding, branching=branching))

    def range_count(self, column: ColumnRef, lo: int, hi: int, *,
                    reduce_every: int = 0) -> QueryResult:
        return self.run(RangeCount(Between(column, lo, hi),
                                   reduce_every=reduce_every))

    def range_select(self, column: ColumnRef, lo: int, hi: int, *,
                     reduce_every: int = 0,
                     padding: Padding = Padding.NONE) -> QueryResult:
        return self.run(RangeSelect(Between(column, lo, hi),
                                    reduce_every=reduce_every,
                                    padding=padding))

    def join(self, right: SecretSharedDB,
             on: Tuple[ColumnRef, ColumnRef], *, kind: str = "pkfk",
             padding: Padding = Padding.NONE) -> QueryResult:
        return self.run(Join(right=right, on=on, kind=kind, padding=padding))
