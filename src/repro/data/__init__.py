from .pipeline import (TokenStream, synthetic_relation, make_lm_batches,
                       Prefetcher)

__all__ = ["TokenStream", "synthetic_relation", "make_lm_batches",
           "Prefetcher"]
