"""Data pipeline: deterministic synthetic sources + sharded host feed.

Two producers:
  * ``synthetic_relation`` — string relations for the secret-shared query
    engine (names/departments/salaries with controllable skew — the paper's
    selection/"skewed data" discussion needs multi-occurrence predicates);
  * ``TokenStream`` / ``make_lm_batches`` — reproducible LM token batches
    (counter-based PRNG: worker-restart-safe; a restarted job re-derives
    batch N exactly, which the checkpoint/restart test asserts).

``Prefetcher`` overlaps host batch synthesis with device compute (depth-k
background thread), the standard input-pipeline overlap trick.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, List, Optional, Sequence

import jax
import numpy as np

FIRST = ["Adam", "John", "Eve", "Mia", "Noah", "Lily", "Omar", "Zoe",
         "Ivan", "Nina"]
LAST = ["Smith", "Taylor", "Williams", "Brown", "Lee", "Patel", "Cohen",
        "Garcia"]
DEPT = ["Sale", "Design", "HR", "R-D"]


def synthetic_relation(n: int, *, seed: int = 0, skew: float = 0.0
                       ) -> List[List[str]]:
    """Employee-style relation. skew>0 biases FirstName toward FIRST[1]
    ("John") so predicates hit multiple tuples (the paper's ℓ>1 regime)."""
    rng = np.random.default_rng(seed)
    rows = []
    for i in range(n):
        if skew and rng.random() < skew:
            first = FIRST[1]
        else:
            first = FIRST[rng.integers(len(FIRST))]
        rows.append([
            f"E{100 + i}",
            first,
            LAST[rng.integers(len(LAST))],
            str(int(rng.integers(500, 8000))),
            DEPT[rng.integers(len(DEPT))],
        ])
    return rows


class TokenStream:
    """Counter-based deterministic token batches: batch(i) is a pure
    function of (seed, i) — restartable mid-stream with no state."""

    def __init__(self, vocab_size: int, batch: int, seq: int, *,
                 seed: int = 0):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def batch_at(self, index: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, index))
        toks = rng.integers(0, self.vocab, size=(self.batch, self.seq + 1),
                            dtype=np.int32)
        # learnable structure: next token correlated with current
        toks[:, 1:] = (toks[:, :-1] + rng.integers(
            0, 7, size=(self.batch, self.seq), dtype=np.int32)) % self.vocab
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch_at(i)
            i += 1


def make_lm_batches(cfg, shape_batch: int, seq: int, *, seed: int = 0
                    ) -> TokenStream:
    return TokenStream(cfg.vocab_size, shape_batch, seq, seed=seed)


class Prefetcher:
    """Depth-k background prefetch of host batches (+ optional device_put)."""

    def __init__(self, it: Iterator, depth: int = 2, sharding=None):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._sharding = sharding
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                if self._sharding is not None:
                    item = jax.tree.map(
                        lambda a: jax.device_put(a, self._sharding), item)
                self._q.put(item)

        self._th = threading.Thread(target=worker, daemon=True)
        self._th.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
