"""train_step / serve_step builders — the functions the dry-run lowers.

``make_train_step`` returns a pure ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` function with microbatched gradient
accumulation under ``lax.scan``: each microbatch's backward finishes with the
gradient psum, which XLA overlaps with the next microbatch's forward
(compute/comm overlap); the optimizer applies once per global batch.

``make_serve_steps`` returns (prefill_fn, decode_fn) for the serving shapes.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import train_loss, prefill, decode_step
from ..models.config import ModelConfig
from .optim import AdamWConfig, AdamWState, apply_updates
from .compress import compress_grads, decompress_grads


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig, *,
                    grad_accum: int = 1, compress: bool = False):
    """Build the jittable global train step."""

    def loss_fn(params, mb):
        loss, metrics = train_loss(params, cfg, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch):
        if grad_accum > 1:
            # batch arrives MICROBATCH-MAJOR: (accum, B/accum, ...) with the
            # accum axis unsharded. Scanning over xs slices the leading
            # unsharded axis — slicing a *sharded* batch axis would force
            # XLA to all-gather the batch and replicate every microbatch
            # (measured: 16x flops inflation; see EXPERIMENTS.md §Perf).
            def accum(carry, mb):
                gsum, lsum = carry
                (loss, _), grads = grad_fn(params, mb)
                grads = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     gsum, grads)
                return (grads, lsum + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), batch)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss_sum / grad_accum
        else:
            (loss, _), grads = grad_fn(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

        if compress:
            grads = decompress_grads(compress_grads(grads))

        params, opt_state, om = apply_updates(opt_cfg, params, grads,
                                              opt_state)
        return params, opt_state, {"loss": loss, **om}

    return train_step


def make_serve_steps(cfg: ModelConfig):
    def prefill_fn(params, batch):
        return prefill(params, cfg, batch)

    def decode_fn(params, cache, cache_len, batch):
        return decode_step(params, cfg, cache, cache_len, batch)

    return prefill_fn, decode_fn
