"""AdamW + LR schedules from scratch (no optax dependency).

Optimizer state inherits the parameter sharding automatically (the pytrees
are parallel), so ZeRO-like sharding falls out of the param specs. Supports
optional int8 gradient compression with error feedback for the DP all-reduce
(see ``compress.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1),
                       1.0)
    prog = jnp.clip((step.astype(jnp.float32) - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState
                  ) -> tuple[Any, AdamWState, dict]:
    """One AdamW step. grads: f32 pytree matching params."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {
        "lr": lr, "grad_norm": gnorm}
