"""Gradient compression for the data-parallel all-reduce.

int8 block-quantized gradients with per-block scales: 4× less DP traffic.
``compress -> psum -> decompress`` is numerically a stochastic-rounding-free
uniform quantizer; the train loop keeps an error-feedback buffer so the
quantization error is re-injected next step (1-bit-Adam-style residual
correction), preserving convergence.

Used opt-in (``make_train_step(compress=True)``); the dry-run baseline keeps
exact f32 gradient reduction.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


class Compressed(NamedTuple):
    q: Any       # int8 pytree
    scale: Any   # f32 per-block scales


def _quant_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    flat = g.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_leaf(q: jax.Array, scale: jax.Array, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads(grads) -> Tuple[Compressed, Any]:
    qs = jax.tree.map(_quant_leaf, grads)
    q = jax.tree.map(lambda t: t[0], qs,
                     is_leaf=lambda t: isinstance(t, tuple))
    s = jax.tree.map(lambda t: t[1], qs,
                     is_leaf=lambda t: isinstance(t, tuple))
    return Compressed(q, s), jax.tree.map(lambda g: g.shape, grads)


def decompress_grads(packed) -> Any:
    comp, shapes = packed
    return jax.tree.map(_dequant_leaf, comp.q, comp.scale, shapes)


def error_feedback_update(grads, residual):
    """g' = g + residual;  new_residual = g' - dequant(quant(g'))."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                grads)
    corrected = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                             grads, residual)
    packed = compress_grads(corrected)
    deq = decompress_grads(packed)
    new_res = jax.tree.map(lambda c, d: c - d, corrected, deq)
    return deq, new_res
