from .optim import AdamWConfig, AdamWState, init_state, apply_updates
from .step import make_train_step, make_serve_steps

__all__ = ["AdamWConfig", "AdamWState", "init_state", "apply_updates",
           "make_train_step", "make_serve_steps"]
