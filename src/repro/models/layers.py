"""Core transformer layers: norms, RoPE variants, flash attention (GQA/MLA),
MLPs and MoE. Pure JAX, dtype-explicit, pjit-friendly (no device logic here —
sharding is applied by name in ``repro.sharding``).

Attention is computed **blockwise** (online-softmax flash algorithm via
``lax.scan`` over KV blocks) so the 32k/500k shape cells never materialize a
(T×T) score tensor — this is what keeps the dry-run memory_analysis inside a
v5e's HBM.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype) -> Array:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int) -> Array:
    return jnp.zeros((d,), jnp.float32)


def rmsnorm(x: Array, w: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + w)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (neox-style full or partial rotary — chatglm's "RoPE 2d" applies the
# rotation to half the head dim, leaving the rest pass-through)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, fraction: float, theta: float) -> Array:
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot))
    return jnp.asarray(inv)                       # (rot/2,)


def apply_rope(x: Array, positions: Array, inv_freq: Array) -> Array:
    """x: (..., T, n_heads, head_dim); positions: (..., T)."""
    rot = inv_freq.shape[0] * 2
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    ang = positions[..., :, None].astype(jnp.float32) * inv_freq  # (..., T, r/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., None, :]
    cos = cos[..., None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                              axis=-1)
    return jnp.concatenate([rotated.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# Flash attention (GQA) — lax.scan over KV blocks with online softmax
# ---------------------------------------------------------------------------

def _softcap(scores: Array, cap: float) -> Array:
    if cap and cap > 0:
        return cap * jnp.tanh(scores / cap)
    return scores


def flash_attention(q: Array, k: Array, v: Array, *,
                    q_offset: Array | int = 0,
                    kv_len: Optional[Array] = None,
                    window: Optional[int] = None,
                    causal: bool = True,
                    block_k: int = 512,
                    softcap: float = 0.0) -> Array:
    """Blockwise attention.

    q: (B, Tq, Hq, D); k, v: (B, Tk, Hkv, D). Hq % Hkv == 0 (GQA).
    q_offset: absolute position of q[0] (decode: cache length).
    kv_len:  number of valid kv entries (None = all of Tk).
    window:  sliding-window width (None = full).
    Returns (B, Tq, Hq, D).
    """
    b, tq, hq, d = q.shape
    _, tk, hkv, _ = k.shape
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    # f32-once upcast. (bf16 operands + preferred_element_type=f32 was
    # evaluated in §Perf — XLA:CPU re-legalizes per block and the measured
    # traffic REGRESSED 73→84 s on qwen train; on TPU the bf16 form would
    # win — revisit with a real-hardware profile. Refuted here, reverted.)
    qf = (q.astype(jnp.float32) * scale).reshape(b, tq, hkv, g, d)
    qf = jnp.einsum("btkgd->bkgtd", qf)             # (B, Hkv, G, Tq, D)
    kf = jnp.einsum("bskd->bksd", k.astype(jnp.float32))
    vf = jnp.einsum("bskd->bksd", v.astype(jnp.float32))

    block_k = min(block_k, tk)
    n_blocks = (tk + block_k - 1) // block_k
    tk_pad = n_blocks * block_k
    if tk_pad != tk:
        pad = [(0, 0), (0, 0), (0, tk_pad - tk), (0, 0)]
        kf = jnp.pad(kf, pad)
        vf = jnp.pad(vf, pad)
    kf = kf.reshape(b, hkv, n_blocks, block_k, d)
    vf = vf.reshape(b, hkv, n_blocks, block_k, d)

    q_pos = jnp.asarray(q_offset) + jnp.arange(tq)           # (Tq,)
    valid_len = jnp.asarray(kv_len if kv_len is not None else tk)

    def step(carry, blk):
        m_prev, l_prev, acc = carry
        k_blk, v_blk, blk_idx = blk
        kv_pos = blk_idx * block_k + jnp.arange(block_k)      # (bk,)
        s = jnp.einsum("bkgtd,bksd->bkgts", qf, k_blk)        # scores
        s = _softcap(s, softcap)
        mask = kv_pos[None, :] < valid_len                    # padding
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = mask & (q_pos[:, None] - kv_pos[None, :] < window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgts,bksd->bkgtd", p, v_blk)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, hkv, g, tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, tq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, g, tq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kf.swapaxes(0, 2).swapaxes(1, 2),     # (n_blocks, B, Hkv, bk, D)
         vf.swapaxes(0, 2).swapaxes(1, 2),
         jnp.arange(n_blocks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.einsum("bkgtd->btkgd", out).reshape(b, tq, hq, d)
    return out.astype(q.dtype)


def decode_attention(q: Array, k: Array, v: Array, *,
                     kv_len: Array, window: Optional[Array],
                     softcap: float = 0.0, n_chunks: int = 64) -> Array:
    """Single-token attention against a (possibly sequence-sharded) cache.

    Chunked log-sum-exp combine (§Perf opt): the cache is viewed as
    (n_chunks, chunk); per-chunk max/sum/weighted-V are computed
    independently and merged with LSE weights. When the cache's sequence
    axis is sharded over the mesh "model" axis and n_chunks is a multiple
    of its size, every per-chunk term is shard-LOCAL and the only
    cross-shard traffic is the tiny (B,H,D)-sized combine — replacing the
    full per-layer cache all-gather that the scan-flash path costs on a
    sharded cache (measured 4.3 s -> ~0 of collective time on
    qwen decode_32k; EXPERIMENTS.md §Perf).

    q: (B, 1, Hq, D); k, v: (B, S, Hkv, D). Returns (B, 1, Hq, D).
    """
    b, t, hq, d = q.shape
    s = k.shape[1]
    hkv = k.shape[2]
    g = hq // hkv
    assert t == 1
    nc = n_chunks
    chunk = s // nc
    scale = 1.0 / math.sqrt(d)
    cdt = k.dtype
    kc = k.reshape(b, nc, chunk, hkv, d)
    vc = v.reshape(b, nc, chunk, hkv, d)
    qf = (q.astype(jnp.float32) * scale).astype(cdt)
    qf = qf.reshape(b, hkv, g, d)
    # scores per chunk: (B, nc, Hkv, G, chunk), f32 accumulation
    sc = jnp.einsum("bkgd,bnckd->bnkgc", qf, kc,
                    preferred_element_type=jnp.float32)
    sc = _softcap(sc, softcap)
    pos = (jnp.arange(nc)[:, None] * chunk
           + jnp.arange(chunk)[None, :])                  # (nc, chunk)
    mask = pos < kv_len
    if window is not None:
        mask = mask & ((kv_len - 1) - pos < window)
    sc = jnp.where(mask[None, :, None, None, :], sc, NEG_INF)
    m_c = jnp.max(sc, axis=-1)                            # (B,nc,Hkv,G)
    p = jnp.exp(sc - m_c[..., None])
    l_c = jnp.sum(p, axis=-1)
    acc_c = jnp.einsum("bnkgc,bnckd->bnkgd", p.astype(cdt), vc,
                       preferred_element_type=jnp.float32)
    m = jnp.max(m_c, axis=1)                              # (B,Hkv,G)
    w_c = jnp.exp(m_c - m[:, None])                       # (B,nc,Hkv,G)
    l = jnp.sum(w_c * l_c, axis=1)
    out = jnp.sum(w_c[..., None] * acc_c, axis=1)
    out = out / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, 1, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (with optional QKV bias, QK-norm, sliding window)
# ---------------------------------------------------------------------------

def attention_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def attention_qkv(p: dict, cfg: ModelConfig, x: Array, positions: Array,
                  inv_freq: Array) -> Tuple[Array, Array, Array]:
    b, t, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, t, cfg.n_heads, hd)
    k = k.reshape(b, t, cfg.n_kv_heads, hd)
    v = v.reshape(b, t, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, inv_freq)
    k = apply_rope(k, positions, inv_freq)
    return q, k, v


def attention_forward(p: dict, cfg: ModelConfig, x: Array, *,
                      positions: Array, inv_freq: Array,
                      window: Optional[int], causal: bool = True,
                      kv_cache: Optional[Tuple[Array, Array]] = None,
                      cache_len: Optional[Array] = None,
                      cross_kv: Optional[Tuple[Array, Array]] = None,
                      ) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """Full/prefill path when kv_cache is None; decode path otherwise.

    kv_cache: (k_cache, v_cache) of shape (B, S_max, Hkv, D); cache_len is the
    number of valid entries BEFORE this call. Returns (out, new_cache).
    """
    b, t, _ = x.shape
    q, k, v = attention_qkv(p, cfg, x, positions, inv_freq)
    if cross_kv is not None:
        k, v = cross_kv
        out = flash_attention(q, k, v, causal=False,
                              softcap=cfg.logit_softcap)
        new_cache = None
    elif kv_cache is None:
        out = flash_attention(q, k, v, window=window,
                              softcap=cfg.logit_softcap)
        new_cache = None
    else:
        k_cache, v_cache = kv_cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cache_len, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cache_len, axis=1)
        s_max = k_cache.shape[1]
        if t == 1 and s_max >= 1024 and s_max % 64 == 0:
            # chunked-LSE decode: shard-local per-chunk stats (see
            # decode_attention docstring)
            out = decode_attention(q, k_cache, v_cache,
                                   kv_len=cache_len + t, window=window,
                                   softcap=cfg.logit_softcap)
        else:
            out = flash_attention(q, k_cache, v_cache,
                                  q_offset=cache_len, kv_len=cache_len + t,
                                  window=window, softcap=cfg.logit_softcap)
        new_cache = (k_cache, v_cache)
    out = out.reshape(b, t, cfg.n_heads * cfg.resolved_head_dim)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLA attention (minicpm3 / deepseek-style multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    qk_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    return {
        "wdq": dense_init(ks[0], d, cfg.q_lora_rank, dtype),
        "q_norm": rmsnorm_init(cfg.q_lora_rank),
        "wuq": dense_init(ks[1], cfg.q_lora_rank, cfg.n_heads * qk_head,
                          dtype),
        "wdkv": dense_init(ks[2], d,
                           cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype),
        "kv_norm": rmsnorm_init(cfg.kv_lora_rank),
        "wuk": dense_init(ks[3], cfg.kv_lora_rank,
                          cfg.n_heads * cfg.qk_nope_head_dim, dtype),
        "wuv": dense_init(ks[4], cfg.kv_lora_rank,
                          cfg.n_heads * cfg.v_head_dim, dtype),
        "wo": dense_init(ks[5], cfg.n_heads * cfg.v_head_dim, d, dtype),
    }


def mla_forward(p: dict, cfg: ModelConfig, x: Array, *, positions: Array,
                inv_freq_rope: Array,
                kv_cache: Optional[Tuple[Array, Array]] = None,
                cache_len: Optional[Array] = None
                ) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """MLA: queries from a low-rank latent; K/V expanded from a compressed
    cache (c_kv, k_pe) — the cache holds kv_lora_rank + rope dims per token.

    kv_cache: (c_kv_cache (B,S,r_kv), k_pe_cache (B,S,r_pe)).
    """
    b, t, _ = x.shape
    nh = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    ql = rmsnorm(x @ p["wdq"], p["q_norm"], cfg.norm_eps)
    q = (ql @ p["wuq"]).reshape(b, t, nh, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    q_pe = apply_rope(q_pe, positions, inv_freq_rope)

    dkv = x @ p["wdkv"]                                   # (B,T,r_kv+r_pe)
    c_kv = rmsnorm(dkv[..., :cfg.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope(dkv[..., None, cfg.kv_lora_rank:], positions,
                      inv_freq_rope)[:, :, 0]             # (B,T,r_pe)

    if kv_cache is not None:
        ckv_cache, kpe_cache = kv_cache
        ckv_cache = jax.lax.dynamic_update_slice_in_dim(
            ckv_cache, c_kv.astype(ckv_cache.dtype), cache_len, axis=1)
        kpe_cache = jax.lax.dynamic_update_slice_in_dim(
            kpe_cache, k_pe.astype(kpe_cache.dtype), cache_len, axis=1)
        c_all, kpe_all = ckv_cache, kpe_cache
        kv_len = cache_len + t
        q_offset = cache_len
        new_cache = (ckv_cache, kpe_cache)
        if t == 1:
            # ---- ABSORBED decode (DeepSeek-style; §Perf opt) ----------
            # Fold W_uk into the query and W_uv out of the attention so
            # scores/values contract directly against the COMPRESSED
            # cache: 2·H·S·r flops instead of expanding S·r·H·(dn+dv)
            # K/V rows every step, and — crucially — the only cross-shard
            # traffic over a sequence-sharded cache is the softmax
            # normalizer + an (B,H,r) psum, not a cache gather.
            scale = 1.0 / math.sqrt(dn + dr)
            # f32 einsums: bf16×bf16→f32 dots compile for TPU but XLA:CPU's
            # DotThunk cannot execute them, and CPU is the test substrate.
            # (bf16 operands measured t_mem 0.081 vs 0.125 s here — re-apply
            # on real TPU; §Perf hillclimb 2 notes.)
            wuk = p["wuk"].reshape(cfg.kv_lora_rank, nh, dn)
            q_eff = jnp.einsum("bthd,rhd->bthr",
                               q_nope.astype(jnp.float32),
                               wuk.astype(jnp.float32))
            s_lat = jnp.einsum("bthr,bsr->bhts", q_eff,
                               c_all.astype(jnp.float32))
            s_pe = jnp.einsum("bthd,bsd->bhts", q_pe.astype(jnp.float32),
                              kpe_all.astype(jnp.float32))
            s_all = (s_lat + s_pe) * scale               # (B,H,1,S) f32
            pos = jnp.arange(c_all.shape[1])
            mask = pos[None, None, None, :] < kv_len
            s_all = jnp.where(mask, s_all, NEG_INF)
            probs = jax.nn.softmax(s_all, axis=-1)
            o_lat = jnp.einsum("bhts,bsr->bthr", probs,
                               c_all.astype(jnp.float32))
            wuv = p["wuv"].reshape(cfg.kv_lora_rank, nh, dv)
            out = jnp.einsum("bthr,rhd->bthd", o_lat,
                             wuv.astype(jnp.float32))
            out = out.reshape(b, t, nh * dv).astype(x.dtype)
            return out @ p["wo"], new_cache
    else:
        c_all, kpe_all = c_kv, k_pe
        kv_len = None
        q_offset = 0
        new_cache = None

    # expand K/V from the compressed cache (naive MLA — used for
    # prefill/train where q-length makes expansion compute-optimal)
    s = c_all.shape[1]
    k_nope = (c_all @ p["wuk"]).reshape(b, s, nh, dn)
    v = (c_all @ p["wuv"]).reshape(b, s, nh, dv)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(kpe_all[:, :, None, :],
                                          (b, s, nh, dr))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    # pad V up to the QK head dim so flash_attention can share one D
    out = flash_attention(q_full, k,
                          jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                      (0, dn + dr - dv))),
                          q_offset=q_offset, kv_len=kv_len)
    out = out[..., :dv].reshape(b, t, nh * dv)
    return out @ p["wo"], new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, f: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {"w_gate": dense_init(ks[0], d, f, dtype),
            "w_up": dense_init(ks[1], d, f, dtype),
            "w_down": dense_init(ks[2], f, d, dtype)}


def mlp_forward(p: dict, x: Array, act: str = "silu") -> Array:
    gate = x @ p["w_gate"]
    up = x @ p["w_up"]
    if act == "silu":
        h = jax.nn.silu(gate) * up
    elif act == "geglu":
        h = jax.nn.gelu(gate, approximate=True) * up
    else:
        h = jax.nn.gelu(gate, approximate=True) * up
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# MoE (top-k routing). Baseline dispatch: one-hot einsum (GShard-style).
# Optimized dispatch ("sort"): argsort + capacity gather (see §Perf).
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig, dtype) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                   * scale).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                 * scale).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   / math.sqrt(f)).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], d, f * cfg.n_shared_experts, dtype)
    return p


def _moe_einsum_dispatch(p: dict, cfg: ModelConfig, x2: Array,
                         weights: Array, idx: Array) -> Array:
    """Dense one-hot dispatch: every token-expert pair through an einsum."""
    n, d = x2.shape
    e = cfg.n_experts
    comb = jnp.zeros((n, e), x2.dtype)
    for j in range(cfg.top_k):
        comb = comb + jax.nn.one_hot(idx[:, j], e,
                                     dtype=x2.dtype) * weights[:, j:j + 1]
    xe = jnp.einsum("ne,nd->end", (comb > 0).astype(x2.dtype), x2)
    h = jnp.einsum("end,edf->enf", xe, p["w_gate"])
    u = jnp.einsum("end,edf->enf", xe, p["w_up"])
    h = jax.nn.silu(h) * u
    y = jnp.einsum("enf,efd->end", h, p["w_down"])
    return jnp.einsum("end,ne->nd", y, comb).astype(x2.dtype)


def _moe_sort_dispatch(p: dict, cfg: ModelConfig, x2: Array,
                       weights: Array, idx: Array) -> Array:
    """Capacity-based sort/gather dispatch: compute only top-k·T expert rows.

    FLOPs: E·C·(3·d·f) with C = ceil(T·k/E · capacity_factor) — the useful
    compute, vs. the einsum path's extra O(T·E·d) dispatch matmuls.
    """
    n, d = x2.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(math.ceil(n * k / e * cfg.capacity_factor))
    flat_expert = idx.reshape(-1)                          # (n·k,)
    flat_weight = weights.reshape(-1)
    flat_token = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sw = flat_expert[order], flat_token[order], flat_weight[order]
    pos = jnp.arange(n * k) - jnp.searchsorted(se, se, side="left")
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)        # overflow slot
    buf = jnp.zeros((e * cap + 1, d), x2.dtype).at[slot].set(x2[st])
    xe = buf[:e * cap].reshape(e, cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(e * cap, d)
    y = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)], axis=0)
    out = jnp.zeros((n, d), x2.dtype)
    out = out.at[st].add(y[slot] * sw[:, None].astype(y.dtype) *
                         keep[:, None])
    return out


def moe_forward(p: dict, cfg: ModelConfig, x: Array) -> Array:
    b, t, d = x.shape
    x2 = x.reshape(b * t, d)
    logits = (x2.astype(jnp.float32) @ p["router"])
    weights, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), cfg.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    weights = weights.astype(x.dtype)
    if cfg.moe_dispatch == "sort":
        y = _moe_sort_dispatch(p, cfg, x2, weights, idx)
    else:
        y = _moe_einsum_dispatch(p, cfg, x2, weights, idx)
    if cfg.n_shared_experts:
        y = y + mlp_forward(p["shared"], x2, cfg.act)
    return y.reshape(b, t, d)
