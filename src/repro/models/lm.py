"""Unified LM: decoder-only, encoder-decoder, VLM/audio-prefixed, SSM and
hybrid families behind one functional API.

    params = init_params(key, cfg)
    loss, metrics = train_loss(params, cfg, batch)
    logits, cache = prefill(params, cfg, batch)
    logits, cache = decode_step(params, cfg, cache, cache_len, tokens)

Layers are stacked (leading L axis) and driven by ``lax.scan`` so the HLO is
O(1) in depth (fast multi-pod compiles); ``cfg.remat`` wraps the block body in
``jax.checkpoint`` for training. Per-layer heterogeneity (gemma3's 5:1
local:global window pattern) is expressed as a scanned per-layer window array
— global layers get window = 2³¹−1, so one homogeneous block program serves
every layer (no lax.switch, no per-layer HLO duplication).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from . import layers as L
from . import ssm as S

Array = jax.Array
GLOBAL_WINDOW = np.int32(2**31 - 1)


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, *, cross: bool = False,
                causal_attn: bool = True) -> dict:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"ln1": L.rmsnorm_init(cfg.d_model)}
    fam = cfg.family
    if fam == "ssm":
        p["ssm"] = S.ssm_init(ks[0], cfg, dt)
        return p
    if cfg.attn_type == "mla":
        p["attn"] = L.mla_init(ks[0], cfg, dt)
    else:
        p["attn"] = L.attention_init(ks[0], cfg, dt)
    if cfg.hybrid_ssm:
        p["ssm"] = S.ssm_init(ks[1], cfg, dt)
        p["mix_a"] = jnp.zeros((), jnp.float32)
        p["mix_s"] = jnp.zeros((), jnp.float32)
    if cross:
        p["cross"] = L.attention_init(ks[2], cfg, dt)
        p["ln_cross"] = L.rmsnorm_init(cfg.d_model)
    p["ln2"] = L.rmsnorm_init(cfg.d_model)
    if cfg.n_experts and fam == "moe":
        p["moe"] = L.moe_init(ks[3], cfg, dt)
    else:
        p["mlp"] = L.mlp_init(ks[3], cfg.d_model, cfg.d_ff, dt)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    dt = _dtype(cfg)
    k_emb, k_blocks, k_enc, k_head, k_fe = jax.random.split(key, 5)
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model),
                                    jnp.float32)
                  * (1.0 / math.sqrt(cfg.d_model))).astype(dt),
        "final_norm": L.rmsnorm_init(cfg.d_model),
    }
    cross = cfg.n_enc_layers > 0
    blk_keys = jax.random.split(k_blocks, cfg.n_layers)
    params["blocks"] = jax.vmap(
        lambda k: _block_init(k, cfg, cross=cross))(blk_keys)
    if cross:
        enc_cfg = cfg  # same dims for encoder stack
        enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: _block_init(k, enc_cfg))(enc_keys)
        params["enc_norm"] = L.rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab_size,
                                         dt)
    if cfg.frontend:
        params["frontend_proj"] = L.dense_init(k_fe, cfg.frontend_dim,
                                               cfg.d_model, dt)
    return params


def layer_windows(cfg: ModelConfig) -> Array:
    """Per-layer sliding-window widths; GLOBAL_WINDOW means full attention."""
    ws = []
    for i in range(cfg.n_layers):
        w = cfg.window_for_layer(i)
        ws.append(GLOBAL_WINDOW if w is None else np.int32(w))
    return jnp.asarray(ws, jnp.int32)


# ---------------------------------------------------------------------------
# block application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _block_apply(cfg: ModelConfig, p: dict, x: Array, *, positions: Array,
                 inv_freq: Array, window: Array, mode: str,
                 cache: Optional[dict], cache_len,
                 enc_out: Optional[Array],
                 causal: bool = True) -> Tuple[Array, Optional[dict]]:
    """mode: 'train' (no cache) | 'prefill' (build cache) | 'decode' (use)."""
    new_cache: Dict[str, Any] = {}
    fam = cfg.family
    if fam == "ssm":
        h, sc = S.ssm_forward(
            p["ssm"], cfg, L.rmsnorm(x, p["ln1"], cfg.norm_eps),
            cache=cache["ssm"] if mode == "decode" else None,
            return_cache=(mode == "prefill"))
        if sc is not None:
            new_cache["ssm"] = sc
        return x + h, (new_cache or None)

    y = L.rmsnorm(x, p["ln1"], cfg.norm_eps)
    win = None if cfg.sliding_window is None else window
    kv_in = cache["kv"] if mode in ("prefill", "decode") else None
    if cfg.attn_type == "mla":
        a, kv = L.mla_forward(p["attn"], cfg, y, positions=positions,
                              inv_freq_rope=inv_freq,
                              kv_cache=kv_in, cache_len=cache_len)
    else:
        a, kv = L.attention_forward(p["attn"], cfg, y, positions=positions,
                                    inv_freq=inv_freq, window=win,
                                    causal=causal,
                                    kv_cache=kv_in, cache_len=cache_len)
    if kv is not None:
        new_cache["kv"] = kv
    if cfg.hybrid_ssm:
        s_out, sc = S.ssm_forward(
            p["ssm"], cfg, y,
            cache=cache["ssm"] if mode == "decode" else None,
            return_cache=(mode == "prefill"))
        if sc is not None:
            new_cache["ssm"] = sc
        ga = jax.nn.sigmoid(p["mix_a"]).astype(a.dtype)
        gs = jax.nn.sigmoid(p["mix_s"]).astype(a.dtype)
        x = x + a * ga + s_out * gs
    else:
        x = x + a
    if cfg.n_enc_layers and (enc_out is not None or mode == "decode"):
        yc = L.rmsnorm(x, p["ln_cross"], cfg.norm_eps)
        if mode == "decode":
            cross_kv = cache["cross"]
        else:
            # compute cross K/V from encoder output (train/prefill)
            b, te, _ = enc_out.shape
            hd = cfg.resolved_head_dim
            ck = (enc_out @ p["cross"]["wk"]).reshape(b, te, cfg.n_kv_heads,
                                                      hd)
            cv = (enc_out @ p["cross"]["wv"]).reshape(b, te, cfg.n_kv_heads,
                                                      hd)
            if cfg.qkv_bias:
                ck += p["cross"]["bk"].reshape(1, 1, cfg.n_kv_heads, hd)
                cv += p["cross"]["bv"].reshape(1, 1, cfg.n_kv_heads, hd)
            cross_kv = (ck, cv)
        if mode in ("prefill", "decode"):
            new_cache["cross"] = cross_kv
        c, _ = L.attention_forward(p["cross"], cfg, yc, positions=positions,
                                   inv_freq=inv_freq, window=None,
                                   cross_kv=cross_kv)
        x = x + c
    y2 = L.rmsnorm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        x = x + L.moe_forward(p["moe"], cfg, y2)
    else:
        x = x + L.mlp_forward(p["mlp"], y2, cfg.act)
    return x, (new_cache or None)


def _run_blocks(cfg: ModelConfig, blocks: dict, x: Array, *,
                positions: Array, caches: Optional[dict], cache_len,
                enc_out: Optional[Array], mode: str) -> Tuple[Array,
                                                              Optional[dict]]:
    inv_freq = L.rope_freqs(
        cfg.resolved_head_dim if cfg.attn_type != "mla"
        else cfg.qk_rope_head_dim,
        cfg.rope_fraction, cfg.rope_theta)
    windows = layer_windows(cfg)

    def body(carry, xs):
        if caches is None:
            lp, win = xs
            cache_l = None
        else:
            lp, win, cache_l = xs
        h, nc = _block_apply(cfg, lp, carry, positions=positions,
                             inv_freq=inv_freq, window=win, mode=mode,
                             cache=cache_l, cache_len=cache_len,
                             enc_out=enc_out)
        return h, nc

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body)

    xs = (blocks, windows) if caches is None else (blocks, windows, caches)
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, xs)
    else:
        new_list = []
        for i in range(cfg.n_layers):
            xi = jax.tree.map(lambda a: a[i], xs)
            x, nc = body(x, xi)
            new_list.append(nc)
        new_caches = (jax.tree.map(lambda *a: jnp.stack(a), *new_list)
                      if new_list and new_list[0] is not None else None)
    return x, new_caches


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed_tokens(params: dict, cfg: ModelConfig, tokens: Array,
                  *, embeds: Optional[Array] = None) -> Array:
    """Token embeddings, three sources: precomputed ``embeds`` (a serving
    frontend already ran the lookups — e.g. obliviously, through the
    ``EmbedLookup`` query family), the in-graph private path
    (``cfg.private_embed``), or the plaintext table."""
    if embeds is not None:
        x = embeds.astype(_dtype(cfg))
    elif cfg.private_embed:
        from .private_embed import private_lookup_inline
        x = private_lookup_inline(params, cfg, tokens)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _prefix_inputs(params: dict, cfg: ModelConfig, batch: dict) -> Array:
    """Assemble the input sequence: [modality prefix] + token embeddings."""
    x = _embed_tokens(params, cfg, batch["tokens"],
                      embeds=batch.get("embeds"))
    if cfg.frontend == "vit" and "patches" in batch:
        pre = (batch["patches"].astype(_dtype(cfg))
               @ params["frontend_proj"])
        x = jnp.concatenate([pre, x], axis=1)
    return x


def _logits(params: dict, cfg: ModelConfig, x: Array) -> Array:
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return logits.astype(jnp.float32)


def _encode(params: dict, cfg: ModelConfig, frames: Array) -> Array:
    """Audio/text encoder stack (seamless): bidirectional attention."""
    x = frames.astype(_dtype(cfg)) @ params["frontend_proj"]
    positions = jnp.arange(x.shape[1])[None, :]
    inv_freq = L.rope_freqs(cfg.resolved_head_dim, cfg.rope_fraction,
                            cfg.rope_theta)

    def body(carry, lp):
        h, _ = _block_apply(cfg, lp, carry, positions=positions,
                            inv_freq=inv_freq, window=GLOBAL_WINDOW,
                            mode="train", cache=None, cache_len=None,
                            enc_out=None, causal=False)
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# public API: train / prefill / decode
# ---------------------------------------------------------------------------

def forward(params: dict, cfg: ModelConfig, batch: dict) -> Array:
    """Training/eval forward -> logits (B, T, V)."""
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = _encode(params, cfg, batch["frames"])
    x = _prefix_inputs(params, cfg, batch)
    positions = jnp.arange(x.shape[1])[None, :]
    x, _ = _run_blocks(cfg, params["blocks"], x, positions=positions,
                       caches=None, cache_len=None, enc_out=enc_out,
                       mode="train")
    return _logits(params, cfg, x)


def train_loss(params: dict, cfg: ModelConfig, batch: dict
               ) -> Tuple[Array, dict]:
    logits = forward(params, cfg, batch)
    labels = batch["labels"]
    if cfg.frontend == "vit" and "patches" in batch:
        logits = logits[:, batch["patches"].shape[1]:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    mask = (labels >= 0).astype(jnp.float32)
    labels_safe = jnp.maximum(labels, 0)
    take = jnp.take_along_axis(logp, labels_safe[..., None], axis=-1)[..., 0]
    loss = -jnp.sum(take * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "tokens": jnp.sum(mask)}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0) -> dict:
    """Stacked (L-leading) decode cache for the arch family."""
    dt = _dtype(cfg)
    hd = cfg.resolved_head_dim
    cache: Dict[str, Any] = {}
    if cfg.family != "ssm":
        if cfg.attn_type == "mla":
            cache["kv"] = (
                jnp.zeros((cfg.n_layers, batch, max_len, cfg.kv_lora_rank),
                          dt),
                jnp.zeros((cfg.n_layers, batch, max_len,
                           cfg.qk_rope_head_dim), dt))
        else:
            cache["kv"] = (
                jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd),
                          dt),
                jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd),
                          dt))
    if cfg.family == "ssm" or cfg.hybrid_ssm:
        sc = S.ssm_cache_init(cfg, batch, dt)
        cache["ssm"] = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), sc)
    if cfg.n_enc_layers:
        cache["cross"] = (
            jnp.zeros((cfg.n_layers, batch, enc_len, cfg.n_kv_heads, hd), dt),
            jnp.zeros((cfg.n_layers, batch, enc_len, cfg.n_kv_heads, hd), dt))
    return cache


def prefill(params: dict, cfg: ModelConfig, batch: dict, *,
            max_len: Optional[int] = None) -> Tuple[Array, dict]:
    """Run the prompt through the model, returning last-token logits and a
    decode-ready cache of capacity ``max_len`` (default: prompt length)."""
    enc_out = None
    if cfg.n_enc_layers:
        enc_out = _encode(params, cfg, batch["frames"])
    x = _prefix_inputs(params, cfg, batch)
    b, t, _ = x.shape
    max_len = max_len or t
    positions = jnp.arange(t)[None, :]
    caches = init_cache(cfg, b, max_len,
                        enc_len=enc_out.shape[1] if enc_out is not None
                        else 0)
    x, new_caches = _run_blocks(cfg, params["blocks"], x,
                                positions=positions,
                                caches=caches, cache_len=jnp.int32(0),
                                enc_out=enc_out, mode="prefill")
    return _logits(params, cfg, x[:, -1:]), (new_caches or caches)


def decode_step(params: dict, cfg: ModelConfig, cache: dict, cache_len,
                batch: dict) -> Tuple[Array, dict]:
    """One-token autoregressive step against a filled cache.

    ``batch["embeds"]``, when present, carries this step's already-computed
    token embeddings (e.g. an oblivious ``EmbedLookup`` served off-graph);
    otherwise the embeddings come from ``batch["tokens"]`` as usual."""
    x = _embed_tokens(params, cfg, batch["tokens"],
                      embeds=batch.get("embeds"))
    positions = (jnp.asarray(cache_len)[None, None]
                 + jnp.arange(x.shape[1])[None, :])
    x, new_caches = _run_blocks(cfg, params["blocks"], x,
                                positions=positions, caches=cache,
                                cache_len=jnp.asarray(cache_len, jnp.int32),
                                enc_out=None, mode="decode")
    return _logits(params, cfg, x), new_caches
