"""Unified model configuration covering all 10 assigned architectures.

One dataclass drives the whole zoo; family-specific fields are ignored by
families that don't use them. Full configs live in ``repro.configs.<arch>``;
every full config has a reduced ``smoke()`` sibling for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default d_model // n_heads

    # --- attention ---------------------------------------------------------
    attn_type: str = "gqa"           # gqa | mla | none
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0       # chatglm "RoPE 2d": rotary on half dims
    qkv_bias: bool = False
    qk_norm: bool = False            # gemma3
    sliding_window: Optional[int] = None
    global_every: int = 0            # gemma3 5:1 -> every 6th layer global
    logit_softcap: float = 0.0

    # --- MLA (minicpm3) ------------------------------------------------------
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_dispatch: str = "einsum"     # einsum (baseline) | sort (optimized)
    capacity_factor: float = 1.25

    # --- SSM / Mamba2 --------------------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- hybrid (hymba): parallel attention + SSM heads ----------------------
    hybrid_ssm: bool = False

    # --- encoder-decoder (seamless) ------------------------------------------
    n_enc_layers: int = 0

    # --- modality frontend stubs ---------------------------------------------
    frontend: Optional[str] = None   # "vit" (internvl) | "audio" (seamless)
    n_prefix: int = 0                # vision prefix length (patches)
    frontend_dim: int = 0            # raw frame/patch embedding dim

    # --- training/runtime ----------------------------------------------------
    embed_scale: bool = False        # gemma: x *= sqrt(d_model)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    act: str = "silu"                # silu | gelu | geglu
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    private_embed: bool = False      # paper integration: SSS embedding lookup

    # -------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def window_for_layer(self, layer: int) -> Optional[int]:
        """gemma3 pattern: every ``global_every``-th layer is global."""
        if self.sliding_window is None:
            return None
        if self.global_every and (layer + 1) % self.global_every == 0:
            return None              # global layer
        return self.sliding_window

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        n_q = self.n_heads * hd
        n_kv = self.n_kv_heads * hd

        def attn_params() -> int:
            if self.attn_type == "mla":
                q = (self.d_model * self.q_lora_rank
                     + self.q_lora_rank * self.n_heads
                     * (self.qk_nope_head_dim + self.qk_rope_head_dim))
                kv = (d * (self.kv_lora_rank + self.qk_rope_head_dim)
                      + self.kv_lora_rank * self.n_heads
                      * (self.qk_nope_head_dim + self.v_head_dim))
                o = self.n_heads * self.v_head_dim * d
                return q + kv + o
            if self.attn_type == "none":
                return 0
            return d * n_q + 2 * d * n_kv + n_q * d

        def mlp_params() -> int:
            if self.n_experts:
                expert = 3 * d * f
                shared = self.n_shared_experts * 3 * d * f
                return self.n_experts * expert + shared + d * self.n_experts
            return 3 * d * f

        def ssm_params() -> int:
            if not (self.family in ("ssm",) or self.hybrid_ssm):
                return 0
            di, ns = self.d_inner, self.ssm_state
            nh = self.ssm_n_heads
            in_p = d * (2 * di + 2 * ns + nh)
            out_p = di * d
            return in_p + out_p + di * self.ssm_conv + 3 * nh

        per_layer = attn_params() + mlp_params() + ssm_params() + 2 * d
        total = self.n_layers * per_layer + v * d + d
        if self.n_enc_layers:
            total += self.n_enc_layers * (d * n_q + 2 * d * n_kv + n_q * d
                                          + 3 * d * f + 2 * d)
            total += self.n_layers * (d * n_q + 2 * d * n_kv + n_q * d)  # cross
        if not self.tie_embeddings:
            total += v * d
        return total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                       LONG_500K)
