"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
compute inside fixed-size chunks (MXU-friendly einsums) + a linear recurrence
over chunk states (``lax.scan``). Decode is the O(1)-per-token recurrent
update on an (H, P, N) state — the SSM analog of a KV cache, and the reason
``long_500k`` is runnable for SSM/hybrid archs.

TP note: all head-indexed parameters are stored **head-shaped** — (D, H, P)
instead of (D, H·P) — so sharding the H axis on the mesh "model" axis is a
pure layout choice (no misaligned flat-dim reshapes, no surprise
collectives). See repro/sharding.py.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import dense_init

Array = jax.Array


class SSMCache(NamedTuple):
    """Decode-time recurrent state."""
    conv_x: Array   # (B, k-1, H, P) rolling conv buffer for x
    conv_B: Array   # (B, k-1, N)
    conv_C: Array   # (B, k-1, N)
    state: Array    # (B, H, P, N)


def ssm_init(key, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    n = cfg.ssm_state
    h = cfg.ssm_n_heads
    pd = cfg.ssm_head_dim
    ks = jax.random.split(key, 8)
    scale = 1.0 / math.sqrt(d)
    dt = jnp.exp(jax.random.uniform(ks[6], (h,), jnp.float32)
                 * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        "w_z": (jax.random.normal(ks[0], (d, h, pd), jnp.float32)
                * scale).astype(dtype),
        "w_x": (jax.random.normal(ks[1], (d, h, pd), jnp.float32)
                * scale).astype(dtype),
        "w_B": dense_init(ks[2], d, n, dtype),
        "w_C": dense_init(ks[3], d, n, dtype),
        "w_dt": dense_init(ks[4], d, h, dtype),
        "conv_x": (jax.random.normal(ks[5], (cfg.ssm_conv, h, pd),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_B": (jax.random.normal(ks[7], (cfg.ssm_conv, n),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (cfg.ssm_conv, n),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_bx": jnp.zeros((h, pd), dtype),
        "conv_bB": jnp.zeros((n,), dtype),
        "conv_bC": jnp.zeros((n,), dtype),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "norm": jnp.zeros((h, pd), jnp.float32),
        "out_proj": (jax.random.normal(ks[6], (h, pd, d), jnp.float32)
                     * (1.0 / math.sqrt(h * pd))).astype(dtype),
    }


def _conv1d(x: Array, w: Array, b: Array, hist: Optional[Array]) -> Array:
    """Causal depthwise conv along axis 1. x: (B, T, ...ch); w: (k, ...ch)."""
    k = w.shape[0]
    if hist is None:
        pad_shape = (x.shape[0], k - 1) + x.shape[2:]
        hist = jnp.zeros(pad_shape, x.dtype)
    xp = jnp.concatenate([hist.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    return jax.nn.silu(out + b)


def _rmsnorm_hp(x: Array, w: Array, eps: float) -> Array:
    """RMS norm over the joint (H, P) feature dims."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=(-2, -1), keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + w)).astype(x.dtype)


def _segsum(a: Array) -> Array:
    """a: (..., L) -> (..., L, L) lower-tri cumulative segment sums."""
    cs = jnp.cumsum(a, axis=-1)
    s = cs[..., :, None] - cs[..., None, :]
    li = jnp.arange(a.shape[-1])
    mask = li[:, None] >= li[None, :]
    return jnp.where(mask, s, -jnp.inf)


def ssd_chunked(x: Array, a_dt: Array, B: Array, C: Array, *,
                chunk: int, init_state: Optional[Array] = None
                ) -> Tuple[Array, Array]:
    """Chunked SSD scan.

    x:    (b, T, H, P)  — dt-weighted inputs
    a_dt: (b, T, H)     — dt·A (negative)
    B, C: (b, T, N)     — single group, broadcast over heads
    Returns (y (b,T,H,P), final_state (b,H,P,N)).
    """
    b, T, H, P = x.shape
    N = B.shape[-1]
    nc = T // chunk
    assert nc * chunk == T, (T, chunk)
    xs = x.reshape(b, nc, chunk, H, P)
    As = a_dt.reshape(b, nc, chunk, H).transpose(0, 3, 1, 2)   # (b,H,nc,L)
    Bs = B.reshape(b, nc, chunk, N)
    Cs = C.reshape(b, nc, chunk, N)
    A_cum = jnp.cumsum(As, axis=-1)                            # (b,H,nc,L)

    # 1) intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(As))                                   # (b,H,nc,L,L)
    scores = jnp.einsum("bcln,bcsn->bcls", Cs, Bs)             # (b,nc,L,L)
    y_diag = jnp.einsum("bcls,bhcls,bcshp->bclhp",
                        scores, L, xs.astype(jnp.float32))

    # 2) chunk-final states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)            # (b,H,nc,L)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        Bs, decay_states, xs.astype(jnp.float32))

    # 3) inter-chunk recurrence (sequential scan over chunks)
    chunk_decay = jnp.exp(A_cum[..., -1])                      # (b,H,nc)
    s0 = (jnp.zeros((b, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, inp):
        st, dec = inp                                          # (b,H,P,N),(b,H)
        new = carry * dec[..., None, None] + st
        return new, carry                                      # emit prev

    final, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # (b,nc,H,P,N)

    # 4) inter-chunk output
    out_decay = jnp.exp(A_cum)                                 # (b,H,nc,L)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp",
                       Cs, prev_states, out_decay)
    y = (y_diag + y_off).reshape(b, T, H, P)
    return y.astype(x.dtype), final


def ssm_forward(p: dict, cfg: ModelConfig, u: Array, *,
                cache: Optional[SSMCache] = None,
                return_cache: bool = False
                ) -> Tuple[Array, Optional[SSMCache]]:
    """Full Mamba-2 block.

    cache=None & return_cache=False : training (chunked SSD, no state out)
    cache=None & return_cache=True  : prefill (chunked SSD + decode cache)
    cache=SSMCache                  : one-token recurrent decode
    """
    b, t, _ = u.shape
    n, h = cfg.ssm_state, cfg.ssm_n_heads
    pd = cfg.ssm_head_dim
    z = jnp.einsum("btd,dhp->bthp", u, p["w_z"])
    x_raw = jnp.einsum("btd,dhp->bthp", u, p["w_x"])
    B_raw = u @ p["w_B"]
    C_raw = u @ p["w_C"]
    dt = jax.nn.softplus((u @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"])                         # (b,t,h)
    A = -jnp.exp(p["A_log"])                                     # (h,)

    if cache is None:
        x = _conv1d(x_raw, p["conv_x"], p["conv_bx"], None)
        Bm = _conv1d(B_raw, p["conv_B"], p["conv_bB"], None).astype(
            jnp.float32)
        Cm = _conv1d(C_raw, p["conv_C"], p["conv_bC"], None).astype(
            jnp.float32)
        chunk = min(cfg.ssm_chunk, t)
        pad_t = (chunk - t % chunk) % chunk
        if pad_t:
            x = jnp.pad(x, ((0, 0), (0, pad_t), (0, 0), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad_t), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad_t), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad_t), (0, 0)))
        else:
            dt_p = dt
        y, final = ssd_chunked(
            (x.astype(jnp.float32) * dt_p[..., None]).astype(x.dtype),
            dt_p * A, Bm, Cm, chunk=chunk)
        y = y[:, :t]
        y = y + x[:, :t].astype(jnp.float32) * p["D"][None, None, :, None]
        if return_cache:
            k = cfg.ssm_conv

            def hist(v):
                hv = v[:, max(t - (k - 1), 0):]
                if t < k - 1:
                    pad = [(0, 0), (k - 1 - t, 0)] + [(0, 0)] * (v.ndim - 2)
                    hv = jnp.pad(hv, pad)
                return hv

            new_cache = SSMCache(conv_x=hist(x_raw), conv_B=hist(B_raw),
                                 conv_C=hist(C_raw), state=final)
        else:
            new_cache = None
    else:
        # single-token recurrent update
        assert t == 1
        k = cfg.ssm_conv

        def step_conv(hist_buf, new, w, bias):
            buf = jnp.concatenate([hist_buf.astype(new.dtype), new], axis=1)
            val = sum(buf[:, i] * w[i][None] for i in range(k))
            return jax.nn.silu(val + bias), buf[:, 1:]

        xv, cx = step_conv(cache.conv_x, x_raw, p["conv_x"], p["conv_bx"])
        Bv, cb = step_conv(cache.conv_B, B_raw, p["conv_B"], p["conv_bB"])
        Cv, cc = step_conv(cache.conv_C, C_raw, p["conv_C"], p["conv_bC"])
        dA = jnp.exp(dt[:, 0] * A[None])                         # (b,h)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0],
                         Bv.astype(jnp.float32),
                         xv.astype(jnp.float32))
        state = cache.state * dA[..., None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", state,
                       Cv.astype(jnp.float32))[:, None]
        y = y + xv[:, None].astype(jnp.float32) * p["D"][None, None, :, None]
        new_cache = SSMCache(conv_x=cx, conv_B=cb, conv_C=cc, state=state)

    y = _rmsnorm_hp(y.astype(u.dtype)
                    * jax.nn.silu(z.astype(jnp.float32)).astype(u.dtype),
                    p["norm"], cfg.norm_eps)
    return jnp.einsum("bthp,hpd->btd", y, p["out_proj"]), new_cache


def ssm_cache_init(cfg: ModelConfig, batch: int, dtype) -> SSMCache:
    return SSMCache(
        conv_x=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_n_heads,
                          cfg.ssm_head_dim), dtype),
        conv_B=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_state), dtype),
        conv_C=jnp.zeros((batch, cfg.ssm_conv - 1, cfg.ssm_state), dtype),
        state=jnp.zeros((batch, cfg.ssm_n_heads, cfg.ssm_head_dim,
                         cfg.ssm_state), jnp.float32))
