"""Private embedding lookup — the paper's §3.2.1 selection as an LM layer.

A token id is a one-hot row over the vocabulary: exactly the paper's unary
encoding. Secret-share the one-hot (degree 1, fresh polynomials per query),
hold Shamir shares of the fixed-point-quantized embedding table at each
"cloud" (mesh slice), and the lookup is the oblivious selection
``Σ_v onehot_share[v] · E_share[v, :]`` — a share-space matmul (ss_matmul
hotspot). The serving cloud learns neither the token id (access-pattern
hidden: every vocab row is touched identically) nor the embedding row.

Two paths:

* :func:`private_lookup` — the per-call reference: one ``shamir.share`` +
  one contraction per invocation. Kept as the correctness oracle and the
  bench baseline.
* :func:`private_lookup_batched` — the serving fast path on the batched
  engine (``core.queries.embed``): all batch×seq one-hots share in ONE
  jitted program (vectorized degree-1 evaluation from fold_in-derived
  per-token keys) and contract in ONE ``ss_matmul`` of shape
  ``(c, B·n, V)·(c, V, D)``, with opt-in OBSCURE-style ``verify=``.

:func:`as_embed_relation` wraps the shared table as a relation so it
attaches to a ``QueryClient``/``QueryServer`` like any other tenant —
sharded over the vocab axis, device-resident under ``MeshDispatcher``.

Fixed-point: values quantized at scale 2¹², range ±2¹⁸ ≪ p/2, so signed
round-trip through F_p is exact (out-of-range tables raise). Degree after
lookup = 2 ⇒ 3 clouds suffice (4 with ``verify=``).
"""
from __future__ import annotations

import itertools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core import encoding, shamir
from ..core.engine import SecretSharedDB
from ..core.queries.embed import (QUANT_RANGE, QUANT_SCALE,
                                  dequantize_from_field, quantize_to_field,
                                  share_tokens)
from ..core.shamir import Shares
from .config import ModelConfig

__all__ = [
    "QUANT_SCALE", "QUANT_RANGE", "quantize_to_field",
    "dequantize_from_field", "setup_private_embed", "as_embed_relation",
    "private_lookup", "private_lookup_batched", "private_lookup_inline",
]


def setup_private_embed(key, embed: jax.Array, *, n_shares: int = 4,
                        degree: int = 1) -> Shares:
    """DB-owner-side, one-time: share the quantized embedding table."""
    return shamir.share(key, quantize_to_field(embed), n_shares=n_shares,
                        degree=degree)


def as_embed_relation(embed_shares: Shares) -> SecretSharedDB:
    """Wrap a shared ``(c, V, D)`` table so it attaches like any relation.

    ``n_tuples = V`` (the axis ``ShardedRelation`` splits — vocab shards),
    ``n_attrs = D``. The codec is a placeholder: embedding relations carry
    no encoded string columns, only the raw share tensor participates.
    """
    if embed_shares.values.ndim != 3:
        raise ValueError(f"expected a (c, V, D) share tensor, got shape "
                         f"{tuple(embed_shares.values.shape)}")
    return SecretSharedDB(relation=embed_shares, codec=encoding.Codec(),
                          column_names=(), numeric={}, numeric_bits={},
                          base_degree=embed_shares.degree)


def private_lookup(key, embed_shares: Shares, tokens: jax.Array,
                   *, backend="jnp") -> jax.Array:
    """Per-call reference lookup of ``tokens`` (any shape) -> float32.

    One ``shamir.share`` and one contraction per invocation — the
    correctness oracle the batched fast path is held bit-identical to
    (post-dequantize), and the bench baseline it is measured against.
    """
    from ..api.backends import get_backend  # deferred: api sits above models
    be = get_backend(backend)
    v = embed_shares.shape[0]
    flat = tokens.reshape(-1)
    onehot = jax.nn.one_hot(flat, v, dtype=jnp.uint32)
    q_sh = shamir.share(key, onehot, n_shares=embed_shares.n_shares,
                        degree=embed_shares.degree)          # (c, n, V)
    picked = be.ss_matmul(q_sh.values, embed_shares.values)  # (c, n, D)
    out = shamir.interpolate(
        Shares(picked, q_sh.degree + embed_shares.degree))
    return dequantize_from_field(out).reshape(*tokens.shape, -1)


def private_lookup_batched(key, embed_shares: Shares, tokens: jax.Array,
                           *, backend="jnp", verify: bool = False
                           ) -> jax.Array:
    """Serving fast path: ONE share program + ONE ``ss_matmul``.

    All one-hots of ``tokens`` (any shape) share in a single jitted
    program — per-token fold_in keys, vectorized degree-1 polynomial
    evaluation — then contract against the table in one share-space
    matmul. ``verify=True`` cross-checks the redundant shares of the
    opened result (needs ``n_shares >= degree+3`` clouds) and raises
    ``core.queries.VerificationError`` on inconsistency.

    For the sharded / device-resident / billed path, attach the table via
    :func:`as_embed_relation` and issue ``plans.EmbedLookup`` through a
    ``QueryClient`` — this standalone entry point serves in-process use
    (e.g. ``private_lookup_inline``).
    """
    from ..api.backends import get_backend  # deferred: api sits above models
    be = get_backend(backend)
    tokens = jnp.asarray(tokens)
    v = embed_shares.shape[0]
    q_sh = share_tokens(key, tokens, vocab=v,
                        n_shares=embed_shares.n_shares)       # (c, N, V)
    picked = be.ss_matmul(q_sh.values, embed_shares.values)   # (c, N, D)
    out_sh = Shares(picked, q_sh.degree + embed_shares.degree)
    if verify:
        from ..core.queries.aggregate import VerificationError
        import numpy as np
        ok = np.asarray(shamir.verify_consistency(out_sh))
        if not bool(ok.all()):
            raise VerificationError(
                f"embedding lookup verification failed: "
                f"{int((~ok).sum())}/{ok.size} openings inconsistent")
    out = dequantize_from_field(shamir.interpolate(out_sh))
    return out.reshape(*tokens.shape, -1)


# Eager in-graph calls derive a fresh key per call from this counter; no two
# lookups ever reuse sharing polynomials (the §2.1 frequency-attack defence).
_INLINE_CALLS = itertools.count()


def _next_inline_key(params: dict) -> jax.Array:
    base = params.get("embed_key")
    if base is None:
        base = jax.random.PRNGKey(0)
    return jax.random.fold_in(base, next(_INLINE_CALLS))


def private_lookup_inline(params: dict, cfg: ModelConfig, tokens: jax.Array,
                          *, key: Optional[jax.Array] = None) -> jax.Array:
    """In-graph variant used when ``cfg.private_embed`` is set.

    If the params carry pre-shared tables (``embed_shares``), use them;
    otherwise quantize+share the plaintext table on the fly (test path).
    The lookup result matches ``take(embed)`` to quantization error (2⁻¹²).

    Sharing randomness: each call folds a fresh counter value into the base
    key (``params["embed_key"]`` when present), so no two eager calls emit
    identical share tensors. Under ``jit`` the Python counter is baked at
    trace time — jitted callers must thread ``key=`` (or a per-step
    ``params["embed_key"]``) themselves for fresh per-call polynomials.
    """
    if key is None:
        key = _next_inline_key(params)
    if "embed_shares" in params:
        sh = Shares(params["embed_shares"], 1)
    else:
        sh = setup_private_embed(jax.random.fold_in(key, 0),
                                 params["embed"], n_shares=4)
    out = private_lookup_batched(jax.random.fold_in(key, 1), sh, tokens)
    return jax.lax.stop_gradient(out).astype(jnp.dtype(cfg.dtype))
