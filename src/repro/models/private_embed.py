"""Private embedding lookup — the paper's §3.2.1 selection as an LM layer.

A token id is a one-hot row over the vocabulary: exactly the paper's unary
encoding. Secret-share the one-hot (degree 1, fresh polynomials per query),
hold Shamir shares of the fixed-point-quantized embedding table at each
"cloud" (mesh slice), and the lookup is the oblivious selection
``Σ_v onehot_share[v] · E_share[v, :]`` — a share-space matmul (ss_matmul
hotspot). The serving cloud learns neither the token id (access-pattern
hidden: every vocab row is touched identically) nor the embedding row.

Fixed-point: values quantized at scale 2¹², range ±2¹⁸ ≪ p/2, so signed
round-trip through F_p is exact. Degree after lookup = 2 ⇒ 3 clouds suffice.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import field, shamir
from ..core.shamir import Shares
from .config import ModelConfig

QUANT_SCALE = 4096.0  # 2**12


def quantize_to_field(x: jax.Array) -> jax.Array:
    """float -> fixed-point F_p element (signed values wrap mod p)."""
    q = jnp.round(x.astype(jnp.float32) * QUANT_SCALE).astype(jnp.int64)
    return (q % jnp.int64(int(field.P))).astype(field.DTYPE)


def dequantize_from_field(x: jax.Array) -> jax.Array:
    return field.from_signed(x).astype(jnp.float32) / QUANT_SCALE


def setup_private_embed(key, embed: jax.Array, *, n_shares: int = 4,
                        degree: int = 1) -> Shares:
    """DB-owner-side, one-time: share the quantized embedding table."""
    return shamir.share(key, quantize_to_field(embed), n_shares=n_shares,
                        degree=degree)


def private_lookup(key, embed_shares: Shares, tokens: jax.Array,
                   *, backend="jnp") -> jax.Array:
    """Oblivious lookup of ``tokens`` (any shape) -> float32 embeddings.

    The share-space matmul goes through the backend registry
    (``repro.api.backends``), so the serving stack picks kernels the same
    way the query suite does.
    """
    from ..api.backends import get_backend  # deferred: api sits above models
    be = get_backend(backend)
    v = embed_shares.shape[0]
    flat = tokens.reshape(-1)
    onehot = jax.nn.one_hot(flat, v, dtype=jnp.uint32)
    q_sh = shamir.share(key, onehot, n_shares=embed_shares.n_shares,
                        degree=embed_shares.degree)          # (c, n, V)
    picked = be.ss_matmul(q_sh.values, embed_shares.values)  # (c, n, D)
    out = shamir.interpolate(
        Shares(picked, q_sh.degree + embed_shares.degree))
    return dequantize_from_field(out).reshape(*tokens.shape, -1)


def private_lookup_inline(params: dict, cfg: ModelConfig, tokens: jax.Array
                          ) -> jax.Array:
    """In-graph variant used when ``cfg.private_embed`` is set.

    If the params carry pre-shared tables (``embed_shares``), use them;
    otherwise quantize+share the plaintext table on the fly (test path).
    The lookup result matches ``take(embed)`` to quantization error (2⁻¹²).
    """
    key = jax.random.PRNGKey(0)  # fresh per-call keys come from the server
    if "embed_shares" in params:
        sh = Shares(params["embed_shares"], 1)
    else:
        sh = setup_private_embed(key, params["embed"], n_shares=4)
    out = private_lookup(jax.random.fold_in(key, 1), sh, tokens)
    return jax.lax.stop_gradient(out).astype(jnp.dtype(cfg.dtype))
