# Assigned-architecture model zoo: one functional LM covering dense / MoE /
# SSM / hybrid / enc-dec / VLM families, plus the paper-integrated private
# embedding lookup.
from .config import (ModelConfig, ShapeConfig, ALL_SHAPES, TRAIN_4K,
                     PREFILL_32K, DECODE_32K, LONG_500K)
from .lm import (init_params, forward, train_loss, prefill, decode_step,
                 init_cache)

__all__ = [
    "ModelConfig", "ShapeConfig", "ALL_SHAPES", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "init_params", "forward", "train_loss",
    "prefill", "decode_step", "init_cache",
]
