"""Verified secret-shared aggregation — SUM / AVG / MIN-MAX (OBSCURE-style).

The paper's query surface stops at count/selection/range/join; OBSCURE
(arXiv 2004.13115) shows the same Shamir-sharing model supports
information-theoretically secure aggregation *with result verification*: a
cheating cloud returning a garbage share is detected rather than silently
interpolated into a wrong answer. This module adds that family to the
round engine, batch-first like everything in :mod:`.rounds`:

  * :func:`agg_sum_phase`     — conditional / unconditional SUM for B jobs:
    the predicate match bits contract against the numeric value column in
    ONE ``ss_matmul`` per shard (per distinct value column), partial sums
    combining additively in F_p, one fused interpolation. AVG rides this
    phase for its numerator; the denominator reuses the §3.1 count phase
    (the client fuses it into the batch's existing count dispatch).
  * :func:`agg_minmax_rounds` — MIN/MAX for B jobs as a knockout tournament
    on the bitwise (two's-complement) column: each level compares candidate
    pairs with the §3.4 SS-SUB ripple-carry comparator (one fused
    ``ripple_segment`` dispatch per ``reduce_every`` boundary interval for
    the whole batch) and obliviously selects each winner as
    ``x₁ + s·(x₂ − x₁)``. Conditional jobs first mask non-matching rows to
    a public sentinel (+/− (2^(t−2) − 1)) so they can never win. Levels
    run on the gathered relation — like the tree engine's Q&A rounds — so
    the transcript is bit-identical for every shard count by construction;
    the match/mask step and the SUM contraction are the sharded cloud
    steps.

Numeric-domain contracts (documented, not enforceable on shares):
  * SUM/AVG open an exact field sum — the phase refuses relations where
    ``n · 2^(t−1)`` could wrap the Mersenne-31 half-range.
  * MIN/MAX comparisons subtract t-bit values; like the paper's SS-SUB,
    differences must fit in t bits. Conditional jobs additionally compare
    against the ±(2^(t−2) − 1) sentinel, so values should stay within
    one headroom bit of the column's width.

Verification (``verify=True`` per job) runs an OBSCURE-style consistency
round on every opened aggregate tensor: with r = c − (deg+1) redundant
clouds, the user cross-checks that each redundant share lies on the unique
degree-``deg`` polynomial through the first deg+1 shares
(:func:`repro.core.shamir.verify_consistency`) and raises
:class:`VerificationError` on any mismatch. The extra round and the c
checksum elements per opened tensor are billed to the job's ledger (and
priced identically by ``repro.api.planner.estimate_aggregate_cost``).
Scope: verification covers the cloud→user openings — any share tampered
after the last re-sharing round is caught; the cloud↔cloud degree-reduction
rounds themselves assume honest re-share participants (OBSCURE's full
checksum chain per round is future work, see ROADMAP).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import dataplane, encoding, field, shamir
from ..costs import CostLedger
from ..dataplane import RelationLike
from ..shamir import Shares
from .rounds import (MatchJob, _batched_matcher, _fused_interpolate,
                     _ripple_segmenter, _segment_edges, _share_patterns,
                     _stack_columns, _stack_numeric)

AGG_OPS = ("sum", "avg", "min", "max")


class VerificationError(RuntimeError):
    """A cloud's share failed the OBSCURE-style consistency check."""


# ---------------------------------------------------------------------------
# batch job descriptors
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AggJob:
    """One aggregation query's slot in a fused aggregation phase.

    ``value_column`` is the numeric (binary-form) column being aggregated;
    ``pred_column``/``pattern`` carry the optional equality predicate
    (None = aggregate over the whole relation). ``verify`` opts the job
    into the consistency round on its opened tensors.
    """
    value_column: int
    key: jax.Array
    ledger: CostLedger
    pred_column: Optional[int] = None
    pattern: Optional[str] = None
    verify: bool = False

    @property
    def conditional(self) -> bool:
        return self.pattern is not None


@dataclasses.dataclass
class SumJob(AggJob):
    """One SUM (or AVG numerator) slot in :func:`agg_sum_phase`."""


@dataclasses.dataclass
class MinMaxJob(AggJob):
    """One MIN/MAX slot in :func:`agg_minmax_rounds`.

    Jobs fused into one tournament must share the column bit-width and
    ``reduce_every`` (the comparator carry chains march in lockstep).
    """
    op: str = "min"
    reduce_every: int = 0

    def __post_init__(self):
        if self.op not in ("min", "max"):
            raise ValueError(f"MinMaxJob.op must be 'min' or 'max', "
                             f"got {self.op!r}")


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _value_weights(t_bits: int) -> jax.Array:
    """Public bit weights lifting an LSB-first two's-complement bit sharing
    to a sharing of the (centered) field value: Σ 2^i·b_i with the sign bit
    weighted −2^(t−1) mod p. A public linear combination — each cloud
    applies it locally, degree unchanged."""
    w = [1 << i for i in range(t_bits - 1)]
    w.append(int(field.P) - (1 << (t_bits - 1)))
    return jnp.asarray(w, field.DTYPE)


def _centered(v: int) -> int:
    """Lift a field representative back to the signed integer it encodes."""
    p = int(field.P)
    return v - p if v > p // 2 else v


def _validate_numeric(db, jobs: Sequence[AggJob], what: str) -> int:
    t_all = []
    for j in jobs:
        if j.value_column not in db.numeric:
            raise ValueError(f"column {j.value_column} was not outsourced "
                             f"in binary form")
        t_all.append(db.numeric_bits[j.value_column])
    if len(set(t_all)) != 1:
        raise ValueError(f"a fused {what} needs a uniform value-column "
                         f"bit width across its jobs (group them)")
    return t_all[0]


def _verify_openings(job: AggJob, tensors: Sequence[Shares],
                     what: str) -> None:
    """The OBSCURE-style verification round for one job: one extra round in
    which the user cross-checks every redundant cloud's share of each opened
    tensor against the polynomial the first deg+1 shares determine."""
    job.ledger.round()
    for s in tensors:
        t1 = s.degree + 1
        c = s.n_shares
        if c < t1 + 1:
            raise VerificationError(
                f"verify=True needs at least degree+2 = {t1 + 1} clouds to "
                f"cross-check the {what} opening (degree {s.degree}); "
                f"have {c}")
        n_elems = int(np.prod(s.shape, dtype=np.int64)) if s.shape else 1
        job.ledger.recv(c)
        job.ledger.user((c - t1) * t1 * n_elems)
        ok = np.asarray(shamir.verify_consistency(s))
        if not bool(ok.all()):
            raise VerificationError(
                f"{what} verification failed: a cloud's response share is "
                f"inconsistent with the degree-{s.degree} sharing the "
                f"honest clouds define")


# ---------------------------------------------------------------------------
# SUM / AVG numerator — one fused contraction round
# ---------------------------------------------------------------------------

def agg_sum_phase(be, db: RelationLike, jobs: Sequence[SumJob]
                  ) -> List[int]:
    """Exact signed SUM for B jobs: ONE cloud step (one dispatch per
    shard), partial sums reduced mod p across shards, one fused
    interpolation, optional verification round.

    Conditional jobs match their predicate with the fused AA matcher and
    contract the match bits against the value column via ``ss_matmul``
    (one matmul per distinct value column); unconditional jobs sum the
    value column directly. Both ride the same ``run_sum`` dispatch set.
    """
    if not jobs:
        return []
    plane = dataplane.as_dataplane(db)
    db = plane.db
    codec = db.codec
    c = db.n_shares
    n = db.n_tuples
    t_bits = _validate_numeric(db, jobs, "agg_sum_phase")
    if n << (t_bits - 1) >= 1 << 30:
        raise ValueError(
            f"SUM over n={n} tuples of a {t_bits}-bit column may exceed "
            f"the Mersenne-31 half-range — the field sum would no longer "
            f"be exact")
    cond = [i for i, j in enumerate(jobs) if j.conditional]
    free = [i for i, j in enumerate(jobs) if not j.conditional]
    p_all = (_share_patterns(db, [jobs[i] for i in cond]) if cond else None)
    w = db.relation.values.shape[-2]
    match_deg = ((db.relation.degree + p_all.degree) * w if cond else 0)
    weights = _value_weights(t_bits)

    # one ss_matmul per distinct value column of the conditional jobs
    by_vcol: dict = {}
    for k, i in enumerate(cond):
        by_vcol.setdefault(jobs[i].value_column, []).append(k)

    def one(v, sh):
        parts = []
        if cond:
            bits = _batched_matcher(be)(
                _stack_columns(v, [jobs[i].pred_column
                                   for i in cond]).values,
                p_all.values)                              # (c, Bc, n_s)
            out: List[Optional[jax.Array]] = [None] * len(cond)
            for vc, ks in by_vcol.items():
                col = field.sum_(field.mul(v.numeric[vc].values,
                                           weights[None, None, :]),
                                 axis=2)                   # (c, n_s)
                prod = be.ss_matmul(bits[:, jnp.asarray(ks)],
                                    col[:, :, None])       # (c, |ks|, 1)
                for r, k in enumerate(ks):
                    out[k] = prod[:, r, 0]
            parts.append(jnp.stack(out, axis=1))           # (c, Bc)
        if free:
            cols = jnp.stack(
                [field.sum_(field.mul(v.numeric[jobs[i].value_column].values,
                                      weights[None, None, :]), axis=2)
                 for i in free], axis=1)                   # (c, Bf, n_s)
            parts.append(field.sum_(cols, axis=2))         # (c, Bf)
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts,
                                                                axis=1)

    sums_flat = plane.run_sum(one)                         # (c, Bc+Bf)
    per_job: List[Optional[Shares]] = [None] * len(jobs)
    for k, i in enumerate(cond):
        per_job[i] = Shares(sums_flat[:, k],
                            match_deg + db.numeric[jobs[i].value_column]
                            .degree)
    for k, i in enumerate(free):
        per_job[i] = Shares(sums_flat[:, len(cond) + k],
                            db.numeric[jobs[i].value_column].degree)
    opened = _fused_interpolate(per_job)

    per_q = codec.word_length * codec.alphabet_size
    for i, j in enumerate(jobs):
        j.ledger.round()
        if j.conditional:
            j.ledger.send(c * per_q)
            j.ledger.cloud(n * (per_q + t_bits))
        else:
            j.ledger.cloud(n * t_bits)
        j.ledger.recv(c)
        j.ledger.user(per_job[i].degree + 1)
    for i, j in enumerate(jobs):
        if j.verify:
            _verify_openings(j, [per_job[i]], "SUM")
    return [_centered(int(opened[i])) for i in range(len(jobs))]


# ---------------------------------------------------------------------------
# MIN / MAX — sentinel mask + knockout tournament on the SS-SUB comparator
# ---------------------------------------------------------------------------

def agg_minmax_rounds(be, db: RelationLike, jobs: Sequence[MinMaxJob]
                      ) -> List[Tuple[Optional[int], Optional[int]]]:
    """MIN/MAX for B jobs, every tournament level fused across the batch.

    Returns ``(value, count)`` per job: ``count`` is the opened predicate
    cardinality for conditional jobs (None otherwise); ``value`` is None
    when a conditional job matched nothing (the tournament would open the
    sentinel). The final level's winner opens at its native comparator
    degree — no trailing re-share — so a share tampered anywhere after the
    last reduction fails verification.
    """
    if not jobs:
        return []
    plane = dataplane.as_dataplane(db)
    db = plane.db
    codec = db.codec
    c = db.n_shares
    n = db.n_tuples
    d = db.base_degree
    t_bits = _validate_numeric(db, jobs, "agg_minmax_rounds")
    if t_bits < 2:
        raise ValueError("MIN/MAX needs a >= 2-bit value column")
    if len({j.reduce_every for j in jobs}) != 1:
        raise ValueError("a fused agg_minmax_rounds needs uniform "
                         "reduce_every across its jobs (group them)")
    reduce_every = jobs[0].reduce_every
    b = len(jobs)
    w = codec.word_length
    per_q = w * codec.alphabet_size
    cond = [i for i, j in enumerate(jobs) if j.conditional]

    # round 1: predicates travel up; the final bits come back in the same
    # logical round once the tournament's re-share rounds are done.
    for j in jobs:
        j.ledger.round()
        if j.conditional:
            j.ledger.send(c * per_q)

    # every job's key splits (pattern, reduction-chain); the fused
    # reduction chain seeds from the first job, as in range_phase —
    # re-share randomness never changes opened values.
    split_keys = [jax.random.split(j.key) for j in jobs]
    red_key = split_keys[0][1]

    counts: Optional[Shares] = None
    masked_by_pos: dict = {}
    if cond:
        cond_jobs = [jobs[i] for i in cond]
        p_all = _share_patterns(db, [
            MatchJob(j.pred_column, j.pattern, split_keys[i][0], j.ledger)
            for i, j in zip(cond, cond_jobs)])
        match_deg = (db.relation.degree + p_all.degree) * w
        bits = Shares(plane.run_concat(
            lambda v, sh: _batched_matcher(be)(
                _stack_columns(v, [j.pred_column
                                   for j in cond_jobs]).values,
                p_all.values), axis=2), match_deg)          # (c, Bc, n)
        counts = Shares(field.sum_(bits.values, axis=2), match_deg)
        # sentinel mask: non-matching rows become the op's losing extreme
        # (a public constant, so masking is cloud-local share arithmetic):
        # masked = m·(x − s) + s.
        bound = (1 << (t_bits - 2)) - 1
        sent = np.stack([encoding.encode_number_bits(
            bound if j.op == "min" else -bound, t_bits)
            for j in cond_jobs])                            # (Bc, t)
        sent_b = jnp.asarray(sent, field.DTYPE)[None, :, None, :]
        x = _stack_numeric(db, [j.value_column for j in cond_jobs])
        delta = field.sub(x.values, jnp.broadcast_to(sent_b,
                                                     x.values.shape))
        masked = field.add(field.mul(bits.values[..., None], delta),
                           jnp.broadcast_to(sent_b, x.values.shape))
        red_key, sub = jax.random.split(red_key)
        masked = shamir.reduce_degree(
            sub, Shares(masked, match_deg + x.degree), target_degree=d)
        for i, j in enumerate(cond_jobs):
            j.ledger.round()                 # the mask re-share round
            j.ledger.send(c * c)
            j.ledger.cloud(n * (per_q + t_bits))
            masked_by_pos[cond[i]] = masked.values[:, i]
    for i, j in enumerate(jobs):
        if not j.conditional:
            j.ledger.cloud(n * t_bits)

    cand = jnp.stack(
        [masked_by_pos[i] if i in masked_by_pos
         else db.numeric[jobs[i].value_column].values
         for i in range(b)], axis=1)                        # (c, B, n, t)
    cand_deg = d

    # knockout tournament: global fixed pairing (2i, 2i+1) per level, odd
    # leftover carried unpaired; each level is one batched SS-SUB ripple
    # (sign s = [loser-side < winner-side]) plus the oblivious select
    # x₁ + s·(x₂ − x₁). Levels run on the gathered relation, like tree
    # Q&A rounds — identical transcript for every shard count.
    segment = _ripple_segmenter(be)
    is_min = jnp.asarray([j.op == "min" for j in jobs],
                         bool)[None, :, None, None]
    k = n
    while k > 1:
        pairs = k // 2
        x1 = cand[:, :, 0:2 * pairs:2]                      # (c,B,pairs,t)
        x2 = cand[:, :, 1:2 * pairs:2]
        # SS-SUB(lhs, rhs) opens [rhs < lhs]: min wants s = [x2 < x1]
        # (lhs=x1), max wants s = [x1 < x2] (lhs=x2); either way the
        # winner is x1 + s·(x2 − x1).
        lhs = jnp.where(is_min, x1, x2)
        rhs = jnp.where(is_min, x2, x1)
        carry = None
        carry_deg = 0
        s_bits = None
        for seg_i, (s0, s1) in enumerate(_segment_edges(t_bits,
                                                        reduce_every)):
            if seg_i > 0 and carry_deg > 1:
                red_key, sub = jax.random.split(red_key)
                carry = shamir.reduce_degree(
                    sub, Shares(carry, carry_deg), target_degree=1).values
                carry_deg = 1
                for j in jobs:
                    j.ledger.round()
                    j.ledger.send(c * c)
            s_bits, carry = segment(lhs[..., s0:s1], rhs[..., s0:s1],
                                    carry)
            carry_deg = carry_deg + 2 * cand_deg * (s1 - s0)
        win = field.add(x1, field.mul(s_bits[..., None],
                                      field.sub(x2, x1)))
        win_deg = carry_deg + cand_deg
        for j in jobs:
            j.ledger.cloud(2 * pairs * t_bits)
        if 2 * pairs < k:
            win = jnp.concatenate([win, cand[:, :, 2 * pairs:]], axis=2)
        k = win.shape[2]
        if k > 1:
            # inter-level re-share back to the base degree (one round);
            # the FINAL level opens at its native degree instead, so a
            # post-reduction tamper is visible to verification.
            red_key, sub = jax.random.split(red_key)
            cand = shamir.reduce_degree(sub, Shares(win, win_deg),
                                        target_degree=d).values
            cand_deg = d
            for j in jobs:
                j.ledger.round()
                j.ledger.send(c * c)
        else:
            cand = win
            cand_deg = win_deg

    val_parts = [Shares(cand[:, i, 0], cand_deg) for i in range(b)]
    cnt_parts = {i: Shares(counts.values[:, kk], counts.degree)
                 for kk, i in enumerate(cond)}
    opened = _fused_interpolate(val_parts + [cnt_parts[i] for i in cond])

    for i, j in enumerate(jobs):
        j.ledger.recv(c * t_bits)
        j.ledger.user((cand_deg + 1) * t_bits)
        if j.conditional:
            j.ledger.recv(c)
            j.ledger.user(counts.degree + 1)
    for i, j in enumerate(jobs):
        if j.verify:
            tensors = [val_parts[i]]
            if j.conditional:
                tensors.append(cnt_parts[i])
            _verify_openings(j, tensors, j.op.upper())

    out: List[Tuple[Optional[int], Optional[int]]] = []
    cnt_at = {i: b + kk for kk, i in enumerate(cond)}
    for i, j in enumerate(jobs):
        val = encoding.decode_number_bits(np.asarray(opened[i]))
        if j.conditional:
            cnt = int(opened[cnt_at[i]])
            out.append((val if cnt > 0 else None, cnt))
        else:
            out.append((val, None))
    return out
