"""Oblivious joins on secret-shares (paper §3.3).

``pkfk_join`` (§3.3.1): reducer *j* (one per child tuple) string-matches the
child's join value against ALL parent join values, multiplies the 0/1 share
into each parent tuple and sums — only the unique matching parent survives.
In MapReduce terms the mapper fan-out (each parent tuple keyed 1..n_y) is the
broadcast inside the einsum; the reducer is the contraction over parents.
The match matrix is the ``ss_matmul``/``match_matrix`` hotspot: per word
position a (n_x × A) · (A × n_y) mod-p matmul, chained over positions.

``equijoin`` (§3.3.2): two *layers* of c clouds. The user interpolates both
join columns (2n·c′ values), derives common values + their tuple addresses,
then per common value the first layer obliviously fetches the matching tuples
(one-hot matrix fetch) and hands the *still-shared* results to its same-index
second-layer cloud, which emits the ℓx×ℓy concatenations. Clouds within a
layer never communicate.

Both are thin B = 1 wrappers over the round-structured batch engine
(``repro.core.queries.rounds``): a PK/FK join's reducer contraction is a
row-block of the same fused fetch ``ss_matmul`` the selection/range groups
ride (``join_match_round`` + ``fetch_fusion`` + ``join_emit_round``), and B
equijoins fuse their column-open, layer-1 fetches and layer-2 pair
interpolations per phase (``equijoin_rounds``). A join run here is
bit-identical (rows *and* ``CostLedger``) to the same join inside a
``QueryClient.run_batch`` group.

Prefer ``repro.api.QueryClient.join``; the canonical ``pkfk_join`` signature
is key-first like the rest of the suite (the key re-randomizes the outgoing
joined shares with owner-provisioned zero-sharings so transmitted shares
cannot be linked to the stored relation); the historical key-less positional
form is still accepted.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax

from ..costs import CostLedger
from ..engine import SecretSharedDB
from . import rounds
from ._common import resolve_backend


# ---------------------------------------------------------------------------
# §3.3.1 — PK/FK oblivious join
# ---------------------------------------------------------------------------

def pkfk_join(*args, **kwargs) -> Tuple[List[List[str]], CostLedger]:
    """X ⋈ Y on X.col_x = Y.col_y, where col_x is a primary key of X.

    Canonical call: ``pkfk_join(key, dbX, dbY, col_x, col_y)`` — key-first
    like every other query. The legacy key-less form
    ``pkfk_join(dbX, dbY, col_x, col_y)`` (positional or with ``col_x=``/
    ``col_y=`` keywords; no output re-randomization) is still accepted.
    """
    if args and isinstance(args[0], SecretSharedDB):  # key-less positional
        args = (kwargs.pop("key", None),) + args
    return _pkfk_join(*args, **kwargs)


def _pkfk_join(key: Optional[jax.Array], dbX: SecretSharedDB,
               dbY: SecretSharedDB, col_x: int, col_y: int, *,
               ledger: Optional[CostLedger] = None,
               backend="jnp", impl: Optional[str] = None
               ) -> Tuple[List[List[str]], CostLedger]:
    ledger = ledger if ledger is not None else CostLedger()
    be = resolve_backend(backend, impl)
    job = rounds.JoinJob(dbY, col_x, col_y, key, ledger)
    entries = rounds.join_match_round(be, dbX, [job])
    _, fetched = rounds.fetch_fusion(be, dbX, [], entries)
    rows = rounds.join_emit_round(dbX, [job], fetched)[0]
    return rows, ledger


# ---------------------------------------------------------------------------
# §3.3.2 — non-PK/FK oblivious equijoin (two cloud layers)
# ---------------------------------------------------------------------------

def equijoin(key: jax.Array, dbX: SecretSharedDB, dbY: SecretSharedDB,
             col_x: int, col_y: int, *,
             ledger: Optional[CostLedger] = None,
             padded_values: int = 0,
             backend="jnp", impl: Optional[str] = None
             ) -> Tuple[List[List[str]], CostLedger]:
    """General equijoin; join values may repeat in BOTH relations.

    ``padded_values`` adds fake (no-op) join values to hide k (leakage
    discussion of §3.3.2).
    """
    ledger = ledger if ledger is not None else CostLedger()
    be = resolve_backend(backend, impl)
    rows = rounds.equijoin_rounds(be, dbX, [
        rounds.EquiJob(dbY, col_x, col_y, key, ledger,
                       padded_values=padded_values)])[0]
    return rows, ledger
