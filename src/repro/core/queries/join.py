"""Oblivious joins on secret-shares (paper §3.3).

``pkfk_join`` (§3.3.1): reducer *j* (one per child tuple) string-matches the
child's join value against ALL parent join values, multiplies the 0/1 share
into each parent tuple and sums — only the unique matching parent survives.
In MapReduce terms the mapper fan-out (each parent tuple keyed 1..n_y) is the
broadcast inside the einsum; the reducer is the contraction over parents.
The match matrix is the ``ss_matmul``/``match_matrix`` hotspot: per word
position a (n_x × A) · (A × n_y) mod-p matmul, chained over positions.

``equijoin`` (§3.3.2): two *layers* of c clouds. The user interpolates both
join columns (2n·c′ values), derives common values + their tuple addresses,
then per common value the first layer obliviously fetches the matching tuples
(one-hot matrix fetch) and hands the *still-shared* results to its same-index
second-layer cloud, which emits the ℓx×ℓy concatenations. Clouds within a
layer never communicate.

Prefer ``repro.api.QueryClient.join``; the canonical ``pkfk_join`` signature
is key-first like the rest of the suite (the key re-randomizes the outgoing
joined shares with owner-provisioned zero-sharings so transmitted shares
cannot be linked to the stored relation); the historical key-less positional
form is still accepted.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import encoding, field, shamir
from ..costs import CostLedger
from ..engine import SecretSharedDB
from ..shamir import Shares
from ._common import match_matrix_shares, resolve_backend


# ---------------------------------------------------------------------------
# §3.3.1 — PK/FK oblivious join
# ---------------------------------------------------------------------------

def _rerandomize(key: jax.Array, s: Shares) -> Shares:
    """Add a fresh sharing of zero: same secret, unlinkable share values."""
    zero = shamir.share(key, jnp.zeros(s.shape, dtype=s.values.dtype),
                        n_shares=s.n_shares, degree=s.degree)
    return s + zero


def pkfk_join(*args, **kwargs) -> Tuple[List[List[str]], CostLedger]:
    """X ⋈ Y on X.col_x = Y.col_y, where col_x is a primary key of X.

    Canonical call: ``pkfk_join(key, dbX, dbY, col_x, col_y)`` — key-first
    like every other query. The legacy key-less form
    ``pkfk_join(dbX, dbY, col_x, col_y)`` (positional or with ``col_x=``/
    ``col_y=`` keywords; no output re-randomization) is still accepted.
    """
    if args and isinstance(args[0], SecretSharedDB):  # key-less positional
        args = (kwargs.pop("key", None),) + args
    return _pkfk_join(*args, **kwargs)


def _pkfk_join(key: Optional[jax.Array], dbX: SecretSharedDB,
               dbY: SecretSharedDB, col_x: int, col_y: int, *,
               ledger: Optional[CostLedger] = None,
               backend="jnp", impl: Optional[str] = None
               ) -> Tuple[List[List[str]], CostLedger]:
    ledger = ledger if ledger is not None else CostLedger()
    codec = dbX.codec
    be = resolve_backend(backend, impl)
    c = dbX.n_shares
    nx, ny = dbX.n_tuples, dbY.n_tuples
    W, A = codec.word_length, codec.alphabet_size

    # --- cloud: match matrix over join columns (the n² string matches) -----
    bx = dbX.column(col_x)                       # (c, nx, W, A)
    by = dbY.column(col_y)                       # (c, ny, W, A)
    M = match_matrix_shares(be, bx, by)          # (c, nx, ny)
    ledger.cloud(nx * ny * W * A)

    # --- reducer j: Σ_i M[i,j] · X_tuple_i  (share-space select) -----------
    relX = dbX.relation.values                   # (c, nx, m, W, A)
    mX = dbX.n_attrs
    joined_x_flat = be.ss_matmul(
        jnp.swapaxes(M.values, -1, -2),          # (c, ny, nx)
        relX.reshape(c, nx, mX * W * A))         # -> (c, ny, m·W·A)
    joined_x = Shares(joined_x_flat.reshape(c, ny, mX, W, A),
                      M.degree + dbX.relation.degree)
    ledger.cloud(nx * ny * mX * W)

    # child's own attributes ride along at base degree
    y_part = dbY.relation                        # (c, ny, mY, W, A)

    # key-threaded output re-randomization: each cloud adds its slice of an
    # owner-provisioned zero-sharing before transmitting, so the returned
    # shares cannot be correlated with the stored relation shares.
    if key is not None:
        kx, ky = jax.random.split(key)
        joined_x = _rerandomize(kx, joined_x)
        y_part = _rerandomize(ky, y_part)
        ledger.cloud(ny * (mX + dbY.n_attrs) * W * A)

    # --- cloud -> user: n_y joined tuples per cloud -------------------------
    ledger.round()
    ledger.recv(c * ny * (mX + dbY.n_attrs) * W * A)

    # --- user: interpolate both parts, decode, assemble ---------------------
    xs = np.asarray(shamir.interpolate(joined_x))          # (ny, mX, W, A)
    ys = np.asarray(shamir.interpolate(y_part))            # (ny, mY, W, A)
    ledger.user((joined_x.degree + 1) * ny * mX * W
                + (y_part.degree + 1) * ny * dbY.n_attrs * W)
    rows = []
    for j in range(ny):
        x_row = codec.decode_row(xs[j])
        if all(v == "" for v in x_row):
            continue                              # dangling child (no parent)
        y_row = codec.decode_row(ys[j])
        rows.append(x_row + [v for k, v in enumerate(y_row) if k != col_y])
    return rows, ledger


# ---------------------------------------------------------------------------
# §3.3.2 — non-PK/FK oblivious equijoin (two cloud layers)
# ---------------------------------------------------------------------------

def _fetch_shares(key: jax.Array, db: SecretSharedDB, addresses: List[int],
                  ledger: CostLedger, be) -> Shares:
    """Layer-1 oblivious fetch that KEEPS the result in share form."""
    n = db.n_tuples
    m_host = np.zeros((len(addresses), n), dtype=np.uint32)
    for r, a in enumerate(addresses):
        m_host[r, a] = 1
    m_sh = encoding.share_encoded(key, m_host, n_shares=db.n_shares,
                                  degree=db.base_degree)
    ledger.send(db.n_shares * len(addresses) * n)
    c, _, m, w, a = db.relation.values.shape
    fetched = be.ss_matmul(m_sh.values,
                           db.relation.values.reshape(c, n, m * w * a))
    ledger.cloud(len(addresses) * n * m * w * a)
    return Shares(fetched.reshape(c, len(addresses), m, w, a),
                  m_sh.degree + db.relation.degree)


def equijoin(key: jax.Array, dbX: SecretSharedDB, dbY: SecretSharedDB,
             col_x: int, col_y: int, *,
             ledger: Optional[CostLedger] = None,
             padded_values: int = 0,
             backend="jnp", impl: Optional[str] = None
             ) -> Tuple[List[List[str]], CostLedger]:
    """General equijoin; join values may repeat in BOTH relations.

    ``padded_values`` adds fake (no-op) join values to hide k (leakage
    discussion of §3.3.2).
    """
    ledger = ledger if ledger is not None else CostLedger()
    codec = dbX.codec
    be = resolve_backend(backend, impl)

    # --- step 1: user interpolates both join columns ------------------------
    bx, by = dbX.column(col_x), dbY.column(col_y)
    ledger.round()
    ledger.recv(dbX.n_shares * dbX.n_tuples * codec.word_length
                * codec.alphabet_size
                + dbY.n_shares * dbY.n_tuples * codec.word_length
                * codec.alphabet_size)
    x_vals = [codec.decode_word(v)
              for v in np.asarray(shamir.interpolate(bx))]
    y_vals = [codec.decode_word(v)
              for v in np.asarray(shamir.interpolate(by))]
    ledger.user((bx.degree + 1) * dbX.n_tuples * codec.word_length
                + (by.degree + 1) * dbY.n_tuples * codec.word_length)

    common = sorted(set(x_vals) & set(y_vals))

    # --- step 2: per common value, layer-1 fetch -> layer-2 concat ----------
    rows: List[List[str]] = []
    n_jobs = len(common) + padded_values
    for idx in range(n_jobs):
        key, kx, ky = jax.random.split(key, 3)
        if idx < len(common):
            b = common[idx]
            addr_x = [i for i, v in enumerate(x_vals) if v == b]
            addr_y = [j for j, v in enumerate(y_vals) if v == b]
        else:  # fake job: fetch nothing (all-zero matrices), same traffic
            addr_x, addr_y = [0], [0]
        # layer 1: oblivious fetches (one round per value — Thm 6's 2k rounds)
        ledger.round(2)
        Xp = _fetch_shares(kx, dbX, addr_x, ledger, be)  # (c, ℓx, mX, W, A)
        Yp = _fetch_shares(ky, dbY, addr_y, ledger, be)  # (c, ℓy, mY, W, A)

        # layer-1 -> layer-2 hand-off (cloud i -> cloud i): counted as cloud
        # traffic, not user traffic; layer 2 concatenates all ℓx×ℓy pairs.
        lx, ly = Xp.shape[0], Yp.shape[0]
        pairs_x = Shares(jnp.repeat(Xp.values, ly, axis=1), Xp.degree)
        pairs_y = Shares(jnp.tile(Yp.values, (1, lx, 1, 1, 1)), Yp.degree)
        ledger.cloud(lx * ly * (dbX.n_attrs + dbY.n_attrs)
                     * codec.word_length * codec.alphabet_size)

        if idx >= len(common):
            continue  # fake job output discarded at user side
        # --- step 3: user interpolates the ℓx·ℓy concatenations -------------
        ledger.recv(dbX.n_shares * lx * ly
                    * (dbX.n_attrs + dbY.n_attrs)
                    * codec.word_length * codec.alphabet_size)
        xs = np.asarray(shamir.interpolate(pairs_x))
        ys = np.asarray(shamir.interpolate(pairs_y))
        ledger.user((pairs_x.degree + 1) * lx * ly * dbX.n_attrs
                    * codec.word_length
                    + (pairs_y.degree + 1) * lx * ly * dbY.n_attrs
                    * codec.word_length)
        for r in range(lx * ly):
            x_row = codec.decode_row(xs[r])
            y_row = codec.decode_row(ys[r])
            rows.append(x_row + [v for k2, v in enumerate(y_row)
                                 if k2 != col_y])
    return rows, ledger
