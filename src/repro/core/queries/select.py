"""Selection queries on secret-shares (paper §3.2; Algorithms 3 & 4).

Three variants, exactly as the paper structures them:

* ``select_one_tuple``  (§3.2.1, Alg 3) — one value holds one tuple: match-bit
  × tuple, summed over n; only the satisfying tuple survives the sum.
* ``select_one_round``  (§3.2.2 "one-round") — cloud returns all n match bits
  (user interpolates n·c′ values), then a secret-shared ℓ'×n one-hot fetch
  matrix is multiplied against the relation (share-space matmul).
* ``select_tree``       (§3.2.2 "tree-based", Alg 4) — Q&A rounds of
  block-partitioned counts; the user interpolates only O(ℓ) values per round;
  address of a single-hit block via Address_fetch (Σ matchᵢ · i).

All cloud work is oblivious: identical ops on every tuple regardless of data.

These free functions are thin wrappers over the round-structured batch
engine in ``repro.core.queries.rounds`` run at batch size 1 — every protocol
round is one fused device dispatch plus one interpolation (never a per-block
Python loop), and a query run here is bit-identical (rows *and* ledger) to
the same query run inside a ``QueryClient.run_batch`` group. Prefer
``repro.api.QueryClient.select``, which also cost-plans the strategy.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax

from ..costs import CostLedger
from ..engine import SecretSharedDB
from . import rounds
from ._common import resolve_backend
from .count import count_query


class CardinalityError(ValueError):
    """A selection algorithm's ℓ precondition failed (e.g. one_tuple on a
    multi-match predicate). Carries the true ``count`` the aborted count
    phase learned, so callers can replan without re-counting. Subclasses
    ValueError for backward compat."""

    def __init__(self, message: str, *, count: Optional[int] = None):
        super().__init__(message)
        self.count = count


# ---------------------------------------------------------------------------
# §3.2.1 — one value, one tuple (Algorithm 3)
# ---------------------------------------------------------------------------

def select_one_tuple(key: jax.Array, db: SecretSharedDB, column: int,
                     pattern: str, *, ledger: Optional[CostLedger] = None,
                     skip_count_phase: bool = False,
                     backend="jnp", impl: Optional[str] = None
                     ) -> Tuple[List[List[str]], CostLedger]:
    """SELECT * WHERE col = pattern, when the predicate hits exactly 1 tuple."""
    ledger = ledger if ledger is not None else CostLedger()
    be = resolve_backend(backend, impl)
    k_count, k_sel = jax.random.split(key)

    if not skip_count_phase:  # Phase 0 (Alg 3 line 1)
        ell, ledger = count_query(k_count, db, column, pattern, ledger=ledger,
                                  backend=be)
        if ell != 1:
            raise CardinalityError(
                f"select_one_tuple needs ℓ=1, predicate has {ell}"
                " — use select_one_round/select_tree", count=ell)

    # Alg 3 lines 3-12: one fused map round + one interpolation
    row = rounds.one_tuple_round(
        be, db, [rounds.MatchJob(column, pattern, k_sel, ledger)])[0]
    return [row], ledger


# ---------------------------------------------------------------------------
# shared Phase-2: oblivious fetch by secret-shared one-hot matrix
# ---------------------------------------------------------------------------

def fetch_by_addresses(key: jax.Array, db: SecretSharedDB,
                       addresses: Sequence[int], *, ledger: CostLedger,
                       padded_rows: Optional[int] = None,
                       backend="jnp", impl: Optional[str] = None
                       ) -> List[List[str]]:
    """Fetch tuples at known addresses with an ℓ'×n shared one-hot matrix.

    ``padded_rows`` ≥ ℓ hides the true result size (fake-row padding, §3.2.2
    leakage discussion): extra rows are all-zero one-hots and fetch nothing.
    """
    be = resolve_backend(backend, impl)
    return rounds.fetch_round(
        be, db, [rounds.FetchJob(key, list(addresses), ledger,
                                 padded_rows)])[0]


# ---------------------------------------------------------------------------
# §3.2.2 — one-round algorithm
# ---------------------------------------------------------------------------

def select_one_round(key: jax.Array, db: SecretSharedDB, column: int,
                     pattern: str, *, ledger: Optional[CostLedger] = None,
                     padded_rows: Optional[int] = None,
                     backend="jnp", impl: Optional[str] = None
                     ) -> Tuple[List[List[str]], List[int], CostLedger]:
    """Phase 1: per-tuple match bits in ONE round (user interpolates n·c′).
    Phase 2: oblivious matrix fetch."""
    ledger = ledger if ledger is not None else CostLedger()
    be = resolve_backend(backend, impl)
    k_pat, k_fetch = jax.random.split(key)

    addresses = rounds.match_all_round(
        be, db, [rounds.MatchJob(column, pattern, k_pat, ledger)])[0]
    rows = rounds.fetch_round(
        be, db, [rounds.FetchJob(k_fetch, addresses, ledger,
                                 padded_rows)])[0]
    return rows, addresses, ledger


# ---------------------------------------------------------------------------
# §3.2.2 — tree-based algorithm (Algorithm 4)
# ---------------------------------------------------------------------------

def select_tree(key: jax.Array, db: SecretSharedDB, column: int, pattern: str,
                *, ledger: Optional[CostLedger] = None,
                branching: Optional[int] = None,
                padded_rows: Optional[int] = None,
                known_count: Optional[int] = None,
                backend="jnp", impl: Optional[str] = None
                ) -> Tuple[List[List[str]], List[int], CostLedger]:
    """Tree-based multi-round address discovery + oblivious fetch (Alg 4).

    Rounds ≤ ⌊log_ℓ n⌋ + ⌊log₂ ℓ⌋ + 1 (Theorem 4). The user interpolates only
    per-block counts, never the full n-vector; each Q&A round is one padded
    block-matrix device dispatch and one interpolation. ``known_count`` skips
    the Phase-0 count when the caller (e.g. the planner) already ran it.

    On a sharded dataplane the Q&A block gathers execute per shard (each
    gather stays inside one shard's tuple range) — but the block partition
    itself is PUBLIC and fixed by (n, ℓ, branching) alone, so the priced
    and measured ledger never moves with the shard count.
    """
    ledger = ledger if ledger is not None else CostLedger()
    be = resolve_backend(backend, impl)
    k_count, k_pat, k_fetch = jax.random.split(key, 3)

    # Phase 0: count occurrences (unless the caller already did)
    if known_count is None:
        ell, ledger = count_query(k_count, db, column, pattern, ledger=ledger,
                                  backend=be)
    else:
        ell = known_count
    if ell == 0:
        return [], [], ledger

    addresses = rounds.tree_rounds(
        be, db, [rounds.TreeJob(column, pattern, k_pat, ledger,
                                ell=ell, branching=branching)])[0]
    rows = rounds.fetch_round(
        be, db, [rounds.FetchJob(k_fetch, addresses, ledger,
                                 padded_rows)])[0]
    return rows, addresses, ledger
