"""Selection queries on secret-shares (paper §3.2; Algorithms 3 & 4).

Three variants, exactly as the paper structures them:

* ``select_one_tuple``  (§3.2.1, Alg 3) — one value holds one tuple: match-bit
  × tuple, summed over n; only the satisfying tuple survives the sum.
* ``select_one_round``  (§3.2.2 "one-round") — cloud returns all n match bits
  (user interpolates n·c′ values), then a secret-shared ℓ'×n one-hot fetch
  matrix is multiplied against the relation (share-space matmul).
* ``select_tree``       (§3.2.2 "tree-based", Alg 4) — Q&A rounds of
  block-partitioned counts; the user interpolates only O(ℓ) values per round;
  address of a single-hit block via Address_fetch (Σ matchᵢ · i).

All cloud work is oblivious: identical ops on every tuple regardless of data.
Cloud-side hotspots go through the backend registry (``repro.api.backends``);
prefer ``repro.api.QueryClient.select``, which also cost-plans the strategy.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import encoding, field, shamir
from ..costs import CostLedger
from ..engine import SecretSharedDB
from ..shamir import Shares
from ._common import match_bits as _match_bits
from ._common import resolve_backend
from .count import count_query


class CardinalityError(ValueError):
    """A selection algorithm's ℓ precondition failed (e.g. one_tuple on a
    multi-match predicate). Carries the true ``count`` the aborted count
    phase learned, so callers can replan without re-counting. Subclasses
    ValueError for backward compat."""

    def __init__(self, message: str, *, count: Optional[int] = None):
        super().__init__(message)
        self.count = count


# ---------------------------------------------------------------------------
# §3.2.1 — one value, one tuple (Algorithm 3)
# ---------------------------------------------------------------------------

def select_one_tuple(key: jax.Array, db: SecretSharedDB, column: int,
                     pattern: str, *, ledger: Optional[CostLedger] = None,
                     skip_count_phase: bool = False,
                     backend="jnp", impl: Optional[str] = None
                     ) -> Tuple[List[List[str]], CostLedger]:
    """SELECT * WHERE col = pattern, when the predicate hits exactly 1 tuple."""
    ledger = ledger if ledger is not None else CostLedger()
    codec = db.codec
    be = resolve_backend(backend, impl)
    k_count, k_sel = jax.random.split(key)

    if not skip_count_phase:  # Phase 0 (Alg 3 line 1)
        ell, ledger = count_query(k_count, db, column, pattern, ledger=ledger,
                                  backend=be)
        if ell != 1:
            raise CardinalityError(
                f"select_one_tuple needs ℓ=1, predicate has {ell}"
                " — use select_one_round/select_tree", count=ell)

    # --- user: send shared predicate (Alg 3 line 3) ------------------------
    p_sh = encoding.share_pattern(k_sel, codec, pattern,
                                  n_shares=db.n_shares, degree=db.base_degree)
    ledger.round()
    ledger.send(db.n_shares * codec.word_length * codec.alphabet_size)

    # --- cloud: MAP_single_tuple_fetch (Alg 3 lines 8-12) ------------------
    col = db.column(column)
    m_bits = _match_bits(be, col, p_sh)                 # (c, n)
    rel = db.relation                                    # (c, n, m, W, A)
    mb = Shares(m_bits.values[:, :, None, None, None], m_bits.degree)
    picked = Shares(
        field.mul(jnp.broadcast_to(mb.values, rel.values.shape), rel.values),
        m_bits.degree + rel.degree)
    sums = picked.sum(axis=0)                            # (c, m, W, A)
    ledger.cloud(db.n_tuples * db.n_attrs * codec.word_length
                 * codec.alphabet_size)

    # --- cloud -> user: one summed tuple per cloud -------------------------
    ledger.recv(db.n_shares * db.n_attrs * codec.word_length
                * codec.alphabet_size)

    # --- user: interpolate + decode -----------------------------------------
    tup = shamir.interpolate(sums)                       # (m, W, A)
    ledger.user((sums.degree + 1) * db.n_attrs * codec.word_length)
    row = codec.decode_row(np.asarray(tup))
    return [row], ledger


# ---------------------------------------------------------------------------
# shared Phase-2: oblivious fetch by secret-shared one-hot matrix
# ---------------------------------------------------------------------------

def fetch_by_addresses(key: jax.Array, db: SecretSharedDB,
                       addresses: Sequence[int], *, ledger: CostLedger,
                       padded_rows: Optional[int] = None,
                       backend="jnp", impl: Optional[str] = None
                       ) -> List[List[str]]:
    """Fetch tuples at known addresses with an ℓ'×n shared one-hot matrix.

    ``padded_rows`` ≥ ℓ hides the true result size (fake-row padding, §3.2.2
    leakage discussion): extra rows are all-zero one-hots and fetch nothing.
    """
    codec = db.codec
    be = resolve_backend(backend, impl)
    n = db.n_tuples
    ell = len(addresses)
    ellp = max(padded_rows or ell, ell)

    # --- user: build + share the fetch matrix ------------------------------
    m_host = np.zeros((ellp, n), dtype=np.uint32)
    for r, a in enumerate(addresses):
        m_host[r, a] = 1
    m_sh = encoding.share_encoded(key, m_host, n_shares=db.n_shares,
                                  degree=db.base_degree)   # (c, ℓ', n)
    ledger.round()
    ledger.send(db.n_shares * ellp * n)

    # --- cloud: share-space matmul  M @ R  ----------------------------------
    rel = db.relation.values                         # (c, n, m, W, A)
    c, _, m, w, a = rel.shape
    rel_flat = rel.reshape(c, n, m * w * a)
    fetched_flat = be.ss_matmul(m_sh.values, rel_flat)
    fetched = Shares(fetched_flat.reshape(c, ellp, m, w, a),
                     m_sh.degree + db.relation.degree)
    ledger.cloud(ellp * n * m * w * a)

    # --- cloud -> user, interpolate + decode --------------------------------
    ledger.recv(db.n_shares * ellp * m * w * a)
    out = shamir.interpolate(fetched)                 # (ℓ', m, W, A)
    ledger.user((fetched.degree + 1) * ellp * m * w)
    rows = [codec.decode_row(np.asarray(out[r])) for r in range(ell)]
    return rows


# ---------------------------------------------------------------------------
# §3.2.2 — one-round algorithm
# ---------------------------------------------------------------------------

def select_one_round(key: jax.Array, db: SecretSharedDB, column: int,
                     pattern: str, *, ledger: Optional[CostLedger] = None,
                     padded_rows: Optional[int] = None,
                     backend="jnp", impl: Optional[str] = None
                     ) -> Tuple[List[List[str]], List[int], CostLedger]:
    """Phase 1: per-tuple match bits in ONE round (user interpolates n·c′).
    Phase 2: oblivious matrix fetch."""
    ledger = ledger if ledger is not None else CostLedger()
    codec = db.codec
    be = resolve_backend(backend, impl)
    k_pat, k_fetch = jax.random.split(key)

    # --- round 1: user sends predicate, cloud returns n match bits ---------
    p_sh = encoding.share_pattern(k_pat, codec, pattern,
                                  n_shares=db.n_shares, degree=db.base_degree)
    ledger.round()
    ledger.send(db.n_shares * codec.word_length * codec.alphabet_size)
    col = db.column(column)
    m_bits = _match_bits(be, col, p_sh)                       # (c, n)
    ledger.cloud(db.n_tuples * codec.word_length * codec.alphabet_size)
    ledger.recv(db.n_shares * db.n_tuples)

    # --- user: interpolate all n bits, collect addresses --------------------
    v = np.asarray(shamir.interpolate(m_bits))                # (n,)
    ledger.user((m_bits.degree + 1) * db.n_tuples)
    addresses = [int(i) for i in np.nonzero(v)[0]]

    # --- round 2: oblivious fetch -------------------------------------------
    rows = fetch_by_addresses(k_fetch, db, addresses, ledger=ledger,
                              padded_rows=padded_rows, backend=be)
    return rows, addresses, ledger


# ---------------------------------------------------------------------------
# §3.2.2 — tree-based algorithm (Algorithm 4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Block:
    start: int
    end: int    # exclusive

    @property
    def size(self) -> int:
        return self.end - self.start


def _count_blocks(be, db: SecretSharedDB, column: int, p_sh: Shares,
                  blocks: Sequence[_Block], ledger: CostLedger
                  ) -> List[int]:
    """One Q&A round: cloud counts p in each block, user interpolates."""
    codec = db.codec
    counts = []
    for b in blocks:
        col = Shares(db.relation.values[:, b.start:b.end, column],
                     db.relation.degree)
        cnt = _match_bits(be, col, p_sh).sum(axis=0)    # (c,) share
        counts.append(cnt)
        ledger.cloud(b.size * codec.word_length * codec.alphabet_size)
    ledger.round()
    ledger.recv(db.n_shares * len(blocks))
    out = []
    for cnt in counts:
        out.append(int(np.asarray(shamir.interpolate(cnt))))
        ledger.user(cnt.degree + 1)
    return out


def _address_fetch(be, db: SecretSharedDB, column: int, p_sh: Shares,
                   block: _Block, ledger: CostLedger) -> int:
    """Alg 4 line 14: line_number = Σ matchᵢ · (i+1) over the block."""
    col = Shares(db.relation.values[:, block.start:block.end, column],
                 db.relation.degree)
    m_bits = _match_bits(be, col, p_sh)                  # (c, h)
    idx = jnp.arange(block.start + 1, block.end + 1, dtype=field.DTYPE)
    line = Shares(field.mul(m_bits.values,
                            jnp.broadcast_to(idx[None], m_bits.values.shape)),
                  m_bits.degree).sum(axis=0)
    ledger.cloud(block.size * db.codec.word_length * db.codec.alphabet_size)
    ledger.recv(db.n_shares)
    addr = int(np.asarray(shamir.interpolate(line))) - 1
    ledger.user(line.degree + 1)
    return addr


def select_tree(key: jax.Array, db: SecretSharedDB, column: int, pattern: str,
                *, ledger: Optional[CostLedger] = None,
                branching: Optional[int] = None,
                padded_rows: Optional[int] = None,
                known_count: Optional[int] = None,
                backend="jnp", impl: Optional[str] = None
                ) -> Tuple[List[List[str]], List[int], CostLedger]:
    """Tree-based multi-round address discovery + oblivious fetch (Alg 4).

    Rounds ≤ ⌊log_ℓ n⌋ + ⌊log₂ ℓ⌋ + 1 (Theorem 4). The user interpolates only
    per-block counts, never the full n-vector. ``known_count`` skips the
    Phase-0 count when the caller (e.g. the planner) already ran it.
    """
    ledger = ledger if ledger is not None else CostLedger()
    codec = db.codec
    be = resolve_backend(backend, impl)
    k_count, k_pat, k_fetch = jax.random.split(key, 3)

    # Phase 0: count occurrences (unless the caller already did)
    if known_count is None:
        ell, ledger = count_query(k_count, db, column, pattern, ledger=ledger,
                                  backend=be)
    else:
        ell = known_count
    if ell == 0:
        return [], [], ledger
    p_sh = encoding.share_pattern(k_pat, codec, pattern,
                                  n_shares=db.n_shares, degree=db.base_degree)
    ledger.send(db.n_shares * codec.word_length * codec.alphabet_size)
    if ell == 1:
        # Alg 4 line 2 -> Alg 3; reuse the generic path below with one block.
        addr = _address_fetch(be, db, column, p_sh,
                              _Block(0, db.n_tuples), ledger)
        ledger.round()
        rows = fetch_by_addresses(k_fetch, db, [addr], ledger=ledger,
                                  padded_rows=padded_rows, backend=be)
        return rows, [addr], ledger

    fanout = branching or ell
    addresses: List[int] = []
    active = [_Block(0, db.n_tuples)]
    first_round = True
    while active:
        # partition every active block into ≤ fanout equal sub-blocks
        sub_blocks: List[_Block] = []
        for b in active:
            k = min(fanout if first_round else max(2, fanout), b.size)
            bounds = np.linspace(b.start, b.end, k + 1).astype(int)
            sub_blocks += [_Block(int(bounds[i]), int(bounds[i + 1]))
                           for i in range(k) if bounds[i] < bounds[i + 1]]
        first_round = False
        counts = _count_blocks(be, db, column, p_sh, sub_blocks, ledger)
        active = []
        for b, cnt in zip(sub_blocks, counts):
            if cnt == 0:                       # Case 1
                continue
            if cnt == 1:                       # Case 2: Address_fetch
                addresses.append(_address_fetch(be, db, column, p_sh, b,
                                                ledger))
            elif cnt == b.size:                # Case 3: whole block matches
                addresses.extend(range(b.start, b.end))
            else:                              # Case 4: recurse
                active.append(b)

    addresses.sort()
    rows = fetch_by_addresses(k_fetch, db, addresses, ledger=ledger,
                              padded_rows=padded_rows, backend=be)
    return rows, addresses, ledger
