"""Count query (paper §3.1, Algorithm 2; Theorem 1).

User sends a secret-shared predicate (O(1) communication — independent of n),
each cloud runs the accumulating automaton over the target attribute (nw work)
and returns ONE share; the user interpolates c' = deg+1 values (O(1) work).

Prefer ``repro.api.QueryClient.count`` — this free function remains as the
protocol implementation the client delegates to.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

from .. import encoding, shamir
from ..costs import CostLedger
from ..engine import SecretSharedDB
from ._common import match_bits, resolve_backend


def count_query(key: jax.Array, db: SecretSharedDB, column: int, pattern: str,
                *, ledger: Optional[CostLedger] = None,
                backend="jnp", impl: Optional[str] = None
                ) -> Tuple[int, CostLedger]:
    """COUNT(*) WHERE col = pattern — oblivious, one round."""
    ledger = ledger if ledger is not None else CostLedger()
    codec = db.codec
    be = resolve_backend(backend, impl)

    # --- user side: encode + share the predicate (Alg 2 line 1-2) ----------
    p_sh = encoding.share_pattern(key, codec, pattern,
                                  n_shares=db.n_shares, degree=db.base_degree)
    ledger.round()
    ledger.send(db.n_shares * codec.word_length * codec.alphabet_size)

    # --- cloud side: AA over every value of the attribute (MAP_count) ------
    col = db.column(column)                      # (c, n, W, A)
    counts = match_bits(be, col, p_sh).sum(axis=0)   # (c,) count share
    ledger.cloud(db.n_tuples * codec.word_length * codec.alphabet_size)

    # --- cloud -> user: one word per cloud ---------------------------------
    ledger.recv(db.n_shares)

    # --- user side: interpolate c' shares (Alg 2 line 5-6) -----------------
    result = shamir.interpolate(counts)
    ledger.user(counts.degree + 1)
    return int(np.asarray(result)), ledger
