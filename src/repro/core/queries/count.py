"""Count query (paper §3.1, Algorithm 2; Theorem 1).

User sends a secret-shared predicate (O(1) communication — independent of n),
each cloud runs the accumulating automaton over the target attribute (nw work)
and returns ONE share; the user interpolates c' = deg+1 values (O(1) work).

Prefer ``repro.api.QueryClient.count`` — this free function remains as the
protocol implementation the client delegates to.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from ..costs import CostLedger
from ..engine import SecretSharedDB
from . import rounds
from ._common import resolve_backend


def count_query(key: jax.Array, db: SecretSharedDB, column: int, pattern: str,
                *, ledger: Optional[CostLedger] = None,
                backend="jnp", impl: Optional[str] = None
                ) -> Tuple[int, CostLedger]:
    """COUNT(*) WHERE col = pattern — oblivious, one round.

    Thin wrapper over the batched count phase at B = 1: user shares the
    predicate, the cloud runs one fused AA dispatch, the user interpolates
    one count share per contacted cloud.
    """
    ledger = ledger if ledger is not None else CostLedger()
    be = resolve_backend(backend, impl)
    cnt = rounds.count_phase(
        be, db, [rounds.MatchJob(column, pattern, key, ledger)])[0]
    return cnt, ledger
