"""Round-structured, batch-first protocol core for §3.1/§3.2 queries.

Every selection/count protocol is decomposed here into explicit *rounds*,
each round being one pure cloud step (a single fused device dispatch over a
stack of B concurrent queries) followed by one user step (a single Lagrange
interpolation over everything that round returned). The per-query free
functions in ``select.py`` / ``count.py`` are thin wrappers that run these
engines with B = 1, so a batch of B queries and B sequential queries execute
*the same code* — per-query ``CostLedger`` totals and result rows are
bit-identical by construction (asserted by ``tests/test_batch.py``).

Protocol phases (one function per phase; a phase is one round except the
tree engine, which loops):

  * :func:`count_phase`     — §3.1 Alg 2 over B predicates: one
    ``aa_match_batch`` dispatch, one interpolation of the B count shares.
  * :func:`one_tuple_round` — §3.2.1 Alg 3 map round over B (verified ℓ=1)
    predicates: one dispatch, one interpolation of B tuples.
  * :func:`match_all_round` — §3.2.2 one-round Phase 1: one dispatch, one
    interpolation of the B·n match-bit matrix.
  * :func:`tree_rounds`     — §3.2.2 Alg 4 Q&A rounds, *lockstep over the
    batch*: per round, every query's active blocks are padded to a uniform
    height and stacked into one block matrix — a single dispatch and a
    single interpolation replace the historical per-block Python loop.
    Address fetches (Alg 4 line 14) discovered in a round are likewise
    batched into one dispatch + one interpolation.
  * :func:`fetch_round`     — §3.2.2 Phase 2 oblivious fetch: the B padded
    one-hot matrices are stacked row-wise and multiplied against the
    relation in one fused ``ss_matmul``.

Ledgers record *protocol* cost (each query's own blocks/rows, Table 1
units), never the padding the fused dispatch adds — padding is an execution
artifact of batching, invisible to the user↔cloud transcript.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import encoding, field, shamir
from ..costs import CostLedger
from ..engine import SecretSharedDB
from ..partition import split_bounds
from ..shamir import Shares


# ---------------------------------------------------------------------------
# batch job descriptors
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MatchJob:
    """One query's slot in a predicate-match phase (count / select)."""
    column: int
    pattern: str
    key: jax.Array          # key for sharing this query's predicate
    ledger: CostLedger


@dataclasses.dataclass
class TreeJob(MatchJob):
    """One query's slot in the tree-selection Q&A engine (ℓ ≥ 1 known)."""
    ell: int = 1
    branching: Optional[int] = None


@dataclasses.dataclass
class FetchJob:
    """One query's slot in the fused oblivious-fetch round."""
    key: jax.Array
    addresses: Sequence[int]
    ledger: CostLedger
    padded_rows: Optional[int] = None


# ---------------------------------------------------------------------------
# shared user/cloud helpers
# ---------------------------------------------------------------------------

def _batched_matcher(be):
    """Backend's fused stacked-predicate matcher (deferred registry import
    keeps core below ``repro.api`` in the layering)."""
    from ...api import backends as _registry
    return _registry.batched_matcher(be)


def _share_patterns(db: SecretSharedDB, jobs: Sequence[MatchJob]) -> Shares:
    """User step: encode + share every job's predicate -> (c, B, W, A)."""
    vals = [encoding.share_pattern(j.key, db.codec, j.pattern,
                                   n_shares=db.n_shares,
                                   degree=db.base_degree).values
            for j in jobs]
    return Shares(jnp.stack(vals, axis=1), db.base_degree)


def _stack_columns(db: SecretSharedDB, columns: Sequence[int]) -> Shares:
    """Cloud-local view: each job's attribute column -> (c, B, n, W, A).

    When every job targets the same column the stack is a broadcast view,
    not a copy.
    """
    rel = db.relation.values                       # (c, n, m, W, A)
    if len(set(columns)) == 1:
        one = rel[:, :, columns[0]]                # (c, n, W, A)
        stacked = jnp.broadcast_to(one[:, None],
                                   (one.shape[0], len(columns))
                                   + one.shape[1:])
    else:
        stacked = jnp.moveaxis(rel[:, :, np.asarray(columns)], 2, 1)
    return Shares(stacked, db.relation.degree)


def _match_stack(be, cols: Shares, pats: Shares) -> Shares:
    """One fused AA dispatch over the stack, with degree bookkeeping."""
    w = cols.values.shape[-2]
    bits = _batched_matcher(be)(cols.values, pats.values)      # (c, B, n)
    return Shares(bits, (cols.degree + pats.degree) * w)


def _block_match(be, db: SecretSharedDB, p_all: Shares,
                 columns: Sequence[int],
                 entries: Sequence[Tuple[int, int, int]]) -> Shares:
    """One padded block-matrix dispatch for tree rounds.

    entries: (job_index, start, end) block jobs, possibly from different
    queries. Blocks are padded to the round's max height H; padded positions
    are masked to share-of-0 so block sums are exact. Returns match-bit
    Shares (c, K, H).
    """
    starts = np.asarray([s for _, s, _ in entries])
    ends = np.asarray([e for _, _, e in entries])
    jidx = np.asarray([i for i, _, _ in entries])
    h = int((ends - starts).max())
    idx = starts[:, None] + np.arange(h)[None, :]              # (K, H)
    mask = idx < ends[:, None]
    idx = np.where(mask, idx, 0)
    cols_e = np.asarray([columns[i] for i in jidx])
    rel = db.relation.values                                   # (c,n,m,W,A)
    gathered = rel[:, jnp.asarray(idx), jnp.asarray(cols_e)[:, None]]
    pats = Shares(p_all.values[:, jnp.asarray(jidx)], p_all.degree)
    bits = _match_stack(be, Shares(gathered, db.relation.degree), pats)
    masked = jnp.where(jnp.asarray(mask)[None], bits.values, 0)
    return Shares(masked, bits.degree)


# ---------------------------------------------------------------------------
# §3.1 — batched count phase (Algorithm 2)
# ---------------------------------------------------------------------------

def count_phase(be, db: SecretSharedDB, jobs: Sequence[MatchJob]
                ) -> List[int]:
    """COUNT for B predicates: one cloud dispatch, one interpolation."""
    if not jobs:
        return []
    codec = db.codec
    p_all = _share_patterns(db, jobs)
    cols = _stack_columns(db, [j.column for j in jobs])
    bits = _match_stack(be, cols, p_all)                       # (c, B, n)
    counts = bits.sum(axis=1)                                  # (c, B)
    out = np.asarray(shamir.interpolate(counts))
    per_q = codec.word_length * codec.alphabet_size
    for j in jobs:
        j.ledger.round()
        j.ledger.send(db.n_shares * per_q)
        j.ledger.cloud(db.n_tuples * per_q)
        j.ledger.recv(db.n_shares)
        j.ledger.user(counts.degree + 1)
    return [int(v) for v in out]


# ---------------------------------------------------------------------------
# §3.2.1 — batched single-tuple map round (Algorithm 3 lines 3-12)
# ---------------------------------------------------------------------------

def one_tuple_round(be, db: SecretSharedDB, jobs: Sequence[MatchJob]
                    ) -> List[List[str]]:
    """Fetch the single satisfying tuple for B (ℓ=1-verified) predicates."""
    if not jobs:
        return []
    codec = db.codec
    b = len(jobs)
    p_all = _share_patterns(db, jobs)
    cols = _stack_columns(db, [j.column for j in jobs])
    bits = _match_stack(be, cols, p_all)                       # (c, B, n)
    rel = db.relation.values                                   # (c,n,m,W,A)
    c, n, m, w, a = rel.shape
    # Σ_n bit·tuple is a share-space matmul of the match bits against the
    # flattened relation — same mod-p result as the elementwise broadcast
    # product, without materializing a B-fold (c,B,n,m,W,A) intermediate.
    sums_flat = be.ss_matmul(bits.values, rel.reshape(c, n, m * w * a))
    sums = Shares(sums_flat.reshape(c, b, m, w, a),
                  bits.degree + db.relation.degree)            # (c,B,m,W,A)
    tup = np.asarray(shamir.interpolate(sums))                 # (B, m, W, A)
    per_q = codec.word_length * codec.alphabet_size
    for j in jobs:
        j.ledger.round()
        j.ledger.send(db.n_shares * per_q)
        j.ledger.cloud(db.n_tuples * db.n_attrs * per_q)
        j.ledger.recv(db.n_shares * db.n_attrs * per_q)
        j.ledger.user((sums.degree + 1) * db.n_attrs * codec.word_length)
    return [codec.decode_row(tup[i]) for i in range(b)]


# ---------------------------------------------------------------------------
# §3.2.2 one-round — batched Phase 1 (all n match bits per query)
# ---------------------------------------------------------------------------

def match_all_round(be, db: SecretSharedDB, jobs: Sequence[MatchJob]
                    ) -> List[List[int]]:
    """Per-query satisfying addresses via one fused match-bit round."""
    if not jobs:
        return []
    codec = db.codec
    p_all = _share_patterns(db, jobs)
    cols = _stack_columns(db, [j.column for j in jobs])
    bits = _match_stack(be, cols, p_all)                       # (c, B, n)
    v = np.asarray(shamir.interpolate(bits))                   # (B, n)
    per_q = codec.word_length * codec.alphabet_size
    for j in jobs:
        j.ledger.round()
        j.ledger.send(db.n_shares * per_q)
        j.ledger.cloud(db.n_tuples * per_q)
        j.ledger.recv(db.n_shares * db.n_tuples)
        j.ledger.user((bits.degree + 1) * db.n_tuples)
    return [[int(i) for i in np.nonzero(v[b])[0]] for b in range(len(jobs))]


# ---------------------------------------------------------------------------
# §3.2.2 tree — lockstep Q&A rounds over the batch (Algorithm 4)
# ---------------------------------------------------------------------------

def tree_rounds(be, db: SecretSharedDB, jobs: Sequence[TreeJob]
                ) -> List[List[int]]:
    """Address discovery for B tree selections, every round fused.

    Each loop iteration performs at most one *count* Q&A round (all active
    blocks of all queries, padded + stacked, one dispatch + one
    interpolation) and at most one *address-fetch* round (all blocks whose
    count came back 1, same fusion). A query stops participating once it has
    no active blocks; its ledger only ever records its own rounds, blocks
    and bits — identical to running it alone.
    """
    if not jobs:
        return []
    codec = db.codec
    per_q = codec.word_length * codec.alphabet_size
    n = db.n_tuples
    columns = [j.column for j in jobs]
    p_all = _share_patterns(db, jobs)
    for j in jobs:
        j.ledger.send(db.n_shares * per_q)

    addresses: List[List[int]] = [[] for _ in jobs]
    active: List[List[Tuple[int, int]]] = []
    first = [True] * len(jobs)
    pending_addr: List[Tuple[int, int, int]] = []
    # ℓ=1 queries take the Alg 4 line 2 path: one whole-table address fetch
    # that counts as its own round (the per-query wrapper's legacy
    # behaviour), then straight to Phase 2.
    one_shot = set()
    for i, j in enumerate(jobs):
        if j.ell == 1:
            pending_addr.append((i, 0, n))
            one_shot.add(i)
            active.append([])
        else:
            active.append([(0, n)])

    while any(active) or pending_addr:
        # -- partition every query's active blocks (public, host-side) ------
        entries: List[Tuple[int, int, int]] = []
        for i, blocks in enumerate(active):
            if not blocks:
                continue
            fanout = jobs[i].branching or jobs[i].ell
            k = fanout if first[i] else max(2, fanout)
            first[i] = False
            subs = []
            for (s, e) in blocks:
                subs += split_bounds(s, e, k)
            entries += [(i, s, e) for (s, e) in subs]
            active[i] = []

        # -- count Q&A round: ONE dispatch + ONE interpolation --------------
        if entries:
            bits = _block_match(be, db, p_all, columns, entries)
            counts = Shares(field.sum_(bits.values, axis=2), bits.degree)
            vals = np.asarray(shamir.interpolate(counts))      # (K,)
            n_blocks: dict = {}
            for (i, s, e) in entries:
                jobs[i].ledger.cloud((e - s) * per_q)
                n_blocks[i] = n_blocks.get(i, 0) + 1
            for i, k_i in n_blocks.items():
                jobs[i].ledger.round()
                jobs[i].ledger.recv(db.n_shares * k_i)
                jobs[i].ledger.user((counts.degree + 1) * k_i)
            for (i, s, e), v in zip(entries, (int(x) for x in vals)):
                if v == 0:                     # Case 1: dead block
                    continue
                if v == 1:                     # Case 2: Address_fetch
                    pending_addr.append((i, s, e))
                elif v == e - s:               # Case 3: whole block matches
                    addresses[i].extend(range(s, e))
                else:                          # Case 4: recurse
                    active[i].append((s, e))

        # -- address-fetch round: ONE dispatch + ONE interpolation ----------
        if pending_addr:
            addr_entries, pending_addr = pending_addr, []
            bits = _block_match(be, db, p_all, columns, addr_entries)
            h = bits.values.shape[2]
            starts = np.asarray([s for _, s, _ in addr_entries])
            # line_number = Σ match_h · (global index + 1); padded positions
            # hold shares of 0 so their weight never contributes.
            weights = (starts[:, None] + np.arange(h)[None, :] + 1)
            line = Shares(
                field.sum_(field.mul(bits.values,
                                     jnp.asarray(weights,
                                                 field.DTYPE)[None]),
                           axis=2), bits.degree)               # (c, K)
            vals = np.asarray(shamir.interpolate(line))
            for (i, s, e), v in zip(addr_entries, vals):
                jobs[i].ledger.cloud((e - s) * per_q)
                jobs[i].ledger.recv(db.n_shares)
                jobs[i].ledger.user(line.degree + 1)
                addresses[i].append(int(v) - 1)
                if i in one_shot:
                    jobs[i].ledger.round()
                    one_shot.discard(i)

    return [sorted(a) for a in addresses]


# ---------------------------------------------------------------------------
# §3.2.2 Phase 2 — fused oblivious fetch for the whole batch
# ---------------------------------------------------------------------------

def fetch_round(be, db: SecretSharedDB, jobs: Sequence[FetchJob]
                ) -> List[List[List[str]]]:
    """Fetch every job's tuples with ONE share-space matmul.

    Each query's ℓ'×n one-hot matrix (``padded_rows`` ≥ ℓ hides the true
    result size, §3.2.2 leakage discussion) is shared under that query's own
    key; the B matrices are stacked row-wise so the cloud performs a single
    (Σℓ'_b × n) @ (n × mWA) fused fetch, then the user interpolates all
    fetched tuples at once and splits them back per query.
    """
    if not jobs:
        return []
    codec = db.codec
    n = db.n_tuples
    ellps = []
    mats = []
    for j in jobs:
        ell = len(j.addresses)
        ellp = max(j.padded_rows or ell, ell)
        ellps.append(ellp)
        m_host = np.zeros((ellp, n), dtype=np.uint32)
        for r, a in enumerate(j.addresses):
            m_host[r, a] = 1
        m_sh = encoding.share_encoded(j.key, m_host, n_shares=db.n_shares,
                                      degree=db.base_degree)   # (c, ℓ', n)
        mats.append(m_sh.values)
    stacked = jnp.concatenate(mats, axis=1)                    # (c, R, n)
    rel = db.relation.values                                   # (c,n,m,W,A)
    c, _, m, w, a = rel.shape
    rel_flat = rel.reshape(c, n, m * w * a)
    fetched_flat = be.ss_matmul(stacked, rel_flat)             # ONE dispatch
    total = stacked.shape[1]
    fetched = Shares(fetched_flat.reshape(c, total, m, w, a),
                     db.base_degree + db.relation.degree)
    out = np.asarray(shamir.interpolate(fetched))              # (R, m, W, A)

    results: List[List[List[str]]] = []
    off = 0
    for j, ellp in zip(jobs, ellps):
        ell = len(j.addresses)
        j.ledger.round()
        j.ledger.send(db.n_shares * ellp * n)
        j.ledger.cloud(ellp * n * m * w * a)
        j.ledger.recv(db.n_shares * ellp * m * w * a)
        j.ledger.user((fetched.degree + 1) * ellp * m * w)
        results.append([codec.decode_row(out[off + r]) for r in range(ell)])
        off += ellp
    return results
