"""Round-structured, batch-first protocol core for §3.1/§3.2 queries.

Every selection/count protocol is decomposed here into explicit *rounds*,
each round being one pure cloud step (a single fused device dispatch over a
stack of B concurrent queries) followed by one user step (a single Lagrange
interpolation over everything that round returned). The per-query free
functions in ``select.py`` / ``count.py`` are thin wrappers that run these
engines with B = 1, so a batch of B queries and B sequential queries execute
*the same code* — per-query ``CostLedger`` totals and result rows are
bit-identical by construction (asserted by ``tests/test_batch.py``).

Protocol phases (one function per phase; a phase is one round except the
tree engine, which loops):

  * :func:`count_phase`     — §3.1 Alg 2 over B predicates: one
    ``aa_match_batch`` dispatch, one interpolation of the B count shares.
  * :func:`one_tuple_round` — §3.2.1 Alg 3 map round over B (verified ℓ=1)
    predicates: one dispatch, one interpolation of B tuples.
  * :func:`match_all_round` — §3.2.2 one-round Phase 1: one dispatch, one
    interpolation of the B·n match-bit matrix.
  * :func:`tree_rounds`     — §3.2.2 Alg 4 Q&A rounds, *lockstep over the
    batch*: per round, every query's active blocks are padded to a uniform
    height and stacked into one block matrix — a single dispatch and a
    single interpolation replace the historical per-block Python loop.
    Address fetches (Alg 4 line 14) discovered in a round are likewise
    batched into one dispatch + one interpolation.
  * :func:`fetch_round`     — §3.2.2 Phase 2 oblivious fetch: the B padded
    one-hot matrices are stacked row-wise and multiplied against the
    relation in one fused ``ss_matmul``.
  * :func:`range_phase` / :func:`range_rounds` — §3.4 Alg 5/6 over B range
    predicates: the B queries' endpoint/column bit-vectors (×2 directions,
    Eq. 2) stack into ONE ``(c, 2B, n, t)`` SS-SUB carry chain — one
    backend ``ripple_carry`` dispatch per bit-round, one degree-reduction
    re-share per ``reduce_every`` boundary *for the whole batch*.
  * :func:`join_match_round` / :func:`join_emit_round` — §3.3.1 PK/FK joins
    as rounds: the per-join match matrices become :class:`FetchEntry` rows
    of the shared fetch matmul (cross-group fusion), the re-randomized
    outputs interpolate in one fused user step per degree class.
  * :func:`equijoin_rounds` — §3.3.2 over B equijoin jobs: one fused
    column-open interpolation, all layer-1 X-side fetch matrices in one
    ``ss_matmul`` (Y-side fused per distinct right relation), and the
    layer-2 pair interpolations fused per degree class.
  * :func:`fetch_fusion`    — the cross-group fetch: every matrix that
    multiplies the relation this round (one_round / tree / range one-hots
    *and* join match matrices; a zero-match one_round/range query
    contributes a 0-row block) stacks into a single ``ss_matmul``
    dispatch. Tree queries that learned ℓ=0 in the count phase skip the
    fetch entirely, exactly as a solo run does.

Ledgers record *protocol* cost (each query's own blocks/rows, Table 1
units), never the padding the fused dispatch adds — padding is an execution
artifact of batching, invisible to the user↔cloud transcript.

Every function here accepts either a plain :class:`SecretSharedDB` or a
:class:`~repro.core.dataplane.ShardedRelation`. Cloud steps route through
the dataplane: the engine emits one dispatch descriptor per tuple-axis
shard and the relation's placement policy executes and reduces them
(match bits and ripple planes concatenate; count / fetch-matmul partial
sums combine additively in F_p). Reduction is exact modular arithmetic, so
the user↔cloud transcript — rounds, opened values, ledgers — is
bit-identical for every shard count; S is purely an execution knob.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .. import automata, dataplane, encoding, field, shamir
from ..costs import CostLedger
from ..dataplane import RelationLike
from ..engine import SecretSharedDB
from ..partition import split_bounds
from ..shamir import Shares


# ---------------------------------------------------------------------------
# batch job descriptors
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MatchJob:
    """One query's slot in a predicate-match phase (count / select).

    ``spec`` selects the matcher strategy: ``None`` is the exact-word
    equality chain; a :class:`~repro.core.encoding.PatternSpec` lowers the
    job onto the pattern engine — ``masked`` rides the very same full-width
    chain (only the pattern encoding differs), ``prefix`` the truncated
    k-chain, ``suffix``/``contains`` the sliding-window step.
    """
    column: int
    pattern: str
    key: jax.Array          # key for sharing this query's predicate
    ledger: CostLedger
    spec: Optional[encoding.PatternSpec] = None


@dataclasses.dataclass
class TreeJob(MatchJob):
    """One query's slot in the tree-selection Q&A engine (ℓ ≥ 1 known)."""
    ell: int = 1
    branching: Optional[int] = None


@dataclasses.dataclass
class FetchJob:
    """One query's slot in the fused oblivious-fetch round."""
    key: jax.Array
    addresses: Sequence[int]
    ledger: CostLedger
    padded_rows: Optional[int] = None


@dataclasses.dataclass
class RangeJob:
    """One query's slot in the batched §3.4 ripple (Algorithms 5/6).

    ``want_addresses`` distinguishes RangeSelect (the user interpolates all
    n indicator bits and learns addresses) from RangeCount (only the summed
    count travels back). Jobs fused into one :func:`range_phase` must share
    the column bit-width and ``reduce_every`` (the carry chains march in
    lockstep).
    """
    column: int
    lo: int
    hi: int
    key: jax.Array
    ledger: CostLedger
    reduce_every: int = 0
    want_addresses: bool = False


@dataclasses.dataclass
class JoinJob:
    """One PK/FK join's slot in the batched §3.3.1 round structure.

    ``match_method`` picks the backend *execution* of the nx×ny match
    matrix: ``"chain"`` multiplies W per-position one-hot dot sets
    sequentially (Table 3 order); ``"aggregate"`` contracts the flattened
    (W·A) encodings in ONE ``ss_matmul`` and applies the §3.1 equality
    indicator share-side. Both produce the same secrets at the same degree
    (2tW), so transcripts and ledgers are identical — the planner prices
    the choice by backend launch count.
    """
    right: SecretSharedDB
    col_x: int
    col_y: int
    key: Optional[jax.Array]
    ledger: CostLedger
    match_method: str = "chain"


@dataclasses.dataclass
class EquiJob:
    """One general-equijoin's slot in the batched §3.3.2 round structure."""
    right: SecretSharedDB
    col_x: int
    col_y: int
    key: jax.Array
    ledger: CostLedger
    padded_values: int = 0


@dataclasses.dataclass
class FetchEntry:
    """One raw row-block of the cross-group fused fetch matmul.

    ``values`` are raw share rows (c, r, n) multiplying the relation;
    ``degree`` is their sharing degree (one-hot fetch rows are base-degree,
    join match-matrix rows carry the AA product degree). The fused dispatch
    is degree-agnostic — degrees matter only when the output is split back.
    """
    values: jax.Array
    degree: int


# ---------------------------------------------------------------------------
# shared user/cloud helpers
# ---------------------------------------------------------------------------

def _batched_matcher(be):
    """Backend's fused stacked-predicate matcher (deferred registry import
    keeps core below ``repro.api`` in the layering)."""
    from ...api import backends as _registry
    return _registry.batched_matcher(be)


def _ripple_stepper(be):
    """Backend's fused SS-SUB bit step (deferred import, as above)."""
    from ...api import backends as _registry
    return _registry.ripple_stepper(be)


def _ripple_segmenter(be):
    """Backend's fused SS-SUB segment (k bit steps, one dispatch)."""
    from ...api import backends as _registry
    return _registry.ripple_segmenter(be)


def _batched_match_matrix(be):
    """Backend's stacked all-pairs matcher (deferred import, as above)."""
    from ...api import backends as _registry
    return _registry.batched_match_matrix(be)


def _slide_matcher(be):
    """Backend's stacked sliding-window matcher (deferred import, as
    above) — raw window-chain products for suffix/substring patterns."""
    from ...api import backends as _registry
    return _registry.slide_matcher(be)


def _aggregate_matcher(be):
    """Backend's aggregation-form all-pairs matcher: the §3.1 "aggregate"
    method promoted to a planner-priced join execution choice."""
    from ...api import backends as _registry
    return _registry.aggregate_match_matrix(be)


def _share_one_hot(key: jax.Array, db: SecretSharedDB,
                   addresses: Sequence[int],
                   n_rows: Optional[int] = None) -> Shares:
    """User step: an ℓ'×n one-hot fetch matrix shared at base degree.

    ``n_rows`` ≥ ℓ pads with all-zero rows (they fetch nothing) — the
    §3.2.2 output-size defence. Every fetch matrix in the suite (selection,
    range, equijoin layer 1) is built here so its sharing stays uniform.
    """
    n = db.n_tuples
    rows = len(addresses) if n_rows is None else max(n_rows, len(addresses))
    m_host = np.zeros((rows, n), dtype=np.uint32)
    for r, a in enumerate(addresses):
        m_host[r, a] = 1
    return encoding.share_encoded(key, m_host, n_shares=db.n_shares,
                                  degree=db.base_degree)


def _fused_interpolate(parts: Sequence[Shares]) -> List[np.ndarray]:
    """User step: interpolate many share tensors with ONE Lagrange pass per
    (degree, cloud-count) class — the fused batch equivalent of calling
    ``shamir.interpolate`` once per tensor. Returns decoded numpy arrays in
    input order."""
    out: List[Optional[np.ndarray]] = [None] * len(parts)
    by_class: Dict[Tuple[int, int], List[int]] = {}
    for i, s in enumerate(parts):
        by_class.setdefault((s.degree, s.n_shares), []).append(i)
    for (deg, c), idxs in by_class.items():
        flats = [parts[i].values.reshape(c, -1) for i in idxs]
        vals = np.asarray(shamir.interpolate(
            Shares(jnp.concatenate(flats, axis=1), deg)))
        off = 0
        for i in idxs:
            size = int(np.prod(parts[i].shape, dtype=np.int64))
            out[i] = vals[off:off + size].reshape(parts[i].shape)
            off += size
    return out


def _share_patterns(db: SecretSharedDB, jobs: Sequence[MatchJob]) -> Shares:
    """User step: encode + share every job's predicate -> (c, B, W|k, A).

    Exact jobs encode the full terminator-padded word; ``masked`` specs the
    full-width masked pattern (wildcard rows are all-ones); tile specs
    (prefix/suffix/contains) the length-k pattern tile. All jobs in one
    stack must share an encoding width — the engine groups them so.
    """
    codec = db.codec
    vals = []
    for j in jobs:
        s = getattr(j, "spec", None)
        if s is None:
            enc = codec.encode_word(j.pattern)
        elif s.kind == "masked":
            enc = encoding.encode_pattern_word(codec, s)
        else:
            enc = encoding.encode_pattern_tile(codec, s)
        vals.append(encoding.share_encoded(
            j.key, enc, n_shares=db.n_shares, degree=db.base_degree).values)
    return Shares(jnp.stack(vals, axis=1), db.base_degree)


def _needs_pattern_engine(jobs: Sequence[MatchJob]) -> bool:
    """True if any job leaves the full-width chain (``masked`` rides the
    classic exact-match stack unchanged; the tile kinds do not)."""
    return any(getattr(j, "spec", None) is not None
               and j.spec.kind in ("prefix", "suffix", "contains")
               for j in jobs)


def match_phase_cost(spec: Optional[encoding.PatternSpec], *, n: int, c: int,
                     w: int, a: int, col_degree: int = 1,
                     pat_degree: int = 1) -> Dict[str, int]:
    """Table-1-style cost atoms for one predicate's match phase.

    ``send``/``cloud`` are the pattern upload and the per-tuple automata
    work; ``degree`` the final match-bit degree (the user interpolates
    ``degree + 1`` shares per opened element); the ``reduce_*`` atoms are
    the CONTAINS degree-reduction re-share round (zero unless M > 1).
    ``spec=None`` (exact equality) and ``masked`` price the full-width
    chain. The round engine charges these atoms verbatim and the planner
    prices with the same function, so ``explain()`` stays exact for the
    pattern family.
    """
    t2 = col_degree + pat_degree
    none = dict(reduce_rounds=0, reduce_send=0, reduce_cloud=0)
    if spec is None or spec.kind == "masked":
        return dict(send=c * w * a, cloud=n * w * a, degree=t2 * w, **none)
    k = spec.length
    m = w - k + 1
    if spec.kind == "prefix" or m == 1:
        # truncated k-chain; a single-window slide degenerates to the same
        return dict(send=c * k * a, cloud=n * k * a, degree=t2 * k, **none)
    if spec.kind == "suffix":
        return dict(send=c * k * a, cloud=n * m * k * a + n * m,
                    degree=t2 * k + col_degree, **none)
    if spec.kind != "contains":
        raise ValueError(f"unknown pattern kind: {spec.kind!r}")
    return dict(send=c * k * a, cloud=n * m * k * a, degree=m,
                reduce_rounds=1, reduce_send=c * c, reduce_cloud=n * m)


def _charge_match_phase(db: SecretSharedDB, job: MatchJob
                        ) -> Dict[str, int]:
    """Charge one job's match-phase atoms (round + send + cloud + the
    CONTAINS reduction round if any); returns the atoms for the caller's
    recv/user charges."""
    codec = db.codec
    cost = match_phase_cost(getattr(job, "spec", None), n=db.n_tuples,
                            c=db.n_shares, w=codec.word_length,
                            a=codec.alphabet_size,
                            col_degree=db.relation.degree,
                            pat_degree=db.base_degree)
    job.ledger.round()
    job.ledger.send(cost["send"])
    job.ledger.cloud(cost["cloud"])
    if cost["reduce_rounds"]:
        job.ledger.round(cost["reduce_rounds"])
        job.ledger.send(cost["reduce_send"])
        job.ledger.cloud(cost["reduce_cloud"])
    return cost


class _MatcherPlan:
    """Strategy layer of the refactored matcher pipeline.

    Groups a mixed batch of :class:`MatchJob` so each group's per-tuple
    match bits cost ONE backend dispatch per round:

      * ``("full", W)``   — exact + masked patterns: the classic full-width
        ``aa_match_batch`` chain;
      * ``("prefix", k)`` — truncated k-chains over ``col[..., :k, :]``,
        the same op at width k;
      * ``("slide", k)``  — suffix + substring patterns of length k: raw
        window products from ONE ``aa_slide_batch`` dispatch. The suffix
        terminator factor and the CONTAINS window count are linear
        share-local post-processing, so both kinds of the same k share the
        dispatch; CONTAINS (M > 1) additionally runs one degree-reduction
        re-share of its window count — the family's only extra
        communication round — before the share-local zero test.
    """

    def __init__(self, db: SecretSharedDB, jobs: Sequence[MatchJob]):
        self.db = db
        self.jobs = list(jobs)
        self.w = db.codec.word_length
        full: List[int] = []
        prefix: Dict[int, List[int]] = {}
        slide: Dict[int, List[int]] = {}
        for i, j in enumerate(self.jobs):
            s = getattr(j, "spec", None)
            if s is None or s.kind == "masked":
                full.append(i)
            elif s.kind == "prefix":
                prefix.setdefault(s.length, []).append(i)
            else:
                slide.setdefault(s.length, []).append(i)
        self.groups: List[Tuple[str, int, List[int]]] = []
        if full:
            self.groups.append(("full", self.w, full))
        for k in sorted(prefix):
            self.groups.append(("prefix", k, prefix[k]))
        for k in sorted(slide):
            self.groups.append(("slide", k, slide[k]))
        self.pats = [_share_patterns(db, [self.jobs[i] for i in idxs])
                     for _, _, idxs in self.groups]

    def _shard_values(self, be, v: SecretSharedDB, sh):
        """Cloud step on one shard: per group ``(local job idxs, local
        bits, contains job idxs, contains window counts)`` — local bits are
        complete on this shard; window counts still need the cross-shard
        reduction."""
        out = []
        for (kind, k, idxs), pats in zip(self.groups, self.pats):
            cols = _stack_columns(v, [self.jobs[i].column for i in idxs])
            if kind == "full":
                out.append((idxs, _batched_matcher(be)(
                    cols.values, pats.values), [], None))
                continue
            if kind == "prefix":
                out.append((idxs, _batched_matcher(be)(
                    cols.values[..., :k, :], pats.values), [], None))
                continue
            win = _slide_matcher(be)(cols.values, pats.values)  # (c,Bg,ns,M)
            if self.w - k + 1 == 1:
                # one window: the chain product IS the bit, either kind
                out.append((idxs, win[..., 0], [], None))
                continue
            suf = [b for b, i in enumerate(idxs)
                   if self.jobs[i].spec.kind == "suffix"]
            con = [b for b, i in enumerate(idxs)
                   if self.jobs[i].spec.kind == "contains"]
            bits = None
            if suf:
                # suffix ⟺ some window matches AND everything after it is
                # terminator padding. Windows are mutually exclusive (a
                # real pattern char never matches the terminator), so the
                # linear sum of window·terminator products is the exact
                # 0/1 bit.
                term = cols.values[:, suf][..., k:, 0]   # (c,Bs,ns,M-1)
                ones = jnp.ones(term.shape[:-1] + (1,), field.DTYPE)
                bits = field.sum_(
                    field.mul(win[:, suf],
                              jnp.concatenate([term, ones], axis=-1)),
                    axis=-1)
            p_cnt = field.sum_(win[:, con], axis=-1) if con else None
            out.append(([idxs[b] for b in suf], bits,
                        [idxs[b] for b in con], p_cnt))
        return out

    def _local_degree(self, kind: str, k: int) -> int:
        t2 = self.db.relation.degree + self.db.base_degree
        if kind == "full":
            return t2 * self.w
        if kind == "prefix" or self.w - k + 1 == 1:
            return t2 * k
        return t2 * k + self.db.relation.degree      # suffix, M > 1

    def bit_shares(self, be, plane) -> List[Tuple[List[int], Shares]]:
        """Every job's per-tuple match bits: ``[(job idxs, Shares
        (c, Bg, n))]``, bits concatenated across shards. One dataplane
        dispatch wave serves all groups; CONTAINS window counts reassemble
        across shards, reduce ONCE per group (the explicit re-share round,
        mirroring the range engine's carry reduction) and finish with the
        share-local zero test."""
        shard_outs = plane.run_list(
            lambda v, sh: self._shard_values(be, v, sh))

        def cat(gi, slot):
            parts = [so[gi][slot] for so in shard_outs]
            return parts[0] if len(parts) == 1 else jnp.concatenate(
                parts, axis=2)

        t2 = self.db.relation.degree + self.db.base_degree
        result: List[Tuple[List[int], Shares]] = []
        for gi, (kind, k, _) in enumerate(self.groups):
            local_idx = shard_outs[0][gi][0]
            con_idx = shard_outs[0][gi][2]
            if local_idx:
                result.append((local_idx, Shares(
                    cat(gi, 1), self._local_degree(kind, k))))
            if con_idx:
                m = self.w - k + 1
                red_key = jax.random.fold_in(self.jobs[con_idx[0]].key, 1)
                p_red = shamir.reduce_degree(
                    red_key, Shares(cat(gi, 3), t2 * k), target_degree=1)
                z = automata.zero_indicator(p_red.values, m)
                result.append((con_idx, Shares(
                    field.sub(jnp.ones_like(z), z), m)))
        return result


def _stack_columns(db: SecretSharedDB, columns: Sequence[int]) -> Shares:
    """Cloud-local view: each job's attribute column -> (c, B, n, W, A).

    When every job targets the same column the stack is a broadcast view,
    not a copy.
    """
    rel = db.relation.values                       # (c, n, m, W, A)
    if len(set(columns)) == 1:
        one = rel[:, :, columns[0]]                # (c, n, W, A)
        stacked = jnp.broadcast_to(one[:, None],
                                   (one.shape[0], len(columns))
                                   + one.shape[1:])
    else:
        stacked = jnp.moveaxis(rel[:, :, np.asarray(columns)], 2, 1)
    return Shares(stacked, db.relation.degree)


def _stack_numeric(db: SecretSharedDB, columns: Sequence[int]) -> Shares:
    """Cloud-local view of binary-form columns -> (c, B, n, t_bits)."""
    first = db.numeric[columns[0]]
    if len(set(columns)) == 1:
        one = first.values                          # (c, n, t)
        stacked = jnp.broadcast_to(one[:, None],
                                   (one.shape[0], len(columns))
                                   + one.shape[1:])
    else:
        stacked = jnp.stack([db.numeric[c].values for c in columns], axis=1)
    return Shares(stacked, first.degree)


def _match_stack(be, cols: Shares, pats: Shares) -> Shares:
    """One fused AA dispatch over the stack, with degree bookkeeping."""
    w = cols.values.shape[-2]
    bits = _batched_matcher(be)(cols.values, pats.values)      # (c, B, n)
    return Shares(bits, (cols.degree + pats.degree) * w)


def _block_sums(be, plane: "dataplane.ShardedRelation", p_all: Shares,
                columns: Sequence[int],
                entries: Sequence[Tuple[int, int, int]],
                *, address_weights: bool = False) -> Shares:
    """Shard-aligned block-matrix round for tree Q&A: -> Shares (c, K).

    entries: (job_index, start, end) block jobs, possibly from different
    queries, in GLOBAL tuple coordinates. The ledger-visible block
    partition never changes, but execution fans out per dataplane shard:
    each shard gathers only the slice of every block that intersects its
    [lo, hi) range (local indices into the shard view, padded positions
    masked to a literal 0 so they add nothing), matches, and reduces over
    the block axis — plain block-count sums, or line-number sums weighted
    by ``global index + 1`` when ``address_weights`` is set. Per-shard
    partials combine additively in F_p, so the result is bit-identical to
    the unsharded gather-then-sum for every shard count.
    """
    starts = np.asarray([s for _, s, _ in entries])
    ends = np.asarray([e for _, _, e in entries])
    jidx = np.asarray([i for i, _, _ in entries])
    cols_e = np.asarray([columns[i] for i in jidx])
    rel_degree = plane.db.relation.degree

    def one(v, sh) :
        lo_s = np.clip(starts, sh.lo, sh.hi) - sh.lo           # (K,) local
        hi_s = np.clip(ends, sh.lo, sh.hi) - sh.lo
        h = max(1, int((hi_s - lo_s).max()))
        idx = lo_s[:, None] + np.arange(h)[None, :]            # (K, H_s)
        mask = idx < hi_s[:, None]
        idx = np.where(mask, idx, 0)
        rel = v.relation.values                                # (c,n_s,m,W,A)
        gathered = rel[:, jnp.asarray(idx), jnp.asarray(cols_e)[:, None]]
        pats = Shares(p_all.values[:, jnp.asarray(jidx)], p_all.degree)
        bits = _match_stack(be, Shares(gathered, rel_degree), pats)
        masked = jnp.where(jnp.asarray(mask)[None], bits.values, 0)
        if address_weights:
            # line_number = Σ match_h · (global index + 1); masked
            # positions hold a literal 0 so any weight times them is 0.
            weights = sh.lo + idx + 1                          # (K, H_s)
            masked = field.mul(masked,
                               jnp.asarray(weights, field.DTYPE)[None])
        return field.sum_(masked, axis=2)                      # (c, K)

    w = plane.db.relation.values.shape[-2]
    return Shares(plane.run_sum(one), (rel_degree + p_all.degree) * w)


def _block_sums_cached(cached: Dict[int, Shares],
                       entries: Sequence[Tuple[int, int, int]],
                       *, address_weights: bool = False) -> List[Shares]:
    """Tree Q&A block sums over PRE-COMPUTED per-tuple match bits.

    Pattern jobs run their window match (and the CONTAINS re-share) once in
    the tree prelude and cache the per-tuple bit vector; every later Q&A
    round only sums cached bits over the public block partition — a
    cloud-local linear step charged at one element per tuple instead of a
    fresh W·A automata pass. Plain block-count sums, or line-number sums
    weighted by ``global index + 1`` under ``address_weights`` (the cached
    mirror of :func:`_block_sums`; returns one scalar Shares per entry so
    mixed-degree jobs fuse per degree class at interpolation)."""
    out: List[Shares] = []
    for (i, s, e) in entries:
        vec = cached[i]                                    # (c, n)
        seg = vec.values[:, s:e]
        if address_weights:
            wgt = jnp.arange(s + 1, e + 1, dtype=field.DTYPE)
            seg = field.mul(seg, wgt[None])
        out.append(Shares(field.sum_(seg, axis=1), vec.degree))
    return out


# ---------------------------------------------------------------------------
# §3.1 — batched count phase (Algorithm 2)
# ---------------------------------------------------------------------------

def count_phase(be, db: RelationLike, jobs: Sequence[MatchJob]
                ) -> List[int]:
    """COUNT for B predicates: one cloud dispatch *per shard*, partial
    count sums combining additively, one interpolation."""
    if not jobs:
        return []
    plane = dataplane.as_dataplane(db)
    db = plane.db
    codec = db.codec
    if not _needs_pattern_engine(jobs):
        # exact + masked only: the classic single-group fast path (one
        # additive-reduce dispatch set, partial sums combine in F_p)
        columns = [j.column for j in jobs]
        p_all = _share_patterns(db, jobs)
        w = db.relation.values.shape[-2]
        deg = (db.relation.degree + p_all.degree) * w
        counts = Shares(plane.run_sum(
            lambda v, sh: field.sum_(_batched_matcher(be)(
                _stack_columns(v, columns).values, p_all.values), axis=2)),
            deg)                                               # (c, B)
        out = np.asarray(shamir.interpolate(counts))
        per_q = codec.word_length * codec.alphabet_size
        for j in jobs:
            j.ledger.round()
            j.ledger.send(db.n_shares * per_q)
            j.ledger.cloud(db.n_tuples * per_q)
            j.ledger.recv(db.n_shares)
            j.ledger.user(counts.degree + 1)
        return [int(v) for v in out]

    # mixed / pattern batch: per-group fused match bits, summed and
    # interpolated in one fused user pass per degree class
    mp = _MatcherPlan(db, jobs)
    parts = mp.bit_shares(be, plane)
    sums = [Shares(field.sum_(sh.values, axis=2), sh.degree)
            for _, sh in parts]
    vals = _fused_interpolate(sums)
    out = [0] * len(jobs)
    deg_of: Dict[int, int] = {}
    for (idxs, sh), v in zip(parts, vals):
        for b, i in enumerate(idxs):
            out[i] = int(v[b])
            deg_of[i] = sh.degree
    for i, j in enumerate(jobs):
        cost = _charge_match_phase(db, j)
        assert cost["degree"] == deg_of[i], (cost["degree"], deg_of[i])
        j.ledger.recv(db.n_shares)
        j.ledger.user(cost["degree"] + 1)
    return out


# ---------------------------------------------------------------------------
# §3.2.1 — batched single-tuple map round (Algorithm 3 lines 3-12)
# ---------------------------------------------------------------------------

def one_tuple_round(be, db: RelationLike, jobs: Sequence[MatchJob]
                    ) -> List[List[str]]:
    """Fetch the single satisfying tuple for B (ℓ=1-verified) predicates."""
    if not jobs:
        return []
    if _needs_pattern_engine(jobs):
        raise ValueError(
            "one_tuple is the §3.2.1 exact-equality special case; "
            "prefix/suffix/substring selects run one_round or tree")
    plane = dataplane.as_dataplane(db)
    db = plane.db
    codec = db.codec
    b = len(jobs)
    columns = [j.column for j in jobs]
    p_all = _share_patterns(db, jobs)
    c, _, m, w, a = db.relation.values.shape
    match_deg = (db.relation.degree + p_all.degree) * w

    # Σ_n bit·tuple is a share-space matmul of the match bits against the
    # flattened relation — same mod-p result as the elementwise broadcast
    # product, without materializing a B-fold (c,B,n,m,W,A) intermediate.
    # Per shard: match + partial contraction; partials sum additively.
    def one(v: SecretSharedDB, sh):
        bits = _batched_matcher(be)(_stack_columns(v, columns).values,
                                    p_all.values)              # (c,B,n_s)
        return be.ss_matmul(bits, v.relation.values.reshape(
            c, sh.n_tuples, m * w * a))

    sums = Shares(plane.run_sum(one).reshape(c, b, m, w, a),
                  match_deg + db.relation.degree)              # (c,B,m,W,A)
    tup = np.asarray(shamir.interpolate(sums))                 # (B, m, W, A)
    per_q = codec.word_length * codec.alphabet_size
    for j in jobs:
        j.ledger.round()
        j.ledger.send(db.n_shares * per_q)
        j.ledger.cloud(db.n_tuples * db.n_attrs * per_q)
        j.ledger.recv(db.n_shares * db.n_attrs * per_q)
        j.ledger.user((sums.degree + 1) * db.n_attrs * codec.word_length)
    return [codec.decode_row(tup[i]) for i in range(b)]


# ---------------------------------------------------------------------------
# §3.2.2 one-round — batched Phase 1 (all n match bits per query)
# ---------------------------------------------------------------------------

def match_all_round(be, db: RelationLike, jobs: Sequence[MatchJob]
                    ) -> List[List[int]]:
    """Per-query satisfying addresses via one fused match-bit round."""
    if not jobs:
        return []
    plane = dataplane.as_dataplane(db)
    db = plane.db
    codec = db.codec
    if not _needs_pattern_engine(jobs):
        columns = [j.column for j in jobs]
        p_all = _share_patterns(db, jobs)
        w = db.relation.values.shape[-2]
        bits = Shares(plane.run_concat(
            lambda v, sh: _batched_matcher(be)(
                _stack_columns(v, columns).values, p_all.values), axis=2),
            (db.relation.degree + p_all.degree) * w)           # (c, B, n)
        v = np.asarray(shamir.interpolate(bits))               # (B, n)
        per_q = codec.word_length * codec.alphabet_size
        for j in jobs:
            j.ledger.round()
            j.ledger.send(db.n_shares * per_q)
            j.ledger.cloud(db.n_tuples * per_q)
            j.ledger.recv(db.n_shares * db.n_tuples)
            j.ledger.user((bits.degree + 1) * db.n_tuples)
        return [[int(i) for i in np.nonzero(v[b])[0]]
                for b in range(len(jobs))]

    # mixed / pattern batch: grouped dispatches, one fused interpolation
    # pass per degree class — pattern selects then ride the same
    # cross-group fetch_fusion matmul as everything else
    mp = _MatcherPlan(db, jobs)
    parts = mp.bit_shares(be, plane)
    vals = _fused_interpolate([sh for _, sh in parts])
    out: List[List[int]] = [[] for _ in jobs]
    deg_of: Dict[int, int] = {}
    for (idxs, sh), v in zip(parts, vals):
        for b, i in enumerate(idxs):
            out[i] = [int(t) for t in np.nonzero(v[b])[0]]
            deg_of[i] = sh.degree
    n = db.n_tuples
    for i, j in enumerate(jobs):
        cost = _charge_match_phase(db, j)
        assert cost["degree"] == deg_of[i], (cost["degree"], deg_of[i])
        j.ledger.recv(db.n_shares * n)
        j.ledger.user((cost["degree"] + 1) * n)
    return out


# ---------------------------------------------------------------------------
# §3.2.2 tree — lockstep Q&A rounds over the batch (Algorithm 4)
# ---------------------------------------------------------------------------

def tree_rounds(be, db: RelationLike, jobs: Sequence[TreeJob]
                ) -> List[List[int]]:
    """Address discovery for B tree selections, every round fused.

    Each loop iteration performs at most one *count* Q&A round (all active
    blocks of all queries, padded + stacked, one dispatch + one
    interpolation) and at most one *address-fetch* round (all blocks whose
    count came back 1, same fusion). A query stops participating once it has
    no active blocks; its ledger only ever records its own rounds, blocks
    and bits — identical to running it alone.

    Q&A rounds gather *blocks* — a public tuple-axis partition refinement
    that is part of the transcript and never moves with the shard count —
    but their execution is shard-aligned: each dataplane shard gathers only
    the block slices inside its own bounds and the per-shard partial
    count / line-number sums combine additively (:func:`_block_sums`), so
    no Q&A round ever gathers the full relation on one device. The fetch
    that follows rides the sharded :func:`fetch_fusion`.
    """
    if not jobs:
        return []
    plane = dataplane.as_dataplane(db)
    db = plane.db
    codec = db.codec
    per_q = codec.word_length * codec.alphabet_size
    n = db.n_tuples

    # -- prelude: split exact/masked jobs (full-width chain, recomputed
    # per Q&A block) from tile-pattern jobs (window match + CONTAINS
    # re-share run ONCE, per-tuple bits cached for every later round) ----
    pat_pos = [i for i, j in enumerate(jobs)
               if getattr(j, "spec", None) is not None
               and j.spec.kind in ("prefix", "suffix", "contains")]
    exact_pos = [i for i in range(len(jobs)) if i not in set(pat_pos)]
    exact_slot = {i: s for s, i in enumerate(exact_pos)}
    columns = [jobs[i].column for i in exact_pos]
    p_all = (_share_patterns(db, [jobs[i] for i in exact_pos])
             if exact_pos else None)
    cached: Dict[int, Shares] = {}
    if pat_pos:
        mp = _MatcherPlan(db, [jobs[i] for i in pat_pos])
        for idxs, sh in mp.bit_shares(be, plane):
            for b, local in enumerate(idxs):
                cached[pat_pos[local]] = Shares(sh.values[:, b], sh.degree)
    for i, j in enumerate(jobs):
        cost = match_phase_cost(getattr(j, "spec", None), n=n,
                                c=db.n_shares, w=codec.word_length,
                                a=codec.alphabet_size,
                                col_degree=db.relation.degree,
                                pat_degree=db.base_degree)
        j.ledger.send(cost["send"])
        if i in cached:
            # the one-off window match (amortized into the first Q&A
            # round's dispatch) and the explicit CONTAINS re-share round
            j.ledger.cloud(cost["cloud"])
            if cost["reduce_rounds"]:
                j.ledger.round(cost["reduce_rounds"])
                j.ledger.send(cost["reduce_send"])
                j.ledger.cloud(cost["reduce_cloud"])

    addresses: List[List[int]] = [[] for _ in jobs]
    active: List[List[Tuple[int, int]]] = []
    first = [True] * len(jobs)
    pending_addr: List[Tuple[int, int, int]] = []
    # ℓ=1 queries take the Alg 4 line 2 path: one whole-table address fetch
    # that counts as its own round (the per-query wrapper's legacy
    # behaviour), then straight to Phase 2.
    one_shot = set()
    for i, j in enumerate(jobs):
        if j.ell == 1:
            pending_addr.append((i, 0, n))
            one_shot.add(i)
            active.append([])
        else:
            active.append([(0, n)])

    while any(active) or pending_addr:
        # -- partition every query's active blocks (public, host-side) ------
        entries: List[Tuple[int, int, int]] = []
        for i, blocks in enumerate(active):
            if not blocks:
                continue
            fanout = jobs[i].branching or jobs[i].ell
            k = fanout if first[i] else max(2, fanout)
            first[i] = False
            subs = []
            for (s, e) in blocks:
                subs += split_bounds(s, e, k)
            entries += [(i, s, e) for (s, e) in subs]
            active[i] = []

        # -- count Q&A round: ONE dispatch set + ONE interpolation ----------
        if entries:
            vals_by_entry, deg_by_job = _tree_block_round(
                be, plane, p_all, columns, exact_slot, cached, entries)
            n_blocks: dict = {}
            for (i, s, e) in entries:
                jobs[i].ledger.cloud(
                    (e - s) * (per_q if i in exact_slot else 1))
                n_blocks[i] = n_blocks.get(i, 0) + 1
            for i, k_i in n_blocks.items():
                jobs[i].ledger.round()
                jobs[i].ledger.recv(db.n_shares * k_i)
                jobs[i].ledger.user((deg_by_job[i] + 1) * k_i)
            for (i, s, e) in entries:
                v = vals_by_entry[(i, s, e)]
                if v == 0:                     # Case 1: dead block
                    continue
                if v == 1:                     # Case 2: Address_fetch
                    pending_addr.append((i, s, e))
                elif v == e - s:               # Case 3: whole block matches
                    addresses[i].extend(range(s, e))
                else:                          # Case 4: recurse
                    active[i].append((s, e))

        # -- address-fetch round: ONE dispatch set + ONE interpolation ------
        if pending_addr:
            addr_entries, pending_addr = pending_addr, []
            vals_by_entry, deg_by_job = _tree_block_round(
                be, plane, p_all, columns, exact_slot, cached, addr_entries,
                address_weights=True)
            for (i, s, e) in addr_entries:
                jobs[i].ledger.cloud(
                    (e - s) * (per_q if i in exact_slot else 1))
                jobs[i].ledger.recv(db.n_shares)
                jobs[i].ledger.user(deg_by_job[i] + 1)
                addresses[i].append(vals_by_entry[(i, s, e)] - 1)
                if i in one_shot:
                    jobs[i].ledger.round()
                    one_shot.discard(i)

    return [sorted(a) for a in addresses]


def _tree_block_round(be, plane, p_all, columns, exact_slot, cached,
                      entries, *, address_weights: bool = False
                      ) -> Tuple[Dict[Tuple[int, int, int], int],
                                 Dict[int, int]]:
    """One fused tree Q&A round over mixed exact + cached-pattern entries.

    Exact/masked entries recompute their block match through the classic
    shard-aligned :func:`_block_sums` dispatch; pattern entries sum their
    cached per-tuple bits (:func:`_block_sums_cached`). All results
    interpolate in one fused user pass per degree class. Returns the opened
    value per (job, start, end) entry and each job's bit degree (for the
    caller's user-step charge)."""
    ex_meta = [t for t in entries if t[0] in exact_slot]
    pat_meta = [t for t in entries if t[0] not in exact_slot]
    parts: List[Shares] = []
    if ex_meta:
        parts.append(_block_sums(
            be, plane, p_all, columns,
            [(exact_slot[i], s, e) for (i, s, e) in ex_meta],
            address_weights=address_weights))
    parts += _block_sums_cached(cached, pat_meta,
                                address_weights=address_weights)
    vals = _fused_interpolate(parts)
    vals_by_entry: Dict[Tuple[int, int, int], int] = {}
    deg_by_job: Dict[int, int] = {}
    vi = 0
    if ex_meta:
        for t, x in zip(ex_meta, np.asarray(vals[0])):
            vals_by_entry[t] = int(x)
            deg_by_job[t[0]] = parts[0].degree
        vi = 1
    for t, x, p in zip(pat_meta, vals[vi:], parts[vi:]):
        vals_by_entry[t] = int(x)
        deg_by_job[t[0]] = p.degree
    return vals_by_entry, deg_by_job


# ---------------------------------------------------------------------------
# §3.4 — batched range predicates (Algorithms 5 & 6)
# ---------------------------------------------------------------------------

def _segment_edges(t_bits: int, reduce_every: int) -> List[Tuple[int, int]]:
    """[start, end) bit segments between degree-reduction boundaries."""
    if not reduce_every:
        return [(0, t_bits)]
    edges = list(range(0, t_bits, reduce_every)) + [t_bits]
    return list(zip(edges[:-1], edges[1:]))


def range_phase(be, db: RelationLike, jobs: Sequence[RangeJob]) -> Shares:
    """Secret-shared in-range indicator for B range predicates: (c, B, n).

    The fused SS-SUB ripple (Algorithm 6): each query contributes two
    subtractions — ``sign(x − a)`` and ``sign(b − x)`` (Eq. 2) — so the B
    queries' bit-vectors stack into one ``(c, 2B, n, t_bits)`` carry chain.
    The bits between two degree-reduction boundaries fuse into ONE backend
    ``ripple_segment`` dispatch per shard (≈ t_bits/reduce_every segment
    dispatches, one chain for the whole batch; a backend without the fused
    segment op transparently steps per bit); each ``reduce_every`` boundary
    is ONE degree-reduction re-share of the whole stacked carry —
    re-sharing is the protocol's explicit communication round, so the carry
    is reassembled across shards, reduced once, and re-sliced. Ledgers
    record every query's own protocol cost exactly as a solo run (a
    reduction is two logical rounds per query: one per subtraction, as in
    the sequential transcript).
    """
    plane = dataplane.as_dataplane(db)
    db = plane.db
    t_bits_all = []
    for j in jobs:
        if j.column not in db.numeric:
            raise ValueError(
                f"column {j.column} was not outsourced in binary form")
        t_bits_all.append(db.numeric_bits[j.column])
    if len(set(t_bits_all)) != 1 or len({j.reduce_every for j in jobs}) != 1:
        raise ValueError("a fused range_phase needs uniform t_bits and "
                         "reduce_every across its jobs (group them)")
    t_bits = t_bits_all[0]
    reduce_every = jobs[0].reduce_every
    b = len(jobs)
    n = db.n_tuples
    c = db.n_shares

    # -- user round: share both endpoints of every job --------------------
    a_vals, b_vals = [], []
    red_key = None
    for j in jobs:
        k_a, k_b, k_s1, _ = jax.random.split(j.key, 4)
        if red_key is None:
            red_key = k_s1              # seeds the fused reduction chain
        a_vals.append(encoding.share_encoded(
            k_a, encoding.encode_number_bits(j.lo, t_bits),
            n_shares=c, degree=db.base_degree).values)
        b_vals.append(encoding.share_encoded(
            k_b, encoding.encode_number_bits(j.hi, t_bits),
            n_shares=c, degree=db.base_degree).values)
        j.ledger.round()
        j.ledger.send(c * 2 * t_bits)

    x = _stack_numeric(db, [j.column for j in jobs])       # (c, B, n, t)
    d = db.base_degree
    assert x.degree == d, "binary-form columns share the base degree"
    a_all = jnp.stack(a_vals, axis=1)[:, :, None, :]       # (c, B, 1, t)
    b_all = jnp.stack(b_vals, axis=1)[:, :, None, :]
    shape = x.values.shape
    # rows [0, B) ripple sign(x − a): SS-SUB(A=a, B=x); rows [B, 2B) ripple
    # sign(b − x): SS-SUB(A=x, B=b) — one chain for both directions.
    lhs = jnp.concatenate([jnp.broadcast_to(a_all, shape), x.values], axis=1)
    rhs = jnp.concatenate([x.values, jnp.broadcast_to(b_all, shape)], axis=1)

    segment = _ripple_segmenter(be)
    shards = plane.shards
    lhs_parts = [lhs[:, :, sh.lo:sh.hi] for sh in shards]
    rhs_parts = [rhs[:, :, sh.lo:sh.hi] for sh in shards]
    carries: List[Optional[jax.Array]] = [None] * len(shards)
    rb_parts: List[jax.Array] = []
    carry_deg = 0
    for seg_i, (s0, s1) in enumerate(_segment_edges(t_bits, reduce_every)):
        if seg_i > 0 and carry_deg > 1:
            # degree reduction = the explicit re-sharing round: reassemble
            # the carry across shards, reduce ONCE, re-slice per shard.
            carry_full = (carries[0] if len(shards) == 1
                          else jnp.concatenate(carries, axis=2))
            red_key, sub = jax.random.split(red_key)
            carry_full = shamir.reduce_degree(
                sub, Shares(carry_full, carry_deg), target_degree=1).values
            carry_deg = 1
            carries = [carry_full[:, :, sh.lo:sh.hi] for sh in shards]
            for j in jobs:
                j.ledger.round(2)
                j.ledger.send(2 * c * c)
        # per-shard segment dispatch; the result bit leaves each step at
        # the carry's (post-step) degree, +2d per bit position.
        outs = plane.run_list(
            lambda v, sh, s0=s0, s1=s1: segment(
                lhs_parts[sh.index][..., s0:s1],
                rhs_parts[sh.index][..., s0:s1], carries[sh.index]))
        rb_parts = [o[0] for o in outs]
        carries = [o[1] for o in outs]
        carry_deg = carry_deg + 2 * d * (s1 - s0)
    for j in jobs:
        j.ledger.cloud(2 * n * t_bits)

    rb = (rb_parts[0] if len(shards) == 1
          else jnp.concatenate(rb_parts, axis=2))
    # Eq. 2: in-range ⟺ 1 − sign(x−a) − sign(b−x) = 1
    ind = field.sub(field.sub(jnp.ones((c, b, n), field.DTYPE),
                              rb[:, :b]), rb[:, b:])
    return Shares(ind, carry_deg)


def range_rounds(be, db: RelationLike, jobs: Sequence[RangeJob]
                 ) -> List[Union[int, List[int]]]:
    """COUNT / address discovery for B range predicates, rounds fused.

    Returns, aligned with ``jobs``: the count (``want_addresses=False``) or
    the sorted satisfying addresses (``want_addresses=True``, ready for the
    shared :func:`fetch_fusion` matmul). One interpolation serves all count
    jobs and one serves all address jobs.
    """
    if not jobs:
        return []
    ind = range_phase(be, db, jobs)
    c, n = db.n_shares, db.n_tuples
    out: List[Union[int, List[int], None]] = [None] * len(jobs)
    cnt_idx = [i for i, j in enumerate(jobs) if not j.want_addresses]
    sel_idx = [i for i, j in enumerate(jobs) if j.want_addresses]
    if cnt_idx:
        totals = Shares(field.sum_(ind.values[:, cnt_idx], axis=2),
                        ind.degree)                         # (c, Bc)
        vals = np.asarray(shamir.interpolate(totals))
        for i, v in zip(cnt_idx, vals):
            jobs[i].ledger.recv(c)
            jobs[i].ledger.user(ind.degree + 1)
            out[i] = int(v)
    if sel_idx:
        bits = Shares(ind.values[:, sel_idx], ind.degree)   # (c, Bs, n)
        vals = np.asarray(shamir.interpolate(bits))
        for k, i in enumerate(sel_idx):
            jobs[i].ledger.recv(c * n)
            jobs[i].ledger.user((ind.degree + 1) * n)
            out[i] = [int(t) for t in np.nonzero(vals[k])[0]]
    return out


# ---------------------------------------------------------------------------
# §3.2.2 Phase 2 — fused oblivious fetch for the whole batch
# ---------------------------------------------------------------------------

#: one relation's slice of a (possibly multi-relation) fused fetch round:
#: ``(db_or_plane, one-hot jobs, extra share-form row blocks)``.
FetchPart = Tuple[RelationLike, Sequence[FetchJob], Sequence["FetchEntry"]]


def _fetch_stack(be, plane, jobs: Sequence[FetchJob],
                 extras: Sequence[FetchEntry]):
    """Build one relation's stacked fetch matmul as a DispatchSet."""
    db = plane.db
    ellps = []
    mats = []
    for j in jobs:
        ell = len(j.addresses)
        ellp = max(j.padded_rows or ell, ell)
        ellps.append(ellp)
        m_sh = _share_one_hot(j.key, db, j.addresses, ellp)     # (c, ℓ', n)
        mats.append(m_sh.values)
    stacked = jnp.concatenate(mats + [e.values for e in extras], axis=1)
    c, _, m, w, a = db.relation.values.shape
    ds = plane.dispatch_set(                        # ONE dispatch per shard
        lambda v, sh: be.ss_matmul(
            stacked[:, :, sh.lo:sh.hi],
            v.relation.values.reshape(c, sh.n_tuples, m * w * a)),
        reduce="sum")
    return ds, ellps


def _fetch_split(db, fetched_flat, ellps: List[int],
                 jobs: Sequence[FetchJob], extras: Sequence[FetchEntry]
                 ) -> Tuple[List[List[List[str]]], List[Shares]]:
    """User step after the fused matmul: interpolate, decode, charge."""
    codec = db.codec
    n = db.n_tuples
    c, _, m, w, a = db.relation.values.shape
    results: List[List[List[str]]] = []
    job_rows = sum(ellps)
    if jobs:
        fetched = Shares(
            fetched_flat[:, :job_rows].reshape(c, job_rows, m, w, a),
            db.base_degree + db.relation.degree)
        out = np.asarray(shamir.interpolate(fetched))          # (R, m, W, A)
        off = 0
        for j, ellp in zip(jobs, ellps):
            ell = len(j.addresses)
            j.ledger.round()
            j.ledger.send(db.n_shares * ellp * n)
            j.ledger.cloud(ellp * n * m * w * a)
            j.ledger.recv(db.n_shares * ellp * m * w * a)
            j.ledger.user((fetched.degree + 1) * ellp * m * w)
            results.append([codec.decode_row(out[off + r])
                            for r in range(ell)])
            off += ellp

    extra_out: List[Shares] = []
    off = job_rows
    for e in extras:
        r = e.values.shape[1]
        extra_out.append(Shares(
            fetched_flat[:, off:off + r].reshape(c, r, m, w, a),
            e.degree + db.relation.degree))
        off += r
    return results, extra_out


def fetch_fusion_multi(be, parts: Sequence[FetchPart]
                       ) -> List[Tuple[List[List[List[str]]], List[Shares]]]:
    """Cross-RELATION fetch fusion: one dispatch wave for many fetches.

    Each part is one relation's cross-group fetch (its own stacked one-hot
    matmul — batches never mix across relations; every job matrix stays
    shared under its own query key). The parts' per-shard matmul dispatches
    execute as ONE fused wave when their dataplanes share a dispatch pool
    (:func:`repro.core.dataplane.fused_execute`); transcripts, ledgers and
    results are bit-identical to running each part's fetch alone, because
    fusion only co-schedules the already-independent shard dispatches.
    Returns one ``(rows_per_job, extra_shares)`` pair per part, in order.
    """
    live: List[Tuple[int, Any, Any, List[int]]] = []
    out: List[Tuple[List[List[List[str]]], List[Shares]]] = \
        [([], []) for _ in parts]
    for i, (db, jobs, extras) in enumerate(parts):
        if not jobs and not extras:
            continue
        plane = dataplane.as_dataplane(db)
        ds, ellps = _fetch_stack(be, plane, jobs, extras)
        live.append((i, plane, ds, ellps))
    fetched = dataplane.fused_execute([(plane, ds)
                                       for _, plane, ds, _ in live])
    for (i, plane, _, ellps), flat in zip(live, fetched):
        _, jobs, extras = parts[i]
        out[i] = _fetch_split(plane.db, flat, ellps, jobs, extras)
    return out


def fetch_fusion(be, db: RelationLike, jobs: Sequence[FetchJob],
                 extras: Sequence[FetchEntry] = ()
                 ) -> Tuple[List[List[List[str]]], List[Shares]]:
    """The cross-group fetch: ONE share-space matmul for everything.

    Each one-hot job's ℓ'×n matrix (``padded_rows`` ≥ ℓ hides the true
    result size, §3.2.2 leakage discussion) is shared under that query's own
    key; all job matrices — a zero-match, unpadded job contributes a 0-row
    block — AND every extra row-block (e.g. a PK/FK join's transposed
    match matrix) are stacked
    row-wise so the cloud performs a single (ΣR × n) @ (n × mWA) fused
    fetch. On a sharded dataplane the contraction axis n splits per shard —
    one (ΣR × n_s) @ (n_s × mWA) dispatch each, partial products summing
    additively in F_p. The user then interpolates all job tuples in one
    pass and splits them back per query; extras come back *still in share
    form* — their protocol (re-randomization, layer-2 hand-off, …)
    continues outside. (The single-relation view of
    :func:`fetch_fusion_multi`.)
    """
    return fetch_fusion_multi(be, [(db, jobs, extras)])[0]


def fetch_round(be, db: SecretSharedDB, jobs: Sequence[FetchJob]
                ) -> List[List[List[str]]]:
    """Fetch every job's tuples with ONE share-space matmul (the one-hot
    jobs-only view of :func:`fetch_fusion`)."""
    return fetch_fusion(be, db, jobs)[0]


# ---------------------------------------------------------------------------
# §3.3.1 — PK/FK joins as rounds (match matrix -> shared fetch -> emit)
# ---------------------------------------------------------------------------

def rerandomize(key: jax.Array, s: Shares) -> Shares:
    """Add a fresh sharing of zero: same secret, unlinkable share values."""
    zero = shamir.share(key, jnp.zeros(s.shape, dtype=s.values.dtype),
                        n_shares=s.n_shares, degree=s.degree)
    return s + zero


def join_match_round(be, db: RelationLike, jobs: Sequence[JoinJob]
                     ) -> List[FetchEntry]:
    """Cloud step 1 of B PK/FK joins: match matrices, transposed into
    :class:`FetchEntry` rows for the shared :func:`fetch_fusion` matmul
    (reducer j's Σ_i M[i,j]·X_i is a row-block of the same fused fetch the
    selection groups ride).

    Jobs whose right relations have equal size (and sharing degree) stack
    into ONE ``(c, B, nx, ny)`` ``match_matrix_batch`` dispatch per shard —
    mirroring ``aa_match_batch`` for predicates — instead of one
    ``match_matrix`` dispatch per job. Left columns slice per tuple-axis
    shard and the match rows concatenate back along nx. A job's
    ``match_method`` joins the group key (chain / aggregate members never
    mix in one dispatch); ledger charges are method-independent — the
    dot-set volume nx·ny·W·A is the protocol cost either way.
    """
    if not jobs:
        return []
    plane = dataplane.as_dataplane(db)
    db = plane.db
    codec = db.codec
    w_len, a_len = codec.word_length, codec.alphabet_size
    entries: List[Optional[FetchEntry]] = [None] * len(jobs)
    groups: Dict[tuple, List[Tuple[int, Shares]]] = {}
    for i, j in enumerate(jobs):
        if j.match_method not in ("chain", "aggregate"):
            raise ValueError(f"unknown match_method: {j.match_method!r}")
        by = j.right.column(j.col_y)
        groups.setdefault((by.values.shape, by.degree, j.match_method),
                          []).append((i, by))
    for (_, by_deg, method), members in groups.items():
        matcher = (_aggregate_matcher(be) if method == "aggregate"
                   else _batched_match_matrix(be))
        idxs = [i for i, _ in members]
        by_stack = jnp.stack([by.values for _, by in members],
                             axis=1)                    # (c, B, ny, W, A)
        cols_x = [jobs[i].col_x for i in idxs]
        m_vals = plane.run_concat(
            lambda v, sh: matcher(
                jnp.stack([v.column(cx).values for cx in cols_x], axis=1),
                by_stack), axis=2)                      # (c, B, nx, ny)
        deg = (db.relation.degree + by_deg) * w_len
        for k, i in enumerate(idxs):
            j = jobs[i]
            j.ledger.cloud(db.n_tuples * j.right.n_tuples * w_len * a_len)
            entries[i] = FetchEntry(jnp.swapaxes(m_vals[:, k], -1, -2), deg)
    return entries


def join_emit_round(db: RelationLike, jobs: Sequence[JoinJob],
                    fetched: Sequence[Shares]) -> List[List[List[str]]]:
    """User/cloud step 2 of B PK/FK joins: re-randomize the fetched parent
    halves, ship both halves, interpolate ALL jobs' tuples in one fused user
    step per degree class, decode and drop dangling children."""
    db = dataplane.as_dataplane(db).db
    codec = db.codec
    w_len, a_len = codec.word_length, codec.alphabet_size
    c, nx, mx = db.n_shares, db.n_tuples, db.n_attrs
    xs_parts: List[Shares] = []
    ys_parts: List[Shares] = []
    for j, fx in zip(jobs, fetched):
        ny, my = j.right.n_tuples, j.right.n_attrs
        j.ledger.cloud(nx * ny * mx * w_len)
        y_part = j.right.relation                    # (c, ny, mY, W, A)
        if j.key is not None:
            kx, ky = jax.random.split(j.key)
            fx = rerandomize(kx, fx)
            y_part = rerandomize(ky, y_part)
            j.ledger.cloud(ny * (mx + my) * w_len * a_len)
        j.ledger.round()
        j.ledger.recv(c * ny * (mx + my) * w_len * a_len)
        xs_parts.append(fx)
        ys_parts.append(y_part)
    xs_all = _fused_interpolate(xs_parts)
    ys_all = _fused_interpolate(ys_parts)

    results: List[List[List[str]]] = []
    for j, fx, yp, xs, ys in zip(jobs, xs_parts, ys_parts, xs_all, ys_all):
        ny, my = j.right.n_tuples, j.right.n_attrs
        j.ledger.user((fx.degree + 1) * ny * mx * w_len
                      + (yp.degree + 1) * ny * my * w_len)
        rows = []
        for r in range(ny):
            x_row = codec.decode_row(xs[r])
            if all(v == "" for v in x_row):
                continue                  # dangling child (no parent)
            y_row = codec.decode_row(ys[r])
            rows.append(x_row + [v for k, v in enumerate(y_row)
                                 if k != j.col_y])
        results.append(rows)
    return results


# ---------------------------------------------------------------------------
# §3.3.2 — general equijoins as rounds (two cloud layers, fused per phase)
# ---------------------------------------------------------------------------

def _one_hot_fetch_shares(key: jax.Array, db: SecretSharedDB,
                          addresses: Sequence[int], ledger: CostLedger
                          ) -> Shares:
    """Layer-1 fetch matrix (kept in share form); ledger records the send
    and the cloud work exactly as a solo oblivious fetch."""
    n = db.n_tuples
    m_sh = _share_one_hot(key, db, addresses)
    ledger.send(db.n_shares * len(addresses) * n)
    _, _, m, w, a = db.relation.values.shape
    ledger.cloud(len(addresses) * n * m * w * a)
    return m_sh


def equijoin_rounds(be, db: RelationLike, jobs: Sequence[EquiJob]
                    ) -> List[List[List[str]]]:
    """§3.3.2 equijoins over a batch, every phase fused.

    Phase 1 (one round): both join columns of every job travel to the user;
    ONE interpolation pass per degree class opens them all. Phase 2: every
    (job, common-value) pair — including the ``padded_values`` fake jobs
    that hide k — builds its two layer-1 one-hot matrices; all X-side
    matrices multiply the client relation in ONE ``ss_matmul`` per
    tuple-axis shard (partial contractions summing additively), Y-side
    matrices fuse per distinct right relation. Phase 3: layer 2 emits the
    ℓx×ℓy concatenations; the user interpolates all real pairs in one fused
    pass per degree class. Ledgers stay bit-identical to the sequential
    per-value transcript (Thm 6's 2k rounds each)."""
    if not jobs:
        return []
    plane = dataplane.as_dataplane(db)
    db = plane.db
    codec = db.codec
    w_len, a_len = codec.word_length, codec.alphabet_size
    c, nx, mx = db.n_shares, db.n_tuples, db.n_attrs

    # -- phase 1: fused column open ------------------------------------
    col_parts: List[Shares] = []
    for j in jobs:
        bx = db.column(j.col_x)
        by = j.right.column(j.col_y)
        j.ledger.round()
        j.ledger.recv(c * nx * w_len * a_len
                      + j.right.n_shares * j.right.n_tuples * w_len * a_len)
        col_parts += [bx, by]
    opened = _fused_interpolate(col_parts)
    val_lists: List[Tuple[List[str], List[str]]] = []
    for i, j in enumerate(jobs):
        bx, by = col_parts[2 * i], col_parts[2 * i + 1]
        x_vals = [codec.decode_word(v) for v in opened[2 * i]]
        y_vals = [codec.decode_word(v) for v in opened[2 * i + 1]]
        j.ledger.user((bx.degree + 1) * nx * w_len
                      + (by.degree + 1) * j.right.n_tuples * w_len)
        val_lists.append((x_vals, y_vals))

    # -- phase 2: all layer-1 fetch matrices, X side in ONE matmul -------
    specs = []          # (job, addr_x, addr_y, real, x_mat, y_mat)
    for j, (x_vals, y_vals) in zip(jobs, val_lists):
        common = sorted(set(x_vals) & set(y_vals))
        key = j.key
        for idx in range(len(common) + j.padded_values):
            key, kx, ky = jax.random.split(key, 3)
            real = idx < len(common)
            if real:
                v = common[idx]
                addr_x = [i for i, t in enumerate(x_vals) if t == v]
                addr_y = [i for i, t in enumerate(y_vals) if t == v]
            else:   # fake job: all-zero matrices, same traffic (hides k)
                addr_x, addr_y = [0], [0]
            j.ledger.round(2)       # Thm 6: two rounds per (fake) value
            xm = _one_hot_fetch_shares(kx, db, addr_x, j.ledger)
            ym = _one_hot_fetch_shares(ky, j.right, addr_y, j.ledger)
            specs.append((j, addr_x, addr_y, real, xm, ym))

    if not specs:       # every job had zero common values and no padding
        return [[] for _ in jobs]
    x_stack = jnp.concatenate([s[4].values for s in specs], axis=1)
    x_fetched = plane.run_sum(          # ONE X-side dispatch per shard
        lambda v, sh: be.ss_matmul(
            x_stack[:, :, sh.lo:sh.hi],
            v.relation.values.reshape(c, sh.n_tuples, -1)))
    y_by_right: Dict[int, List[int]] = {}
    for i, s in enumerate(specs):
        y_by_right.setdefault(id(s[0].right), []).append(i)
    y_fetched: Dict[int, jax.Array] = {}
    for _, idxs in y_by_right.items():
        right = specs[idxs[0]][0].right
        ny = right.n_tuples
        y_stack = jnp.concatenate([specs[i][5].values for i in idxs], axis=1)
        out = be.ss_matmul(y_stack, right.relation.values.reshape(
            right.n_shares, ny, -1))                 # one per right relation
        off = 0
        for i in idxs:
            rows_i = specs[i][5].values.shape[1]
            y_fetched[i] = out[:, off:off + rows_i]
            off += rows_i

    # -- phase 3: layer-2 pairing; fused final interpolation -------------
    xs_parts, ys_parts, metas = [], [], []
    x_off = 0
    for i, (j, addr_x, addr_y, real, xm, ym) in enumerate(specs):
        lx, ly = len(addr_x), len(addr_y)
        my = j.right.n_attrs
        _, _, mw, ww, aw = db.relation.values.shape
        xp = Shares(x_fetched[:, x_off:x_off + lx].reshape(c, lx, mw, ww, aw),
                    xm.degree + db.relation.degree)
        x_off += lx
        ry = j.right.relation
        _, _, mwy, wwy, awy = ry.values.shape
        yp = Shares(y_fetched[i].reshape(j.right.n_shares, ly, mwy, wwy,
                                         awy), ym.degree + ry.degree)
        pairs_x = Shares(jnp.repeat(xp.values, ly, axis=1), xp.degree)
        pairs_y = Shares(jnp.tile(yp.values, (1, lx, 1, 1, 1)), yp.degree)
        j.ledger.cloud(lx * ly * (mx + my) * w_len * a_len)
        if not real:
            continue                # fake-job output discarded at user side
        j.ledger.recv(c * lx * ly * (mx + my) * w_len * a_len)
        j.ledger.user((pairs_x.degree + 1) * lx * ly * mx * w_len
                      + (pairs_y.degree + 1) * lx * ly * my * w_len)
        xs_parts.append(pairs_x)
        ys_parts.append(pairs_y)
        metas.append((j, lx * ly))
    xs_all = _fused_interpolate(xs_parts)
    ys_all = _fused_interpolate(ys_parts)

    by_job: Dict[int, List[List[str]]] = {id(j): [] for j in jobs}
    for (j, n_pairs), xs, ys in zip(metas, xs_all, ys_all):
        for r in range(n_pairs):
            x_row = codec.decode_row(xs[r])
            y_row = codec.decode_row(ys[r])
            by_job[id(j)].append(
                x_row + [v for k, v in enumerate(y_row) if k != j.col_y])
    return [by_job[id(j)] for j in jobs]
