"""Range queries via 2's-complement subtraction on shares (paper §3.4).

``ss_sub`` is Algorithm 6: a ripple subtract over secret-shared bit vectors
returning the secret-shared sign bit of ``B − A``. The carry chain multiplies
shares, so the polynomial degree grows ~2·t per bit; ``reduce_every`` applies
the paper's degree-reduction (re-sharing, [32]) between bit steps to keep the
required cloud count bounded — each reduction is an explicit protocol round.

``x ∈ [a, b]  ⟺  1 − sign(x−a) − sign(b−x) = 1``           (Eq. 1/2)

``range_count`` is Algorithm 5; ``range_select`` fetches the satisfying
tuples by reusing the selection machinery (§3.2) exactly as the paper says.

Both are thin B = 1 wrappers over the round-structured batch engine
(``repro.core.queries.rounds.range_rounds``): the SS-SUB ripple is
element-wise per bit, so B concurrent range queries stack their bit-vectors
into one carry chain — each bit position is ONE backend ``ripple_carry``
dispatch and each ``reduce_every`` boundary ONE degree-reduction re-share
for the whole batch. A query run here is bit-identical (result *and*
``CostLedger``) to the same query inside a ``QueryClient.run_batch`` group.
``ss_sub`` remains as the reference single-subtraction implementation (and
the parity oracle for the fused engine).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from .. import shamir
from ..costs import CostLedger
from ..engine import SecretSharedDB
from ..shamir import Shares
from . import rounds
from ._common import resolve_backend


def _xor(a: Shares, b: Shares) -> Shares:
    """a ⊕ b = a + b − 2ab (share space)."""
    two_ab = (a * b).mul_public(2)
    return a + b - two_ab


def ss_sub(key: jax.Array, A: Shares, B: Shares, *,
           reduce_every: int = 0,
           ledger: Optional[CostLedger] = None) -> Shares:
    """Sign bit of B − A (Algorithm 6). A, B: (..., t_bits) LSB-first shares.

    reduce_every > 0 re-shares the carry down to the base degree every that
    many bit positions (degree-reduction rounds, counted in the ledger).
    """
    t_bits = A.shape[-1]
    one = Shares(jnp.ones_like(A.values[..., 0]), 0)

    def bit(s: Shares, i: int) -> Shares:
        return Shares(s.values[..., i], s.degree)

    # line 1-3: LSB handles the +1 of two's complement
    a0 = one - bit(A, 0)                                   # invert LSB
    b0 = bit(B, 0)
    carry = a0 + b0 - a0 * b0                              # OR: carry of +1
    rb = a0 + b0 - carry.mul_public(2)

    # line 4: ripple through the remaining bits
    for i in range(1, t_bits):
        if reduce_every and carry.degree > 1 and i % reduce_every == 0:
            key, sub = jax.random.split(key)
            carry = shamir.reduce_degree(sub, carry, target_degree=1)
            if ledger is not None:
                ledger.round()
                ledger.send(carry.n_shares * carry.n_shares)
        ai = one - bit(A, i)
        bi = bit(B, i)
        rb = _xor(ai, bi)
        new_carry = ai * bi + carry * rb
        rb = rb + carry - (carry * rb).mul_public(2)
        carry = new_carry
    return rb                                              # sign of B − A


def range_count(key: jax.Array, db: SecretSharedDB, column: int,
                lo: int, hi: int, *, ledger: Optional[CostLedger] = None,
                reduce_every: int = 0,
                backend="jnp", impl: Optional[str] = None
                ) -> Tuple[int, CostLedger]:
    """COUNT(*) WHERE lo <= col <= hi (Algorithm 5, counting phase).

    B = 1 wrapper over the batched ripple engine: the backend's
    ``ripple_carry`` runs the whole carry chain, one dispatch per bit.
    """
    ledger = ledger if ledger is not None else CostLedger()
    be = resolve_backend(backend, impl)
    cnt = rounds.range_rounds(be, db, [
        rounds.RangeJob(column, lo, hi, key, ledger,
                        reduce_every=reduce_every)])[0]
    return cnt, ledger


def range_select(key: jax.Array, db: SecretSharedDB, column: int,
                 lo: int, hi: int, *, ledger: Optional[CostLedger] = None,
                 reduce_every: int = 0, padded_rows: Optional[int] = None,
                 backend="jnp", impl: Optional[str] = None
                 ) -> Tuple[List[List[str]], List[int], CostLedger]:
    """Fetch all tuples with col ∈ [lo, hi] (Alg 5 "simple solution" path:
    per-tuple indicator bits -> addresses -> oblivious matrix fetch).

    B = 1 wrapper over ``range_rounds`` + the shared ``fetch_round`` — in a
    batch the fetch rides the cross-group fused ``ss_matmul``.
    """
    ledger = ledger if ledger is not None else CostLedger()
    be = resolve_backend(backend, impl)
    k_ind, k_fetch = jax.random.split(key)
    addresses = rounds.range_rounds(be, db, [
        rounds.RangeJob(column, lo, hi, k_ind, ledger,
                        reduce_every=reduce_every, want_addresses=True)])[0]
    rows = rounds.fetch_round(be, db, [
        rounds.FetchJob(k_fetch, addresses, ledger, padded_rows)])[0]
    return rows, addresses, ledger
