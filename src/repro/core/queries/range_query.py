"""Range queries via 2's-complement subtraction on shares (paper §3.4).

``ss_sub`` is Algorithm 6: a ripple subtract over secret-shared bit vectors
returning the secret-shared sign bit of ``B − A``. The carry chain multiplies
shares, so the polynomial degree grows ~2·t per bit; ``reduce_every`` applies
the paper's degree-reduction (re-sharing, [32]) between bit steps to keep the
required cloud count bounded — each reduction is an explicit protocol round.

``x ∈ [a, b]  ⟺  1 − sign(x−a) − sign(b−x) = 1``           (Eq. 1/2)

``range_count`` is Algorithm 5; ``range_select`` fetches the satisfying
tuples by reusing the selection machinery (§3.2) exactly as the paper says.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .. import encoding, field, shamir
from ..costs import CostLedger
from ..engine import SecretSharedDB
from ..shamir import Shares
from .select import fetch_by_addresses


def _xor(a: Shares, b: Shares) -> Shares:
    """a ⊕ b = a + b − 2ab (share space)."""
    two_ab = (a * b).mul_public(2)
    return a + b - two_ab


def ss_sub(key: jax.Array, A: Shares, B: Shares, *,
           reduce_every: int = 0,
           ledger: Optional[CostLedger] = None) -> Shares:
    """Sign bit of B − A (Algorithm 6). A, B: (..., t_bits) LSB-first shares.

    reduce_every > 0 re-shares the carry down to the base degree every that
    many bit positions (degree-reduction rounds, counted in the ledger).
    """
    t_bits = A.shape[-1]
    one = Shares(jnp.ones_like(A.values[..., 0]), 0)

    def bit(s: Shares, i: int) -> Shares:
        return Shares(s.values[..., i], s.degree)

    # line 1-3: LSB handles the +1 of two's complement
    a0 = one - bit(A, 0)                                   # invert LSB
    b0 = bit(B, 0)
    carry = a0 + b0 - a0 * b0                              # OR: carry of +1
    rb = a0 + b0 - carry.mul_public(2)

    # line 4: ripple through the remaining bits
    for i in range(1, t_bits):
        if reduce_every and carry.degree > 1 and i % reduce_every == 0:
            key, sub = jax.random.split(key)
            carry = shamir.reduce_degree(sub, carry, target_degree=1)
            if ledger is not None:
                ledger.round()
                ledger.send(carry.n_shares * carry.n_shares)
        ai = one - bit(A, i)
        bi = bit(B, i)
        rb = _xor(ai, bi)
        new_carry = ai * bi + carry * rb
        rb = rb + carry - (carry * rb).mul_public(2)
        carry = new_carry
    return rb                                              # sign of B − A


def _in_range_bits(key: jax.Array, db: SecretSharedDB, column: int,
                   lo: int, hi: int, *, ledger: CostLedger,
                   reduce_every: int = 0) -> Shares:
    """Share of the in-range indicator for every tuple (c, n)."""
    if column not in db.numeric:
        raise ValueError(f"column {column} was not outsourced in binary form")
    bits = db.numeric[column]                      # (c, n, t_bits)
    t_bits = db.numeric_bits[column]
    n = db.n_tuples

    # user: share the range endpoints (broadcast over tuples)
    k_a, k_b, k_s1, k_s2 = jax.random.split(key, 4)
    a_enc = encoding.encode_number_bits(lo, t_bits)
    b_enc = encoding.encode_number_bits(hi, t_bits)
    a_sh = encoding.share_encoded(k_a, a_enc, n_shares=db.n_shares,
                                  degree=db.base_degree)     # (c, t)
    b_sh = encoding.share_encoded(k_b, b_enc, n_shares=db.n_shares,
                                  degree=db.base_degree)
    ledger.round()
    ledger.send(db.n_shares * 2 * t_bits)

    def bcast(s: Shares) -> Shares:
        v = jnp.broadcast_to(s.values[:, None, :],
                             (s.n_shares, n, t_bits))
        return Shares(v, s.degree)

    x = bits
    # sign(x − a) = SS-SUB(A=a, B=x);  sign(b − x) = SS-SUB(A=x, B=b)
    s_xa = ss_sub(k_s1, bcast(a_sh), x, reduce_every=reduce_every,
                  ledger=ledger)
    s_bx = ss_sub(k_s2, x, bcast(b_sh), reduce_every=reduce_every,
                  ledger=ledger)
    ledger.cloud(2 * n * t_bits)
    one = Shares(jnp.ones_like(s_xa.values), 0)
    return one - s_xa - s_bx                        # Eq. 2 indicator


def range_count(key: jax.Array, db: SecretSharedDB, column: int,
                lo: int, hi: int, *, ledger: Optional[CostLedger] = None,
                reduce_every: int = 0) -> Tuple[int, CostLedger]:
    """COUNT(*) WHERE lo <= col <= hi (Algorithm 5, counting phase).

    Backend-independent by construction: SS-SUB is element-wise share
    arithmetic with no registry hotspot (no aa_match / ss_matmul).
    """
    ledger = ledger if ledger is not None else CostLedger()
    ind = _in_range_bits(key, db, column, lo, hi, ledger=ledger,
                         reduce_every=reduce_every)
    total = ind.sum(axis=0)                         # (c,)
    ledger.recv(db.n_shares)
    out = int(np.asarray(shamir.interpolate(total)))
    ledger.user(total.degree + 1)
    return out, ledger


def range_select(key: jax.Array, db: SecretSharedDB, column: int,
                 lo: int, hi: int, *, ledger: Optional[CostLedger] = None,
                 reduce_every: int = 0, padded_rows: Optional[int] = None,
                 backend="jnp", impl: Optional[str] = None
                 ) -> Tuple[List[List[str]], List[int], CostLedger]:
    """Fetch all tuples with col ∈ [lo, hi] (Alg 5 "simple solution" path:
    per-tuple indicator bits -> addresses -> oblivious matrix fetch)."""
    ledger = ledger if ledger is not None else CostLedger()
    k_ind, k_fetch = jax.random.split(key)
    ind = _in_range_bits(k_ind, db, column, lo, hi, ledger=ledger,
                         reduce_every=reduce_every)
    ledger.recv(db.n_shares * db.n_tuples)
    v = np.asarray(shamir.interpolate(ind))
    ledger.user((ind.degree + 1) * db.n_tuples)
    addresses = [int(i) for i in np.nonzero(v)[0]]
    rows = fetch_by_addresses(k_fetch, db, addresses, ledger=ledger,
                              padded_rows=padded_rows, backend=backend,
                              impl=impl)
    return rows, addresses, ledger
