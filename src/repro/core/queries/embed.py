"""Batched oblivious embedding lookup — §3.2.1 selection at LM serving scale.

A token id is a one-hot row over the vocabulary: exactly the paper's unary
encoding. An LM inference step issues batch×seq of these lookups at once, so
the family is built batch-first like every other phase in this package:

* **One share program.** All of a step's one-hots are shared in ONE jitted
  program: per-token keys come from ``jax.random.fold_in`` (vmapped — each
  token keeps its own fresh polynomial, the §2.1 frequency-attack defence)
  and the degree-1 polynomial ``q_i(x) = onehot_i + a1_i·x`` is evaluated at
  all c points in one vectorized pass. No Python loop, no per-token
  ``shamir.share`` dispatch.
* **One contraction.** Every job's share matrix concatenates along the token
  axis and contracts against the shared table in ONE ``ss_matmul`` of shape
  ``(c, ΣB·n, V) · (c, V, D)`` per shard — the same cross-job fusion as
  ``rounds.fetch_fusion``, so a decode step costs exactly one kernel
  dispatch per shard.
* **Opt-in verification.** ``verify=True`` rides the OBSCURE-style
  redundant-share consistency check (``aggregate._verify_openings``) over
  each job's slice of the opened result; needs c >= degree+2 clouds.

Fixed-point codec: table values quantize at scale 2¹² into a signed range of
±2¹⁸ ≪ p/2, so the signed round-trip through F_p is exact; out-of-range
tables raise instead of silently wrapping mod p.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .. import dataplane, field, shamir
from ..costs import CostLedger
from ..dataplane import RelationLike
from ..shamir import Shares
from .aggregate import VerificationError, _verify_openings

__all__ = [
    "QUANT_SCALE", "QUANT_RANGE", "quantize_to_field",
    "dequantize_from_field", "token_coeffs", "share_tokens", "EmbedJob",
    "embed_phase", "VerificationError",
]

# ---------------------------------------------------------------------------
# fixed-point codec
# ---------------------------------------------------------------------------

QUANT_SCALE = 4096.0                       # 2**12
QUANT_RANGE = float(1 << 18) / QUANT_SCALE  # ±64.0 — signed fixed-point range


def quantize_to_field(x: jax.Array) -> jax.Array:
    """float -> fixed-point F_p element (signed values wrap mod p).

    Raises ``ValueError`` when a value falls outside the signed fixed-point
    range ±2¹⁸/2¹² = ±64.0 — wrapping mod p would silently corrupt the
    table. The guard only runs on concrete (non-traced) inputs; inside a
    jit the caller is responsible for pre-validated tables.
    """
    x = jnp.asarray(x)
    try:
        amax = float(jnp.max(jnp.abs(x.astype(jnp.float32)))) if x.size else 0.0
    except jax.errors.ConcretizationTypeError:  # traced: skip the host check
        amax = None
    if amax is not None and amax > QUANT_RANGE:
        raise ValueError(
            f"value magnitude {amax} exceeds the fixed-point range "
            f"±{QUANT_RANGE} (scale 2^12, signed range ±2^18); refusing to "
            f"wrap mod p — rescale the table first")
    q = jnp.round(x.astype(jnp.float32) * QUANT_SCALE).astype(jnp.int64)
    return (q % jnp.int64(int(field.P))).astype(field.DTYPE)


def dequantize_from_field(x: jax.Array) -> jax.Array:
    return field.from_signed(x).astype(jnp.float32) / QUANT_SCALE


# ---------------------------------------------------------------------------
# fused share generation — ONE jitted program for a whole step
# ---------------------------------------------------------------------------

def _token_coeffs(key: jax.Array, n_tokens: int, vocab: int) -> jax.Array:
    """Per-token degree-1 coefficients a1[i] = uniform(fold_in(key, i), (V,)).

    Traced inline by :func:`_onehot_share_program`; also exposed (jitted, via
    :func:`token_coeffs`) so the Pallas fused share-generation kernel can
    consume bit-identical randomness.
    """
    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        key, jnp.arange(n_tokens, dtype=jnp.uint32))
    return jax.vmap(lambda k: field.uniform(k, (vocab,)))(keys)   # (n, V)


@functools.partial(jax.jit, static_argnames=("vocab",))
def token_coeffs(key: jax.Array, tokens: jax.Array, *, vocab: int
                 ) -> jax.Array:
    return _token_coeffs(key, tokens.shape[0], vocab)


@functools.partial(jax.jit, static_argnames=("vocab", "n_shares"))
def _onehot_share_program(key: jax.Array, flat_tokens: jax.Array, *,
                          vocab: int, n_shares: int) -> jax.Array:
    """All one-hots of a step -> degree-1 share tensor (c, n, V), one jit.

    share[k, i, :] = onehot(token_i) + a1_i · x_k  with per-token fold_in
    keys — vectorized polynomial evaluation, no Python loop.
    """
    a1 = _token_coeffs(key, flat_tokens.shape[0], vocab)          # (n, V)
    onehot = jax.nn.one_hot(flat_tokens, vocab, dtype=field.DTYPE)
    xs = shamir.eval_points(n_shares)                             # (c,)
    ax = field.mul(a1[None, :, :], xs[:, None, None])
    return field.add(onehot[None], ax)


def share_tokens(key: jax.Array, tokens, *, vocab: int, n_shares: int,
                 be=None) -> Shares:
    """Share a whole step's token one-hots in one program -> Shares(c, n, V).

    Degree is fixed at 1 (the fast path's design point: the post-contraction
    degree 1 + table_degree must stay interpolatable from c shares). When
    the backend provides a fused ``share_onehot`` kernel (pallas), the
    one-hot build and polynomial evaluation fuse into one launch fed by the
    same ``token_coeffs`` randomness — bit-identical to the jnp program.
    """
    flat = jnp.asarray(tokens).reshape(-1)
    if flat.size == 0:
        raise ValueError("share_tokens needs at least one token")
    flat = flat.astype(jnp.int32)
    fused = getattr(be, "share_onehot", None)
    if fused is not None:
        a1 = token_coeffs(key, flat, vocab=vocab)
        return Shares(fused(flat, a1, n_shares=n_shares), 1)
    vals = _onehot_share_program(key, flat, vocab=vocab, n_shares=n_shares)
    return Shares(vals, 1)


# ---------------------------------------------------------------------------
# the job family
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EmbedJob:
    """One step's worth of lookups: token ids (any shape, flattened), the
    sharing key, the billing ledger, and the OBSCURE-style verify flag."""
    tokens: np.ndarray
    key: jax.Array
    ledger: CostLedger
    verify: bool = False


def embed_phase(be, rel: RelationLike, jobs: Sequence[EmbedJob]
                ) -> List[np.ndarray]:
    """All jobs' lookups fused into one contraction against the table.

    ``rel`` must carry a rank-3 ``(c, V, D)`` relation (see
    ``models.private_embed.as_embed_relation``); sharding splits the vocab
    axis and the per-shard mod-p partials sum exactly, so the result is
    bit-identical for every shard count S. Returns one float32
    ``(n_tokens_j, D)`` embedding matrix per job (dequantized).
    """
    if not jobs:
        return []
    plane = dataplane.as_dataplane(rel)
    db = plane.db
    vals = db.relation.values
    if vals.ndim != 3:
        raise ValueError(
            f"embed_phase needs a (c, V, D) embedding relation, got a "
            f"rank-{vals.ndim} share tensor; wrap the table with "
            f"models.private_embed.as_embed_relation")
    c, v, d_dim = (int(s) for s in vals.shape)
    t_deg = db.relation.degree
    out_deg = 1 + t_deg
    if c < out_deg + 1:
        raise ValueError(
            f"opening a degree-{out_deg} lookup needs {out_deg + 1} clouds, "
            f"table has {c}")

    mats, spans, pos = [], [], 0
    for job in jobs:
        flat = np.asarray(job.tokens).reshape(-1)
        if flat.size and (flat.min() < 0 or flat.max() >= v):
            raise ValueError(
                f"token id out of range [0, {v}): "
                f"[{int(flat.min())}, {int(flat.max())}]")
        mats.append(share_tokens(job.key, flat, vocab=v, n_shares=c,
                                 be=be).values)
        spans.append((pos, pos + int(flat.size)))
        pos += int(flat.size)

    stacked = mats[0] if len(mats) == 1 else jnp.concatenate(mats, axis=1)
    fetched = plane.run_sum(
        lambda view, sh: be.ss_matmul(stacked[:, :, sh.lo:sh.hi],
                                      view.relation.values))      # (c, N, D)
    out_sh = Shares(fetched, out_deg)

    # Table-1 billing, per job: one round; the shared one-hots go up, the
    # picked share rows come down, the clouds do the V×D contraction, the
    # user interpolates degree+1 shares per output element.
    for job, (lo, hi) in zip(jobs, spans):
        n_tok = hi - lo
        job.ledger.round()
        job.ledger.send(c * n_tok * v)
        job.ledger.cloud(n_tok * v * d_dim)
        job.ledger.recv(c * n_tok * d_dim)
        job.ledger.user((out_deg + 1) * n_tok * d_dim)
    for job, (lo, hi) in zip(jobs, spans):
        if job.verify:
            _verify_openings(job, [out_sh[lo:hi]], "embedding lookup")

    opened = np.asarray(dequantize_from_field(shamir.interpolate(out_sh)))
    return [opened[lo:hi] for lo, hi in spans]
