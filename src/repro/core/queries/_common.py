"""Shared backend resolution for the query suite.

Queries accept ``backend=`` (a registered name or ``repro.api.backends
.Backend`` instance). The historical ``impl="jnp"|"pallas"`` strings are
still accepted as a deprecated alias so pre-registry callers keep working.
The import of the registry is deferred: ``repro.api`` sits *above* the core
layer, and resolving at call time keeps the layering acyclic.
"""
from __future__ import annotations

import warnings
from typing import Optional

from ..shamir import Shares


def resolve_backend(backend, impl: Optional[str] = None):
    """-> Backend; ``impl`` (deprecated) overrides ``backend`` when given."""
    from ...api import backends as _registry
    if impl is not None:
        warnings.warn(
            "the impl= argument is deprecated; use backend= (see "
            "repro.api.backends)", DeprecationWarning, stacklevel=3)
        backend = impl
    return _registry.get_backend(backend)


def match_matrix_shares(be, col_x: Shares, col_y: Shares) -> Shares:
    """Backend all-pairs match with the same degree bookkeeping."""
    w = col_x.values.shape[-2]
    return Shares(be.match_matrix(col_x.values, col_y.values),
                  (col_x.degree + col_y.degree) * w)
