"""Pattern-predicate queries (LIKE / prefix / suffix / substring) on
secret-shares — the §3.1 accumulating automaton generalized past exact
equality.

Lowering (``repro.core.encoding.parse_like`` builds the spec):

* wildcard-free LIKE  → **exact** — not handled here at all; the planner
  rewrites it onto the classic Eq path (provably, see planner tests).
* ``J_hn%`` (masked)  → the full-width chain with a masked pattern
  encoding: wildcard positions share the all-ones vector (their alphabet
  dot is identically 1), trailing positions the terminator one-hot. Rides
  the very same ``aa_match_batch`` stack as Eq.
* ``Jo%`` (prefix)    → a truncated k-chain over ``col[..., :k, :]``.
* ``%hn`` (suffix)    → sliding-window products × the terminator factor
  (windows are mutually exclusive for wildcard-free tiles, so the linear
  sum is the exact 0/1 bit).
* ``%oh%`` (contains) → the window count P ∈ {0..M}, one degree-reduction
  re-share (the family's only extra round), then the share-local zero
  test ``1 − Π_{j=1..M}(j−P)/M!``.

All four kinds keep the final match-bit degree ≤ the exact chain's 2tW, so
any database that supports equality selects supports pattern selects. The
free functions here run the batch engine at B = 1; pattern queries inside a
``QueryClient.run_batch`` group execute the same code, fused (match groups
per strategy/width, fetches in the shared cross-group matmul).

Cost model: :func:`match_phase_cost` (re-exported from ``rounds``) is both
what the round engine charges and what the planner prices, so
``explain()`` is exact for pattern counts and one-round selects.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import jax

from .. import encoding
from ..costs import CostLedger
from ..engine import SecretSharedDB
from . import rounds
from ._common import resolve_backend
from .rounds import match_phase_cost  # noqa: F401  (re-export)

#: spec kinds that leave the full-width chain (``masked`` does not)
TILE_KINDS = ("prefix", "suffix", "contains")


def like_spec(codec: encoding.Codec, pattern: str
              ) -> Optional[encoding.PatternSpec]:
    """Lower a LIKE pattern string to its :class:`~.encoding.PatternSpec`,
    or ``None`` when it is wildcard-free (→ the exact-equality path).
    Raises ``ValueError`` for unsupported shapes (interior ``%``, ``_``
    under a leading ``%``, empty body, k > word_length)."""
    kind, body, wild = encoding.parse_like(pattern)
    if kind == "exact":
        return None
    spec = encoding.PatternSpec(kind, body, wild, pattern)
    # fail fast at lowering time, not inside the dispatch
    encoding.encode_pattern_tile(codec, spec)
    return spec


def pattern_count(key: jax.Array, db: SecretSharedDB, column: int,
                  spec: encoding.PatternSpec, *,
                  ledger: Optional[CostLedger] = None,
                  backend="jnp") -> Tuple[int, CostLedger]:
    """COUNT(*) WHERE col LIKE pattern — one round (two for CONTAINS)."""
    ledger = ledger if ledger is not None else CostLedger()
    be = resolve_backend(backend, None)
    cnt = rounds.count_phase(
        be, db, [rounds.MatchJob(column, spec.body, key, ledger, spec)])[0]
    return cnt, ledger


def pattern_select(key: jax.Array, db: SecretSharedDB, column: int,
                   spec: encoding.PatternSpec, *, strategy: str = "one_round",
                   ell: Optional[int] = None,
                   padded_rows: Optional[int] = None,
                   ledger: Optional[CostLedger] = None, backend="jnp"
                   ) -> Tuple[List[List[str]], List[int], CostLedger]:
    """SELECT * WHERE col LIKE pattern via ``one_round`` or ``tree``.

    ``tree`` needs the match cardinality ℓ (run :func:`pattern_count`
    first, exactly like the Eq tree's Phase 0); ``one_round`` does not.
    The §3.2.1 one-tuple special case stays exact-equality-only. Returns
    ``(rows, addresses, ledger)``.
    """
    ledger = ledger if ledger is not None else CostLedger()
    be = resolve_backend(backend, None)
    k_pat, k_fetch = jax.random.split(key)
    if strategy == "one_round":
        addresses = rounds.match_all_round(
            be, db,
            [rounds.MatchJob(column, spec.body, k_pat, ledger, spec)])[0]
    elif strategy == "tree":
        if ell is None:
            raise ValueError("tree strategy needs ell (run pattern_count)")
        if ell == 0:
            return [], [], ledger
        addresses = rounds.tree_rounds(
            be, db, [rounds.TreeJob(column, spec.body, k_pat, ledger, spec,
                                    ell=ell)])[0]
    else:
        raise ValueError(
            f"pattern selects support one_round/tree, not {strategy!r}")
    rows = rounds.fetch_round(
        be, db, [rounds.FetchJob(k_fetch, addresses, ledger,
                                 padded_rows)])[0]
    return rows, addresses, ledger
