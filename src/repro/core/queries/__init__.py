"""Privacy-preserving query suite on secret-shares (paper §3).

Every query function simulates both protocol sides faithfully:
user-side encode/share/interpolate, cloud-side oblivious share-space
computation, with a CostLedger recording bits/rounds/ops (Table 1 units).

DEPRECATED as a public surface: these free functions are kept as the
protocol implementations (and for backward compatibility), but new code
should use ``repro.api.QueryClient`` — one facade with logical plans,
name-based columns, automatic key derivation, a cost-based selection
planner, and the backend registry replacing the old ``impl=`` strings.
"""
from . import aggregate, embed, rounds
from .aggregate import VerificationError
from .embed import EmbedJob, embed_phase
from .count import count_query
from .pattern import like_spec, match_phase_cost, pattern_count, pattern_select
from .select import (CardinalityError, select_one_tuple, select_one_round,
                     select_tree)
from .join import pkfk_join, equijoin
from .range_query import ss_sub, range_count, range_select

__all__ = [
    "CardinalityError", "VerificationError", "aggregate", "embed", "rounds",
    "EmbedJob", "embed_phase", "count_query", "select_one_tuple",
    "select_one_round", "select_tree", "pkfk_join", "equijoin", "ss_sub",
    "range_count", "range_select", "like_spec", "match_phase_cost",
    "pattern_count", "pattern_select",
]
