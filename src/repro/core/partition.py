"""Contiguous range partitioning — the one split helper everyone shares.

Three call sites used to carry their own ``np.linspace``-based variant of
this logic: the MapReduce input-split bounds (``repro.api.executor``), the
tree-selection block partitioning (``repro.core.queries``), and the executor
backend wrappers. They now all call :func:`split_bounds`, so a split computed
for a MapReduce map task and a block computed for a §3.2.2 Q&A round follow
the same rounding rules (``linspace`` edges truncated toward zero, empty
sub-ranges dropped).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

Bounds = Tuple[int, int]


def split_bounds(lo: int, hi: int, k: int) -> List[Bounds]:
    """Split [lo, hi) into at most ``k`` non-empty contiguous [a, b) ranges.

    Ranges cover [lo, hi) exactly, are close to equal-sized (linspace edges),
    and are never empty — for ``hi - lo < k`` fewer than ``k`` ranges come
    back. An empty input range yields no bounds.
    """
    if hi <= lo:
        return []
    k = max(1, min(k, hi - lo))
    edges = np.linspace(lo, hi, k + 1).astype(int)
    return [(int(edges[i]), int(edges[i + 1])) for i in range(k)
            if edges[i] < edges[i + 1]]


def split_sizes(total: int, k: int) -> List[int]:
    """Sizes of :func:`split_bounds`(0, total, k) — handy for stacking."""
    return [b - a for a, b in split_bounds(0, total, k)]
