"""Device-resident mesh dispatcher: the cloud steps of a batch as SPMD.

Every host dispatcher in :mod:`repro.core.dataplane` (serial / thread pool /
MapReduce) runs one thunk per shard and reassembles the partials on the
host — correct, but the hardware never sees more than one shard-step at a
time and every reduce round-trips through Python. :class:`MeshDispatcher`
executes the same :class:`~repro.core.dataplane.DispatchSet` seam
device-resident:

* **Placement** — on first contact with a plane (``bind_plane``, called by
  ``QueryClient.attach`` and lazily from ``run_set``), the relation's share
  arrays are ``jax.device_put`` once onto a ``jax.make_mesh`` with the
  tuple axis pinned to the ``data`` mesh axis and the cloud axis (the c
  Shamir shares — independent non-communicating clouds) spread across
  ``model`` (``repro.sharding.share_spec``). Everything after that initial
  placement stays on device: shard views are jnp slices of the placed
  arrays, kernel dispatches consume and produce device buffers, and the
  reduce below never touches the host.
* **SPMD reduce** — a ``"sum"`` step's per-shard mod-p partials are stacked
  and lowered through ``shard_map``: each device folds its block in uint64
  and a ``psum`` along the data axes combines them, with a single final
  ``% p`` fold. F_p addition is exact, so this is **bit-identical** to the
  host chain of ``field.add`` for every shard count S — the dataplane's
  standing transcript invariant. The stacked buffer is *donated* into the
  reduction (round-to-round re-shares reuse the storage; donation is a
  no-op on backends without buffer aliasing, e.g. CPU).
* **No blocking inside a batch** — ``run_set`` never calls
  ``block_until_ready``; jax async dispatch overlaps the next shard
  dispatch with the in-flight reduce, and synchronization happens only
  when the user-side protocol opens values at batch boundaries.
* **Predicted cost** — every distinct reduction program it compiles keeps
  its optimized HLO text; :meth:`predicted_cost` runs
  ``repro.launch.hlo_cost`` over them (FLOPs / HBM bytes / collective
  bytes), which the bench harness merges with the per-family kernel HLO
  into the gated ``mesh`` section of ``BENCH_queries.json``.

``strict_transfers=True`` wraps every cloud step in
``jax.transfer_guard("disallow")`` — any implicit host↔device copy inside a
round raises, which is how tests/test_mesh_dispatch.py *proves* the
device-residency invariant instead of asserting it by inspection.
"""
from __future__ import annotations

import contextlib
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                    # jax >= 0.6 promotes it out
    from jax import shard_map           # type: ignore[attr-defined]
except ImportError:                     # pragma: no cover - version skew
    from jax.experimental.shard_map import shard_map

from . import field
from .dataplane import Dispatcher, DispatchSet, ShardedRelation
from .engine import SecretSharedDB
from .shamir import Shares


class MeshDispatcher(Dispatcher):
    """Run a plane's cloud steps as one SPMD program per round on a mesh.

    Parameters
    ----------
    mesh:
        A ``("data", "model")`` (optionally ``("pod", "data", "model")``)
        mesh; defaults to ``repro.launch.mesh.make_dispatch_mesh()`` — all
        visible devices on the data axis. The single-device host mesh
        degrades to a correct (serial-speed) path, so the dispatcher is
        safe to construct anywhere.
    strict_transfers:
        Raise on any *implicit* host↔device transfer inside a cloud step
        (explicit placement via ``bind_plane`` is exempt). Used by tests to
        prove device residency.
    collect_hlo:
        Keep the optimized HLO text of every compiled reduction for
        :meth:`predicted_cost` (cheap: one text per distinct shape).
    """

    device_resident = True

    def __init__(self, mesh: Optional[Mesh] = None, *,
                 strict_transfers: bool = False, collect_hlo: bool = True):
        if mesh is None:
            from ..launch.mesh import make_dispatch_mesh
            mesh = make_dispatch_mesh()
        if "data" not in mesh.axis_names:
            raise ValueError(f"MeshDispatcher needs a 'data' axis, got "
                             f"{mesh.axis_names}")
        self.mesh = mesh
        self.strict_transfers = strict_transfers
        self.collect_hlo = collect_hlo
        self.data_axes: Tuple[str, ...] = tuple(
            a for a in ("pod", "data") if a in mesh.axis_names)
        self.data_size = 1
        for a in self.data_axes:
            self.data_size *= int(mesh.shape[a])
        self._sum_fns: Dict[Tuple[Tuple[int, ...], str], Any] = {}
        self._hlo_texts: Dict[str, str] = {}
        self._pending_transfer_bytes = 0

    # -- placement ----------------------------------------------------------
    def bind_plane(self, plane: ShardedRelation) -> None:
        """Device-put the plane's share arrays onto the mesh, once.

        Idempotent per (plane, dispatcher); re-binding after an attach
        re-shard is a fresh placement. The moved bytes are charged to the
        plane's next ``DispatchStats.record`` — after this, transfer bytes
        stay at zero (the residency invariant).
        """
        if getattr(plane, "_mesh_placed_by", None) is self:
            return
        from .. import sharding

        def put(shares: Shares) -> Shares:
            spec = sharding.share_spec(self.mesh, shares.values.shape)
            arr = jax.device_put(shares.values,
                                 NamedSharding(self.mesh, spec))
            self._pending_transfer_bytes += int(arr.nbytes)
            return Shares(arr, shares.degree)

        db = plane.db
        plane.db = SecretSharedDB(
            relation=put(db.relation), codec=db.codec,
            column_names=db.column_names,
            numeric={c: put(s) for c, s in db.numeric.items()},
            numeric_bits=dict(db.numeric_bits),
            base_degree=db.base_degree)
        plane._views.clear()
        plane._mesh_placed_by = self

    # -- the dispatch seam --------------------------------------------------
    def run_set(self, plane: ShardedRelation, ds: DispatchSet):
        self.bind_plane(plane)
        # strict mode: no device→host pull anywhere inside the cloud step
        # (partials must never stage through the host), and no transfer of
        # EITHER direction inside the reduce. Eager-mode kernel dispatch
        # uploads scalar slice indices (int64[] avals — bytes, not share
        # buffers), so blanket host→device disallow would false-positive
        # there; the share-buffer direction is enforced exactly instead by
        # the placement-only ``transfer_bytes`` accounting.
        d2h = (jax.transfer_guard_device_to_host("disallow")
               if self.strict_transfers else contextlib.nullcontext())
        t0 = time.perf_counter()
        with d2h:
            parts = [d.run() for d in ds.dispatches]
            both = (jax.transfer_guard("disallow") if self.strict_transfers
                    else contextlib.nullcontext())
            with both:
                if ds.reduce == "sum" and len(parts) > 1:
                    out = self._device_sum(parts)
                else:
                    out = ds.combine(parts)  # concat/list: already on device
        moved, self._pending_transfer_bytes = self._pending_transfer_bytes, 0
        plane.stats.record(len(ds.dispatches),
                           wall_s=time.perf_counter() - t0,
                           transfer_bytes=moved)
        return out

    # -- SPMD mod-p reduction ----------------------------------------------
    def _device_sum(self, parts: List[jax.Array]):
        """psum the per-shard partials along the data axes, exactly mod p."""
        d = self.data_size
        pad = (-len(parts)) % d
        if pad:                       # 0 is the additive identity of F_p
            parts = list(parts) + [jnp.zeros_like(parts[0])] * pad
        stacked = jnp.stack(parts)
        return self._sum_fn(stacked.shape, str(stacked.dtype))(stacked)

    def _sum_fn(self, shape: Tuple[int, ...], dtype: str):
        key = (shape, dtype)
        fn = self._sum_fns.get(key)
        if fn is not None:
            return fn
        ndim = len(shape)
        in_spec = P(self.data_axes, *([None] * (ndim - 1)))
        out_spec = P(*([None] * (ndim - 1)))
        axes = self.data_axes

        def psum_fold(block):
            # uint64 accumulation of < 2^31 partials never wraps for any
            # realistic S; ONE fold at the end == the field.add chain.
            acc = jnp.sum(block.astype(jnp.uint64), axis=0)
            acc = jax.lax.psum(acc, axes)
            return (acc % jnp.uint64(field.P)).astype(block.dtype)

        mapped = shard_map(psum_fold, mesh=self.mesh,
                           in_specs=in_spec, out_specs=out_spec)
        # donate the stacked-partials buffer into the reduction: the
        # round-to-round re-share reuses its storage on aliasing backends
        donate = (0,) if jax.default_backend() != "cpu" else ()
        fn = jax.jit(mapped, donate_argnums=donate)
        if self.collect_hlo:
            lowered = fn.lower(
                jax.ShapeDtypeStruct(shape, jnp.dtype(dtype)))
            name = f"sum/{'x'.join(map(str, shape))}/{dtype}"
            self._hlo_texts[name] = lowered.compile().as_text()
        self._sum_fns[key] = fn
        return fn

    # -- predicted cost -----------------------------------------------------
    def hlo_texts(self) -> Dict[str, str]:
        """Optimized HLO of every reduction program compiled so far."""
        return dict(self._hlo_texts)

    def predicted_cost(self) -> Dict[str, float]:
        """HLO-cost-model totals over the compiled reduction programs.

        Per-device numbers (the HLO is the SPMD-partitioned module);
        collective bytes are the psum traffic along the data axes.
        """
        from ..launch import hlo_cost   # lazy: core -> launch on demand
        total = hlo_cost.Cost()
        for text in self._hlo_texts.values():
            total += hlo_cost.analyze_text(text)
        return dict(flops=total.flops, hbm_bytes=total.hbm_bytes,
                    collective_bytes=total.collective_bytes,
                    programs=len(self._hlo_texts))
