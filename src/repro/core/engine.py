"""SecretSharedDB — the outsourced database (paper §2.1–§2.2).

The *trusted DB owner* encodes a relation (strings -> unary one-hots, numeric
range columns -> two's-complement bits), secret-shares every bit with an
independent polynomial, and ships one share-relation per cloud. After that the
owner is offline: queries are issued by the *user* against the clouds only.

In this framework the ``c`` clouds are axis 0 of every share tensor; the
non-communication property is structural (no op mixes different cloud rows
except the explicitly counted re-sharing round) and is verified by
``tests/test_noncommunication.py`` on the lowered HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax
import numpy as np

from . import encoding
from .costs import CostLedger
from .encoding import Codec
from .shamir import Shares


@dataclasses.dataclass
class SecretSharedDB:
    """One outsourced relation R^s_1..R^s_c plus metadata the adversary knows.

    Per §2.3 the adversary may know n, m and the schema — only the *values*
    (and their multiplicities) are hidden.
    """
    relation: Shares                 # (c, n, m, W, A) one-hot shares
    codec: Codec
    column_names: Sequence[str]
    numeric: Dict[int, Shares]       # col index -> (c, n, bits) bit shares
    numeric_bits: Dict[int, int]
    base_degree: int = 1

    @property
    def n_shares(self) -> int:
        return self.relation.n_shares

    @property
    def n_tuples(self) -> int:
        return self.relation.shape[0]

    @property
    def n_attrs(self) -> int:
        return self.relation.shape[1]

    def column(self, col: int) -> Shares:
        """Share view of one attribute: (c, n, W, A)."""
        return Shares(self.relation.values[:, :, col], self.relation.degree)

    def col_index(self, name: str) -> int:
        return list(self.column_names).index(name)


def outsource(key: jax.Array,
              rows: Sequence[Sequence[str]],
              *,
              column_names: Optional[Sequence[str]] = None,
              codec: Optional[Codec] = None,
              n_shares: int,
              degree: int = 1,
              numeric_columns: Optional[Dict[int, int]] = None
              ) -> SecretSharedDB:
    """DB-owner-side, one-time: encode + share + distribute (Algorithm 1).

    numeric_columns maps a column index to a bit-width; those columns are
    *additionally* outsourced in binary form for range queries (§3.4).
    """
    codec = codec or Codec()
    rows = [list(r) for r in rows]
    n = len(rows)
    m = len(rows[0])
    if column_names is None:
        column_names = [f"A{j+1}" for j in range(m)]

    k_rel, k_num = jax.random.split(key)
    encoded = codec.encode_relation(rows)                  # (n, m, W, A)
    relation = encoding.share_encoded(k_rel, encoded, n_shares=n_shares,
                                      degree=degree)

    numeric: Dict[int, Shares] = {}
    numeric_bits: Dict[int, int] = {}
    for col, bits in (numeric_columns or {}).items():
        vals = [int(r[col]) for r in rows]
        enc = encoding.encode_number_column(vals, bits)    # (n, bits)
        k_num, k_col = jax.random.split(k_num)
        numeric[col] = encoding.share_encoded(k_col, enc, n_shares=n_shares,
                                              degree=degree)
        numeric_bits[col] = bits

    return SecretSharedDB(relation=relation, codec=codec,
                          column_names=list(column_names), numeric=numeric,
                          numeric_bits=numeric_bits, base_degree=degree)


def fresh_ledger() -> CostLedger:
    return CostLedger()
