"""Finite-field arithmetic over F_p with p = 2**31 - 1 (Mersenne-31).

All secret-sharing math in this framework happens in this field. Elements are
stored as ``uint32`` in ``[0, p)``. Products are formed in ``uint64`` lanes and
reduced with the Mersenne fold ``x -> (x & p) + (x >> 31)`` — two folds bring
any 62-bit value below ``2p``, one conditional subtract finishes. This is the
TPU-friendly choice: no integer division, no Barrett/Montgomery constants.

The Pallas kernels (``repro.kernels``) re-derive the same arithmetic in 16-bit
limbs for 32-bit-lane hardware; this module is the reference semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# The field prime: Mersenne-31. Fits uint32; products fit uint64 (62 bits).
P = np.uint32(2**31 - 1)
P64 = np.uint64(2**31 - 1)
DTYPE = jnp.uint32

__all__ = [
    "P", "DTYPE", "to_field", "add", "sub", "neg", "mul", "pow_", "inv",
    "sum_", "dot", "matmul", "uniform", "from_signed",
]


def to_field(x) -> jax.Array:
    """Cast integers (possibly negative / oversized) into canonical F_p form."""
    x = jnp.asarray(x)
    if jnp.issubdtype(x.dtype, jnp.signedinteger):
        x = jnp.asarray(x, jnp.int64) % jnp.int64(P)
    return jnp.asarray(x, jnp.uint64) % P64


def _fold64(x: jax.Array) -> jax.Array:
    """Mersenne fold of a uint64 value below 2**62 down to [0, p)."""
    x = (x & P64) + (x >> np.uint64(31))          # < 2**32
    x = (x & P64) + (x >> np.uint64(31))          # < p + 2
    return x - jnp.where(x >= P64, P64, np.uint64(0))


def add(a: jax.Array, b: jax.Array) -> jax.Array:
    s = a.astype(jnp.uint64) + b.astype(jnp.uint64)
    s = s - jnp.where(s >= P64, P64, np.uint64(0))
    return s.astype(DTYPE)


def sub(a: jax.Array, b: jax.Array) -> jax.Array:
    a = a.astype(jnp.uint64)
    b = b.astype(jnp.uint64)
    return (a + jnp.where(a >= b, np.uint64(0), P64) - b).astype(DTYPE)


def neg(a: jax.Array) -> jax.Array:
    a = a.astype(jnp.uint64)
    return jnp.where(a == 0, a, P64 - a).astype(DTYPE)


def mul(a: jax.Array, b: jax.Array) -> jax.Array:
    prod = a.astype(jnp.uint64) * b.astype(jnp.uint64)   # < 2**62
    return _fold64(prod).astype(DTYPE)


def sum_(x: jax.Array, axis=None, keepdims: bool = False) -> jax.Array:
    """Modular sum. Accumulates in uint64 (safe for up to 2**33 addends)."""
    acc = jnp.sum(x.astype(jnp.uint64), axis=axis, keepdims=keepdims)
    # acc < n * p <= 2**33 * 2**31 = 2**64 -> fold via % once (uint64 mod is
    # fine outside the hot path; hot paths use the Pallas kernels).
    return (acc % P64).astype(DTYPE)


def dot(a: jax.Array, b: jax.Array, axis: int = -1) -> jax.Array:
    """Modular inner product along ``axis``."""
    prod = a.astype(jnp.uint64) * b.astype(jnp.uint64)
    prod = _fold64(prod)
    return sum_(prod, axis=axis)


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Modular matmul ``a @ b`` for 2-D (or batched) uint32 operands.

    Limb-decomposed: ``x = x1·2¹⁶ + x0`` turns the mod-p matmul into FOUR
    plain integer dots whose uint64 accumulation is exact for K ≤ 2³⁰
    (x1x1 < 2³⁰, partial sums < K·2³² < 2⁶²), recombined with Mersenne
    folds (2³² ≡ 2, 2¹⁶ stays). XLA lowers the limb dots to real ``dot``
    HLOs — O(MK+KN+MN) HBM traffic — instead of materializing the
    (…,M,K,N) fold-between-multiply-and-sum intermediate of the naive
    formulation (measured 10× memory-term win on the paper_db cell;
    EXPERIMENTS.md §Perf). The Pallas kernel (kernels/ss_matmul.py) is the
    same algorithm tiled for VMEM.
    """
    k_dim = a.shape[-1]
    assert k_dim <= (1 << 28), "limb accumulation exact only for K <= 2^28"
    mask = jnp.uint32(0xFFFF)
    # u32 limb operands (half the read traffic of u64-widened operands);
    # dots accumulate exactly in u64 via preferred_element_type.
    a1, a0 = a >> jnp.uint32(16), a & mask
    b1, b0 = b >> jnp.uint32(16), b & mask

    def dot64(x, y):
        return jnp.matmul(x, y, preferred_element_type=jnp.uint64)

    # Karatsuba: 3 dots instead of 4 — mid = (a1+a0)(b1+b0) − hi − lo.
    d11 = dot64(a1, b1)                        # Σ a1b1       < K·2³⁰
    d00 = dot64(a0, b0)                        # Σ a0b0       < K·2³²
    dk = dot64(a1 + a0, b1 + b0)               # Σ (…)(…)     < K·2³⁴
    dmid = _fold64(dk - d11 - d00)             # exact in u64 (no borrow:
    #                                            dk ≥ d11+d00 elementwise)
    d11 = _fold64(d11)
    d00 = _fold64(d00)
    # x = d11·2³² + dmid·2¹⁶ + d00 ≡ 2·d11 + dmid·2¹⁶ + d00 (mod p)
    t11 = _fold64(d11 << jnp.uint64(1))
    tmid = _fold64(dmid << jnp.uint64(16))
    return add(add(t11.astype(DTYPE), tmid.astype(DTYPE)),
               d00.astype(DTYPE))


def pow_(a: jax.Array, e: int) -> jax.Array:
    """a**e mod p by square-and-multiply (e is a static python int)."""
    e = int(e)
    result = jnp.full_like(a, 1)
    base = a
    while e > 0:
        if e & 1:
            result = mul(result, base)
        base = mul(base, base)
        e >>= 1
    return result


def inv(a: jax.Array) -> jax.Array:
    """Multiplicative inverse by Fermat: a**(p-2)."""
    return pow_(a, int(P) - 2)


def from_signed(x: jax.Array) -> jax.Array:
    """Interpret field element as signed (for small +/- values around 0)."""
    x = x.astype(jnp.int64)
    half = jnp.int64(int(P) // 2)
    return jnp.where(x > half, x - jnp.int64(int(P)), x)


def uniform(key: jax.Array, shape) -> jax.Array:
    """Uniform field elements via rejection-free 62-bit sampling.

    Draws 64 random bits, keeps the low 62, reduces mod p. The bias is
    2**-31-scale (negligible, and irrelevant for tests).
    """
    bits = jax.random.bits(key, shape, dtype=jnp.uint64)
    bits = bits >> np.uint64(2)
    return (bits % P64).astype(DTYPE)
