"""Communication/computation cost ledger (reproduces the units of Table 1).

The paper evaluates algorithms on:
  (i)   total bits transferred user <-> cloud,
  (ii)  number of communication rounds,
  (iii) computational cost at the cloud (bits touched),
  (iv)  computational cost at the user (bits touched).

Every query implementation threads a ``CostLedger`` through its phases so the
benchmarks in ``benchmarks/`` print *measured* values next to the paper's
asymptotic claims. One field element counts as w = 31 bits (Mersenne-31).
"""
from __future__ import annotations

import dataclasses

WORD_BITS = 31  # bit-length of one F_p element


@dataclasses.dataclass
class CostLedger:
    rounds: int = 0
    bits_user_to_cloud: int = 0
    bits_cloud_to_user: int = 0
    cloud_ops_bits: int = 0
    user_ops_bits: int = 0

    # -- recording helpers ---------------------------------------------------
    def round(self, n: int = 1) -> None:
        self.rounds += n

    def send(self, n_elems: int) -> None:
        """User -> cloud transfer of n field elements (all clouds counted)."""
        self.bits_user_to_cloud += n_elems * WORD_BITS

    def recv(self, n_elems: int) -> None:
        self.bits_cloud_to_user += n_elems * WORD_BITS

    def cloud(self, n_elems: int) -> None:
        self.cloud_ops_bits += n_elems * WORD_BITS

    def user(self, n_elems: int) -> None:
        self.user_ops_bits += n_elems * WORD_BITS

    # -- reporting ------------------------------------------------------------
    @property
    def communication_bits(self) -> int:
        return self.bits_user_to_cloud + self.bits_cloud_to_user

    def as_dict(self) -> dict:
        return dict(rounds=self.rounds,
                    bits_user_to_cloud=self.bits_user_to_cloud,
                    bits_cloud_to_user=self.bits_cloud_to_user,
                    communication_bits=self.communication_bits,
                    cloud_ops_bits=self.cloud_ops_bits,
                    user_ops_bits=self.user_ops_bits)

    def __str__(self) -> str:
        d = self.as_dict()
        return ", ".join(f"{k}={v}" for k, v in d.items())
