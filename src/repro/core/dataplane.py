"""Sharded dataplane: tuple-axis partitioning of a secret-shared relation.

The paper states its efficiency claims per *query stream* (rounds and bits
between the user and c non-communicating clouds); how the cloud-side work is
*executed* is free as long as the transcript is unchanged. This module makes
that execution axis explicit: a :class:`ShardedRelation` partitions the share
arrays of a :class:`~repro.core.engine.SecretSharedDB` into S contiguous
tuple-axis shards (the same split MapReduce mappers use in the paper — every
shard holds whole share-columns of a tuple slice, so the non-communication
property is untouched), and the round engine emits one
:class:`ShardDispatch` per shard per cloud step instead of one monolithic
device call.

A :class:`DispatchSet` bundles the per-shard dispatches of one cloud step
together with the reduction that reassembles them:

  * ``"concat"`` — per-tuple outputs (match bits, match-matrix rows, ripple
    planes) concatenate along the tuple axis;
  * ``"sum"``    — partial mod-p sums (counts, one-hot fetch / matmul
    contractions over the tuple axis) combine additively. F_p addition is
    exact and associative, so the combined residues are **bit-identical** to
    the unsharded computation — user-side rounds, opened values and
    ``CostLedger`` totals never see the shard count.
  * ``"list"``   — raw per-shard results for callers that thread shard-local
    state themselves (the ripple carry chain).

Execution is a *placement policy*, not part of the protocol:
:class:`SerialDispatcher` runs shards inline (the S = 1 path is exactly the
pre-shard engine), :class:`ThreadedDispatcher` fans them out over a thread
pool (the async serving runtime), and
``repro.api.executor.MapReduceDispatcher`` places each shard dispatch as a
fault-tolerant MapReduce task.

Two multi-tenant refinements ride on the thread pool:

  * **Weighted fair quotas** — every :class:`PoolHandle` carries a
    ``weight``; dispatches submitted through a handle queue per handle and
    a deficit-round-robin picker admits them to the pool workers in
    weight-proportional order. A hot tenant flooding its handle degrades
    gracefully instead of starving its neighbours' shard dispatches behind
    a FIFO executor queue. Within one handle, dispatch order (and thus the
    shard-order combine) is unchanged — results stay bit-identical.
  * **Fused waves** — :func:`fused_execute` runs several planes' cloud
    steps as ONE dispatch wave when their dispatchers share a pool: all
    shard thunks enqueue together (each under its own handle, so quotas
    still apply) and each step combines in shard order as its futures
    resolve. Planes on serial / device-resident dispatchers execute
    unfused via their own ``run_set`` — transcripts never depend on
    whether a wave was fused.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import threading
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple,
                    Union)

import jax
import jax.numpy as jnp

from . import field
from .engine import SecretSharedDB
from .partition import split_bounds
from .shamir import Shares


def _tree_nbytes(part: Any) -> int:
    """Bytes of every array leaf in one shard result (tuples included)."""
    return sum(getattr(leaf, "nbytes", 0)
               for leaf in jax.tree_util.tree_leaves(part))


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------

class Dispatcher:
    """Placement policy for one round's shard dispatches (serial default).

    Two seams, two levels of control:

    * :meth:`run_all` — run a list of opaque shard thunks; host dispatchers
      (serial / thread pool / MapReduce) override only this.
    * :meth:`run_set` — run one whole :class:`DispatchSet` against its
      :class:`ShardedRelation` and reduce it. The default implementation is
      ``run_all`` + host-side :meth:`DispatchSet.combine`; a device-resident
      dispatcher (``repro.core.mesh_dispatch.MeshDispatcher``) overrides it
      to keep the per-shard partials on device and reduce them there.

    ``device_resident`` tells the telemetry layer how to account transfer
    bytes: host dispatchers stage every shard partial through the combine
    (bytes = the parts), device-resident ones only pay the initial
    placement.
    """

    device_resident = False

    def run_all(self, thunks: Sequence[Callable[[], Any]]) -> List[Any]:
        return [t() for t in thunks]

    def run_set(self, plane: "ShardedRelation", ds: "DispatchSet"):
        """Execute + reduce one cloud step, recording telemetry."""
        t0 = time.perf_counter()
        parts = self.run_all([d.run for d in ds.dispatches])
        out = ds.combine(parts)
        plane.stats.record(len(ds.dispatches),
                           wall_s=time.perf_counter() - t0,
                           transfer_bytes=sum(_tree_nbytes(p)
                                              for p in parts))
        return out


SERIAL = Dispatcher()


#: deficit-round-robin serves one shard dispatch per unit of deficit;
#: weights below this floor still accumulate credit (no silent starvation).
_MIN_WEIGHT = 1e-6


class ThreadedDispatcher(Dispatcher):
    """Run shard dispatches concurrently on a shared thread pool.

    Share-space cloud steps are pure, so concurrent execution is safe; the
    combine step (concat / mod-p sum) happens on the caller's thread in
    shard order, keeping results bit-identical to serial execution.

    One pool can back many relations: :meth:`handle` returns a
    :class:`PoolHandle` — a per-relation view that delegates to this pool
    but whose ``close()`` only detaches the view. A multi-tenant server
    hands each attached relation its own handle, so the global fan-out
    stays bounded by ONE ``max_workers`` no matter how many dataplanes are
    attached, and detaching one tenant never kills its neighbours' pool.

    Handles are *weighted*: dispatches submitted through a handle are
    queued per handle and admitted to the pool workers by deficit round
    robin (:meth:`_pick_locked`) — each rotation visit tops a handle's
    deficit up by its weight and serves one queued shard dispatch per unit
    of deficit. Service is weight-proportional under contention, FIFO
    within a handle, and work-conserving (an idle pool never waits on a
    quota). Direct ``run_all`` calls on the dispatcher itself bypass the
    quota path — they are the single-tenant surface.
    """

    def __init__(self, max_workers: Optional[int] = None):
        # mirror ThreadPoolExecutor's default sizing — the cap doubles as
        # the DRR in-flight bound, so it must be a concrete number.
        self._cap = max_workers or min(32, (os.cpu_count() or 1) + 4)
        self._pool = ThreadPoolExecutor(max_workers=self._cap,
                                        thread_name_prefix="shard")
        self._closed = False
        self._dlock = threading.Lock()
        self._queues: Dict["PoolHandle", deque] = {}
        self._rr: deque = deque()           # handles with queued work
        self._deficits: Dict["PoolHandle", float] = {}
        self._granted: set = set()          # front handle already topped up
        self._inflight = 0

    def run_all(self, thunks: Sequence[Callable[[], Any]]) -> List[Any]:
        if self._closed or len(thunks) <= 1:
            return [t() for t in thunks]
        return list(self._pool.map(lambda t: t(), thunks))

    def handle(self, weight: float = 1.0) -> "PoolHandle":
        """A detachable per-relation view sharing this pool.

        ``weight`` sets the handle's deficit-round-robin share: under
        contention a weight-2 handle's shard dispatches are admitted twice
        as often as a weight-1 neighbour's.
        """
        return PoolHandle(self, weight=weight)

    # -- weighted fair admission (deficit round robin) ----------------------
    def enqueue(self, handle: "PoolHandle",
                thunks: Sequence[Callable[[], Any]]) -> List[Future]:
        """Queue thunks under ``handle``'s quota; returns their futures.

        Non-blocking: admission happens on whichever threads drive the
        queue (this caller now, pool workers as units finish).
        """
        futures = [Future() for _ in thunks]
        with self._dlock:
            q = self._queues.get(handle)
            if q is None:
                q = self._queues[handle] = deque()
                self._rr.append(handle)
            for t, f in zip(thunks, futures):
                q.append((t, f))
        self._drive()
        return futures

    def _pick_locked(self) -> Optional[Tuple[Callable[[], Any], Future]]:
        """Next admissible unit under DRR; caller holds ``_dlock``.

        The front handle's deficit is topped up by its weight once per
        rotation visit and spent one unit per served dispatch; when it runs
        dry (or drains) the rotation advances. Tiny weights merely take
        more rotations to accumulate a unit — they are never starved.
        """
        while self._rr:
            h = self._rr[0]
            q = self._queues.get(h)
            if not q:                       # drained: drop stale credit
                self._rr.popleft()
                self._queues.pop(h, None)
                self._deficits.pop(h, None)
                self._granted.discard(h)
                continue
            if h not in self._granted:
                self._granted.add(h)
                self._deficits[h] = (self._deficits.get(h, 0.0)
                                     + max(h.weight, _MIN_WEIGHT))
            if self._deficits[h] >= 1.0:
                self._deficits[h] -= 1.0
                unit = q.popleft()
                if not q:
                    self._rr.popleft()
                    self._queues.pop(h, None)
                    self._deficits.pop(h, None)
                    self._granted.discard(h)
                return unit
            self._granted.discard(h)        # spent: next visit re-grants
            self._rr.rotate(-1)
        return None

    def _drive(self) -> None:
        """Admit queued units while worker slots are free (cooperative:
        submitters and finishing workers both drive; no dedicated thread).
        """
        while True:
            with self._dlock:
                if not self._closed and self._inflight >= self._cap:
                    return
                unit = self._pick_locked()
                if unit is None:
                    return
                self._inflight += 1
                closed = self._closed
            if closed:
                self._run_unit(*unit)       # inline drain — never strand
            else:
                try:
                    self._pool.submit(self._run_unit, *unit)
                except RuntimeError:        # shut down mid-flight
                    self._run_unit(*unit)

    def _run_unit(self, thunk: Callable[[], Any], fut: Future) -> None:
        try:
            result = thunk()
        except BaseException as e:          # noqa: BLE001 — relayed to waiter
            fut.set_exception(e)
        else:
            fut.set_result(result)
        with self._dlock:
            self._inflight -= 1
        self._drive()

    def close(self) -> None:
        """Release the pool; later dispatches degrade to serial (correct,
        just unparallel) instead of raising on the shut-down executor.
        Units still queued under handle quotas drain inline so no waiter
        blocks forever."""
        self._closed = True
        self._pool.shutdown(wait=False)
        self._drive()


class PoolHandle(Dispatcher):
    """Per-relation view of a shared :class:`ThreadedDispatcher` pool.

    ``run_all`` submits through the pool's weighted fair queue (global
    worker bound, deficit-round-robin admission at this handle's
    ``weight``); ``close()`` detaches only this handle — subsequent
    dispatches through it run serial while the pool keeps serving its
    other handles.
    """

    def __init__(self, pool: ThreadedDispatcher, weight: float = 1.0):
        if weight <= 0:
            raise ValueError(f"PoolHandle weight must be > 0, got {weight}")
        self._shared_pool = pool
        self.weight = float(weight)
        self._detached = False

    def run_all(self, thunks: Sequence[Callable[[], Any]]) -> List[Any]:
        pool = self._shared_pool
        if self._detached or pool._closed or len(thunks) <= 1:
            return [t() for t in thunks]
        futures = pool.enqueue(self, list(thunks))
        return [f.result() for f in futures]

    def close(self) -> None:
        self._detached = True


# ---------------------------------------------------------------------------
# shards and dispatch descriptors
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Shard:
    """One contiguous tuple-axis slice [lo, hi) of the relation."""
    index: int
    lo: int
    hi: int

    @property
    def n_tuples(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass(frozen=True)
class ShardDispatch:
    """One shard's slice of a cloud step: a zero-argument device thunk."""
    shard: Shard
    run: Callable[[], Any]


@dataclasses.dataclass(frozen=True)
class DispatchSet:
    """All shards' dispatches for one cloud step + the reduction rule."""
    dispatches: Tuple[ShardDispatch, ...]
    reduce: str = "concat"          # "concat" | "sum" | "list"
    axis: int = -1                  # concat axis

    def combine(self, parts: List[Any]):
        if self.reduce == "list":
            return parts
        if len(parts) == 1:
            return parts[0]
        if self.reduce == "concat":
            return jnp.concatenate(parts, axis=self.axis)
        if self.reduce == "sum":
            acc = parts[0]
            for p in parts[1:]:
                acc = field.add(acc, p)
            return acc
        raise ValueError(f"unknown reduce mode {self.reduce!r}")


@dataclasses.dataclass
class DispatchStats:
    """Execution-side telemetry (never part of the protocol transcript).

    ``dispatch_s`` accumulates the wall-time of every cloud step (dispatch
    fan-out + reduce, as seen by the dispatcher — jax async dispatch means
    this is *submission* time unless the policy blocks). ``transfer_bytes``
    accumulates staged bytes: for host dispatchers, every shard partial
    that round-trips through the combine; for a device-resident dispatcher,
    only the initial host→device placement (zero afterwards — the
    device-residency invariant, asserted in tests/test_mesh_dispatch.py).
    """
    dispatches: int = 0             # shard dispatches executed
    steps: int = 0                  # cloud steps (DispatchSets) executed
    fused_steps: int = 0            # steps executed inside a fused wave
    dispatch_s: float = 0.0         # cumulative cloud-step wall-time
    transfer_bytes: int = 0         # staged bytes (see above)

    def record(self, n_dispatches: int, wall_s: float = 0.0,
               transfer_bytes: int = 0, fused: bool = False) -> None:
        self.dispatches += n_dispatches
        self.steps += 1
        if fused:
            # the step ran inside a cross-plane fused_execute wave;
            # wall_s then covers the whole wave, not this step alone.
            self.fused_steps += 1
        self.dispatch_s += wall_s
        self.transfer_bytes += transfer_bytes


# ---------------------------------------------------------------------------
# the sharded relation
# ---------------------------------------------------------------------------

class ShardedRelation:
    """Tuple-axis partitioned view of one outsourced relation.

    ``shards=S`` splits [0, n) with the shared :func:`split_bounds` rule
    (the same rounding MapReduce input splits and tree blocks use), so every
    shard is a contiguous ``ceil(n/S)``-ish block. ``view(i)`` materializes
    shard i as a regular :class:`SecretSharedDB` slice (relation + binary
    columns), cheap jnp views over the parent arrays. The attached
    ``dispatcher`` decides *where* shard dispatches run; swapping it never
    changes results.
    """

    def __init__(self, db: SecretSharedDB, shards: int = 1,
                 dispatcher: Optional[Dispatcher] = None):
        if isinstance(db, ShardedRelation):        # re-shard an existing plane
            db = db.db
        self.db = db
        # ``split_bounds`` clamps the shard count to n and never returns an
        # empty range, so ``shards > n_tuples`` degrades to one shard per
        # tuple — a DispatchSet must never carry a zero-width shard (an
        # empty slice would emit degenerate device dispatches and a
        # zero-row concat block). Guarded here and regression-tested for
        # n=1, S=4 in tests/test_dataplane.py.
        bounds = split_bounds(0, db.n_tuples, max(1, shards))
        assert all(lo < hi for lo, hi in bounds), "empty shard bounds"
        self.shards: List[Shard] = [Shard(i, lo, hi)
                                    for i, (lo, hi) in enumerate(bounds)]
        self.dispatcher = dispatcher or SERIAL
        self.stats = DispatchStats()
        self._views: dict = {}

    # -- SecretSharedDB delegation (user-side code reads relation metadata
    # off the plane without caring about the shard count) -------------------
    @property
    def relation(self):
        return self.db.relation

    @property
    def codec(self):
        return self.db.codec

    @property
    def column_names(self):
        return self.db.column_names

    @property
    def numeric(self):
        return self.db.numeric

    @property
    def numeric_bits(self):
        return self.db.numeric_bits

    @property
    def base_degree(self) -> int:
        return self.db.base_degree

    @property
    def n_shares(self) -> int:
        return self.db.n_shares

    @property
    def n_tuples(self) -> int:
        return self.db.n_tuples

    @property
    def n_attrs(self) -> int:
        return self.db.n_attrs

    def column(self, col: int):
        return self.db.column(col)

    def col_index(self, name: str) -> int:
        return self.db.col_index(name)

    # -- structure ----------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def max_shard_rows(self) -> int:
        return max((s.n_tuples for s in self.shards), default=0)

    def view(self, index: int) -> SecretSharedDB:
        """Shard ``index`` as a sliced SecretSharedDB (cached)."""
        sh = self.shards[index]
        if sh.lo == 0 and sh.hi == self.db.n_tuples:
            return self.db
        if index not in self._views:
            db = self.db
            self._views[index] = SecretSharedDB(
                relation=Shares(db.relation.values[:, sh.lo:sh.hi],
                                db.relation.degree),
                codec=db.codec,
                column_names=db.column_names,
                numeric={c: Shares(s.values[:, sh.lo:sh.hi], s.degree)
                         for c, s in db.numeric.items()},
                numeric_bits=dict(db.numeric_bits),
                base_degree=db.base_degree)
        return self._views[index]

    # -- dispatch -----------------------------------------------------------
    def dispatch_set(self, build: Callable[[SecretSharedDB, Shard], Any],
                     *, reduce: str = "concat", axis: int = -1
                     ) -> DispatchSet:
        """One cloud step: a per-shard dispatch descriptor per shard."""
        return DispatchSet(tuple(
            ShardDispatch(sh, functools.partial(build, self.view(sh.index),
                                                sh))
            for sh in self.shards), reduce=reduce, axis=axis)

    def execute(self, ds: DispatchSet):
        """Run one step through the placement policy and reduce it."""
        return self.dispatcher.run_set(self, ds)

    def run_concat(self, build, *, axis: int = -1):
        return self.execute(self.dispatch_set(build, reduce="concat",
                                              axis=axis))

    def run_sum(self, build):
        return self.execute(self.dispatch_set(build, reduce="sum"))

    def run_list(self, build) -> List[Any]:
        return self.execute(self.dispatch_set(build, reduce="list"))


RelationLike = Union[SecretSharedDB, ShardedRelation]


def _fusion_pool(plane: "ShardedRelation") -> Optional[ThreadedDispatcher]:
    """The shared thread pool a plane's cloud steps can fuse into, if any.

    Planes whose dispatchers resolve to the SAME live pool form one fusion
    domain; serial, detached, closed, and device-resident dispatchers fuse
    with nobody (their ``run_set`` may carry placement invariants — e.g.
    the mesh transfer guard — that a pooled wave must not bypass).
    """
    disp = plane.dispatcher
    if isinstance(disp, PoolHandle):
        if disp._detached or disp._shared_pool._closed:
            return None
        return disp._shared_pool
    if isinstance(disp, ThreadedDispatcher) and not disp._closed:
        return disp
    return None


def fused_execute(pairs: Sequence[Tuple["ShardedRelation", DispatchSet]]
                  ) -> List[Any]:
    """Execute one cloud step per (plane, set) pair, fusing shared pools.

    Steps whose planes share a live :class:`ThreadedDispatcher` run as ONE
    dispatch wave: every plane's shard thunks enqueue together — each under
    its own :class:`PoolHandle`, so weighted fair quotas still arbitrate —
    and each step's partials combine in shard order as they resolve.
    Everything else (serial, mesh, detached) executes through its own
    ``run_set``, unfused. Results come back in ``pairs`` order and are
    bit-identical to executing each step alone: fusion changes only *when*
    shard thunks are admitted, never their inputs or combine order.
    """
    results: List[Any] = [None] * len(pairs)
    groups: Dict[ThreadedDispatcher, List[int]] = {}
    for i, (plane, _) in enumerate(pairs):
        pool = _fusion_pool(plane)
        if pool is None:
            plane_, ds = pairs[i]
            results[i] = plane_.execute(ds)
        else:
            groups.setdefault(pool, []).append(i)
    for pool, idxs in groups.items():
        if len(idxs) == 1:
            plane, ds = pairs[idxs[0]]
            results[idxs[0]] = plane.execute(ds)
            continue
        t0 = time.perf_counter()
        waves: List[Tuple[int, List[Future]]] = []
        for i in idxs:
            plane, ds = pairs[i]
            disp = plane.dispatcher
            handle = (disp if isinstance(disp, PoolHandle)
                      else pool.handle())       # transient, weight 1
            waves.append((i, pool.enqueue(handle,
                                          [d.run for d in ds.dispatches])))
        for i, futs in waves:
            plane, ds = pairs[i]
            parts = [f.result() for f in futs]
            out = ds.combine(parts)
            plane.stats.record(len(ds.dispatches),
                               wall_s=time.perf_counter() - t0,
                               transfer_bytes=sum(_tree_nbytes(p)
                                                  for p in parts),
                               fused=True)
            results[i] = out
    return results


def as_dataplane(rel: RelationLike) -> ShardedRelation:
    """Normalize: a plain db becomes its own single-shard dataplane (the
    S = 1 slice is the whole relation, so the sharded path is *the* path)."""
    if isinstance(rel, ShardedRelation):
        return rel
    return ShardedRelation(rel, shards=1)
