"""Sharded dataplane: tuple-axis partitioning of a secret-shared relation.

The paper states its efficiency claims per *query stream* (rounds and bits
between the user and c non-communicating clouds); how the cloud-side work is
*executed* is free as long as the transcript is unchanged. This module makes
that execution axis explicit: a :class:`ShardedRelation` partitions the share
arrays of a :class:`~repro.core.engine.SecretSharedDB` into S contiguous
tuple-axis shards (the same split MapReduce mappers use in the paper — every
shard holds whole share-columns of a tuple slice, so the non-communication
property is untouched), and the round engine emits one
:class:`ShardDispatch` per shard per cloud step instead of one monolithic
device call.

A :class:`DispatchSet` bundles the per-shard dispatches of one cloud step
together with the reduction that reassembles them:

  * ``"concat"`` — per-tuple outputs (match bits, match-matrix rows, ripple
    planes) concatenate along the tuple axis;
  * ``"sum"``    — partial mod-p sums (counts, one-hot fetch / matmul
    contractions over the tuple axis) combine additively. F_p addition is
    exact and associative, so the combined residues are **bit-identical** to
    the unsharded computation — user-side rounds, opened values and
    ``CostLedger`` totals never see the shard count.
  * ``"list"``   — raw per-shard results for callers that thread shard-local
    state themselves (the ripple carry chain).

Execution is a *placement policy*, not part of the protocol:
:class:`SerialDispatcher` runs shards inline (the S = 1 path is exactly the
pre-shard engine), :class:`ThreadedDispatcher` fans them out over a thread
pool (the async serving runtime), and
``repro.api.executor.MapReduceDispatcher`` places each shard dispatch as a
fault-tolerant MapReduce task.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Callable, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from . import field
from .engine import SecretSharedDB
from .partition import split_bounds
from .shamir import Shares


def _tree_nbytes(part: Any) -> int:
    """Bytes of every array leaf in one shard result (tuples included)."""
    return sum(getattr(leaf, "nbytes", 0)
               for leaf in jax.tree_util.tree_leaves(part))


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------

class Dispatcher:
    """Placement policy for one round's shard dispatches (serial default).

    Two seams, two levels of control:

    * :meth:`run_all` — run a list of opaque shard thunks; host dispatchers
      (serial / thread pool / MapReduce) override only this.
    * :meth:`run_set` — run one whole :class:`DispatchSet` against its
      :class:`ShardedRelation` and reduce it. The default implementation is
      ``run_all`` + host-side :meth:`DispatchSet.combine`; a device-resident
      dispatcher (``repro.core.mesh_dispatch.MeshDispatcher``) overrides it
      to keep the per-shard partials on device and reduce them there.

    ``device_resident`` tells the telemetry layer how to account transfer
    bytes: host dispatchers stage every shard partial through the combine
    (bytes = the parts), device-resident ones only pay the initial
    placement.
    """

    device_resident = False

    def run_all(self, thunks: Sequence[Callable[[], Any]]) -> List[Any]:
        return [t() for t in thunks]

    def run_set(self, plane: "ShardedRelation", ds: "DispatchSet"):
        """Execute + reduce one cloud step, recording telemetry."""
        t0 = time.perf_counter()
        parts = self.run_all([d.run for d in ds.dispatches])
        out = ds.combine(parts)
        plane.stats.record(len(ds.dispatches),
                           wall_s=time.perf_counter() - t0,
                           transfer_bytes=sum(_tree_nbytes(p)
                                              for p in parts))
        return out


SERIAL = Dispatcher()


class ThreadedDispatcher(Dispatcher):
    """Run shard dispatches concurrently on a shared thread pool.

    Share-space cloud steps are pure, so concurrent execution is safe; the
    combine step (concat / mod-p sum) happens on the caller's thread in
    shard order, keeping results bit-identical to serial execution.

    One pool can back many relations: :meth:`handle` returns a
    :class:`PoolHandle` — a per-relation view that delegates to this pool
    but whose ``close()`` only detaches the view. A multi-tenant server
    hands each attached relation its own handle, so the global fan-out
    stays bounded by ONE ``max_workers`` no matter how many dataplanes are
    attached, and detaching one tenant never kills its neighbours' pool.
    """

    def __init__(self, max_workers: Optional[int] = None):
        self._pool = ThreadPoolExecutor(max_workers=max_workers,
                                        thread_name_prefix="shard")
        self._closed = False

    def run_all(self, thunks: Sequence[Callable[[], Any]]) -> List[Any]:
        if self._closed or len(thunks) <= 1:
            return [t() for t in thunks]
        return list(self._pool.map(lambda t: t(), thunks))

    def handle(self) -> "PoolHandle":
        """A detachable per-relation view sharing this pool."""
        return PoolHandle(self)

    def close(self) -> None:
        """Release the pool; later dispatches degrade to serial (correct,
        just unparallel) instead of raising on the shut-down executor."""
        self._closed = True
        self._pool.shutdown(wait=False)


class PoolHandle(Dispatcher):
    """Per-relation view of a shared :class:`ThreadedDispatcher` pool.

    ``run_all`` delegates to the shared pool (global worker bound);
    ``close()`` detaches only this handle — subsequent dispatches through
    it run serial while the pool keeps serving its other handles.
    """

    def __init__(self, pool: ThreadedDispatcher):
        self._shared_pool = pool
        self._detached = False

    def run_all(self, thunks: Sequence[Callable[[], Any]]) -> List[Any]:
        if self._detached:
            return [t() for t in thunks]
        return self._shared_pool.run_all(thunks)

    def close(self) -> None:
        self._detached = True


# ---------------------------------------------------------------------------
# shards and dispatch descriptors
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Shard:
    """One contiguous tuple-axis slice [lo, hi) of the relation."""
    index: int
    lo: int
    hi: int

    @property
    def n_tuples(self) -> int:
        return self.hi - self.lo


@dataclasses.dataclass(frozen=True)
class ShardDispatch:
    """One shard's slice of a cloud step: a zero-argument device thunk."""
    shard: Shard
    run: Callable[[], Any]


@dataclasses.dataclass(frozen=True)
class DispatchSet:
    """All shards' dispatches for one cloud step + the reduction rule."""
    dispatches: Tuple[ShardDispatch, ...]
    reduce: str = "concat"          # "concat" | "sum" | "list"
    axis: int = -1                  # concat axis

    def combine(self, parts: List[Any]):
        if self.reduce == "list":
            return parts
        if len(parts) == 1:
            return parts[0]
        if self.reduce == "concat":
            return jnp.concatenate(parts, axis=self.axis)
        if self.reduce == "sum":
            acc = parts[0]
            for p in parts[1:]:
                acc = field.add(acc, p)
            return acc
        raise ValueError(f"unknown reduce mode {self.reduce!r}")


@dataclasses.dataclass
class DispatchStats:
    """Execution-side telemetry (never part of the protocol transcript).

    ``dispatch_s`` accumulates the wall-time of every cloud step (dispatch
    fan-out + reduce, as seen by the dispatcher — jax async dispatch means
    this is *submission* time unless the policy blocks). ``transfer_bytes``
    accumulates staged bytes: for host dispatchers, every shard partial
    that round-trips through the combine; for a device-resident dispatcher,
    only the initial host→device placement (zero afterwards — the
    device-residency invariant, asserted in tests/test_mesh_dispatch.py).
    """
    dispatches: int = 0             # shard dispatches executed
    steps: int = 0                  # cloud steps (DispatchSets) executed
    dispatch_s: float = 0.0         # cumulative cloud-step wall-time
    transfer_bytes: int = 0         # staged bytes (see above)

    def record(self, n_dispatches: int, wall_s: float = 0.0,
               transfer_bytes: int = 0) -> None:
        self.dispatches += n_dispatches
        self.steps += 1
        self.dispatch_s += wall_s
        self.transfer_bytes += transfer_bytes


# ---------------------------------------------------------------------------
# the sharded relation
# ---------------------------------------------------------------------------

class ShardedRelation:
    """Tuple-axis partitioned view of one outsourced relation.

    ``shards=S`` splits [0, n) with the shared :func:`split_bounds` rule
    (the same rounding MapReduce input splits and tree blocks use), so every
    shard is a contiguous ``ceil(n/S)``-ish block. ``view(i)`` materializes
    shard i as a regular :class:`SecretSharedDB` slice (relation + binary
    columns), cheap jnp views over the parent arrays. The attached
    ``dispatcher`` decides *where* shard dispatches run; swapping it never
    changes results.
    """

    def __init__(self, db: SecretSharedDB, shards: int = 1,
                 dispatcher: Optional[Dispatcher] = None):
        if isinstance(db, ShardedRelation):        # re-shard an existing plane
            db = db.db
        self.db = db
        # ``split_bounds`` clamps the shard count to n and never returns an
        # empty range, so ``shards > n_tuples`` degrades to one shard per
        # tuple — a DispatchSet must never carry a zero-width shard (an
        # empty slice would emit degenerate device dispatches and a
        # zero-row concat block). Guarded here and regression-tested for
        # n=1, S=4 in tests/test_dataplane.py.
        bounds = split_bounds(0, db.n_tuples, max(1, shards))
        assert all(lo < hi for lo, hi in bounds), "empty shard bounds"
        self.shards: List[Shard] = [Shard(i, lo, hi)
                                    for i, (lo, hi) in enumerate(bounds)]
        self.dispatcher = dispatcher or SERIAL
        self.stats = DispatchStats()
        self._views: dict = {}

    # -- SecretSharedDB delegation (user-side code reads relation metadata
    # off the plane without caring about the shard count) -------------------
    @property
    def relation(self):
        return self.db.relation

    @property
    def codec(self):
        return self.db.codec

    @property
    def column_names(self):
        return self.db.column_names

    @property
    def numeric(self):
        return self.db.numeric

    @property
    def numeric_bits(self):
        return self.db.numeric_bits

    @property
    def base_degree(self) -> int:
        return self.db.base_degree

    @property
    def n_shares(self) -> int:
        return self.db.n_shares

    @property
    def n_tuples(self) -> int:
        return self.db.n_tuples

    @property
    def n_attrs(self) -> int:
        return self.db.n_attrs

    def column(self, col: int):
        return self.db.column(col)

    def col_index(self, name: str) -> int:
        return self.db.col_index(name)

    # -- structure ----------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.shards)

    @property
    def max_shard_rows(self) -> int:
        return max((s.n_tuples for s in self.shards), default=0)

    def view(self, index: int) -> SecretSharedDB:
        """Shard ``index`` as a sliced SecretSharedDB (cached)."""
        sh = self.shards[index]
        if sh.lo == 0 and sh.hi == self.db.n_tuples:
            return self.db
        if index not in self._views:
            db = self.db
            self._views[index] = SecretSharedDB(
                relation=Shares(db.relation.values[:, sh.lo:sh.hi],
                                db.relation.degree),
                codec=db.codec,
                column_names=db.column_names,
                numeric={c: Shares(s.values[:, sh.lo:sh.hi], s.degree)
                         for c, s in db.numeric.items()},
                numeric_bits=dict(db.numeric_bits),
                base_degree=db.base_degree)
        return self._views[index]

    # -- dispatch -----------------------------------------------------------
    def dispatch_set(self, build: Callable[[SecretSharedDB, Shard], Any],
                     *, reduce: str = "concat", axis: int = -1
                     ) -> DispatchSet:
        """One cloud step: a per-shard dispatch descriptor per shard."""
        return DispatchSet(tuple(
            ShardDispatch(sh, functools.partial(build, self.view(sh.index),
                                                sh))
            for sh in self.shards), reduce=reduce, axis=axis)

    def execute(self, ds: DispatchSet):
        """Run one step through the placement policy and reduce it."""
        return self.dispatcher.run_set(self, ds)

    def run_concat(self, build, *, axis: int = -1):
        return self.execute(self.dispatch_set(build, reduce="concat",
                                              axis=axis))

    def run_sum(self, build):
        return self.execute(self.dispatch_set(build, reduce="sum"))

    def run_list(self, build) -> List[Any]:
        return self.execute(self.dispatch_set(build, reduce="list"))


RelationLike = Union[SecretSharedDB, ShardedRelation]


def as_dataplane(rel: RelationLike) -> ShardedRelation:
    """Normalize: a plain db becomes its own single-shard dataplane (the
    S = 1 slice is the whole relation, so the sharded path is *the* path)."""
    if isinstance(rel, ShardedRelation):
        return rel
    return ShardedRelation(rel, shards=1)
