# The paper's primary contribution: Shamir secret-sharing over F_p,
# accumulating-automata string matching, and the oblivious query suite
# (count / selection / join / range) executed MapReduce-style.
from . import field, shamir, encoding, automata, costs, dataplane, engine
from .engine import SecretSharedDB, outsource
from .dataplane import (Dispatcher, ShardedRelation, ThreadedDispatcher,
                        as_dataplane)
from .shamir import Shares, share, interpolate, reduce_degree
from .encoding import Codec
from .costs import CostLedger

__all__ = [
    "field", "shamir", "encoding", "automata", "costs", "dataplane",
    "engine", "SecretSharedDB", "outsource", "Dispatcher",
    "ShardedRelation", "ThreadedDispatcher", "as_dataplane", "Shares",
    "share", "interpolate", "reduce_degree", "Codec", "CostLedger",
]
