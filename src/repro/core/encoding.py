"""Unary (one-hot) and binary encodings of relation values (paper §2.1, §3.4).

Strings are encoded character-by-character as one-hot ("unary") vectors over a
fixed alphabet, padded to a fixed word length with a terminator symbol — the
paper's fix for the John/Johnson prefix problem (§3.1.2 Aside). Two encoded
letters match iff the inner product of their one-hot vectors is 1, which is a
share-space bilinear op.

Pattern predicates (LIKE / prefix / suffix / substring) lower to a
:class:`PatternSpec` — a short one-hot *tile* of k pattern positions plus a
matcher kind. Wildcard positions share the all-ones vector, so their alphabet
dot against ANY encoded symbol (terminator included) is identically 1:
a wildcard is a don't-care, never a length constraint. Only ``Like`` surface
patterns interpret ``%``/``_`` — in ``Prefix``/``Suffix``/``Contains``
literals every character (including ``_``, which is in the alphabet) is
matched verbatim.

Numbers used in range queries are encoded as two's-complement *bit vectors*
(LSB first) so SS-SUB (Algorithm 6) can ripple through them.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import field, shamir
from .field import DTYPE
from .shamir import Shares

# Default alphabet: terminator + space + a-z + A-Z + 0-9 + a few symbols.
# Index 0 is the terminator/pad so padded positions still match each other.
TERMINATOR = "\0"
DEFAULT_ALPHABET = TERMINATOR + " abcdefghijklmnopqrstuvwxyz" \
    + "ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789.-_/@"


@dataclasses.dataclass(frozen=True)
class Codec:
    """Fixed (alphabet, word_length) unary codec."""
    alphabet: str = DEFAULT_ALPHABET
    word_length: int = 12

    @property
    def alphabet_size(self) -> int:
        return len(self.alphabet)

    def char_index(self, ch: str) -> int:
        i = self.alphabet.find(ch)
        if i < 0:
            raise ValueError(f"character {ch!r} not in alphabet")
        return i

    # -- host-side (numpy) encode: runs at the trusted DB owner / user ------
    def encode_word(self, word: str) -> np.ndarray:
        """-> uint32[word_length, alphabet_size] one-hot rows."""
        if len(word) > self.word_length:
            raise ValueError(f"word {word!r} longer than {self.word_length}")
        out = np.zeros((self.word_length, self.alphabet_size), dtype=np.uint32)
        padded = word + TERMINATOR * (self.word_length - len(word))
        for j, ch in enumerate(padded):
            out[j, self.char_index(ch)] = 1
        return out

    def encode_column(self, words: Sequence[str]) -> np.ndarray:
        """-> uint32[n, word_length, alphabet_size]."""
        return np.stack([self.encode_word(w) for w in words])

    def encode_relation(self, rows: Sequence[Sequence[str]]) -> np.ndarray:
        """-> uint32[n, m, word_length, alphabet_size]."""
        return np.stack([np.stack([self.encode_word(v) for v in row])
                         for row in rows])

    def decode_word(self, onehot: np.ndarray) -> str:
        """Inverse of encode_word; tolerant of all-zero (eliminated) rows."""
        chars = []
        for j in range(onehot.shape[0]):
            nz = np.nonzero(onehot[j])[0]
            if len(nz) == 0:
                return ""          # an obliviously-eliminated tuple
            ch = self.alphabet[int(nz[0])]
            if ch == TERMINATOR:
                break
            chars.append(ch)
        return "".join(chars)

    def decode_row(self, onehot: np.ndarray) -> list:
        return [self.decode_word(onehot[k]) for k in range(onehot.shape[0])]


# ---------------------------------------------------------------------------
# Pattern predicates (§3.1 general matching): spec, LIKE parser, encoders
# ---------------------------------------------------------------------------

#: matcher strategies a PatternSpec can name. "masked" rides the full-width
#: AA chain (same dispatch stack as exact equality); "prefix" the truncated
#: k-chain; "suffix"/"contains" the sliding-window automata step.
PATTERN_KINDS = ("masked", "prefix", "suffix", "contains")


@dataclasses.dataclass(frozen=True)
class PatternSpec:
    """A lowered pattern predicate: k literal positions + matcher kind.

    ``body`` holds the k pattern characters; indices in ``wild`` are
    wildcard (all-ones) positions. Wildcards are only legal where windows
    cannot shift (``masked`` / ``prefix``): a wildcard matches the
    terminator too, so inside a sliding window it would break the
    mutual-exclusivity of window matches. ``source`` is the surface
    pattern (e.g. the original LIKE string) for display and errors.
    """
    kind: str
    body: str
    wild: Tuple[int, ...] = ()
    source: str = ""

    def __post_init__(self):
        if self.kind not in PATTERN_KINDS:
            raise ValueError(f"unknown pattern kind {self.kind!r}")
        if not self.body:
            raise ValueError(
                f"pattern {self.source!r} has an empty literal body")
        if TERMINATOR in self.body:
            raise ValueError("pattern bodies may not contain the terminator")
        if self.wild and self.kind in ("suffix", "contains"):
            raise ValueError(
                f"wildcard positions are not supported in {self.kind} "
                "patterns (a window could match padding)")
        if any(i < 0 or i >= len(self.body) for i in self.wild):
            raise ValueError("wildcard index out of range")

    @property
    def length(self) -> int:
        """k — the AA chain length of this pattern."""
        return len(self.body)

    def windows(self, word_length: int) -> int:
        """M — number of sliding windows at a given word length."""
        return word_length - self.length + 1


def parse_like(pattern: str) -> Tuple[str, str, Tuple[int, ...]]:
    """Parse a SQL-ish LIKE pattern -> (kind, body, wildcard positions).

    Supported shapes (``%`` = any run, ``_`` = any one symbol; no escapes):

      ``lit``      -> ("exact", lit, ())      — rewritten to the Eq path
      ``l_t``      -> ("masked", l_t, (1,))   — fixed positions, full chain
      ``lit%``     -> ("prefix", lit, wilds)  — ``_`` allowed in lit
      ``%lit``     -> ("suffix", lit, ())     — ``_`` unsupported
      ``%lit%``    -> ("contains", lit, ())   — ``_`` unsupported

    Interior/multiple ``%`` runs, a bare ``%``, and ``_`` under a shifted
    window raise ``ValueError`` (callers surface ``PlanNotSupported``).
    """
    if not pattern or pattern.strip("%") == "":
        raise ValueError(f"LIKE pattern {pattern!r} has no literal body")
    lead = pattern.startswith("%")
    trail = pattern.endswith("%")
    body = pattern[1 if lead else 0:len(pattern) - 1 if trail else len(pattern)]
    if "%" in body:
        raise ValueError(
            f"LIKE pattern {pattern!r}: interior '%' is not supported")
    wild = tuple(i for i, ch in enumerate(body) if ch == "_")
    if lead and wild:
        raise ValueError(
            f"LIKE pattern {pattern!r}: '_' under a '%'-shifted window is "
            "not supported")
    if lead and trail:
        return "contains", body, ()
    if lead:
        return "suffix", body, ()
    if trail:
        return "prefix", body, wild
    return ("masked", body, wild) if wild else ("exact", body, ())


def encode_pattern_tile(codec: Codec, spec: PatternSpec) -> np.ndarray:
    """-> uint32[k, alphabet_size] one-hot rows; wildcards are all-ones.

    The tile is the user-shared object for prefix/suffix/contains specs
    (k positions, not the full word width).
    """
    if spec.length > codec.word_length:
        raise ValueError(
            f"pattern {spec.source or spec.body!r} longer than word_length "
            f"{codec.word_length}")
    out = np.zeros((spec.length, codec.alphabet_size), dtype=np.uint32)
    wild = set(spec.wild)
    for j, ch in enumerate(spec.body):
        if j in wild:
            out[j, :] = 1
        else:
            out[j, codec.char_index(ch)] = 1
    return out


def encode_pattern_word(codec: Codec, spec: PatternSpec) -> np.ndarray:
    """-> uint32[word_length, alphabet_size] full-width masked pattern.

    The ``masked`` (fixed-position LIKE) encoding: the k-tile padded with
    terminator one-hots, so the ordinary full-width AA chain enforces both
    the literal positions and the trailing terminators. Because a wildcard
    dot is identically 1 against the terminator as well, ``a_`` matches
    words of length ≤ 2 whose real characters agree (don't-care semantics,
    not SQL's exact-length ``_``) — documented in the README.
    """
    tile = encode_pattern_tile(codec, spec)
    out = np.zeros((codec.word_length, codec.alphabet_size), dtype=np.uint32)
    out[:spec.length] = tile
    out[spec.length:, 0] = 1          # terminator one-hots
    return out


# ---------------------------------------------------------------------------
# Secret-shared encodings
# ---------------------------------------------------------------------------

def share_encoded(key: jax.Array, encoded: np.ndarray, *, n_shares: int,
                  degree: int = 1) -> Shares:
    """Secret-share an encoded (one-hot / bit) tensor, fresh poly per bit."""
    return shamir.share(key, jnp.asarray(encoded, DTYPE),
                        n_shares=n_shares, degree=degree)


def share_pattern(key: jax.Array, codec: Codec, pattern: str, *,
                  n_shares: int, degree: int = 1) -> Shares:
    """User-side: encode + secret-share a query predicate (count/select)."""
    return share_encoded(key, codec.encode_word(pattern),
                         n_shares=n_shares, degree=degree)


# ---------------------------------------------------------------------------
# Binary (two's-complement) encoding for range queries (§3.4)
# ---------------------------------------------------------------------------

def encode_number_bits(x: int, n_bits: int) -> np.ndarray:
    """Two's-complement bits, LSB first -> uint32[n_bits]."""
    if not (-(1 << (n_bits - 1)) <= x < (1 << (n_bits - 1))):
        raise ValueError(f"{x} out of range for {n_bits}-bit two's complement")
    ux = x & ((1 << n_bits) - 1)
    return np.asarray([(ux >> i) & 1 for i in range(n_bits)], dtype=np.uint32)


def encode_number_column(xs: Sequence[int], n_bits: int) -> np.ndarray:
    return np.stack([encode_number_bits(int(x), n_bits) for x in xs])


def decode_number_bits(bits: np.ndarray) -> int:
    n = len(bits)
    ux = sum(int(b) << i for i, b in enumerate(bits))
    return ux - (1 << n) if ux >= (1 << (n - 1)) else ux
