"""Unary (one-hot) and binary encodings of relation values (paper §2.1, §3.4).

Strings are encoded character-by-character as one-hot ("unary") vectors over a
fixed alphabet, padded to a fixed word length with a terminator symbol — the
paper's fix for the John/Johnson prefix problem (§3.1.2 Aside). Two encoded
letters match iff the inner product of their one-hot vectors is 1, which is a
share-space bilinear op.

Numbers used in range queries are encoded as two's-complement *bit vectors*
(LSB first) so SS-SUB (Algorithm 6) can ripple through them.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import field, shamir
from .field import DTYPE
from .shamir import Shares

# Default alphabet: terminator + space + a-z + A-Z + 0-9 + a few symbols.
# Index 0 is the terminator/pad so padded positions still match each other.
TERMINATOR = "\0"
DEFAULT_ALPHABET = TERMINATOR + " abcdefghijklmnopqrstuvwxyz" \
    + "ABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789.-_/@"


@dataclasses.dataclass(frozen=True)
class Codec:
    """Fixed (alphabet, word_length) unary codec."""
    alphabet: str = DEFAULT_ALPHABET
    word_length: int = 12

    @property
    def alphabet_size(self) -> int:
        return len(self.alphabet)

    def char_index(self, ch: str) -> int:
        i = self.alphabet.find(ch)
        if i < 0:
            raise ValueError(f"character {ch!r} not in alphabet")
        return i

    # -- host-side (numpy) encode: runs at the trusted DB owner / user ------
    def encode_word(self, word: str) -> np.ndarray:
        """-> uint32[word_length, alphabet_size] one-hot rows."""
        if len(word) > self.word_length:
            raise ValueError(f"word {word!r} longer than {self.word_length}")
        out = np.zeros((self.word_length, self.alphabet_size), dtype=np.uint32)
        padded = word + TERMINATOR * (self.word_length - len(word))
        for j, ch in enumerate(padded):
            out[j, self.char_index(ch)] = 1
        return out

    def encode_column(self, words: Sequence[str]) -> np.ndarray:
        """-> uint32[n, word_length, alphabet_size]."""
        return np.stack([self.encode_word(w) for w in words])

    def encode_relation(self, rows: Sequence[Sequence[str]]) -> np.ndarray:
        """-> uint32[n, m, word_length, alphabet_size]."""
        return np.stack([np.stack([self.encode_word(v) for v in row])
                         for row in rows])

    def decode_word(self, onehot: np.ndarray) -> str:
        """Inverse of encode_word; tolerant of all-zero (eliminated) rows."""
        chars = []
        for j in range(onehot.shape[0]):
            nz = np.nonzero(onehot[j])[0]
            if len(nz) == 0:
                return ""          # an obliviously-eliminated tuple
            ch = self.alphabet[int(nz[0])]
            if ch == TERMINATOR:
                break
            chars.append(ch)
        return "".join(chars)

    def decode_row(self, onehot: np.ndarray) -> list:
        return [self.decode_word(onehot[k]) for k in range(onehot.shape[0])]


# ---------------------------------------------------------------------------
# Secret-shared encodings
# ---------------------------------------------------------------------------

def share_encoded(key: jax.Array, encoded: np.ndarray, *, n_shares: int,
                  degree: int = 1) -> Shares:
    """Secret-share an encoded (one-hot / bit) tensor, fresh poly per bit."""
    return shamir.share(key, jnp.asarray(encoded, DTYPE),
                        n_shares=n_shares, degree=degree)


def share_pattern(key: jax.Array, codec: Codec, pattern: str, *,
                  n_shares: int, degree: int = 1) -> Shares:
    """User-side: encode + secret-share a query predicate (count/select)."""
    return share_encoded(key, codec.encode_word(pattern),
                         n_shares=n_shares, degree=degree)


# ---------------------------------------------------------------------------
# Binary (two's-complement) encoding for range queries (§3.4)
# ---------------------------------------------------------------------------

def encode_number_bits(x: int, n_bits: int) -> np.ndarray:
    """Two's-complement bits, LSB first -> uint32[n_bits]."""
    if not (-(1 << (n_bits - 1)) <= x < (1 << (n_bits - 1))):
        raise ValueError(f"{x} out of range for {n_bits}-bit two's complement")
    ux = x & ((1 << n_bits) - 1)
    return np.asarray([(ux >> i) & 1 for i in range(n_bits)], dtype=np.uint32)


def encode_number_column(xs: Sequence[int], n_bits: int) -> np.ndarray:
    return np.stack([encode_number_bits(int(x), n_bits) for x in xs])


def decode_number_bits(bits: np.ndarray) -> int:
    n = len(bits)
    ux = sum(int(b) << i for i, b in enumerate(bits))
    return ux - (1 << n) if ux >= (1 << (n - 1)) else ux
